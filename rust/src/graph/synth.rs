//! Structured synthetic generators.
//!
//! `fem_like` is the documented stand-in for the paper's six UF/Parasol
//! matrices (DESIGN.md §1): a 3D-lattice mesh with shell-ordered local
//! connectivity and a controlled degree tail, matched per graph to the
//! |V|, |E| and Δ of Table 1. The essential properties for the paper's
//! experiments — bounded degree, strong locality (small boundary after a
//! decent partition), small chromatic number — are properties of this
//! graph class, not of the specific matrices.

use super::{CsrGraph, GraphBuilder, VertexId};
use crate::util::Rng;

/// 2D grid (4-neighborhood) — simple test workload.
pub fn grid2d(rows: usize, cols: usize) -> CsrGraph {
    let n = rows * cols;
    let mut b = GraphBuilder::with_capacity(n, 2 * n);
    let at = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                b.add_edge(at(r, c), at(r, c + 1));
            }
            if r + 1 < rows {
                b.add_edge(at(r, c), at(r + 1, c));
            }
        }
    }
    b.build(format!("grid2d-{rows}x{cols}"))
}

/// Path, cycle, star, complete — tiny structured graphs for unit tests.
pub fn path(n: usize) -> CsrGraph {
    let mut b = GraphBuilder::new(n);
    for i in 1..n {
        b.add_edge((i - 1) as VertexId, i as VertexId);
    }
    b.build(format!("path-{n}"))
}

pub fn cycle(n: usize) -> CsrGraph {
    assert!(n >= 3);
    let mut b = GraphBuilder::new(n);
    for i in 0..n {
        b.add_edge(i as VertexId, ((i + 1) % n) as VertexId);
    }
    b.build(format!("cycle-{n}"))
}

pub fn star(n: usize) -> CsrGraph {
    assert!(n >= 1);
    let mut b = GraphBuilder::new(n);
    for i in 1..n {
        b.add_edge(0, i as VertexId);
    }
    b.build(format!("star-{n}"))
}

pub fn complete(n: usize) -> CsrGraph {
    let mut b = GraphBuilder::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            b.add_edge(u as VertexId, v as VertexId);
        }
    }
    b.build(format!("k{n}"))
}

/// Erdős-Rényi G(n, m): m distinct uniform edges.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, m);
    // oversample slightly; builder dedups
    let target = (m as f64 * 1.02) as usize + 8;
    for _ in 0..target {
        let u = rng.range(0, n) as VertexId;
        let v = rng.range(0, n) as VertexId;
        b.add_edge(u, v);
    }
    b.build(format!("er-{n}-{m}"))
}

/// FEM-like mesh: vertices on a 3D lattice; each vertex connects to lattice
/// neighbors in shells of increasing distance until its per-vertex degree
/// budget is met. A small fraction of vertices receive a larger budget to
/// produce the degree tail (Δ) that FEM matrices with constraints exhibit.
pub fn fem_like(
    n: usize,
    avg_degree: f64,
    max_degree: usize,
    tail_fraction: f64,
    seed: u64,
    name: &str,
) -> CsrGraph {
    assert!(n > 0);
    let side = (n as f64).cbrt().ceil() as usize;
    let side = side.max(2);
    let mut rng = Rng::new(seed);

    // Offsets sorted by squared distance, excluding origin. Shells out to
    // radius 4 give up to ~700 candidates — enough for Δ up to ~335 (bmw3_2).
    let radius: i64 = 4;
    let mut offsets: Vec<(i64, i64, i64)> = Vec::new();
    for dx in -radius..=radius {
        for dy in -radius..=radius {
            for dz in -radius..=radius {
                if (dx, dy, dz) != (0, 0, 0) {
                    offsets.push((dx, dy, dz));
                }
            }
        }
    }
    offsets.sort_by_key(|&(x, y, z)| (x * x + y * y + z * z, x, y, z));

    // Per-vertex target (full) degree. Edges are added only toward higher
    // ids and tracked in a live degree array, so each undirected edge is
    // created once and both endpoints' realized degrees are exact.
    let base_target = avg_degree.max(1.0);
    let tail_target = (max_degree as f64).max(base_target);

    let mut deg = vec![0u32; n];
    let mut b = GraphBuilder::with_capacity(n, (n as f64 * avg_degree / 2.0) as usize);
    let at = |x: usize, y: usize, z: usize| -> usize { (x * side + y) * side + z };
    for v in 0..n {
        let z = v % side;
        let y = (v / side) % side;
        let x = v / (side * side);
        let is_tail = rng.chance(tail_fraction);
        let target_f = if is_tail { tail_target } else { base_target };
        // dither fractional targets so the average is hit in expectation
        let mut target = target_f as u32;
        if rng.f64() < target_f.fract() {
            target += 1;
        }
        for &(dx, dy, dz) in &offsets {
            if deg[v] >= target {
                break;
            }
            let (nx, ny, nz) = (x as i64 + dx, y as i64 + dy, z as i64 + dz);
            if nx < 0 || ny < 0 || nz < 0 {
                continue;
            }
            let (nx, ny, nz) = (nx as usize, ny as usize, nz as usize);
            if nx >= side || ny >= side || nz >= side {
                continue;
            }
            let u = at(nx, ny, nz);
            // only add toward higher ids: lower ids already had their turn
            if u < n && u > v {
                b.add_edge(v as VertexId, u as VertexId);
                deg[v] += 1;
                deg[u] += 1;
            }
        }
    }
    b.build(name)
}

/// The six Table-1 stand-ins, scaled by `scale` (1.0 = paper size).
/// Returns (graph, paper row) pairs; the paper row records the original
/// V/E/Δ so benches can print paper-vs-ours side by side.
#[derive(Debug, Clone, Copy)]
pub struct PaperGraphSpec {
    pub name: &'static str,
    pub v: usize,
    pub e: usize,
    pub max_deg: usize,
    pub seq_colors_nat: usize,
    pub seq_colors_lf: usize,
    pub seq_colors_sl: usize,
}

pub const TABLE1_SPECS: [PaperGraphSpec; 6] = [
    PaperGraphSpec { name: "auto",   v: 448_695, e: 3_314_611,  max_deg: 37,  seq_colors_nat: 13, seq_colors_lf: 12, seq_colors_sl: 10 },
    PaperGraphSpec { name: "bmw3_2", v: 227_362, e: 5_530_634,  max_deg: 335, seq_colors_nat: 48, seq_colors_lf: 48, seq_colors_sl: 37 },
    PaperGraphSpec { name: "hood",   v: 220_542, e: 4_837_440,  max_deg: 76,  seq_colors_nat: 40, seq_colors_lf: 39, seq_colors_sl: 34 },
    PaperGraphSpec { name: "ldoor",  v: 952_203, e: 20_770_807, max_deg: 76,  seq_colors_nat: 42, seq_colors_lf: 42, seq_colors_sl: 34 },
    PaperGraphSpec { name: "msdoor", v: 415_863, e: 9_378_650,  max_deg: 76,  seq_colors_nat: 42, seq_colors_lf: 42, seq_colors_sl: 35 },
    PaperGraphSpec { name: "pwtk",   v: 217_918, e: 5_653_257,  max_deg: 179, seq_colors_nat: 48, seq_colors_lf: 42, seq_colors_sl: 33 },
];

/// Build the FEM-like stand-in for one Table-1 graph at the given scale
/// (fraction of paper |V|; degree structure is preserved at any scale).
pub fn paper_graph(spec: &PaperGraphSpec, scale: f64, seed: u64) -> CsrGraph {
    let n = ((spec.v as f64 * scale) as usize).max(64);
    let avg = 2.0 * spec.e as f64 / spec.v as f64;
    fem_like(n, avg, spec.max_deg, 0.005, seed, spec.name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_counts() {
        let g = grid2d(3, 4);
        assert_eq!(g.num_vertices(), 12);
        assert_eq!(g.num_edges(), 3 * 3 + 2 * 4); // 17
        assert_eq!(g.max_degree(), 4);
        g.validate().unwrap();
    }

    #[test]
    fn structured_shapes() {
        assert_eq!(path(5).num_edges(), 4);
        assert_eq!(cycle(5).num_edges(), 5);
        assert_eq!(star(5).num_edges(), 4);
        assert_eq!(star(5).max_degree(), 4);
        let k5 = complete(5);
        assert_eq!(k5.num_edges(), 10);
        assert_eq!(k5.max_degree(), 4);
    }

    #[test]
    fn er_edge_count_close() {
        let g = erdos_renyi(1000, 5000, 3);
        let e = g.num_edges();
        assert!((4800..=5300).contains(&e), "e = {e}");
        g.validate().unwrap();
    }

    #[test]
    fn fem_like_matches_targets() {
        let g = fem_like(8000, 14.8, 40, 0.005, 11, "fem");
        g.validate().unwrap();
        let avg = 2.0 * g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(
            (avg - 14.8).abs() / 14.8 < 0.25,
            "avg degree {avg} vs target 14.8"
        );
        assert!(g.max_degree() <= 80, "Δ = {}", g.max_degree());
        assert!(g.max_degree() >= 15, "Δ = {}", g.max_degree());
    }

    #[test]
    fn fem_like_is_local() {
        // most edges should connect nearby lattice ids — the property that
        // makes partitions have small boundary
        let g = fem_like(4096, 12.0, 30, 0.0, 5, "fem");
        let side = (4096f64).cbrt().ceil() as i64;
        let local = g
            .edges()
            .filter(|&(u, v)| ((u as i64) - (v as i64)).abs() <= 2 * side * side)
            .count();
        assert!(local as f64 > 0.9 * g.num_edges() as f64);
    }

    #[test]
    fn paper_graph_small_scale() {
        let g = paper_graph(&TABLE1_SPECS[0], 0.01, 1);
        assert!(g.num_vertices() >= 4000);
        g.validate().unwrap();
    }

    #[test]
    fn deterministic_generators() {
        let a = fem_like(1000, 10.0, 20, 0.01, 9, "a");
        let b = fem_like(1000, 10.0, 20, 0.01, 9, "b");
        assert_eq!(a.adjncy, b.adjncy);
        let a = erdos_renyi(500, 2000, 4);
        let b = erdos_renyi(500, 2000, 4);
        assert_eq!(a.adjncy, b.adjncy);
    }
}
