//! Edge-list → CSR construction with dedup, self-loop removal and
//! symmetrization. Counting-sort based: O(|V| + |E|), no per-vertex Vecs,
//! which matters at the 134M-edge RMAT scale.

use super::{CsrGraph, VertexId};

pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
}

impl GraphBuilder {
    pub fn new(num_vertices: usize) -> Self {
        GraphBuilder {
            n: num_vertices,
            edges: Vec::new(),
        }
    }

    pub fn with_capacity(num_vertices: usize, num_edges: usize) -> Self {
        GraphBuilder {
            n: num_vertices,
            edges: Vec::with_capacity(num_edges),
        }
    }

    /// Add an undirected edge; self-loops are silently dropped, duplicates
    /// are deduplicated at `build`.
    #[inline]
    pub fn add_edge(&mut self, u: VertexId, v: VertexId) {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        if u != v {
            self.edges.push(if u < v { (u, v) } else { (v, u) });
        }
    }

    pub fn num_pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Build the symmetric CSR. Neighbor lists come out sorted ascending.
    pub fn build(mut self, name: impl Into<String>) -> CsrGraph {
        let n = self.n;
        // Dedup canonicalized edges.
        self.edges.sort_unstable();
        self.edges.dedup();

        // Counting sort into symmetric CSR.
        let mut xadj = vec![0u64; n + 1];
        for &(u, v) in &self.edges {
            xadj[u as usize + 1] += 1;
            xadj[v as usize + 1] += 1;
        }
        for i in 0..n {
            xadj[i + 1] += xadj[i];
        }
        let mut adjncy = vec![0 as VertexId; *xadj.last().unwrap_or(&0) as usize];
        let mut cursor: Vec<u64> = xadj[..n].to_vec();
        for &(u, v) in &self.edges {
            adjncy[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            adjncy[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // Each neighbor list is filled from edges sorted by (min, max); the
        // `u`-side entries are ascending but `v`-side entries interleave, so
        // sort each list (cheap: lists are short except for hub vertices).
        for v in 0..n {
            let s = xadj[v] as usize;
            let e = xadj[v + 1] as usize;
            adjncy[s..e].sort_unstable();
        }
        CsrGraph::new(xadj, adjncy, name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_selfloop() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 0); // dup (reversed)
        b.add_edge(0, 1); // dup
        b.add_edge(2, 2); // self-loop dropped
        let g = b.build("t");
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(2), 0);
        g.validate().unwrap();
    }

    #[test]
    fn sorted_lists() {
        let mut b = GraphBuilder::new(6);
        for (u, v) in [(5, 0), (3, 0), (0, 1), (4, 0), (0, 2)] {
            b.add_edge(u, v);
        }
        let g = b.build("t");
        assert_eq!(g.neighbors(0), &[1, 2, 3, 4, 5]);
        assert!(g.is_sorted());
    }

    #[test]
    fn larger_random_roundtrip() {
        use crate::util::Rng;
        let mut rng = Rng::new(99);
        let n = 500;
        let mut b = GraphBuilder::new(n);
        for _ in 0..3000 {
            let u = rng.range(0, n) as VertexId;
            let v = rng.range(0, n) as VertexId;
            b.add_edge(u, v);
        }
        let g = b.build("rand");
        g.validate().unwrap();
        assert!(g.is_sorted());
        // handshake: sum of degrees = 2|E|
        let degsum: usize = (0..n as VertexId).map(|v| g.degree(v)).sum();
        assert_eq!(degsum, 2 * g.num_edges());
    }
}
