//! Matrix Market (.mtx) reader/writer for the `coordinate` format.
//!
//! The paper's real-world graphs come from the UF Sparse Matrix Collection in
//! this format; we support reading `matrix coordinate (real|integer|pattern)
//! (symmetric|general)` as the adjacency structure of an undirected graph
//! (values ignored, diagonal dropped, general matrices symmetrized).

use super::{CsrGraph, GraphBuilder, VertexId};
use crate::util::error::{Context, Error, Result};
use std::fmt::Display;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

pub fn read_mtx(path: &Path) -> Result<CsrGraph> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {path:?}"))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "mtx".into());
    read_mtx_from(BufReader::new(f), &name)
}

/// Parse the coordinate format with every failure reported as
/// [`ErrorKind::Parse`](crate::util::error::ErrorKind) at its 1-based
/// input line, so a malformed multi-GB collection file points at the
/// offending line instead of a bare parse error.
pub fn read_mtx_from<R: BufRead>(mut r: R, name: &str) -> Result<CsrGraph> {
    let mut lineno: u32 = 1;
    let mut line = String::new();
    r.read_line(&mut line)?;
    if !line.starts_with("%%MatrixMarket") {
        return Err(Error::parse_at(
            lineno,
            "not a MatrixMarket file (missing %%MatrixMarket header)",
        ));
    }
    let header: Vec<String> = line
        .trim()
        .split_whitespace()
        .map(|s| s.to_ascii_lowercase())
        .collect();
    if header.len() < 5 || header[1] != "matrix" || header[2] != "coordinate" {
        return Err(Error::parse_at(
            lineno,
            format!("unsupported MatrixMarket header: {}", line.trim()),
        ));
    }
    let field = header[3].as_str(); // real | integer | pattern | complex
    if field == "complex" {
        return Err(Error::parse_at(lineno, "complex matrices unsupported"));
    }
    let _symmetric = header[4] == "symmetric"; // both handled identically:
                                               // builder symmetrizes anyway

    // skip comments
    let mut dims = String::new();
    loop {
        dims.clear();
        lineno += 1;
        if r.read_line(&mut dims)? == 0 {
            return Err(Error::parse_at(
                lineno,
                "unexpected end of file before the dimension line",
            ));
        }
        if !dims.trim_start().starts_with('%') && !dims.trim().is_empty() {
            break;
        }
    }
    let mut it = dims.split_whitespace();
    let rows: usize = parse_field(lineno, "row count", it.next())?;
    let cols: usize = parse_field(lineno, "column count", it.next())?;
    let nnz: usize = parse_field(lineno, "entry count", it.next())?;
    if rows != cols {
        return Err(Error::parse_at(
            lineno,
            format!("adjacency matrix must be square, got {rows}x{cols}"),
        ));
    }

    let mut b = GraphBuilder::with_capacity(rows, nnz);
    let mut seen = 0usize;
    while seen < nnz {
        line.clear();
        lineno += 1;
        if r.read_line(&mut line)? == 0 {
            return Err(Error::parse_at(
                lineno,
                format!("unexpected end of file: saw {seen} of {nnz} entries"),
            ));
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: u64 = parse_field(lineno, "row index", it.next())?;
        let j: u64 = parse_field(lineno, "column index", it.next())?;
        if i == 0 || j == 0 {
            return Err(Error::parse_at(
                lineno,
                format!("zero index in 1-based entry {t:?}"),
            ));
        }
        if i as usize > rows || j as usize > rows {
            return Err(Error::parse_at(
                lineno,
                format!("index out of range in entry {t:?} (matrix is {rows}x{rows})"),
            ));
        }
        // 1-based → 0-based; self-edges (diagonal) dropped by the builder.
        b.add_edge((i - 1) as VertexId, (j - 1) as VertexId);
        seen += 1;
    }
    Ok(b.build(name))
}

/// One whitespace-separated numeric field, with a missing or non-numeric
/// token reported at its 1-based line.
fn parse_field<T: std::str::FromStr>(lineno: u32, what: &str, tok: Option<&str>) -> Result<T>
where
    T::Err: Display,
{
    let tok = tok.ok_or_else(|| Error::parse_at(lineno, format!("missing {what}")))?;
    tok.parse()
        .map_err(|e| Error::parse_at(lineno, format!("invalid {what} {tok:?}: {e}")))
}

/// Write the graph as `pattern symmetric` coordinate MatrixMarket.
pub fn write_mtx(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {path:?}"))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate pattern symmetric")?;
    writeln!(w, "% generated by dgcolor: {}", g.name)?;
    let n = g.num_vertices();
    writeln!(w, "{} {} {}", n, n, g.num_edges())?;
    // symmetric format stores lower triangle: emit (max+1, min+1)
    for (u, v) in g.edges() {
        writeln!(w, "{} {}", v + 1, u + 1)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SAMPLE: &str = "%%MatrixMarket matrix coordinate pattern symmetric\n\
% a comment\n\
4 4 4\n\
2 1\n\
3 1\n\
3 2\n\
4 4\n";

    #[test]
    fn read_symmetric_pattern() {
        let g = read_mtx_from(Cursor::new(SAMPLE), "sample").unwrap();
        assert_eq!(g.num_vertices(), 4);
        // diagonal entry (4,4) dropped
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        g.validate().unwrap();
    }

    #[test]
    fn read_real_general() {
        let s = "%%MatrixMarket matrix coordinate real general\n\
3 3 4\n\
1 2 0.5\n\
2 1 0.5\n\
1 3 -2\n\
2 3 1.0\n";
        let g = read_mtx_from(Cursor::new(s), "gen").unwrap();
        assert_eq!(g.num_edges(), 3); // (1,2) symmetrized+dedup'd
        g.validate().unwrap();
    }

    #[test]
    fn reject_garbage() {
        assert!(read_mtx_from(Cursor::new("hello\n"), "x").is_err());
        let bad = "%%MatrixMarket matrix coordinate real general\n2 3 1\n1 2 1.0\n";
        assert!(read_mtx_from(Cursor::new(bad), "x").is_err(), "non-square");
        let oob = "%%MatrixMarket matrix coordinate pattern symmetric\n2 2 1\n3 1\n";
        assert!(read_mtx_from(Cursor::new(oob), "x").is_err(), "out of range");
    }

    #[test]
    fn malformed_inputs_fail_with_line_numbers() {
        use crate::util::error::ErrorKind;
        let fail = |s: &str| read_mtx_from(Cursor::new(s), "x").unwrap_err();

        let e = fail("hello\n1 1 0\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 1 });
        assert!(e.to_string().contains("%%MatrixMarket"));

        let e = fail("%%MatrixMarket matrix array real general\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 1 });
        assert!(e.to_string().contains("unsupported"));

        let e = fail("%%MatrixMarket matrix coordinate complex general\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 1 });

        let e = fail("%%MatrixMarket matrix coordinate pattern symmetric\n% only comments\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 3 });
        assert!(e.to_string().contains("dimension line"));

        let e = fail("%%MatrixMarket matrix coordinate pattern symmetric\n4 4\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 2 });
        assert!(e.to_string().contains("missing entry count"));

        let e = fail("%%MatrixMarket matrix coordinate pattern symmetric\n4 4 x\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 2 });
        assert!(e.to_string().contains("invalid entry count"));

        let e = fail("%%MatrixMarket matrix coordinate real general\n2 3 1\n1 2 1.0\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 2 });
        assert!(e.to_string().contains("square"));

        let e = fail("%%MatrixMarket matrix coordinate pattern symmetric\n% c\n2 2 1\n0 1\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 4 });
        assert!(e.to_string().contains("zero index"));

        let e = fail("%%MatrixMarket matrix coordinate pattern symmetric\n2 2 1\n3 1\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 3 });
        assert!(e.to_string().contains("out of range"));

        let e = fail("%%MatrixMarket matrix coordinate pattern symmetric\n2 2 1\n1\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 3 });
        assert!(e.to_string().contains("missing column index"));

        let e = fail("%%MatrixMarket matrix coordinate pattern symmetric\n2 2 1\n1 two\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 3 });
        assert!(e.to_string().contains("invalid column index"));

        let e = fail("%%MatrixMarket matrix coordinate pattern symmetric\n3 3 2\n1 2\n");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 4 });
        assert!(e.to_string().contains("saw 1 of 2"));
    }

    #[test]
    fn roundtrip() {
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new(5);
        for (u, v) in [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (1, 3)] {
            b.add_edge(u, v);
        }
        let g = b.build("ring+");
        let dir = std::env::temp_dir().join("dgcolor_mtx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ring.mtx");
        write_mtx(&g, &p).unwrap();
        let g2 = read_mtx(&p).unwrap();
        assert_eq!(g.xadj, g2.xadj);
        assert_eq!(g.adjncy, g2.adjncy);
    }
}
