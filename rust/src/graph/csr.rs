//! Compressed-sparse-row undirected graph.
//!
//! Symmetric storage: every undirected edge `{u,v}` appears as both `(u,v)`
//! and `(v,u)`. `num_edges()` reports undirected edge count (|E|), matching
//! the paper's tables.

use super::VertexId;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CsrGraph {
    /// Row offsets, length `n + 1`.
    pub xadj: Vec<u64>,
    /// Column indices (neighbor lists), length `2|E|`.
    pub adjncy: Vec<VertexId>,
    /// Optional human-readable name (used in experiment tables).
    pub name: String,
}

impl CsrGraph {
    pub fn new(xadj: Vec<u64>, adjncy: Vec<VertexId>, name: impl Into<String>) -> Self {
        debug_assert!(!xadj.is_empty());
        debug_assert_eq!(*xadj.last().unwrap() as usize, adjncy.len());
        CsrGraph {
            xadj,
            adjncy,
            name: name.into(),
        }
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.xadj.len() - 1
    }

    /// Number of *undirected* edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.adjncy.len() / 2
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: VertexId) -> usize {
        (self.xadj[v as usize + 1] - self.xadj[v as usize]) as usize
    }

    /// Maximum degree Δ.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Neighbor slice of `v`.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.adjncy[self.xadj[v as usize] as usize..self.xadj[v as usize + 1] as usize]
    }

    /// Iterate all undirected edges `(u, v)` with `u < v`.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices() as VertexId).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .copied()
                .filter(move |&v| u < v)
                .map(move |v| (u, v))
        })
    }

    /// Structural sanity: offsets monotone, neighbor ids in range, no
    /// self-loops, symmetric adjacency. The reverse-edge check
    /// binary-searches the neighbor's (sorted) adjacency list — O(|E| log
    /// d), as the builders guarantee sorted lists; a hand-built CSR with
    /// unsorted lists falls back to a linear probe (O(|E|·d)).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_vertices();
        for i in 0..n {
            if self.xadj[i] > self.xadj[i + 1] {
                return Err(format!("xadj not monotone at {i}"));
            }
        }
        let sorted = self.is_sorted();
        for u in 0..n as VertexId {
            for &v in self.neighbors(u) {
                if v as usize >= n {
                    return Err(format!("neighbor {v} of {u} out of range"));
                }
                if v == u {
                    return Err(format!("self-loop at {u}"));
                }
                let reverse_present = if sorted {
                    self.neighbors(v).binary_search(&u).is_ok()
                } else {
                    self.neighbors(v).contains(&u)
                };
                if !reverse_present {
                    return Err(format!("asymmetric edge ({u},{v})"));
                }
            }
        }
        Ok(())
    }

    /// Whether each adjacency list is sorted (builders guarantee this;
    /// partition-local views rely on it for binary search).
    pub fn is_sorted(&self) -> bool {
        (0..self.num_vertices() as VertexId).all(|v| self.neighbors(v).windows(2).all(|w| w[0] < w[1]))
    }

    /// Estimated resident bytes.
    pub fn memory_bytes(&self) -> usize {
        self.xadj.len() * 8 + self.adjncy.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn triangle() -> CsrGraph {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(0, 2);
        b.build("triangle")
    }

    #[test]
    fn basic_counts() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.max_degree(), 2);
        assert_eq!(g.degree(1), 2);
        g.validate().unwrap();
    }

    #[test]
    fn neighbors_sorted_and_symmetric() {
        let g = triangle();
        assert!(g.is_sorted());
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn edges_iterator_unique() {
        let g = triangle();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(0).build("empty");
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.max_degree(), 0);
        g.validate().unwrap();
    }

    #[test]
    fn isolated_vertices() {
        let mut b = GraphBuilder::new(5);
        b.add_edge(0, 4);
        let g = b.build("iso");
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(2), 0);
        g.validate().unwrap();
    }

    #[test]
    fn validate_catches_asymmetry() {
        let g = CsrGraph::new(vec![0, 1, 1], vec![1], "bad");
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_falls_back_for_unsorted_adjacency() {
        // hand-built CSR with a descending list: symmetric but unsorted,
        // so the reverse-edge check must use the linear probe
        let g = CsrGraph::new(vec![0, 2, 3, 4], vec![2, 1, 0, 0], "unsorted");
        assert!(!g.is_sorted());
        g.validate().unwrap();
        // and asymmetry is still caught on unsorted lists
        let bad = CsrGraph::new(vec![0, 2, 2, 3], vec![2, 1, 0], "unsorted-bad");
        assert!(!bad.is_sorted());
        assert!(bad.validate().is_err());
    }
}
