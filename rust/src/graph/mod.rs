//! Graph substrate: CSR storage, construction, Matrix-Market I/O, RMAT and
//! structured synthetic generators, and degree statistics.

pub mod builder;
pub mod csr;
pub mod mtx;
pub mod rmat;
pub mod stats;
pub mod synth;

pub use builder::GraphBuilder;
pub use csr::CsrGraph;

/// Vertex id type used across the library. u32 supports up to 4.29B vertices
/// which covers the paper's largest graphs (2^24) with room to spare while
/// halving memory traffic versus u64 — the greedy loop is bandwidth-bound.
pub type VertexId = u32;
