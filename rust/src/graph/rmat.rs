//! R-MAT recursive random graph generator (Chakrabarti et al., SDM'04).
//!
//! The paper's three synthetic graphs:
//!   RMAT-ER   (0.25, 0.25, 0.25, 0.25)  — Erdős-Rényi-like
//!   RMAT-Good (0.45, 0.15, 0.15, 0.25)  — mild skew, small-world
//!   RMAT-Bad  (0.55, 0.15, 0.15, 0.15)  — heavy skew, power-law hubs
//! at scale 24 (2^24 vertices) and 8 edges per vertex. The generator is
//! deterministic given a seed; duplicates and self-loops are removed by the
//! CSR builder, so the realized |E| lands slightly under `edge_factor * n`
//! exactly as in the paper's Table 2.

use super::{CsrGraph, GraphBuilder, VertexId};
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RmatParams {
    /// log2 of the number of vertices.
    pub scale: u32,
    /// Requested edges per vertex (before dedup).
    pub edge_factor: usize,
    /// Quadrant probabilities (a, b, c, d); must sum to 1.
    pub probs: (f64, f64, f64, f64),
    /// Noise added per recursion level to avoid exact-degree artifacts.
    pub noise: f64,
}

impl RmatParams {
    pub fn er(scale: u32, edge_factor: usize) -> Self {
        RmatParams {
            scale,
            edge_factor,
            probs: (0.25, 0.25, 0.25, 0.25),
            noise: 0.0,
        }
    }

    pub fn good(scale: u32, edge_factor: usize) -> Self {
        RmatParams {
            scale,
            edge_factor,
            probs: (0.45, 0.15, 0.15, 0.25),
            noise: 0.05,
        }
    }

    pub fn bad(scale: u32, edge_factor: usize) -> Self {
        RmatParams {
            scale,
            edge_factor,
            probs: (0.55, 0.15, 0.15, 0.15),
            noise: 0.05,
        }
    }

    pub fn num_vertices(&self) -> usize {
        1usize << self.scale
    }
}

/// Generate an R-MAT graph.
pub fn generate(params: &RmatParams, seed: u64, name: &str) -> CsrGraph {
    let n = params.num_vertices();
    let m = n * params.edge_factor;
    let (a, b, c, _d) = params.probs;
    assert!(
        (params.probs.0 + params.probs.1 + params.probs.2 + params.probs.3 - 1.0).abs() < 1e-9,
        "RMAT probabilities must sum to 1"
    );
    let mut rng = Rng::new(seed);
    let mut builder = GraphBuilder::with_capacity(n, m);
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for level in 0..params.scale {
            // jitter quadrant probabilities per level (standard RMAT noise)
            let jit = if params.noise > 0.0 {
                1.0 + params.noise * (2.0 * rng.f64() - 1.0)
            } else {
                1.0
            };
            let aj = a * jit;
            let bj = b * jit;
            let cj = c * jit;
            let r = rng.f64() * (aj + bj + cj + (1.0 - a - b - c) * jit);
            let half = 1usize << (params.scale - 1 - level);
            if r < aj {
                // top-left quadrant: no bits set
            } else if r < aj + bj {
                v += half;
            } else if r < aj + bj + cj {
                u += half;
            } else {
                u += half;
                v += half;
            }
        }
        builder.add_edge(u as VertexId, v as VertexId);
    }
    builder.build(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_is_near_uniform() {
        let g = generate(&RmatParams::er(10, 8), 1, "er10");
        assert_eq!(g.num_vertices(), 1024);
        // dedup removes few edges in the ER case at this density
        assert!(g.num_edges() > 7000, "edges: {}", g.num_edges());
        g.validate().unwrap();
        // max degree should be modest (no hubs)
        assert!(g.max_degree() < 50, "Δ = {}", g.max_degree());
    }

    #[test]
    fn bad_is_skewed() {
        let er = generate(&RmatParams::er(12, 8), 2, "er");
        let bad = generate(&RmatParams::bad(12, 8), 2, "bad");
        assert!(
            bad.max_degree() > 3 * er.max_degree(),
            "bad Δ {} vs er Δ {}",
            bad.max_degree(),
            er.max_degree()
        );
    }

    #[test]
    fn deterministic() {
        let a = generate(&RmatParams::good(8, 4), 7, "a");
        let b = generate(&RmatParams::good(8, 4), 7, "b");
        assert_eq!(a.xadj, b.xadj);
        assert_eq!(a.adjncy, b.adjncy);
        let c = generate(&RmatParams::good(8, 4), 8, "c");
        assert_ne!(a.adjncy, c.adjncy);
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn rejects_bad_probs() {
        let mut p = RmatParams::er(4, 2);
        p.probs = (0.5, 0.5, 0.5, 0.5);
        generate(&p, 1, "x");
    }
}
