//! Degree statistics and the summary block printed by `dgcolor info` and the
//! table benches.

use super::CsrGraph;

#[derive(Debug, Clone, PartialEq)]
pub struct GraphSummary {
    pub name: String,
    pub num_vertices: usize,
    pub num_edges: usize,
    pub max_degree: usize,
    pub avg_degree: f64,
    pub min_degree: usize,
    pub isolated: usize,
}

pub fn summarize(g: &CsrGraph) -> GraphSummary {
    let n = g.num_vertices();
    let mut max_d = 0usize;
    let mut min_d = usize::MAX;
    let mut isolated = 0usize;
    for v in 0..n as u32 {
        let d = g.degree(v);
        max_d = max_d.max(d);
        min_d = min_d.min(d);
        if d == 0 {
            isolated += 1;
        }
    }
    if n == 0 {
        min_d = 0;
    }
    GraphSummary {
        name: g.name.clone(),
        num_vertices: n,
        num_edges: g.num_edges(),
        max_degree: max_d,
        avg_degree: if n == 0 { 0.0 } else { 2.0 * g.num_edges() as f64 / n as f64 },
        min_degree: min_d,
        isolated,
    }
}

/// Degree histogram in log2 buckets: `hist[k]` counts vertices with degree
/// in `[2^k, 2^(k+1))`; `hist[0]` additionally counts degree 0 and 1.
pub fn degree_histogram_log2(g: &CsrGraph) -> Vec<usize> {
    let mut hist = vec![0usize; 33];
    for v in 0..g.num_vertices() as u32 {
        let d = g.degree(v);
        let bucket = if d <= 1 { 0 } else { (usize::BITS - d.leading_zeros()) as usize - 1 };
        hist[bucket] += 1;
    }
    while hist.len() > 1 && *hist.last().unwrap() == 0 {
        hist.pop();
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    #[test]
    fn summary_star() {
        let g = synth::star(10);
        let s = summarize(&g);
        assert_eq!(s.num_vertices, 10);
        assert_eq!(s.num_edges, 9);
        assert_eq!(s.max_degree, 9);
        assert_eq!(s.min_degree, 1);
        assert_eq!(s.isolated, 0);
        assert!((s.avg_degree - 1.8).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets() {
        let g = synth::star(10); // center deg 9 → bucket 3; leaves deg 1 → bucket 0
        let h = degree_histogram_log2(&g);
        assert_eq!(h[0], 9);
        assert_eq!(h[3], 1);
        assert_eq!(h.iter().sum::<usize>(), 10);
    }

    #[test]
    fn empty_summary() {
        let g = crate::graph::GraphBuilder::new(0).build("e");
        let s = summarize(&g);
        assert_eq!(s.num_vertices, 0);
        assert_eq!(s.min_degree, 0);
    }
}
