//! Shared-memory execution layer: engines that skip the simulated
//! transport entirely and work on flat arrays over the process-wide
//! [`WorkerPool`](crate::util::pool::WorkerPool).
//!
//! The distributed runtime (`dist`) *models* a message-passing machine —
//! every superstep pays for encoded messages, collectives and virtual
//! clocks even though all p simulated processes share one address space.
//! That is the point when the object of study is the paper's communication
//! behavior, and pure overhead when the object is raw coloring speed on
//! one box. Rokos et al. (arXiv:1505.04086) and Taş et al. "Greed is
//! Good" (arXiv:1701.02628) show the optimistic speculate-then-resolve
//! formulation on shared arrays wins by orders of magnitude there.
//!
//! [`datapar`] is that formulation: chunked vertex ranges fan out over the
//! pool, each worker speculatively colors its chunks against a frozen
//! snapshot of the color array, a parallel sweep detects
//! defectively-colored vertices, and only those re-enter the next round —
//! the paper's iterated-recoloring structure reused as the conflict-resolve
//! loop. It is surfaced through the coordinator as
//! [`Engine::DataPar`](crate::dist::Engine::DataPar).

pub mod datapar;

pub use datapar::{
    color_graph, color_graph_cancellable, color_graph_on, DataParConfig, DataParMetrics,
    DataParRound,
};
