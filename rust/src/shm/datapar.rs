//! Data-parallel speculative coloring (`Engine::DataPar`).
//!
//! The algorithm is the classic optimistic three-step loop over flat
//! arrays (Gebremedhin-Manne speculation as refined by Rokos et al. and
//! Taş et al.), with the paper's iterated-recoloring structure as the
//! resolve loop:
//!
//! 1. **Speculate** — the active vertices (initially all of them) are
//!    colored in parallel. The vertex range `0..n` is cut into a fixed
//!    grid of chunks; workers claim chunks round-robin and color each
//!    chunk's active vertices sequentially with the ordinary
//!    ordering/selection machinery ([`compute_order`] + [`SelectState`]
//!    with its epoch-stamped `ColorMarker` palette scan). Within a chunk,
//!    reads see live writes (the chunk is exclusive to one worker); across
//!    chunks, reads see a frozen snapshot of the previous round — so no
//!    write is ever observed racily.
//! 2. **Detect** — a parallel sweep over the active vertices finds
//!    defectively-colored ones: `v` is a *loser* iff some neighbor carries
//!    the same color and `v` loses the seeded priority tie-break
//!    ([`loses`]). Exactly one endpoint of every conflicting edge keeps
//!    its color.
//! 3. **Resolve** — only the losers re-enter the next round; iterate
//!    until no conflicts remain.
//!
//! # Determinism, independent of worker count
//!
//! The chunk grid is fixed by `n` and [`DataParConfig::chunk_size`] —
//! never by the number of workers. Each chunk's round output is a pure
//! function of (graph, config, round, chunk, previous-round snapshot): the
//! per-chunk RNG and [`SelectState`] are re-seeded from
//! `mix64(seed, round ‖ chunk)` every round, and cross-chunk reads go
//! through the snapshot. Which *worker* happens to process a chunk
//! therefore cannot affect any color, so a pinned fixture holds across
//! machines and `--threads 1` equals `--threads 8` bit-for-bit.
//!
//! # Termination
//!
//! Fixed (non-active) neighbors can never conflict with a speculated
//! vertex: same-chunk fixed colors are read live and forbidden, and
//! cross-chunk fixed colors equal their snapshot value (the invariant
//! restored after every round), so they were forbidden too. Conflicts are
//! thus always between two active vertices — and the active vertex with
//! the globally maximal seeded priority never loses, so the active set
//! shrinks strictly every round and the loop terminates in at most `n`
//! rounds (in practice a handful; round 1 colors everything and later
//! rounds only touch chunk-boundary losers).

use std::sync::Mutex;

use crate::color::order::compute_order;
use crate::color::select::SelectState;
use crate::color::{Color, Coloring, Ordering, Selection, UNCOLORED};
use crate::dist::framework::loses;
use crate::graph::{CsrGraph, VertexId};
use crate::util::error::Result;
use crate::util::pool::{self, WorkerPool};
use crate::util::rng::mix64;
use crate::util::timer::Timer;
use crate::util::Rng;

/// Default chunk width in vertices. Small enough to load-balance irregular
/// degree distributions over the pool, large enough to amortize the
/// per-chunk ordering/selection setup.
pub const DEFAULT_CHUNK_SIZE: usize = 4096;

/// Configuration for a data-parallel speculative coloring run.
#[derive(Debug, Clone)]
pub struct DataParConfig {
    /// Vertex-visit order *within a chunk*. Partition-aware orders
    /// (Internal/Boundary-first) have no partition here and degrade to
    /// natural order.
    pub ordering: Ordering,
    /// Color-selection strategy (per-chunk [`SelectState`], re-seeded each
    /// round, so every strategy — including RandomX — stays deterministic).
    pub selection: Selection,
    /// Seeds the chunk RNGs and the conflict tie-break priorities.
    pub seed: u64,
    /// Chunk width in vertices; part of the deterministic result (the
    /// chunk grid is fixed by `n` and this, never by worker count).
    pub chunk_size: usize,
    /// Defensive cap on resolve rounds; `0` means unlimited (the
    /// strict-shrink invariant already bounds rounds by `n`).
    pub max_rounds: u32,
}

impl Default for DataParConfig {
    fn default() -> Self {
        DataParConfig {
            ordering: Ordering::Natural,
            selection: Selection::FirstFit,
            seed: 1,
            chunk_size: DEFAULT_CHUNK_SIZE,
            max_rounds: 0,
        }
    }
}

/// Per-round accounting for [`DataParMetrics`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DataParRound {
    /// Vertices speculatively (re)colored this round.
    pub speculated: u64,
    /// Vertices found defectively colored (they re-enter the next round).
    pub conflicted: u64,
    /// Wall-clock seconds for the round (speculate + detect).
    pub secs: f64,
}

/// What a DataPar run measures — the shared-memory analogue of
/// `DistMetrics` (there is no transport, so no messages/bytes/clocks:
/// rounds and vertex counts are the whole story).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DataParMetrics {
    /// Resolve rounds until conflict-free (round 1 colors everything).
    pub rounds: u32,
    /// Total speculative colorings across all rounds (first round
    /// contributes `n`; the rest is re-coloring work).
    pub speculated: u64,
    /// Total defectively-colored vertices detected across all rounds.
    pub conflicted: u64,
    /// Per-round breakdown, `per_round.len() == rounds`.
    pub per_round: Vec<DataParRound>,
    /// End-to-end wall-clock seconds.
    pub wall_secs: f64,
    /// Pool workers the run fanned out over (never affects the colors).
    pub workers: usize,
    /// Chunks in the fixed grid, `ceil(n / chunk_size)`.
    pub chunks: usize,
}

/// Color `g` on the process-wide worker pool. See [`color_graph_on`].
pub fn color_graph(g: &CsrGraph, cfg: &DataParConfig) -> Result<(Coloring, DataParMetrics)> {
    color_graph_on(pool::global(), g, cfg)
}

/// Color `g` on an explicit pool (tests pin worker counts this way).
/// The coloring is bit-for-bit identical for every pool size.
pub fn color_graph_on(
    pool: &WorkerPool,
    g: &CsrGraph,
    cfg: &DataParConfig,
) -> Result<(Coloring, DataParMetrics)> {
    color_graph_with(pool, g, cfg, &mut |_, _| {})
}

/// [`color_graph_on`] with a per-round observer: `on_round(round,
/// conflicts)` fires after each detection sweep (the pipeline forwards it
/// as `Event::ConflictRound`).
///
/// Must not be called from inside a pool shard closure (it runs
/// `scoped_run` itself — see `util::pool`).
pub fn color_graph_with(
    pool: &WorkerPool,
    g: &CsrGraph,
    cfg: &DataParConfig,
    on_round: &mut dyn FnMut(u32, u64),
) -> Result<(Coloring, DataParMetrics)> {
    let (c, m, _) = color_graph_cancellable(pool, g, cfg, None, on_round)?;
    Ok((c, m))
}

/// [`color_graph_with`] with an optional [`CancelToken`], polled once at
/// the top of every speculate/detect/resolve round — DataPar's natural
/// checkpoint, so a token raised during round *k* is observed before round
/// *k+1* starts. There is no virtual clock here (the poll passes `0.0`, so
/// virtual-clock budgets never fire — job validation rejects that
/// combination); wall deadlines and external cancels do. On a stop the
/// partial coloring is returned as-is — complete but possibly conflicted
/// after round 1, all-uncolored if the token fired before it — together
/// with `Some(cause)`; the pipeline repairs it under the `Degrade` policy.
pub fn color_graph_cancellable(
    pool: &WorkerPool,
    g: &CsrGraph,
    cfg: &DataParConfig,
    cancel: Option<&crate::util::cancel::CancelToken>,
    on_round: &mut dyn FnMut(u32, u64),
) -> Result<(Coloring, DataParMetrics, Option<crate::util::cancel::StopCause>)> {
    let mut stopped = None;
    let n = g.num_vertices();
    let cs = cfg.chunk_size.max(1);
    let nchunks = n.div_ceil(cs);
    let mut metrics = DataParMetrics {
        workers: pool.workers(),
        chunks: nchunks,
        ..DataParMetrics::default()
    };
    if n == 0 {
        return Ok((Coloring::uncolored(0), metrics, None));
    }
    let wall = Timer::start();
    let shards = pool.workers().min(nchunks).max(1);
    let estimate = (g.max_degree() + 1) as u32;

    let mut colors: Vec<Color> = vec![UNCOLORED; n];
    // Frozen previous-round snapshot for cross-chunk reads. Invariant at
    // the top of every round: `prev[v] == colors[v]` for every vertex not
    // in the active set (restored after each round).
    let mut prev: Vec<Color> = vec![UNCOLORED; n];
    // Active vertices per chunk, ascending; chunk c owns [c*cs, (c+1)*cs).
    let mut active: Vec<Vec<VertexId>> = (0..nchunks)
        .map(|c| {
            let lo = c * cs;
            let hi = ((c + 1) * cs).min(n);
            (lo as VertexId..hi as VertexId).collect()
        })
        .collect();
    let mut active_count = n as u64;

    let mut round: u32 = 0;
    loop {
        if let Some(tok) = cancel {
            // round-top checkpoint: single-threaded here (between
            // scoped_run fan-outs), so the stop decision is trivially
            // uniform and no worker is left mid-round
            if let Some(cause) = tok.check(0.0) {
                stopped = Some(cause);
                break;
            }
        }
        round += 1;
        if cfg.max_rounds > 0 && round > cfg.max_rounds {
            crate::bail!(
                "datapar did not converge within {} rounds ({} vertices still conflicted)",
                cfg.max_rounds,
                active_count
            );
        }
        let rt = Timer::start();

        // --- speculate: color every active vertex ---
        {
            // Exclusive per-chunk windows into the live color array. Each
            // chunk's mutex is locked once, by the one worker that owns the
            // chunk this round — the locks are never contended, they only
            // make the disjoint &mut windows safe to hand across threads.
            let slices: Vec<Mutex<&mut [Color]>> = colors.chunks_mut(cs).map(Mutex::new).collect();
            let prev_ref = &prev;
            let active_ref = &active;
            pool.scoped_run(shards, &|shard| {
                let mut c = shard;
                while c < nchunks {
                    let verts = &active_ref[c];
                    if !verts.is_empty() {
                        let base = c * cs;
                        // Pure function of (seed, round, chunk): worker
                        // assignment cannot influence the outcome.
                        let chunk_seed = mix64(cfg.seed, ((round as u64) << 32) ^ c as u64);
                        let mut rng = Rng::new(chunk_seed);
                        let order = compute_order(g, verts, cfg.ordering, |_| false, &mut rng);
                        let mut st = SelectState::new(cfg.selection, estimate, chunk_seed);
                        let mut slice = slices[c].lock().unwrap();
                        for &v in &order {
                            st.begin_vertex();
                            for &u in g.neighbors(v) {
                                let cu = if u as usize / cs == c {
                                    slice[u as usize - base] // same chunk: live
                                } else {
                                    prev_ref[u as usize] // other chunk: snapshot
                                };
                                if cu != UNCOLORED {
                                    st.forbid(cu);
                                }
                            }
                            slice[v as usize - base] = st.pick();
                        }
                    }
                    c += shards;
                }
            });
        }

        // --- detect: find the losers of every conflicting edge ---
        let loser_slots: Vec<Mutex<Vec<VertexId>>> =
            (0..nchunks).map(|_| Mutex::new(Vec::new())).collect();
        {
            let colors_ref = &colors;
            let active_ref = &active;
            pool.scoped_run(shards, &|shard| {
                let mut c = shard;
                while c < nchunks {
                    let verts = &active_ref[c];
                    if !verts.is_empty() {
                        let mut lost: Vec<VertexId> = Vec::new();
                        for &v in verts {
                            let cv = colors_ref[v as usize];
                            if g.neighbors(v).iter().any(|&u| {
                                colors_ref[u as usize] == cv && loses(v, u, cfg.seed)
                            }) {
                                lost.push(v);
                            }
                        }
                        if !lost.is_empty() {
                            *loser_slots[c].lock().unwrap() = lost;
                        }
                    }
                    c += shards;
                }
            });
        }

        // --- resolve: losers (in deterministic chunk order) re-enter ---
        let mut conflicted = 0u64;
        let mut next_active: Vec<Vec<VertexId>> = Vec::with_capacity(nchunks);
        for slot in loser_slots {
            let lost = slot.into_inner().unwrap();
            conflicted += lost.len() as u64;
            next_active.push(lost);
        }

        metrics.per_round.push(DataParRound {
            speculated: active_count,
            conflicted,
            secs: rt.secs(),
        });
        metrics.speculated += active_count;
        metrics.conflicted += conflicted;
        on_round(round, conflicted);

        if conflicted == 0 {
            break;
        }
        crate::ensure!(
            conflicted < active_count,
            "datapar made no progress in round {round}: {conflicted} of {active_count} \
             active vertices conflicted (speculation invariant violated)"
        );

        // Restore the snapshot invariant for everything this round touched
        // (losers included — their stale snapshot value only over-forbids).
        for verts in &active {
            for &v in verts {
                prev[v as usize] = colors[v as usize];
            }
        }
        active = next_active;
        active_count = conflicted;
    }

    metrics.rounds = round;
    metrics.wall_secs = wall.secs();
    Ok((Coloring::from_vec(colors), metrics, stopped))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    fn small_cfg(seed: u64, chunk_size: usize) -> DataParConfig {
        DataParConfig {
            seed,
            chunk_size,
            ..DataParConfig::default()
        }
    }

    #[test]
    fn colors_a_path_validly() {
        let g = synth::path(64);
        let (c, m) = color_graph(&g, &DataParConfig::default()).unwrap();
        c.validate(&g).unwrap();
        assert!(m.rounds >= 1);
        assert_eq!(m.per_round.len() as u32, m.rounds);
        assert_eq!(m.per_round[0].speculated, 64);
    }

    #[test]
    fn empty_graph_is_a_noop() {
        let g = synth::path(0);
        let (c, m) = color_graph(&g, &DataParConfig::default()).unwrap();
        assert!(c.is_empty());
        assert_eq!(m.rounds, 0);
        assert_eq!(m.chunks, 0);
    }

    #[test]
    fn cross_chunk_conflicts_resolve_via_priority() {
        // chunk_size 1 puts the path(2) endpoints in different chunks: round
        // 1 speculates both to color 0 (the snapshot is all-UNCOLORED), the
        // detect sweep picks exactly one loser, round 2 recolors it.
        let g = synth::path(2);
        let (c, m) = color_graph(&g, &small_cfg(7, 1)).unwrap();
        c.validate(&g).unwrap();
        assert_eq!(m.rounds, 2);
        assert_eq!(m.per_round[0].conflicted, 1);
        assert_eq!(m.speculated, 3); // 2 + the single loser
        let mut sorted = c.colors.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1]);
    }

    #[test]
    fn max_rounds_cap_is_a_typed_error() {
        let g = synth::path(2);
        let cfg = DataParConfig {
            max_rounds: 1,
            ..small_cfg(7, 1)
        };
        let err = color_graph(&g, &cfg).unwrap_err();
        assert!(err.to_string().contains("did not converge"), "{err}");
    }

    #[test]
    fn identical_across_worker_counts() {
        // Small chunks force many cross-chunk edges (the racy part); the
        // colors and the full per-round conflict trace must not depend on
        // how many workers the chunks landed on.
        let g = synth::fem_like(1500, 8.0, 24, 0.05, 3, "dp-det");
        let cfg = small_cfg(42, 64);
        let (c1, m1) = color_graph_on(&WorkerPool::new(1), &g, &cfg).unwrap();
        c1.validate(&g).unwrap();
        for workers in [2, 8] {
            let (cw, mw) = color_graph_on(&WorkerPool::new(workers), &g, &cfg).unwrap();
            assert_eq!(c1.colors, cw.colors, "colors diverged at {workers} workers");
            assert_eq!(m1.rounds, mw.rounds);
            assert_eq!(
                m1.per_round
                    .iter()
                    .map(|r| (r.speculated, r.conflicted))
                    .collect::<Vec<_>>(),
                mw.per_round
                    .iter()
                    .map(|r| (r.speculated, r.conflicted))
                    .collect::<Vec<_>>(),
                "round trace diverged at {workers} workers"
            );
        }
    }

    #[test]
    fn every_strategy_and_ordering_is_deterministic_and_valid() {
        let g = synth::erdos_renyi(800, 4800, 11);
        for selection in [
            Selection::FirstFit,
            Selection::StaggeredFirstFit,
            Selection::LeastUsed,
            Selection::RandomX(3),
        ] {
            for ordering in [Ordering::Natural, Ordering::LargestFirst, Ordering::Random] {
                let cfg = DataParConfig {
                    ordering,
                    selection,
                    ..small_cfg(9, 128)
                };
                let (c1, _) = color_graph_on(&WorkerPool::new(1), &g, &cfg).unwrap();
                let (c4, _) = color_graph_on(&WorkerPool::new(4), &g, &cfg).unwrap();
                c1.validate(&g).unwrap();
                assert_eq!(
                    c1.colors, c4.colors,
                    "{selection:?}/{ordering:?} not worker-count independent"
                );
            }
        }
    }

    #[test]
    fn first_fit_stays_within_max_degree_plus_one() {
        let g = synth::erdos_renyi(500, 3000, 5);
        let (c, _) = color_graph(&g, &small_cfg(13, 32)).unwrap();
        c.validate(&g).unwrap();
        assert!(
            c.num_colors() <= g.max_degree() + 1,
            "{} colors > Δ+1 = {}",
            c.num_colors(),
            g.max_degree() + 1
        );
    }

    #[test]
    fn cancelled_token_stops_at_the_round_boundary() {
        use crate::util::cancel::{CancelToken, StopCause};
        let g = synth::path(64);
        let cfg = DataParConfig::default();
        // pre-cancelled: observed before round 1, nothing speculated
        let tok = CancelToken::new();
        tok.cancel();
        let (c, m, stopped) =
            color_graph_cancellable(pool::global(), &g, &cfg, Some(&tok), &mut |_, _| {}).unwrap();
        assert_eq!(stopped, Some(StopCause::Cancelled));
        assert_eq!(m.rounds, 0);
        assert!(c.colors.iter().all(|&x| x == UNCOLORED));
        // live token: bit-for-bit the uncancellable path, stop is None
        let live = CancelToken::new();
        let (c2, m2, s2) =
            color_graph_cancellable(pool::global(), &g, &cfg, Some(&live), &mut |_, _| {}).unwrap();
        let (c3, m3) = color_graph(&g, &cfg).unwrap();
        assert_eq!(s2, None);
        assert_eq!(c2.colors, c3.colors);
        assert_eq!(m2.rounds, m3.rounds);
    }

    #[test]
    fn observer_sees_every_round() {
        let g = synth::fem_like(600, 8.0, 20, 0.05, 1, "dp-obs");
        let mut trace: Vec<(u32, u64)> = Vec::new();
        let cfg = small_cfg(21, 64);
        let (_, m) = color_graph_with(pool::global(), &g, &cfg, &mut |r, k| {
            trace.push((r, k));
        })
        .unwrap();
        assert_eq!(trace.len() as u32, m.rounds);
        assert_eq!(trace.last().unwrap().1, 0, "last round must be clean");
        for (i, (r, k)) in trace.iter().enumerate() {
            assert_eq!(*r, i as u32 + 1);
            assert_eq!(*k, m.per_round[i].conflicted);
        }
    }
}
