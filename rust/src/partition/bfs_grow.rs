//! BFS-grow partitioner with greedy boundary refinement — the ParMETIS
//! stand-in for the real-world graphs (DESIGN.md §1).
//!
//! Phase 1 grows `k` regions breadth-first from spread-out seeds under a
//! strict size cap, which yields connected, low-cut parts on mesh-like
//! graphs. Phase 2 does a few passes of greedy boundary-vertex migration
//! (move a vertex to the neighboring part that reduces cut, subject to the
//! balance cap) — a light Kernighan-Lin-style refinement.

use super::Partition;
use crate::graph::{CsrGraph, VertexId};
use crate::util::Rng;
use std::collections::VecDeque;

const UNASSIGNED: u32 = u32::MAX;
/// Allowed size slack over perfect balance.
const BALANCE_SLACK: f64 = 1.03;
const REFINE_PASSES: usize = 4;

pub fn partition(g: &CsrGraph, num_parts: usize, seed: u64) -> Partition {
    assert!(num_parts > 0);
    let n = g.num_vertices();
    if num_parts == 1 || n == 0 {
        return Partition::new(vec![0; n], num_parts.max(1));
    }
    let cap = ((n as f64 / num_parts as f64) * BALANCE_SLACK).ceil() as usize;
    let cap = cap.max(1);

    let mut parts = vec![UNASSIGNED; n];
    let mut sizes = vec![0usize; num_parts];
    let mut rng = Rng::new(seed);

    // Seeds: pseudo-random spread (one try list per part; collisions fall
    // back to a linear scan for an unassigned vertex).
    let mut queues: Vec<VecDeque<VertexId>> = (0..num_parts).map(|_| VecDeque::new()).collect();
    let mut scan_cursor = 0usize;
    let seed_part = |p: usize,
                         parts: &mut Vec<u32>,
                         sizes: &mut Vec<usize>,
                         queues: &mut Vec<VecDeque<VertexId>>,
                         rng: &mut Rng,
                         scan_cursor: &mut usize|
     -> bool {
        for _ in 0..32 {
            let s = rng.range(0, n);
            if parts[s] == UNASSIGNED {
                parts[s] = p as u32;
                sizes[p] += 1;
                queues[p].push_back(s as VertexId);
                return true;
            }
        }
        while *scan_cursor < n {
            if parts[*scan_cursor] == UNASSIGNED {
                parts[*scan_cursor] = p as u32;
                sizes[p] += 1;
                queues[p].push_back(*scan_cursor as VertexId);
                return true;
            }
            *scan_cursor += 1;
        }
        false
    };
    for p in 0..num_parts.min(n) {
        seed_part(p, &mut parts, &mut sizes, &mut queues, &mut rng, &mut scan_cursor);
    }

    // Smallest-part-first growth: repeatedly let the smallest growable part
    // expand a chunk. This keeps parts balanced and never strands a region:
    // when every queue is dry but unassigned vertices remain (disconnected
    // components or capped fronts), the smallest part is reseeded there.
    let mut assigned: usize = sizes.iter().sum();
    const CHUNK: usize = 32;
    while assigned < n {
        // pick smallest part with a non-empty queue and room under the cap
        let candidate = (0..num_parts)
            .filter(|&p| !queues[p].is_empty() && sizes[p] < cap)
            .min_by_key(|&p| sizes[p]);
        match candidate {
            Some(p) => {
                let mut grabbed = 0usize;
                while grabbed < CHUNK && sizes[p] < cap {
                    let Some(u) = queues[p].pop_front() else { break };
                    for &v in g.neighbors(u) {
                        if parts[v as usize] == UNASSIGNED && sizes[p] < cap {
                            parts[v as usize] = p as u32;
                            sizes[p] += 1;
                            assigned += 1;
                            grabbed += 1;
                            queues[p].push_back(v);
                        }
                    }
                }
            }
            None => {
                // all growable queues dry: reseed the globally smallest part
                // (raising the cap if even that part is full — can only
                // happen via rounding at tiny n).
                let p = (0..num_parts).min_by_key(|&p| sizes[p]).unwrap();
                if sizes[p] >= cap {
                    // every part is at cap but vertices remain: relax
                    // (bounded: each relax assigns at least one vertex)
                    let p = (0..num_parts).min_by_key(|&p| sizes[p]).unwrap();
                    if seed_part(p, &mut parts, &mut sizes, &mut queues, &mut rng, &mut scan_cursor)
                    {
                        assigned += 1;
                    }
                    continue;
                }
                if seed_part(p, &mut parts, &mut sizes, &mut queues, &mut rng, &mut scan_cursor) {
                    assigned += 1;
                }
            }
        }
    }

    // Greedy boundary refinement.
    let mut gains_scratch = vec![0i64; num_parts];
    for _ in 0..REFINE_PASSES {
        let mut moved = 0usize;
        for u in 0..n {
            let pu = parts[u];
            let neigh = g.neighbors(u as VertexId);
            if neigh.is_empty() {
                continue;
            }
            // count neighbors per part (sparse touch + undo)
            let mut touched: Vec<u32> = Vec::with_capacity(4);
            for &v in neigh {
                let pv = parts[v as usize];
                if gains_scratch[pv as usize] == 0 {
                    touched.push(pv);
                }
                gains_scratch[pv as usize] += 1;
            }
            let own = gains_scratch[pu as usize];
            let mut best_part = pu;
            let mut best_gain = 0i64;
            for &tp in &touched {
                if tp != pu {
                    let gain = gains_scratch[tp as usize] - own;
                    if gain > best_gain && sizes[tp as usize] < cap {
                        best_gain = gain;
                        best_part = tp;
                    }
                }
            }
            for &tp in &touched {
                gains_scratch[tp as usize] = 0;
            }
            if best_part != pu {
                sizes[pu as usize] -= 1;
                sizes[best_part as usize] += 1;
                parts[u] = best_part;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }

    Partition::new(parts, num_parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;
    use crate::partition::{block, metrics};

    #[test]
    fn all_assigned_and_balanced() {
        let g = synth::grid2d(40, 40);
        let p = partition(&g, 8, 1);
        assert!(p.parts.iter().all(|&x| x < 8));
        let m = metrics(&g, &p);
        assert!(m.imbalance <= 1.2, "imbalance {}", m.imbalance);
    }

    #[test]
    fn beats_block_on_mesh() {
        // On a locality-heavy mesh with shuffled... actually grid ids are
        // already ordered, so block is decent; compare on the FEM generator.
        let g = synth::fem_like(8000, 12.0, 30, 0.0, 3, "fem");
        let pb = block::partition(&g, 16);
        let pg = partition(&g, 16, 3);
        let mb = metrics(&g, &pb);
        let mg = metrics(&g, &pg);
        // BFS-grow should not be dramatically worse; on meshes it is usually
        // better or comparable.
        assert!(
            (mg.edge_cut as f64) < 1.5 * mb.edge_cut as f64,
            "bfs cut {} vs block cut {}",
            mg.edge_cut,
            mb.edge_cut
        );
    }

    #[test]
    fn single_part() {
        let g = synth::path(10);
        let p = partition(&g, 1, 0);
        assert_eq!(metrics(&g, &p).edge_cut, 0);
    }

    #[test]
    fn handles_disconnected() {
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new(100);
        // two components + isolated vertices
        for i in 0..40u32 {
            b.add_edge(i, (i + 1) % 41);
        }
        for i in 50..90u32 {
            b.add_edge(i, i + 1);
        }
        let g = b.build("disc");
        let p = partition(&g, 4, 7);
        assert!(p.parts.iter().all(|&x| x < 4));
        let m = metrics(&g, &p);
        assert!(m.imbalance < 1.6, "imbalance {}", m.imbalance);
    }

    #[test]
    fn deterministic() {
        let g = synth::grid2d(20, 20);
        let a = partition(&g, 4, 9);
        let b = partition(&g, 4, 9);
        assert_eq!(a.parts, b.parts);
    }
}
