//! Graph partitioning substrate (the ParMETIS stand-in).
//!
//! The distributed framework only consumes a `Partition` (vertex → part
//! map); the paper partitions real-world graphs with ParMETIS (good cuts)
//! and RMAT graphs with block partitioning. We provide both classes:
//! [`block`] and the BFS-grow partitioner in [`bfs_grow`] with boundary
//! refinement.

pub mod bfs_grow;
pub mod block;

use crate::graph::{CsrGraph, VertexId};

/// A vertex → part assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub parts: Vec<u32>,
    pub num_parts: usize,
}

impl Partition {
    pub fn new(parts: Vec<u32>, num_parts: usize) -> Self {
        debug_assert!(parts.iter().all(|&p| (p as usize) < num_parts));
        Partition { parts, num_parts }
    }

    #[inline]
    pub fn part_of(&self, v: VertexId) -> u32 {
        self.parts[v as usize]
    }

    /// Vertices owned by each part.
    pub fn members(&self) -> Vec<Vec<VertexId>> {
        let mut m = vec![Vec::new(); self.num_parts];
        for (v, &p) in self.parts.iter().enumerate() {
            m[p as usize].push(v as VertexId);
        }
        m
    }

    /// Part sizes.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.num_parts];
        for &p in &self.parts {
            s[p as usize] += 1;
        }
        s
    }
}

/// Quality metrics of a partition, as used in the experiment logs.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionMetrics {
    /// Edges crossing parts.
    pub edge_cut: usize,
    /// Vertices with ≥1 neighbor in another part.
    pub boundary_vertices: usize,
    /// max part size / avg part size.
    pub imbalance: f64,
}

pub fn metrics(g: &CsrGraph, p: &Partition) -> PartitionMetrics {
    assert_eq!(g.num_vertices(), p.parts.len());
    let mut cut = 0usize;
    let mut boundary = 0usize;
    for u in 0..g.num_vertices() as VertexId {
        let pu = p.part_of(u);
        let mut is_boundary = false;
        for &v in g.neighbors(u) {
            if p.part_of(v) != pu {
                is_boundary = true;
                if u < v {
                    cut += 1;
                }
            }
        }
        if is_boundary {
            boundary += 1;
        }
    }
    let sizes = p.sizes();
    let max = sizes.iter().copied().max().unwrap_or(0) as f64;
    let avg = g.num_vertices() as f64 / p.num_parts as f64;
    PartitionMetrics {
        edge_cut: cut,
        boundary_vertices: boundary,
        imbalance: if avg > 0.0 { max / avg } else { 1.0 },
    }
}

/// Partitioner selector used by the CLI / config layer. `Hash` so the
/// session layer can key partition caches by `(partitioner, procs, seed)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Partitioner {
    Block,
    BfsGrow,
}

impl std::str::FromStr for Partitioner {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "block" => Ok(Partitioner::Block),
            "bfs" | "bfsgrow" | "bfs-grow" => Ok(Partitioner::BfsGrow),
            other => Err(format!("unknown partitioner {other:?} (block|bfs)")),
        }
    }
}

pub fn partition(g: &CsrGraph, method: Partitioner, num_parts: usize, seed: u64) -> Partition {
    match method {
        Partitioner::Block => block::partition(g, num_parts),
        Partitioner::BfsGrow => bfs_grow::partition(g, num_parts, seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    #[test]
    fn metrics_on_path() {
        let g = synth::path(4);
        let p = Partition::new(vec![0, 0, 1, 1], 2);
        let m = metrics(&g, &p);
        assert_eq!(m.edge_cut, 1);
        assert_eq!(m.boundary_vertices, 2);
        assert!((m.imbalance - 1.0).abs() < 1e-12);
    }

    #[test]
    fn members_and_sizes() {
        let p = Partition::new(vec![1, 0, 1, 1], 2);
        assert_eq!(p.sizes(), vec![1, 3]);
        assert_eq!(p.members()[0], vec![1]);
        assert_eq!(p.members()[1], vec![0, 2, 3]);
    }

    #[test]
    fn partitioner_from_str() {
        assert_eq!("block".parse::<Partitioner>().unwrap(), Partitioner::Block);
        assert_eq!("bfs".parse::<Partitioner>().unwrap(), Partitioner::BfsGrow);
        assert!("zzz".parse::<Partitioner>().is_err());
    }
}
