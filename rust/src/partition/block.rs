//! Block partitioning: contiguous ranges of vertex ids, sizes differing by
//! at most one. This is what the paper uses for the RMAT graphs.

use super::Partition;
use crate::graph::CsrGraph;

pub fn partition(g: &CsrGraph, num_parts: usize) -> Partition {
    assert!(num_parts > 0);
    let n = g.num_vertices();
    let base = n / num_parts;
    let extra = n % num_parts; // first `extra` parts get one more vertex
    let mut parts = vec![0u32; n];
    let mut v = 0usize;
    for p in 0..num_parts {
        let sz = base + usize::from(p < extra);
        for _ in 0..sz {
            parts[v] = p as u32;
            v += 1;
        }
    }
    Partition::new(parts, num_parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;
    use crate::partition::metrics;

    #[test]
    fn balanced_sizes() {
        let g = synth::path(10);
        let p = partition(&g, 3);
        assert_eq!(p.sizes(), vec![4, 3, 3]);
    }

    #[test]
    fn contiguous_ranges() {
        let g = synth::path(10);
        let p = partition(&g, 3);
        // contiguity: parts vector is non-decreasing
        assert!(p.parts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn path_cut_equals_parts_minus_one() {
        let g = synth::path(100);
        let p = partition(&g, 8);
        assert_eq!(metrics(&g, &p).edge_cut, 7);
    }

    #[test]
    fn one_part_no_cut() {
        let g = synth::grid2d(5, 5);
        let p = partition(&g, 1);
        assert_eq!(metrics(&g, &p).edge_cut, 0);
        assert_eq!(metrics(&g, &p).boundary_vertices, 0);
    }

    #[test]
    fn more_parts_than_vertices() {
        let g = synth::path(3);
        let p = partition(&g, 5);
        assert_eq!(p.sizes(), vec![1, 1, 1, 0, 0]);
    }
}
