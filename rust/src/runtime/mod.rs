//! PJRT runtime: loads the HLO-text artifacts AOT-compiled by
//! `python/compile/aot.py` and exposes batched kernel-backed coloring to
//! the coordinator. Python never runs at request time — after
//! `make artifacts` the rust binary is self-contained.
//!
//! Note on threading: the `xla` crate's PJRT wrappers are not `Send`, so a
//! [`client::KernelRuntime`] lives on the thread that created it. The
//! kernel backend therefore drives whole-graph batch coloring from the
//! leader thread (`batch::BatchColorer`); the multi-process distributed
//! path uses the native implementation of the identical semantics (pinned
//! to the kernels by `rust/tests/runtime_kernels.rs` and `python/tests`).

pub mod batch;
pub mod client;

pub use batch::BatchColorer;
pub use client::KernelRuntime;
