//! PJRT bridge: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them once on the CPU PJRT client, and
//! execute them from the coordinator's hot path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids, which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate is only available on hosts with the PJRT toolchain, so
//! the real client is gated behind the off-by-default `xla` cargo feature.
//! The default (offline, zero-dependency) build ships a stub with the same
//! API whose `artifacts_present()` is always `false`, which makes every
//! kernel test, bench and example skip gracefully.

use std::path::PathBuf;

/// Fixed kernel-contract shapes — must match `python/compile/kernels/
/// coloring.py`.
pub const BATCH: usize = 256;
pub const DMAX: usize = 64;
pub const WORDS: usize = 8;
pub const NCOLORS: u32 = (WORDS as u32) * 32;
pub const EDGE_BATCH: usize = 4096;

/// Default artifact location: `$DGCOLOR_ARTIFACTS` or `artifacts/`.
fn artifacts_dir_impl() -> PathBuf {
    std::env::var("DGCOLOR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(feature = "xla")]
mod real {
    use super::*;
    use crate::util::error::{Context, Result};
    use std::path::Path;

    /// The compiled kernel set.
    pub struct KernelRuntime {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        first_fit: xla::PjRtLoadedExecutable,
        random_x: xla::PjRtLoadedExecutable,
        conflict: xla::PjRtLoadedExecutable,
        forbid_mask: xla::PjRtLoadedExecutable,
    }

    fn load_one(
        client: &xla::PjRtClient,
        dir: &Path,
        name: &str,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {path:?} — run `make artifacts` first"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))
    }

    impl KernelRuntime {
        /// Load and compile all artifacts from `dir` (typically `artifacts/`).
        pub fn load(dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(KernelRuntime {
                first_fit: load_one(&client, dir, "first_fit")?,
                random_x: load_one(&client, dir, "random_x")?,
                conflict: load_one(&client, dir, "conflict")?,
                forbid_mask: load_one(&client, dir, "forbid_mask")?,
                client,
            })
        }

        pub fn artifacts_dir() -> PathBuf {
            super::artifacts_dir_impl()
        }

        /// Whether the artifacts exist (tests skip gracefully when absent).
        pub fn artifacts_present() -> bool {
            Self::artifacts_dir().join("first_fit.hlo.txt").exists()
        }

        /// First-fit colors for one batch. `neigh_colors` is row-major
        /// [BATCH, DMAX] i32 with -1 padding.
        pub fn first_fit_batch(&self, neigh_colors: &[i32]) -> Result<Vec<i32>> {
            debug_assert_eq!(neigh_colors.len(), BATCH * DMAX);
            let nc = xla::Literal::vec1(neigh_colors).reshape(&[BATCH as i64, DMAX as i64])?;
            let out = self.first_fit.execute::<xla::Literal>(&[nc])?[0][0]
                .to_literal_sync()?
                .to_tuple1()?;
            Ok(out.to_vec::<i32>()?)
        }

        /// Random-X-Fit colors for one batch; `u` are uniforms in [0,1).
        pub fn random_x_batch(&self, neigh_colors: &[i32], u: &[f32], x: u32) -> Result<Vec<i32>> {
            debug_assert_eq!(neigh_colors.len(), BATCH * DMAX);
            debug_assert_eq!(u.len(), BATCH);
            let nc = xla::Literal::vec1(neigh_colors).reshape(&[BATCH as i64, DMAX as i64])?;
            let uu = xla::Literal::vec1(u);
            let xx = xla::Literal::vec1(&[x as i32]);
            let out = self.random_x.execute::<xla::Literal>(&[nc, uu, xx])?[0][0]
                .to_literal_sync()?
                .to_tuple1()?;
            Ok(out.to_vec::<i32>()?)
        }

        /// Forbidden bitsets for one batch: [BATCH, WORDS] u32 words (as i32).
        pub fn forbid_mask_batch(&self, neigh_colors: &[i32]) -> Result<Vec<i32>> {
            debug_assert_eq!(neigh_colors.len(), BATCH * DMAX);
            let nc = xla::Literal::vec1(neigh_colors).reshape(&[BATCH as i64, DMAX as i64])?;
            let out = self.forbid_mask.execute::<xla::Literal>(&[nc])?[0][0]
                .to_literal_sync()?
                .to_tuple1()?;
            Ok(out.to_vec::<i32>()?)
        }

        /// Batched conflict detection over EDGE_BATCH edges. Inputs are i32
        /// arrays (priorities are u32 bit-cast to i32). Returns (lose_u,
        /// lose_v) 0/1 flags.
        #[allow(clippy::too_many_arguments)]
        pub fn conflict_batch(
            &self,
            cu: &[i32],
            cv: &[i32],
            pu: &[i32],
            pv: &[i32],
            gu: &[i32],
            gv: &[i32],
        ) -> Result<(Vec<i32>, Vec<i32>)> {
            debug_assert_eq!(cu.len(), EDGE_BATCH);
            let args = [cu, cv, pu, pv, gu, gv].map(xla::Literal::vec1);
            let out = self.conflict.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            // return_tuple=True with two results → 2-tuple
            let (a, b) = out.to_tuple2()?;
            Ok((a.to_vec::<i32>()?, b.to_vec::<i32>()?))
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use super::*;
    use crate::util::error::Result;
    use std::path::Path;

    /// Offline stand-in for the PJRT kernel set: same API, never available.
    pub struct KernelRuntime {
        _priv: (),
    }

    impl KernelRuntime {
        pub fn load(_dir: &Path) -> Result<Self> {
            Err(crate::err!(
                "PJRT runtime unavailable: built without the `xla` cargo feature"
            ))
        }

        pub fn artifacts_dir() -> PathBuf {
            artifacts_dir_impl()
        }

        /// Always `false` in the offline build so callers skip gracefully.
        pub fn artifacts_present() -> bool {
            false
        }

        pub fn first_fit_batch(&self, _neigh_colors: &[i32]) -> Result<Vec<i32>> {
            Err(crate::err!("PJRT runtime unavailable"))
        }

        pub fn random_x_batch(
            &self,
            _neigh_colors: &[i32],
            _u: &[f32],
            _x: u32,
        ) -> Result<Vec<i32>> {
            Err(crate::err!("PJRT runtime unavailable"))
        }

        pub fn forbid_mask_batch(&self, _neigh_colors: &[i32]) -> Result<Vec<i32>> {
            Err(crate::err!("PJRT runtime unavailable"))
        }

        #[allow(clippy::too_many_arguments)]
        pub fn conflict_batch(
            &self,
            _cu: &[i32],
            _cv: &[i32],
            _pu: &[i32],
            _pv: &[i32],
            _gu: &[i32],
            _gv: &[i32],
        ) -> Result<(Vec<i32>, Vec<i32>)> {
            Err(crate::err!("PJRT runtime unavailable"))
        }
    }
}

#[cfg(feature = "xla")]
pub use real::KernelRuntime;
#[cfg(not(feature = "xla"))]
pub use stub::KernelRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contract_shapes_consistent() {
        assert_eq!(NCOLORS as usize, WORDS * 32);
        assert!(DMAX <= NCOLORS as usize);
        assert_eq!(BATCH % 2, 0);
        assert_eq!(EDGE_BATCH % 2, 0);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_reports_unavailable() {
        assert!(!KernelRuntime::artifacts_present());
        assert!(KernelRuntime::load(&KernelRuntime::artifacts_dir()).is_err());
    }
}
