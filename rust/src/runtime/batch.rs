//! Kernel-backed batch coloring: the L3 → L2/L1 integration.
//!
//! Colors a vertex sequence in BATCH-sized chunks through the AOT-compiled
//! PJRT executables. Within a chunk, tentative colors are assigned
//! data-parallel against *finalized* colors only, then intra-chunk
//! conflicts (two adjacent vertices in the same chunk) are resolved by
//! earliest-index priority and the losers are re-run — the shared-memory
//! speculative-coloring semantics (Gebremedhin-Manne) that DESIGN.md §2
//! adopts for the TPU formulation. Converges in ≤3 passes on all tested
//! graphs.
//!
//! Rows that exceed the kernel contract (degree > DMAX, or a forbidden
//! color ≥ NCOLORS) fall back to the native marker path and are counted.

use super::client::{KernelRuntime, BATCH, DMAX, EDGE_BATCH, NCOLORS};
use crate::color::{Color, Coloring, UNCOLORED};
use crate::graph::{CsrGraph, VertexId};
use crate::util::error::Result;
use crate::util::{ColorMarker, Rng};

pub struct BatchColorer {
    rt: KernelRuntime,
    rng: Rng,
    marker: ColorMarker,
    /// Rows handled natively because they exceeded the kernel contract.
    pub fallbacks: u64,
    /// Kernel invocations performed.
    pub kernel_calls: u64,
}

impl BatchColorer {
    pub fn new(rt: KernelRuntime, seed: u64) -> Self {
        BatchColorer {
            rt,
            rng: Rng::new(seed),
            marker: ColorMarker::new(DMAX * 2),
            fallbacks: 0,
            kernel_calls: 0,
        }
    }

    /// Greedily color `order` into `coloring` (UNCOLORED entries only are
    /// assigned; existing colors are respected as constraints).
    /// `x = None` → first fit; `x = Some(X)` → Random-X-Fit.
    pub fn color_sequence(
        &mut self,
        g: &CsrGraph,
        order: &[VertexId],
        x: Option<u32>,
        coloring: &mut Coloring,
    ) -> Result<()> {
        for chunk in order.chunks(BATCH) {
            self.color_chunk(g, chunk, x, coloring)?;
        }
        Ok(())
    }

    fn native_color(&mut self, g: &CsrGraph, v: VertexId, x: Option<u32>, coloring: &Coloring) -> Color {
        self.marker.next_epoch();
        for &u in g.neighbors(v) {
            let cu = coloring.get(u);
            if cu != UNCOLORED {
                self.marker.mark(cu);
            }
        }
        match x {
            None => self.marker.first_unmarked(),
            Some(x) => {
                let k = self.rng.below(x.max(1) as u64) as u32;
                self.marker.kth_unmarked(k)
            }
        }
    }

    fn color_chunk(
        &mut self,
        g: &CsrGraph,
        chunk: &[VertexId],
        x: Option<u32>,
        coloring: &mut Coloring,
    ) -> Result<()> {
        let chunk_pos: std::collections::HashMap<VertexId, usize> = chunk
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, i))
            .collect();
        let mut unresolved: Vec<usize> = (0..chunk.len()).collect();
        let mut passes = 0usize;
        while !unresolved.is_empty() {
            passes += 1;
            debug_assert!(passes <= BATCH + 1, "chunk fixup failed to converge");
            // build the padded neighbor-color matrix for unresolved rows
            let mut rows: Vec<usize> = Vec::with_capacity(unresolved.len());
            let mut matrix = vec![-1i32; BATCH * DMAX];
            for &ci in &unresolved {
                let v = chunk[ci];
                if g.degree(v) > DMAX {
                    // oversize row: native fallback, finalized immediately
                    let c = self.native_color(g, v, x, coloring);
                    coloring.set(v, c);
                    self.fallbacks += 1;
                    continue;
                }
                let row = rows.len();
                let base = row * DMAX;
                let mut w = 0usize;
                let mut oversize_color = false;
                for &u in g.neighbors(v) {
                    let cu = coloring.get(u);
                    if cu != UNCOLORED {
                        if cu >= NCOLORS {
                            oversize_color = true;
                            break;
                        }
                        matrix[base + w] = cu as i32;
                        w += 1;
                    }
                }
                if oversize_color {
                    let c = self.native_color(g, v, x, coloring);
                    coloring.set(v, c);
                    self.fallbacks += 1;
                    // clear the partially-written row
                    matrix[base..base + w].iter_mut().for_each(|m| *m = -1);
                    continue;
                }
                rows.push(ci);
            }
            if rows.is_empty() {
                break;
            }

            // run the kernel on the (padded) batch
            let colors = match x {
                None => {
                    self.kernel_calls += 1;
                    self.rt.first_fit_batch(&matrix)?
                }
                Some(xv) => {
                    let mut u = vec![0f32; BATCH];
                    for uu in u.iter_mut().take(rows.len()) {
                        *uu = self.rng.f64() as f32;
                    }
                    self.kernel_calls += 1;
                    self.rt.random_x_batch(&matrix, &u, xv)?
                }
            };
            for (row, &ci) in rows.iter().enumerate() {
                coloring.set(chunk[ci], colors[row] as Color);
            }

            // intra-chunk conflict fixup: earliest chunk index wins
            let mut next_unresolved = Vec::new();
            for &ci in &rows {
                let v = chunk[ci];
                let cv = coloring.get(v);
                let mut lost = false;
                for &u in g.neighbors(v) {
                    if u != v && coloring.get(u) == cv {
                        if let Some(&cj) = chunk_pos.get(&u) {
                            if cj < ci {
                                lost = true;
                                break;
                            }
                        }
                        // conflicts with out-of-chunk finalized vertices are
                        // impossible: their colors were in the mask
                    }
                }
                if lost {
                    coloring.set(v, UNCOLORED);
                    next_unresolved.push(ci);
                }
            }
            unresolved = next_unresolved;
        }
        Ok(())
    }

    /// Kernel-batched conflict detection over arbitrary-length edge lists
    /// (padded to EDGE_BATCH chunks). Mirrors `dist::framework::loses`.
    #[allow(clippy::type_complexity)]
    pub fn detect_conflicts(
        &mut self,
        edges: &[(u32, u32)],
        colors: &Coloring,
        seed: u64,
    ) -> Result<(Vec<u32>, Vec<u32>)> {
        use crate::util::rng::mix64;
        let mut lose_u = Vec::new();
        let mut lose_v = Vec::new();
        for chunk in edges.chunks(EDGE_BATCH) {
            let mut cu = vec![-1i32; EDGE_BATCH];
            let mut cv = vec![-1i32; EDGE_BATCH];
            let mut pu = vec![0i32; EDGE_BATCH];
            let mut pv = vec![0i32; EDGE_BATCH];
            let mut gu = vec![0i32; EDGE_BATCH];
            let mut gv = vec![0i32; EDGE_BATCH];
            for (i, &(u, v)) in chunk.iter().enumerate() {
                cu[i] = colors.get(u) as i32;
                cv[i] = colors.get(v) as i32;
                pu[i] = (mix64(seed, u as u64) as u32) as i32;
                pv[i] = (mix64(seed, v as u64) as u32) as i32;
                gu[i] = u as i32;
                gv[i] = v as i32;
            }
            self.kernel_calls += 1;
            let (lu, lv) = self.rt.conflict_batch(&cu, &cv, &pu, &pv, &gu, &gv)?;
            for (i, &(u, v)) in chunk.iter().enumerate() {
                if lu[i] != 0 {
                    lose_u.push(u);
                }
                if lv[i] != 0 {
                    lose_v.push(v);
                }
            }
        }
        Ok((lose_u, lose_v))
    }
}
