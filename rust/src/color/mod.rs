//! Sequential coloring core: the `Coloring` type, vertex-visit orderings,
//! color-selection strategies, greedy coloring (Algorithm 1 of the paper)
//! and Culberson iterated-greedy recoloring with the paper's color-class
//! permutation schedules.

pub mod coloring;
pub mod distance2;
pub mod greedy;
pub mod order;
pub mod recolor;
pub mod select;

pub use coloring::{Color, Coloring, UNCOLORED};
pub use greedy::greedy_color;
pub use order::Ordering;
pub use recolor::{Permutation, RecolorSchedule};
pub use select::Selection;
