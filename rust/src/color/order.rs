//! Vertex-visit orderings (paper §2.1, §2.2.1).
//!
//! * **Natural** — storage order (the paper's "unordered").
//! * **LargestFirst** — Welsh-Powell: non-increasing degree, O(|V|) via
//!   counting sort by degree.
//! * **SmallestLast** — Matula-Beck: repeatedly remove a minimum-*residual*-
//!   degree vertex, order backwards; O(|E|) with a bucket structure.
//! * **IncidenceDegree** — dynamic: next vertex = most already-ordered
//!   neighbors (a static-ordering approximation of the dynamic heuristic,
//!   computed the same bucketed way).
//! * **InternalFirst / BoundaryFirst** — the distributed framework's
//!   partition-aware orders: interior vertices before boundary vertices or
//!   vice versa (ties in natural order).
//! * **Random** — uniform shuffle (used by tests and as a baseline).

use crate::graph::{CsrGraph, VertexId};
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ordering {
    Natural,
    LargestFirst,
    SmallestLast,
    IncidenceDegree,
    InternalFirst,
    BoundaryFirst,
    Random,
}

impl std::str::FromStr for Ordering {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "natural" | "nat" | "n" => Ok(Ordering::Natural),
            "largestfirst" | "lf" => Ok(Ordering::LargestFirst),
            "smallestlast" | "sl" => Ok(Ordering::SmallestLast),
            "incidencedegree" | "id" => Ok(Ordering::IncidenceDegree),
            "internalfirst" | "if" | "internal" => Ok(Ordering::InternalFirst),
            "boundaryfirst" | "bf" | "boundary" => Ok(Ordering::BoundaryFirst),
            "random" | "rand" => Ok(Ordering::Random),
            other => Err(format!(
                "unknown ordering {other:?} (nat|lf|sl|id|if|bf|random)"
            )),
        }
    }
}

impl Ordering {
    pub fn short_name(&self) -> &'static str {
        match self {
            Ordering::Natural => "NAT",
            Ordering::LargestFirst => "LF",
            Ordering::SmallestLast => "SL",
            Ordering::IncidenceDegree => "ID",
            Ordering::InternalFirst => "I",
            Ordering::BoundaryFirst => "B",
            Ordering::Random => "RND",
        }
    }
}

/// Compute a visit order over `verts` (a subset of the graph's vertices —
/// in the distributed setting each processor orders only the vertices it
/// owns, using only locally-known structure, exactly as in the paper).
///
/// `is_boundary(v)` is consulted only by Internal/Boundary-first.
pub fn compute_order(
    g: &CsrGraph,
    verts: &[VertexId],
    ordering: Ordering,
    is_boundary: impl Fn(VertexId) -> bool,
    rng: &mut Rng,
) -> Vec<VertexId> {
    match ordering {
        Ordering::Natural => verts.to_vec(),
        Ordering::Random => {
            let mut v = verts.to_vec();
            rng.shuffle(&mut v);
            v
        }
        Ordering::LargestFirst => largest_first(g, verts),
        Ordering::SmallestLast => smallest_last(g, verts),
        Ordering::IncidenceDegree => incidence_degree(g, verts),
        Ordering::InternalFirst => {
            let (mut int, bnd): (Vec<_>, Vec<_>) =
                verts.iter().partition(|&&v| !is_boundary(v));
            int.extend(bnd);
            int
        }
        Ordering::BoundaryFirst => {
            let (mut bnd, int): (Vec<_>, Vec<_>) =
                verts.iter().partition(|&&v| is_boundary(v));
            bnd.extend(int);
            bnd
        }
    }
}

/// Welsh-Powell largest-first via counting sort on degree — O(|verts| + Δ).
/// Stable within equal degrees (natural order preserved).
fn largest_first(g: &CsrGraph, verts: &[VertexId]) -> Vec<VertexId> {
    let max_d = verts.iter().map(|&v| g.degree(v)).max().unwrap_or(0);
    let mut buckets: Vec<Vec<VertexId>> = vec![Vec::new(); max_d + 1];
    for &v in verts {
        buckets[g.degree(v)].push(v);
    }
    let mut out = Vec::with_capacity(verts.len());
    for d in (0..=max_d).rev() {
        out.extend_from_slice(&buckets[d]);
    }
    out
}

/// Matula-Beck smallest-last with a bucketed min-residual-degree structure —
/// O(|E_local| + |verts|). Residual degrees count only edges inside `verts`.
fn smallest_last(g: &CsrGraph, verts: &[VertexId]) -> Vec<VertexId> {
    bucket_elimination(g, verts, /*smallest_last=*/ true)
}

/// Incidence-degree ordering: greedily pick the vertex with the most
/// already-ordered neighbors (ties: smaller residual degree first). Shares
/// the elimination machinery with SL (picking from the other end).
fn incidence_degree(g: &CsrGraph, verts: &[VertexId]) -> Vec<VertexId> {
    bucket_elimination(g, verts, /*smallest_last=*/ false)
}

/// Shared bucketed elimination. For `smallest_last`, repeatedly removes a
/// minimum-residual-degree vertex and prepends it (SL). Otherwise removes a
/// maximum-saturation vertex (# ordered neighbors) and appends it (ID).
fn bucket_elimination(g: &CsrGraph, verts: &[VertexId], smallest_last: bool) -> Vec<VertexId> {
    let nv = verts.len();
    if nv == 0 {
        return Vec::new();
    }
    // dense index over the subset
    let n = g.num_vertices();
    const ABSENT: u32 = u32::MAX;
    let mut idx = vec![ABSENT; n];
    for (i, &v) in verts.iter().enumerate() {
        idx[v as usize] = i as u32;
    }
    // key per subset-vertex: residual degree (SL) or saturation (ID)
    let mut key: Vec<u32> = verts
        .iter()
        .map(|&v| {
            if smallest_last {
                g.neighbors(v).iter().filter(|&&u| idx[u as usize] != ABSENT).count() as u32
            } else {
                0
            }
        })
        .collect();
    let max_key = if smallest_last {
        key.iter().copied().max().unwrap_or(0) as usize
    } else {
        verts
            .iter()
            .map(|&v| g.neighbors(v).iter().filter(|&&u| idx[u as usize] != ABSENT).count())
            .max()
            .unwrap_or(0)
    };
    // buckets by key, with lazy deletion via a "processed" flag
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); max_key + 1];
    for (i, &k) in key.iter().enumerate() {
        buckets[k as usize].push(i as u32);
    }
    let mut processed = vec![false; nv];
    let mut out: Vec<VertexId> = Vec::with_capacity(nv);
    let mut cursor: i64 = if smallest_last { 0 } else { max_key as i64 };

    for _ in 0..nv {
        // find the next unprocessed vertex at the current extreme key
        let i = loop {
            let b = cursor as usize;
            if let Some(&cand) = buckets[b].last() {
                if processed[cand as usize] || key[cand as usize] != b as u32 {
                    buckets[b].pop(); // stale entry
                    continue;
                }
                buckets[b].pop();
                break cand;
            }
            if smallest_last {
                cursor += 1;
            } else {
                cursor -= 1;
                if cursor < 0 {
                    cursor = 0;
                }
            }
        };
        processed[i as usize] = true;
        let v = verts[i as usize];
        out.push(v);
        // update neighbor keys
        for &u in g.neighbors(v) {
            let j = idx[u as usize];
            if j == ABSENT || processed[j as usize] {
                continue;
            }
            let newk = if smallest_last {
                key[j as usize].saturating_sub(1)
            } else {
                (key[j as usize] + 1).min(max_key as u32)
            };
            if newk != key[j as usize] {
                key[j as usize] = newk;
                buckets[newk as usize].push(j);
                if smallest_last {
                    cursor = cursor.min(newk as i64);
                } else {
                    cursor = cursor.max(newk as i64);
                }
            }
        }
    }
    if smallest_last {
        out.reverse(); // removal order is reversed to get smallest-LAST
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    fn no_boundary(_v: VertexId) -> bool {
        false
    }

    fn all_verts(g: &CsrGraph) -> Vec<VertexId> {
        (0..g.num_vertices() as VertexId).collect()
    }

    #[test]
    fn natural_is_identity() {
        let g = synth::path(5);
        let mut rng = Rng::new(1);
        let o = compute_order(&g, &all_verts(&g), Ordering::Natural, no_boundary, &mut rng);
        assert_eq!(o, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn lf_sorts_by_degree_desc() {
        let g = synth::star(5); // center 0 has degree 4
        let mut rng = Rng::new(1);
        let o = compute_order(&g, &all_verts(&g), Ordering::LargestFirst, no_boundary, &mut rng);
        assert_eq!(o[0], 0);
        let degs: Vec<usize> = o.iter().map(|&v| g.degree(v)).collect();
        assert!(degs.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn sl_on_star_puts_center_first() {
        // SL removes min-degree (leaves) first, so the center ends up FIRST
        // in the final order.
        let g = synth::star(6);
        let mut rng = Rng::new(1);
        let o = compute_order(&g, &all_verts(&g), Ordering::SmallestLast, no_boundary, &mut rng);
        assert_eq!(o.len(), 6);
        // Leaves (min residual degree) are removed first, so the center is
        // ordered at/near the front (tie handling may interleave one leaf).
        let pos = o.iter().position(|&v| v == 0).unwrap();
        assert!(pos <= 1, "center should be ordered first-ish, got {o:?}");
        // and the very last ordered vertex is a leaf
        assert_ne!(*o.last().unwrap(), 0);
    }

    #[test]
    fn sl_is_permutation_on_random_graph() {
        let g = synth::erdos_renyi(300, 1500, 7);
        let mut rng = Rng::new(1);
        for ord in [
            Ordering::SmallestLast,
            Ordering::LargestFirst,
            Ordering::IncidenceDegree,
            Ordering::Random,
        ] {
            let mut o = compute_order(&g, &all_verts(&g), ord, no_boundary, &mut rng);
            o.sort_unstable();
            assert_eq!(o, all_verts(&g), "{ord:?} not a permutation");
        }
    }

    #[test]
    fn sl_degeneracy_on_grid() {
        // grid2d has degeneracy 2: SL greedy coloring should use ≤3 colors
        let g = synth::grid2d(12, 12);
        let mut rng = Rng::new(2);
        let order = compute_order(&g, &all_verts(&g), Ordering::SmallestLast, no_boundary, &mut rng);
        let coloring = crate::color::greedy::greedy_color_ordered(
            &g,
            &order,
            &mut crate::color::select::SelectState::new(crate::color::Selection::FirstFit, 64, 1),
        );
        assert!(coloring.num_colors() <= 3, "SL used {}", coloring.num_colors());
    }

    #[test]
    fn internal_boundary_split() {
        let g = synth::path(6);
        let mut rng = Rng::new(1);
        let is_b = |v: VertexId| v == 2 || v == 3;
        let o = compute_order(&g, &all_verts(&g), Ordering::InternalFirst, is_b, &mut rng);
        assert_eq!(o, vec![0, 1, 4, 5, 2, 3]);
        let o = compute_order(&g, &all_verts(&g), Ordering::BoundaryFirst, is_b, &mut rng);
        assert_eq!(o, vec![2, 3, 0, 1, 4, 5]);
    }

    #[test]
    fn subset_ordering_only_uses_subset() {
        let g = synth::star(8);
        let mut rng = Rng::new(1);
        // exclude the hub: SL over leaves only
        let verts: Vec<VertexId> = (1..8).collect();
        let o = compute_order(&g, &verts, Ordering::SmallestLast, no_boundary, &mut rng);
        assert_eq!(o.len(), 7);
        assert!(!o.contains(&0));
    }

    #[test]
    fn parses() {
        assert_eq!("sl".parse::<Ordering>().unwrap(), Ordering::SmallestLast);
        assert_eq!("LF".parse::<Ordering>().unwrap(), Ordering::LargestFirst);
        assert!("bogus".parse::<Ordering>().is_err());
    }
}
