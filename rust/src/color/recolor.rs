//! Sequential iterated-greedy recoloring (Culberson) with the paper's
//! color-class permutations and hybrid randomness schedules (§2.1, §4.2.1).
//!
//! One recoloring iteration: take the previous coloring's color classes,
//! order the classes by a permutation strategy, visit all vertices of each
//! class consecutively, and greedily first-fit recolor. Culberson's theorem:
//! with first-fit and class-consecutive visiting, the number of colors never
//! increases.

use crate::color::select::{SelectState, Selection};
use crate::color::{greedy, Coloring};
use crate::graph::{CsrGraph, VertexId};
use crate::util::Rng;

/// Color-class permutation strategies (paper §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Permutation {
    /// Reverse color order.
    Reverse,
    /// Non-increasing class size (largest classes first).
    NonIncreasing,
    /// Non-decreasing class size (smallest classes first) — the paper's best
    /// fixed permutation: small classes go early so large classes can absorb
    /// them.
    NonDecreasing,
    /// Uniform random permutation (Knuth shuffle).
    Random,
}

impl std::str::FromStr for Permutation {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "rv" | "reverse" => Ok(Permutation::Reverse),
            "ni" | "nonincreasing" => Ok(Permutation::NonIncreasing),
            "nd" | "nondecreasing" => Ok(Permutation::NonDecreasing),
            "rand" | "random" => Ok(Permutation::Random),
            other => Err(format!("unknown permutation {other:?} (rv|ni|nd|rand)")),
        }
    }
}

impl Permutation {
    pub fn short_name(&self) -> &'static str {
        match self {
            Permutation::Reverse => "RV",
            Permutation::NonIncreasing => "NI",
            Permutation::NonDecreasing => "ND",
            Permutation::Random => "RAND",
        }
    }

    /// Order the color classes `0..k` given their sizes. Ties and the base
    /// order are stable on color index, matching a deterministic
    /// implementation of the paper.
    pub fn permute_classes(&self, class_sizes: &[usize], rng: &mut Rng) -> Vec<u32> {
        let k = class_sizes.len();
        let mut order: Vec<u32> = (0..k as u32).collect();
        match self {
            Permutation::Reverse => order.reverse(),
            Permutation::NonIncreasing => {
                order.sort_by_key(|&c| std::cmp::Reverse(class_sizes[c as usize]))
            }
            Permutation::NonDecreasing => order.sort_by_key(|&c| class_sizes[c as usize]),
            Permutation::Random => rng.shuffle(&mut order),
        }
        order
    }
}

/// Which permutation to use at each recoloring iteration (paper Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecolorSchedule {
    /// The same permutation every iteration.
    Fixed(Permutation),
    /// ND, but RAND every `x`-th iteration (`ND-RAND%x`).
    NdRandEvery(u32),
    /// ND, but RAND at iterations 2, 4, 8, 16, ... (`ND-RAND%2^i`).
    NdRandPow2,
}

impl RecolorSchedule {
    /// Permutation for 1-based iteration `i`.
    pub fn permutation_at(&self, i: u32) -> Permutation {
        match self {
            RecolorSchedule::Fixed(p) => *p,
            RecolorSchedule::NdRandEvery(x) => {
                if *x > 0 && i % x == 0 {
                    Permutation::Random
                } else {
                    Permutation::NonDecreasing
                }
            }
            RecolorSchedule::NdRandPow2 => {
                if i >= 2 && i.is_power_of_two() {
                    Permutation::Random
                } else {
                    Permutation::NonDecreasing
                }
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            RecolorSchedule::Fixed(p) => p.short_name().to_string(),
            RecolorSchedule::NdRandEvery(x) => format!("ND-RAND%{x}"),
            RecolorSchedule::NdRandPow2 => "ND-RAND%2^i".to_string(),
        }
    }
}

impl std::str::FromStr for RecolorSchedule {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let l = s.to_ascii_lowercase();
        if let Some(x) = l.strip_prefix("nd-rand%") {
            if x == "2^i" || x == "pow2" {
                return Ok(RecolorSchedule::NdRandPow2);
            }
            return x
                .parse()
                .map(RecolorSchedule::NdRandEvery)
                .map_err(|e| e.to_string());
        }
        l.parse::<Permutation>().map(RecolorSchedule::Fixed)
    }
}

/// Build the recoloring vertex-visit order: classes in permuted order,
/// vertices of a class consecutive (ascending id within a class).
///
/// Counting-sort construction: one pass for class sizes, one scatter pass
/// into a single buffer — no per-class vectors. (§Perf: this took
/// `recolor_once` from 2.8× to ~1.4× the cost of a plain greedy pass.)
pub fn recolor_order(coloring: &Coloring, perm: Permutation, rng: &mut Rng) -> Vec<VertexId> {
    let sizes = coloring.class_sizes();
    let class_order = perm.permute_classes(&sizes, rng);
    // starting offset of each class in the permuted concatenation
    let mut offset = vec![0usize; sizes.len()];
    let mut acc = 0usize;
    for &c in &class_order {
        offset[c as usize] = acc;
        acc += sizes[c as usize];
    }
    let mut order = vec![0 as VertexId; acc];
    for (v, &c) in coloring.colors.iter().enumerate() {
        if c != crate::color::UNCOLORED {
            let slot = &mut offset[c as usize];
            order[*slot] = v as VertexId;
            *slot += 1;
        }
    }
    order
}

/// One sequential recoloring iteration (first-fit; Culberson's theorem needs
/// first-fit for monotonicity). The pass allocates only the visit order and
/// the output coloring: forbidden-color marking rides the stamped bit-set
/// marker inside [`SelectState`], reset per vertex in O(1).
pub fn recolor_once(
    g: &CsrGraph,
    coloring: &Coloring,
    perm: Permutation,
    rng: &mut Rng,
) -> Coloring {
    let order = recolor_order(coloring, perm, rng);
    let mut st = SelectState::new(Selection::FirstFit, coloring.num_colors() as u32, rng.next_u64());
    greedy::greedy_color_ordered(g, &order, &mut st)
}

/// Run `iterations` recoloring passes under `schedule`, recording the color
/// count after every iteration (index 0 = the input coloring).
pub fn recolor_iterate(
    g: &CsrGraph,
    initial: &Coloring,
    schedule: RecolorSchedule,
    iterations: u32,
    rng: &mut Rng,
) -> (Coloring, Vec<usize>) {
    let mut current = initial.clone();
    let mut trace = Vec::with_capacity(iterations as usize + 1);
    trace.push(current.num_colors());
    for i in 1..=iterations {
        let perm = schedule.permutation_at(i);
        current = recolor_once(g, &current, perm, rng);
        trace.push(current.num_colors());
    }
    (current, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::{greedy_color, Ordering};
    use crate::graph::synth;

    fn initial(g: &CsrGraph) -> Coloring {
        greedy_color(g, Ordering::Natural, Selection::FirstFit, 42)
    }

    #[test]
    fn monotone_noninc_colors_all_perms() {
        let g = synth::erdos_renyi(600, 4000, 11);
        let c0 = initial(&g);
        let mut rng = Rng::new(1);
        for perm in [
            Permutation::Reverse,
            Permutation::NonIncreasing,
            Permutation::NonDecreasing,
            Permutation::Random,
        ] {
            let mut c = c0.clone();
            for _ in 0..5 {
                let next = recolor_once(&g, &c, perm, &mut rng);
                next.validate(&g).unwrap();
                assert!(
                    next.num_colors() <= c.num_colors(),
                    "{perm:?} increased colors {} -> {}",
                    c.num_colors(),
                    next.num_colors()
                );
                c = next;
            }
        }
    }

    #[test]
    fn recolor_improves_bad_initial() {
        // Random-50 produces a deliberately bad initial coloring; a few ND
        // iterations should improve it substantially (paper §4.3).
        let g = synth::fem_like(4000, 12.0, 30, 0.0, 5, "fem");
        let bad = greedy_color(&g, Ordering::Natural, Selection::RandomX(50), 3);
        let mut rng = Rng::new(2);
        let (out, trace) = recolor_iterate(
            &g,
            &bad,
            RecolorSchedule::Fixed(Permutation::NonDecreasing),
            5,
            &mut rng,
        );
        out.validate(&g).unwrap();
        assert!(
            out.num_colors() * 2 <= bad.num_colors(),
            "trace {trace:?}"
        );
    }

    #[test]
    fn class_consecutive_order() {
        let g = synth::cycle(6);
        let c = initial(&g);
        let mut rng = Rng::new(3);
        let order = recolor_order(&c, Permutation::Reverse, &mut rng);
        // vertices of equal previous color must be consecutive
        let mut seen_colors = Vec::new();
        for v in &order {
            let col = c.get(*v);
            if seen_colors.last() != Some(&col) {
                assert!(
                    !seen_colors.contains(&col),
                    "class {col} split in order {order:?}"
                );
                seen_colors.push(col);
            }
        }
        assert_eq!(order.len(), 6);
    }

    #[test]
    fn schedule_selection() {
        let s = RecolorSchedule::NdRandEvery(5);
        assert_eq!(s.permutation_at(1), Permutation::NonDecreasing);
        assert_eq!(s.permutation_at(5), Permutation::Random);
        assert_eq!(s.permutation_at(10), Permutation::Random);
        let p = RecolorSchedule::NdRandPow2;
        assert_eq!(p.permutation_at(1), Permutation::NonDecreasing);
        assert_eq!(p.permutation_at(2), Permutation::Random);
        assert_eq!(p.permutation_at(4), Permutation::Random);
        assert_eq!(p.permutation_at(6), Permutation::NonDecreasing);
        assert_eq!(p.permutation_at(8), Permutation::Random);
    }

    #[test]
    fn schedule_parsing() {
        assert_eq!(
            "nd".parse::<RecolorSchedule>().unwrap(),
            RecolorSchedule::Fixed(Permutation::NonDecreasing)
        );
        assert_eq!(
            "ND-RAND%5".parse::<RecolorSchedule>().unwrap(),
            RecolorSchedule::NdRandEvery(5)
        );
        assert_eq!(
            "nd-rand%2^i".parse::<RecolorSchedule>().unwrap(),
            RecolorSchedule::NdRandPow2
        );
    }

    #[test]
    fn permute_classes_shapes() {
        let sizes = vec![5, 1, 3];
        let mut rng = Rng::new(4);
        assert_eq!(
            Permutation::Reverse.permute_classes(&sizes, &mut rng),
            vec![2, 1, 0]
        );
        assert_eq!(
            Permutation::NonIncreasing.permute_classes(&sizes, &mut rng),
            vec![0, 2, 1]
        );
        assert_eq!(
            Permutation::NonDecreasing.permute_classes(&sizes, &mut rng),
            vec![1, 2, 0]
        );
        let mut r = Permutation::Random.permute_classes(&sizes, &mut rng);
        r.sort_unstable();
        assert_eq!(r, vec![0, 1, 2]);
    }

    #[test]
    fn trace_starts_with_initial() {
        let g = synth::grid2d(10, 10);
        let c0 = initial(&g);
        let mut rng = Rng::new(9);
        let (_, trace) = recolor_iterate(
            &g,
            &c0,
            RecolorSchedule::Fixed(Permutation::NonDecreasing),
            3,
            &mut rng,
        );
        assert_eq!(trace.len(), 4);
        assert_eq!(trace[0], c0.num_colors());
        assert!(trace.windows(2).all(|w| w[1] <= w[0]));
    }
}
