//! Coloring storage, validation and quality metrics.

use crate::graph::{CsrGraph, VertexId};
use crate::util::pool;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Colors are 0-based `u32`s; the paper reports `num_colors = max + 1`.
pub type Color = u32;

/// Sentinel for "not yet colored".
pub const UNCOLORED: Color = u32::MAX;

/// A (possibly partial) vertex coloring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Coloring {
    pub colors: Vec<Color>,
}

impl Coloring {
    pub fn uncolored(n: usize) -> Self {
        Coloring {
            colors: vec![UNCOLORED; n],
        }
    }

    pub fn from_vec(colors: Vec<Color>) -> Self {
        Coloring { colors }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.colors.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.colors.is_empty()
    }

    #[inline]
    pub fn get(&self, v: VertexId) -> Color {
        self.colors[v as usize]
    }

    #[inline]
    pub fn set(&mut self, v: VertexId, c: Color) {
        self.colors[v as usize] = c;
    }

    pub fn is_complete(&self) -> bool {
        self.colors.iter().all(|&c| c != UNCOLORED)
    }

    /// Number of colors used (max color + 1 over colored vertices).
    pub fn num_colors(&self) -> usize {
        self.colors
            .iter()
            .filter(|&&c| c != UNCOLORED)
            .map(|&c| c as usize + 1)
            .max()
            .unwrap_or(0)
    }

    /// Vertices per color class; length = `num_colors()`.
    pub fn class_sizes(&self) -> Vec<usize> {
        let k = self.num_colors();
        let mut sizes = vec![0usize; k];
        for &c in &self.colors {
            if c != UNCOLORED {
                sizes[c as usize] += 1;
            }
        }
        sizes
    }

    /// Color classes as vertex lists, ordered by color.
    pub fn classes(&self) -> Vec<Vec<VertexId>> {
        let k = self.num_colors();
        let mut cls = vec![Vec::new(); k];
        for (v, &c) in self.colors.iter().enumerate() {
            if c != UNCOLORED {
                cls[c as usize].push(v as VertexId);
            }
        }
        cls
    }

    /// Check distance-1 validity: complete, and no edge is monochromatic.
    /// Returns the offending edge on failure.
    pub fn validate(&self, g: &CsrGraph) -> Result<(), ColoringError> {
        if self.colors.len() != g.num_vertices() {
            return Err(ColoringError::WrongSize {
                expected: g.num_vertices(),
                actual: self.colors.len(),
            });
        }
        for v in 0..g.num_vertices() as VertexId {
            if self.get(v) == UNCOLORED {
                return Err(ColoringError::Uncolored { vertex: v });
            }
        }
        for u in 0..g.num_vertices() as VertexId {
            let cu = self.get(u);
            for &v in g.neighbors(u) {
                if u < v && self.get(v) == cu {
                    return Err(ColoringError::Conflict { u, v, color: cu });
                }
            }
        }
        Ok(())
    }

    /// Count conflicting edges (diagnostics for speculative phases; the
    /// DataPar engine's validity checker and the pipeline's post-job
    /// validation fast path).
    ///
    /// Large graphs fan the sweep out over the process-wide worker pool
    /// (chunked vertex ranges, per-worker partial counts reduced at the
    /// end) — so this must not be called from inside a pool shard closure
    /// (see `util::pool`). Each undirected edge is counted exactly once,
    /// at its smaller endpoint.
    pub fn count_conflicts(&self, g: &CsrGraph) -> usize {
        const PARALLEL_MIN_VERTICES: usize = 1 << 14;
        let n = g.num_vertices();
        let pool = pool::global();
        if n < PARALLEL_MIN_VERTICES || pool.workers() == 1 {
            return self.count_conflicts_in(g, 0, n);
        }
        let shards = pool.workers();
        let chunk = n.div_ceil(shards);
        let partials: Vec<AtomicUsize> = (0..shards).map(|_| AtomicUsize::new(0)).collect();
        pool.scoped_run(shards, &|shard| {
            let lo = shard * chunk;
            let hi = ((shard + 1) * chunk).min(n);
            if lo < hi {
                partials[shard].store(self.count_conflicts_in(g, lo, hi), Ordering::Relaxed);
            }
        });
        partials.iter().map(|p| p.load(Ordering::Relaxed)).sum()
    }

    /// Serial kernel of [`count_conflicts`](Self::count_conflicts):
    /// conflicts among edges whose smaller endpoint lies in `lo..hi`.
    fn count_conflicts_in(&self, g: &CsrGraph, lo: usize, hi: usize) -> usize {
        let mut count = 0;
        for u in lo..hi {
            let cu = self.colors[u];
            if cu == UNCOLORED {
                continue;
            }
            for &v in g.neighbors(u as VertexId) {
                if v as usize > u && cu == self.colors[v as usize] {
                    count += 1;
                }
            }
        }
        count
    }

    /// Balance of the color distribution: max class size / avg class size.
    /// Random-X-Fit's selling point is a value near 1.
    pub fn balance(&self) -> f64 {
        let sizes = self.class_sizes();
        if sizes.is_empty() {
            return 1.0;
        }
        let max = *sizes.iter().max().unwrap() as f64;
        let avg = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        if avg > 0.0 {
            max / avg
        } else {
            1.0
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ColoringError {
    WrongSize { expected: usize, actual: usize },
    Uncolored { vertex: VertexId },
    Conflict { u: VertexId, v: VertexId, color: Color },
}

impl std::fmt::Display for ColoringError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ColoringError::WrongSize { expected, actual } => {
                write!(f, "coloring covers {actual} vertices, graph has {expected}")
            }
            ColoringError::Uncolored { vertex } => write!(f, "vertex {vertex} is uncolored"),
            ColoringError::Conflict { u, v, color } => {
                write!(f, "edge ({u},{v}) monochromatic with color {color}")
            }
        }
    }
}

impl std::error::Error for ColoringError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    #[test]
    fn validate_accepts_proper() {
        let g = synth::path(4);
        let c = Coloring::from_vec(vec![0, 1, 0, 1]);
        c.validate(&g).unwrap();
        assert_eq!(c.num_colors(), 2);
    }

    #[test]
    fn validate_rejects_conflict() {
        let g = synth::path(3);
        let c = Coloring::from_vec(vec![0, 0, 1]);
        assert_eq!(
            c.validate(&g),
            Err(ColoringError::Conflict { u: 0, v: 1, color: 0 })
        );
        assert_eq!(c.count_conflicts(&g), 1);
    }

    #[test]
    fn validate_rejects_partial() {
        let g = synth::path(3);
        let c = Coloring::from_vec(vec![0, UNCOLORED, 1]);
        assert!(matches!(
            c.validate(&g),
            Err(ColoringError::Uncolored { vertex: 1 })
        ));
    }

    #[test]
    fn class_accounting() {
        let c = Coloring::from_vec(vec![0, 1, 0, 2, 0]);
        assert_eq!(c.class_sizes(), vec![3, 1, 1]);
        assert_eq!(c.classes()[0], vec![0, 2, 4]);
        assert!((c.balance() - 3.0 / (5.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn parallel_conflict_count_matches_serial() {
        // large enough to take the pooled path (PARALLEL_MIN_VERTICES)
        let n = 1 << 15;
        let g = synth::path(n);
        let mut colors: Vec<Color> = (0..n as Color).map(|v| v % 2).collect();
        let c = Coloring::from_vec(colors.clone());
        assert_eq!(c.count_conflicts(&g), 0);
        // plant one monochromatic stretch: edges (100,101) and (101,102)
        colors[101] = 0;
        let c = Coloring::from_vec(colors);
        assert_eq!(c.count_conflicts(&g), 2);
        assert_eq!(c.count_conflicts_in(&g, 0, n), 2, "serial kernel agrees");
    }

    #[test]
    fn empty_and_uncolored() {
        let c = Coloring::uncolored(3);
        assert!(!c.is_complete());
        assert_eq!(c.num_colors(), 0);
        let e = Coloring::from_vec(vec![]);
        assert!(e.is_complete());
        assert_eq!(e.num_colors(), 0);
    }
}
