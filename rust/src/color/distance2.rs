//! Distance-2 greedy coloring — the paper's §1 notes that "all the
//! techniques and results presented in this paper can be extended to the
//! other variants of the graph coloring problem"; this module provides the
//! distance-2 variant (vertices within two hops get distinct colors, the
//! Jacobian-estimation use case) for the sequential core, including
//! iterated-greedy recoloring, sharing the same `Ordering`/`Selection`
//! machinery.

use crate::color::recolor::{recolor_order, Permutation};
use crate::color::select::{SelectState, Selection};
use crate::color::{Coloring, Ordering, UNCOLORED};
use crate::graph::{CsrGraph, VertexId};
use crate::util::Rng;

/// Greedy distance-2 coloring of the whole graph.
pub fn greedy_color_d2(
    g: &CsrGraph,
    ordering: Ordering,
    selection: Selection,
    seed: u64,
) -> Coloring {
    let verts: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
    let mut rng = Rng::new(seed);
    let order = crate::color::order::compute_order(g, &verts, ordering, |_| false, &mut rng);
    // distance-2 degree bound: Δ² + 1 colors suffice
    let d = g.max_degree() as u32;
    let mut st = SelectState::new(selection, d.saturating_mul(d) + 1, seed);
    let mut coloring = Coloring::uncolored(g.num_vertices());
    color_subset_d2(g, &order, &mut st, &mut coloring);
    coloring
}

/// Color `order` distance-2-properly into an existing partial coloring.
pub fn color_subset_d2(
    g: &CsrGraph,
    order: &[VertexId],
    st: &mut SelectState,
    coloring: &mut Coloring,
) {
    for &v in order {
        st.begin_vertex();
        for &u in g.neighbors(v) {
            let cu = coloring.get(u);
            if cu != UNCOLORED {
                st.forbid(cu);
            }
            for &w in g.neighbors(u) {
                if w != v {
                    let cw = coloring.get(w);
                    if cw != UNCOLORED {
                        st.forbid(cw);
                    }
                }
            }
        }
        let c = st.pick();
        coloring.set(v, c);
    }
}

/// Validate distance-2 properness. Returns the offending pair on failure.
pub fn validate_d2(g: &CsrGraph, c: &Coloring) -> Result<(), (VertexId, VertexId)> {
    for v in 0..g.num_vertices() as VertexId {
        let cv = c.get(v);
        for &u in g.neighbors(v) {
            if c.get(u) == cv {
                return Err((v, u));
            }
            for &w in g.neighbors(u) {
                if w != v && c.get(w) == cv && w > v {
                    return Err((v, w));
                }
            }
        }
    }
    Ok(())
}

/// One distance-2 iterated-greedy recoloring pass (class-consecutive,
/// first-fit) — Culberson's monotonicity argument carries over: visiting a
/// distance-2 color class (a distance-2 independent set) consecutively
/// under first-fit cannot increase the color count.
pub fn recolor_once_d2(
    g: &CsrGraph,
    coloring: &Coloring,
    perm: Permutation,
    rng: &mut Rng,
) -> Coloring {
    let order = recolor_order(coloring, perm, rng);
    let mut st = SelectState::new(
        Selection::FirstFit,
        coloring.num_colors() as u32,
        rng.next_u64(),
    );
    let mut out = Coloring::uncolored(g.num_vertices());
    color_subset_d2(g, &order, &mut st, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    #[test]
    fn star_needs_n_colors_d2() {
        // every pair of leaves is at distance 2 through the hub
        let g = synth::star(8);
        let c = greedy_color_d2(&g, Ordering::Natural, Selection::FirstFit, 1);
        validate_d2(&g, &c).unwrap();
        assert_eq!(c.num_colors(), 8);
    }

    #[test]
    fn path_needs_three_d2() {
        let g = synth::path(9);
        let c = greedy_color_d2(&g, Ordering::Natural, Selection::FirstFit, 1);
        validate_d2(&g, &c).unwrap();
        assert_eq!(c.num_colors(), 3);
    }

    #[test]
    fn d2_is_valid_d1() {
        // any distance-2 coloring is also a proper distance-1 coloring
        let g = synth::erdos_renyi(300, 1200, 5);
        let c = greedy_color_d2(&g, Ordering::SmallestLast, Selection::FirstFit, 2);
        validate_d2(&g, &c).unwrap();
        c.validate(&g).unwrap();
        // Δ²+1 bound
        let d = g.max_degree();
        assert!(c.num_colors() <= d * d + 1);
    }

    #[test]
    fn validate_catches_d2_conflict() {
        let g = synth::path(3); // 0-1-2: 0 and 2 are distance-2
        let c = Coloring::from_vec(vec![0, 1, 0]);
        assert_eq!(validate_d2(&g, &c), Err((0, 2)));
    }

    #[test]
    fn recolor_d2_monotone() {
        let g = synth::fem_like(800, 10.0, 24, 0.004, 7, "fem");
        let mut c = greedy_color_d2(&g, Ordering::Natural, Selection::RandomX(8), 3);
        validate_d2(&g, &c).unwrap();
        let mut rng = Rng::new(4);
        for _ in 0..4 {
            let next = recolor_once_d2(&g, &c, Permutation::NonDecreasing, &mut rng);
            validate_d2(&g, &next).unwrap();
            assert!(next.num_colors() <= c.num_colors());
            c = next;
        }
    }

    #[test]
    fn random_x_d2_valid() {
        let g = synth::grid2d(12, 12);
        let c = greedy_color_d2(&g, Ordering::Natural, Selection::RandomX(5), 9);
        validate_d2(&g, &c).unwrap();
    }
}
