//! Sequential greedy coloring — Algorithm 1 of the paper.
//!
//! The hot loop is allocation-free: forbidden colors are tracked in the
//! epoch-stamped [`ColorMarker`](crate::util::ColorMarker) owned by the
//! [`SelectState`], and neighbor scans stream straight over the CSR.

use crate::color::select::{SelectState, Selection};
use crate::color::{Coloring, Ordering, UNCOLORED};
use crate::graph::{CsrGraph, VertexId};
use crate::util::Rng;

/// Color `g` sequentially with the given ordering and selection strategy.
pub fn greedy_color(
    g: &CsrGraph,
    ordering: Ordering,
    selection: Selection,
    seed: u64,
) -> Coloring {
    let verts: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
    let mut rng = Rng::new(seed);
    let order = crate::color::order::compute_order(g, &verts, ordering, |_| false, &mut rng);
    let estimate = g.max_degree() as u32 + 1;
    let mut st = SelectState::new(selection, estimate, seed);
    greedy_color_ordered(g, &order, &mut st)
}

/// Color the whole graph visiting vertices exactly in `order`.
pub fn greedy_color_ordered(
    g: &CsrGraph,
    order: &[VertexId],
    st: &mut SelectState,
) -> Coloring {
    let mut coloring = Coloring::uncolored(g.num_vertices());
    color_subset(g, order, st, &mut coloring);
    coloring
}

/// Color `order`'s vertices into an existing (partial) coloring, treating
/// already-colored vertices as fixed. This is the inner primitive shared by
/// the sequential path, each distributed superstep, and recoloring steps.
#[inline]
pub fn color_subset(
    g: &CsrGraph,
    order: &[VertexId],
    st: &mut SelectState,
    coloring: &mut Coloring,
) {
    for &v in order {
        st.begin_vertex();
        for &u in g.neighbors(v) {
            let cu = coloring.get(u);
            if cu != UNCOLORED {
                st.forbid(cu);
            }
        }
        let c = st.pick();
        coloring.set(v, c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    #[test]
    fn path_two_colors() {
        let g = synth::path(10);
        let c = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 0);
        c.validate(&g).unwrap();
        assert_eq!(c.num_colors(), 2);
    }

    #[test]
    fn odd_cycle_three_colors() {
        let g = synth::cycle(7);
        let c = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 0);
        c.validate(&g).unwrap();
        assert_eq!(c.num_colors(), 3);
    }

    #[test]
    fn complete_graph_n_colors() {
        let g = synth::complete(6);
        let c = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 0);
        c.validate(&g).unwrap();
        assert_eq!(c.num_colors(), 6);
    }

    #[test]
    fn delta_plus_one_bound_all_strategies() {
        let g = synth::erdos_renyi(400, 2400, 5);
        let bound = g.max_degree() + 1;
        for sel in [
            Selection::FirstFit,
            Selection::StaggeredFirstFit,
            Selection::LeastUsed,
        ] {
            for ord in [Ordering::Natural, Ordering::LargestFirst, Ordering::SmallestLast] {
                let c = greedy_color(&g, ord, sel, 7);
                c.validate(&g).unwrap();
                assert!(
                    c.num_colors() <= bound,
                    "{ord:?}/{sel:?} used {} > Δ+1 = {bound}",
                    c.num_colors()
                );
            }
        }
    }

    #[test]
    fn random_x_valid_but_more_colors() {
        let g = synth::erdos_renyi(500, 3000, 9);
        let ff = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 1);
        let r50 = greedy_color(&g, Ordering::Natural, Selection::RandomX(50), 1);
        ff.validate(&g).unwrap();
        r50.validate(&g).unwrap();
        assert!(
            r50.num_colors() >= ff.num_colors(),
            "R50 {} < FF {}",
            r50.num_colors(),
            ff.num_colors()
        );
        // Random-X gives a flatter class-size distribution
        assert!(r50.balance() <= ff.balance() + 1e-9);
    }

    #[test]
    fn sl_competitive_with_nat_on_meshes() {
        // SL is a heuristic, not a dominance theorem; on FEM-like meshes it
        // is at worst marginally behind NAT and usually ahead (paper Tab. 1).
        let g = synth::fem_like(3000, 12.0, 30, 0.0, 3, "fem");
        let nat = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 0);
        let sl = greedy_color(&g, Ordering::SmallestLast, Selection::FirstFit, 0);
        nat.validate(&g).unwrap();
        sl.validate(&g).unwrap();
        assert!(
            sl.num_colors() <= nat.num_colors() + 1,
            "SL {} vs NAT {}",
            sl.num_colors(),
            nat.num_colors()
        );
    }

    #[test]
    fn color_subset_respects_fixed() {
        let g = synth::path(4);
        let mut c = Coloring::uncolored(4);
        c.set(1, 5);
        let mut st = SelectState::new(Selection::FirstFit, 4, 0);
        color_subset(&g, &[0, 2, 3], &mut st, &mut c);
        assert_eq!(c.get(1), 5);
        c.validate(&g).unwrap();
    }
}
