//! Color-selection strategies (paper §2.1, §3.2).
//!
//! Given the forbidden set of a vertex (colors of already-colored
//! neighbors), pick a permissible color:
//!
//! * **FirstFit** — smallest permissible color (Algorithm 1).
//! * **StaggeredFirstFit** — first fit starting from a per-processor offset
//!   inside an initial estimate `K` of the color count, wrapping around and
//!   overflowing past `K` only when the window is saturated (Bozdağ et al.).
//! * **LeastUsed** — the (locally) least-used permissible color among those
//!   seen so far, to balance class sizes.
//! * **RandomX(X)** — uniform among the first `X` permissible colors
//!   (Gebremedhin et al.; the paper's §3.2 contribution pairs this with
//!   recoloring).

use crate::color::Color;
use crate::util::{ColorMarker, Rng};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    FirstFit,
    /// Estimate-based staggered first fit; the estimate is supplied via
    /// `SelectState::new` (typically Δ+1 or the previous round's colors).
    StaggeredFirstFit,
    LeastUsed,
    RandomX(u32),
}

impl std::str::FromStr for Selection {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let l = s.to_ascii_lowercase();
        match l.as_str() {
            "firstfit" | "ff" | "f" => Ok(Selection::FirstFit),
            "staggered" | "sff" => Ok(Selection::StaggeredFirstFit),
            "leastused" | "lu" => Ok(Selection::LeastUsed),
            _ => {
                if let Some(x) = l.strip_prefix("randomx") {
                    x.parse().map(Selection::RandomX).map_err(|e| e.to_string())
                } else if let Some(x) = l.strip_prefix("random-") {
                    x.parse().map(Selection::RandomX).map_err(|e| e.to_string())
                } else if let Some(x) = l.strip_prefix('r') {
                    x.parse().map(Selection::RandomX).map_err(|e| e.to_string())
                } else {
                    Err(format!("unknown selection {s:?} (ff|sff|lu|r<X>)"))
                }
            }
        }
    }
}

impl Selection {
    pub fn short_name(&self) -> String {
        match self {
            Selection::FirstFit => "F".into(),
            Selection::StaggeredFirstFit => "SF".into(),
            Selection::LeastUsed => "LU".into(),
            Selection::RandomX(x) => format!("R{x}"),
        }
    }
}

/// Mutable per-processor state a selection strategy needs across a coloring
/// sweep: the forbidden-marker, local color-usage counts (LeastUsed), the
/// stagger offset (SFF) and the RNG (RandomX).
///
/// Forbidden colors are marked in the epoch-stamped bit-set
/// [`ColorMarker`]: `begin_vertex` invalidates all marks in O(1) (no
/// per-vertex clearing) and the palette scan reads 64 colors per word, so
/// a whole coloring sweep performs zero heap allocations after the marker
/// reaches the palette size.
#[derive(Clone)]
pub struct SelectState {
    pub strategy: Selection,
    pub marker: ColorMarker,
    usage: Vec<u64>,
    /// SFF initial-estimate window and this processor's starting offset.
    estimate: u32,
    offset: u32,
    rng: Rng,
}

impl SelectState {
    /// `estimate` seeds StaggeredFirstFit's window (ignored by others);
    /// `seed` feeds RandomX and the per-processor stagger offset.
    pub fn new(strategy: Selection, estimate: u32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5E1EC7);
        let estimate = estimate.max(1);
        let offset = (rng.below(estimate as u64)) as u32;
        SelectState {
            strategy,
            marker: ColorMarker::new(64),
            usage: Vec::new(),
            estimate,
            offset,
            rng,
        }
    }

    /// Forbid `c` for the current vertex. Call `begin_vertex` first.
    #[inline]
    pub fn forbid(&mut self, c: Color) {
        self.marker.mark(c);
    }

    #[inline]
    pub fn begin_vertex(&mut self) {
        self.marker.next_epoch();
    }

    /// Pick a color given the marks made since `begin_vertex`.
    pub fn pick(&mut self) -> Color {
        let c = match self.strategy {
            Selection::FirstFit => self.marker.first_unmarked(),
            Selection::StaggeredFirstFit => self.pick_staggered(),
            Selection::LeastUsed => self.pick_least_used(),
            Selection::RandomX(x) => {
                let k = self.rng.below(x.max(1) as u64) as u32;
                self.marker.kth_unmarked(k)
            }
        };
        // track usage for LeastUsed
        if matches!(self.strategy, Selection::LeastUsed) {
            let ci = c as usize;
            if ci >= self.usage.len() {
                self.usage.resize(ci + 1, 0);
            }
            self.usage[ci] += 1;
        }
        c
    }

    fn pick_staggered(&mut self) -> Color {
        // scan offset..estimate then 0..offset, else overflow past estimate
        for c in (self.offset..self.estimate).chain(0..self.offset) {
            if !self.marker.is_marked(c) {
                return c;
            }
        }
        let mut c = self.estimate;
        while self.marker.is_marked(c) {
            c += 1;
        }
        c
    }

    fn pick_least_used(&mut self) -> Color {
        // Among the colors used locally so far (the palette), pick the
        // permissible one with the lowest usage; only open a new color when
        // no existing color is permissible. Ties break toward lower colors.
        let palette = self.usage.len() as u32;
        let mut best: Option<(u64, Color)> = None;
        for c in 0..palette {
            if !self.marker.is_marked(c) {
                let u = self.usage[c as usize];
                if best.is_none_or(|(bu, _)| u < bu) {
                    best = Some((u, c));
                }
            }
        }
        match best {
            Some((_, c)) => c,
            None => self.marker.first_unmarked(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn forbid_all(st: &mut SelectState, cs: &[Color]) {
        st.begin_vertex();
        for &c in cs {
            st.forbid(c);
        }
    }

    #[test]
    fn first_fit_smallest() {
        let mut st = SelectState::new(Selection::FirstFit, 8, 1);
        forbid_all(&mut st, &[0, 1, 3]);
        assert_eq!(st.pick(), 2);
        forbid_all(&mut st, &[]);
        assert_eq!(st.pick(), 0);
    }

    #[test]
    fn random_x_in_first_x_permissible() {
        let mut st = SelectState::new(Selection::RandomX(5), 8, 2);
        for _ in 0..200 {
            forbid_all(&mut st, &[0, 2]);
            let c = st.pick();
            // first 5 permissible: 1,3,4,5,6
            assert!([1, 3, 4, 5, 6].contains(&c), "picked {c}");
        }
    }

    #[test]
    fn random_x_covers_choices() {
        let mut st = SelectState::new(Selection::RandomX(3), 8, 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            forbid_all(&mut st, &[1]);
            seen.insert(st.pick());
        }
        // first 3 permissible: 0,2,3
        assert_eq!(seen, [0, 2, 3].into_iter().collect());
    }

    #[test]
    fn random_1_is_first_fit() {
        let mut st = SelectState::new(Selection::RandomX(1), 8, 4);
        forbid_all(&mut st, &[0, 1]);
        assert_eq!(st.pick(), 2);
    }

    #[test]
    fn staggered_wraps_and_overflows() {
        let mut st = SelectState::new(Selection::StaggeredFirstFit, 4, 5);
        st.offset = 2; // deterministic for the test
        forbid_all(&mut st, &[2, 3]);
        assert_eq!(st.pick(), 0, "wraps to low colors");
        forbid_all(&mut st, &[0, 1, 2, 3]);
        assert_eq!(st.pick(), 4, "overflows past estimate");
    }

    #[test]
    fn least_used_prefers_rare_colors() {
        let mut st = SelectState::new(Selection::LeastUsed, 8, 6);
        forbid_all(&mut st, &[]);
        assert_eq!(st.pick(), 0, "empty palette opens color 0");
        forbid_all(&mut st, &[0]);
        assert_eq!(st.pick(), 1, "0 forbidden, palette exhausted, opens 1");
        forbid_all(&mut st, &[]);
        // usage now {0:1, 1:1}; tie breaks to lower color
        assert_eq!(st.pick(), 0);
        forbid_all(&mut st, &[0]);
        // usage {0:2, 1:1}; 0 forbidden anyway → picks 1
        assert_eq!(st.pick(), 1);
        forbid_all(&mut st, &[]);
        // usage {0:2, 1:2}; tie → 0... then LU keeps classes balanced
        assert_eq!(st.pick(), 0);
    }

    #[test]
    fn parses() {
        assert_eq!("ff".parse::<Selection>().unwrap(), Selection::FirstFit);
        assert_eq!("r5".parse::<Selection>().unwrap(), Selection::RandomX(5));
        assert_eq!("randomx10".parse::<Selection>().unwrap(), Selection::RandomX(10));
        assert_eq!("lu".parse::<Selection>().unwrap(), Selection::LeastUsed);
        assert!("x".parse::<Selection>().is_err());
    }
}
