//! # dgcolor — Distributed Graph Coloring with Iterative Recoloring
//!
//! A production-grade reproduction of *"On Distributed Graph Coloring with
//! Iterative Recoloring"* (Sarıyüce, Saule, Çatalyürek; CS.DC 2014) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — self-contained substrates this offline build cannot take from
//!   crates.io: PRNG, bitsets, statistics, CLI parsing, a micro-benchmark
//!   harness and a property-test driver.
//! * [`graph`] — CSR graphs, Matrix-Market I/O, RMAT and FEM-like generators.
//! * [`partition`] — block and BFS-grow partitioners (the ParMETIS stand-in).
//! * [`color`] — the sequential coloring core: vertex-visit orderings, color
//!   selection strategies, greedy coloring and Culberson iterated greedy
//!   (sequential recoloring) with all permutation schedules from the paper.
//! * [`dist`] — the distributed-memory runtime: message transport with exact
//!   message/byte accounting, an α-β network model driving per-process
//!   virtual clocks, the Bozdağ superstep framework (sync/async) with
//!   conflict-resolution rounds, distributed synchronous recoloring with the
//!   paper's piggybacked communication scheme, and asynchronous recoloring.
//! * [`shm`] — the shared-memory execution layer: the data-parallel
//!   speculative engine (`Engine::DataPar`) that skips the simulated
//!   transport entirely and colors flat arrays over the worker pool with
//!   a speculate/detect/resolve loop — the raw-speed path for graphs that
//!   fit one address space.
//! * [`runtime`] — the PJRT bridge: loads the AOT-compiled HLO artifacts
//!   produced by `python/compile/aot.py` and exposes batched kernel-backed
//!   color selection to the coordinator.
//! * [`coordinator`] — the user-facing layer: reusable
//!   [`Session`](coordinator::Session)s owning a graph plus cached
//!   partitions and cost models, validated [`Job`](coordinator::Job)s
//!   built fluently with presets and an early-stop policy, a streaming
//!   [`Event`](coordinator::Event)/[`Observer`](coordinator::Observer)
//!   layer over the pipeline (partition → initial coloring → recoloring →
//!   validation), and the experiment drivers behind every paper table and
//!   figure.
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for
//! reproduction results.

pub mod color;
pub mod coordinator;
pub mod dist;
pub mod graph;
pub mod partition;
pub mod runtime;
pub mod shm;
pub mod util;
