//! A persistent worker-thread pool with *scoped* execution.
//!
//! The BSP step engine (`dist::engine`) runs thousands of simulated
//! processes per job; spawning an OS thread per process — or even per run —
//! is exactly the oversubscription the engine exists to avoid. This pool
//! spawns `W` worker threads once per OS process ([`global`]) and reuses
//! them for every run: [`WorkerPool::scoped_run`] hands shard indices
//! `0..shards` to distinct workers, blocks until every shard finished, and
//! propagates the first panic. Because the caller blocks, the shard
//! closure may borrow stack data — the same contract as
//! `std::thread::scope`, without the per-call thread spawns.
//!
//! Rules of use:
//!
//! * `shards` must not exceed [`WorkerPool::workers`]; shards are placed on
//!   distinct workers so closures that synchronize with each other (the
//!   engine's per-step barrier) cannot self-deadlock.
//! * Runs are serialized: a second `scoped_run` (from another thread)
//!   waits for the first to finish. Never call `scoped_run` from inside a
//!   shard closure — that would wait on the pool from the pool.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Load counters of a [`WorkerPool`], read without locking. `runs` counts
/// completed `scoped_run`s; `saturated_runs` counts runs that arrived while
/// another run held the pool (the scheduler's saturation signal); `waiting`
/// is the instantaneous number of runs queued on the run lock right now
/// (the queue depth behind the pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    pub runs: u64,
    pub saturated_runs: u64,
    pub waiting: u64,
}

/// `&(dyn Fn(usize) + Sync)` with its lifetime erased so it can cross the
/// worker channels. Sound because [`WorkerPool::scoped_run`] blocks on the
/// completion latch before returning, keeping the referent alive for as
/// long as any worker may touch it.
struct ErasedFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the referent is `Sync` (shared calls from many threads are fine)
// and outlives every use (see `ErasedFn` docs), so sending the pointer to
// a worker thread is safe.
unsafe impl Send for ErasedFn {}

struct Job {
    f: ErasedFn,
    shard: usize,
    latch: Arc<Latch>,
}

/// Countdown latch that also carries the first panic payload.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn Any + Send>>,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState {
                remaining: n,
                panic: None,
            }),
            cv: Condvar::new(),
        }
    }

    fn complete(&self, panic: Option<Box<dyn Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        if st.panic.is_none() {
            st.panic = panic;
        }
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Option<Box<dyn Any + Send>> {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.panic.take()
    }
}

/// A fixed set of persistent worker threads. See the module docs.
pub struct WorkerPool {
    workers: Vec<Sender<Job>>,
    run_lock: Mutex<()>,
    runs: AtomicU64,
    saturated_runs: AtomicU64,
    waiting: AtomicU64,
}

impl WorkerPool {
    /// Spawn `threads.max(1)` named, detached worker threads.
    pub fn new(threads: usize) -> WorkerPool {
        let workers = (0..threads.max(1))
            .map(|i| {
                let (tx, rx) = channel::<Job>();
                std::thread::Builder::new()
                    .name(format!("bsp-worker-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            // SAFETY: see `ErasedFn` — the referent is alive
                            // until `complete` below releases the caller.
                            let f = unsafe { &*job.f.0 };
                            let r = catch_unwind(AssertUnwindSafe(|| f(job.shard)));
                            job.latch.complete(r.err());
                        }
                    })
                    .expect("failed to spawn pool worker");
                tx
            })
            .collect();
        WorkerPool {
            workers,
            run_lock: Mutex::new(()),
            runs: AtomicU64::new(0),
            saturated_runs: AtomicU64::new(0),
            waiting: AtomicU64::new(0),
        }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Current load counters (see [`PoolStats`]).
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            runs: self.runs.load(AtomicOrdering::Relaxed),
            saturated_runs: self.saturated_runs.load(AtomicOrdering::Relaxed),
            waiting: self.waiting.load(AtomicOrdering::Relaxed),
        }
    }

    /// Run `f(shard)` for every `shard` in `0..shards`, each on its own
    /// worker thread, and block until all finished. Panics in `f` are
    /// re-raised here (after every shard completed, so no worker is left
    /// touching caller data).
    pub fn scoped_run(&self, shards: usize, f: &(dyn Fn(usize) + Sync)) {
        if let Err(p) = self.try_scoped_run(shards, f) {
            resume_unwind(p);
        }
    }

    /// [`scoped_run`](Self::scoped_run) with the first shard panic handed
    /// back as an `Err` payload instead of re-raised: a worker panic never
    /// poisons the pool (the latch is always counted down), so the caller
    /// can turn it into a typed error and keep going.
    pub fn try_scoped_run(
        &self,
        shards: usize,
        f: &(dyn Fn(usize) + Sync),
    ) -> std::result::Result<(), Box<dyn Any + Send>> {
        assert!(
            shards >= 1 && shards <= self.workers.len(),
            "scoped_run wants {shards} shards but the pool has {} workers",
            self.workers.len()
        );
        // Saturation accounting: a run that cannot take the lock at once is
        // contending with an in-flight run. The counters feed the scheduler's
        // overload signal; they never affect execution.
        let _serial = match self.run_lock.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::WouldBlock) => {
                self.saturated_runs.fetch_add(1, AtomicOrdering::Relaxed);
                self.waiting.fetch_add(1, AtomicOrdering::Relaxed);
                let g = self.run_lock.lock().unwrap_or_else(|e| e.into_inner());
                self.waiting.fetch_sub(1, AtomicOrdering::Relaxed);
                g
            }
            Err(std::sync::TryLockError::Poisoned(e)) => e.into_inner(),
        };
        self.runs.fetch_add(1, AtomicOrdering::Relaxed);
        let latch = Arc::new(Latch::new(shards));
        // SAFETY: lifetime erasure only — the latch wait below outlives
        // every worker-side use of the reference.
        let erased: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        for (shard, tx) in self.workers[..shards].iter().enumerate() {
            tx.send(Job {
                f: ErasedFn(erased),
                shard,
                latch: Arc::clone(&latch),
            })
            .expect("pool worker thread died");
        }
        match latch.wait() {
            Some(p) => Err(p),
            None => Ok(()),
        }
    }
}

/// The process-wide pool, sized to the host's available parallelism and
/// created on first use. Every engine run and parallel local-graph build
/// shares these threads — nothing is spawned per run.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        WorkerPool::new(n)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn shards_run_concurrently_and_complete() {
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        // a barrier across all shards proves they run on distinct threads
        // at the same time (a sequential pool would deadlock here)
        let barrier = Barrier::new(4);
        pool.scoped_run(4, &|shard| {
            barrier.wait();
            hits.fetch_add(shard + 1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1 + 2 + 3 + 4);
    }

    #[test]
    fn pool_is_reusable_across_runs() {
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.scoped_run(2, &|_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn borrows_of_caller_data_work() {
        let pool = WorkerPool::new(3);
        let data: Vec<usize> = (0..300).collect();
        let slots: Vec<Mutex<usize>> = (0..3).map(|_| Mutex::new(0)).collect();
        pool.scoped_run(3, &|shard| {
            let mut sum = 0;
            let mut i = shard;
            while i < data.len() {
                sum += data[i];
                i += 3;
            }
            *slots[shard].lock().unwrap() = sum;
        });
        let total: usize = slots.iter().map(|m| *m.lock().unwrap()).sum();
        assert_eq!(total, 300 * 299 / 2);
    }

    #[test]
    fn panics_propagate_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.scoped_run(2, &|shard| {
                if shard == 1 {
                    panic!("shard boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must reach the caller");
        // the pool is still usable afterwards
        let ok = AtomicUsize::new(0);
        pool.scoped_run(2, &|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn try_scoped_run_returns_the_panic_payload() {
        let pool = WorkerPool::new(2);
        let r = pool.try_scoped_run(2, &|shard| {
            if shard == 0 {
                panic!("shard zero boom");
            }
        });
        let payload = r.expect_err("shard panic must surface as Err");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .expect("literal panic carries a &str payload");
        assert_eq!(msg, "shard zero boom");
        // the pool is not poisoned: a clean run still works
        let ok = AtomicUsize::new(0);
        pool.try_scoped_run(2, &|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        })
        .expect("clean run");
        assert_eq!(ok.load(Ordering::SeqCst), 2);
    }

    #[test]
    #[should_panic(expected = "shards")]
    fn too_many_shards_is_an_error() {
        let pool = WorkerPool::new(2);
        pool.scoped_run(3, &|_| {});
    }

    #[test]
    fn global_pool_has_at_least_one_worker() {
        assert!(global().workers() >= 1);
    }

    #[test]
    fn stats_count_runs_and_saturation() {
        let pool = Arc::new(WorkerPool::new(2));
        assert_eq!(pool.stats(), PoolStats::default());
        pool.scoped_run(2, &|_| {});
        let s = pool.stats();
        assert_eq!(s.runs, 1);
        assert_eq!(s.saturated_runs, 0, "an uncontended run is not saturation");
        assert_eq!(s.waiting, 0);

        // two threads race one pool: the loser of the run lock must be
        // counted as a saturated run
        let gate = Arc::new(Barrier::new(2));
        let inner = Arc::new(Barrier::new(3));
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let (pool, gate, inner) = (pool.clone(), gate.clone(), inner.clone());
                std::thread::spawn(move || {
                    gate.wait();
                    pool.scoped_run(2, &|_| {
                        // both shards + the peer run's submitter rendezvous,
                        // proving the peer arrived while this run was live
                        inner.wait();
                    });
                })
            })
            .collect();
        // the third participant: release the inner barrier only once both
        // runs were submitted (one is inside, one is queued on the lock)
        loop {
            let s = pool.stats();
            if s.saturated_runs >= 1 && s.waiting >= 1 {
                break;
            }
            std::thread::yield_now();
        }
        inner.wait();
        inner.wait(); // second run's shards
        for h in hs {
            h.join().unwrap();
        }
        let s = pool.stats();
        assert_eq!(s.runs, 3);
        assert_eq!(s.saturated_runs, 1);
        assert_eq!(s.waiting, 0, "nobody left queued");
    }
}
