//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Runs a closure with warmup, then either a fixed iteration count or until
//! a time budget is exhausted, and reports min/median/mean. Used by all
//! `rust/benches/*.rs` (which are `harness = false`).

use crate::util::stats;
use crate::util::table::fmt_secs;
use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop adding iterations once the measured total exceeds this budget.
    pub time_budget_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            time_budget_secs: 2.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_secs: Vec<f64>,
}

impl BenchResult {
    pub fn min(&self) -> f64 {
        stats::min(&self.samples_secs)
    }
    pub fn median(&self) -> f64 {
        stats::median(&self.samples_secs)
    }
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples_secs)
    }
    pub fn summary(&self) -> String {
        format!(
            "{:<40} min {:>10}  med {:>10}  mean {:>10}  (n={})",
            self.name,
            fmt_secs(self.min()),
            fmt_secs(self.median()),
            fmt_secs(self.mean()),
            self.samples_secs.len()
        )
    }
}

/// Benchmark `f`, which receives the iteration index and returns a value
/// that is black-boxed to prevent dead-code elimination.
pub fn bench<T, F: FnMut(usize) -> T>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for i in 0..cfg.warmup_iters {
        std::hint::black_box(f(i));
    }
    let mut samples = Vec::with_capacity(cfg.min_iters);
    let budget_start = Instant::now();
    let mut i = 0;
    while i < cfg.max_iters
        && (i < cfg.min_iters || budget_start.elapsed().as_secs_f64() < cfg.time_budget_secs)
    {
        let t = Instant::now();
        std::hint::black_box(f(i));
        samples.push(t.elapsed().as_secs_f64());
        i += 1;
    }
    let r = BenchResult {
        name: name.to_string(),
        samples_secs: samples,
    };
    println!("{}", r.summary());
    r
}

/// Run `f` once and report its duration (for long end-to-end experiments
/// where repetition is driven at a higher level).
pub fn once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t = Instant::now();
    let v = f();
    let secs = t.elapsed().as_secs_f64();
    println!("{:<40} {:>10}", name, fmt_secs(secs));
    (v, secs)
}

/// Whether the full-scale (paper-sized) workloads were requested.
pub fn full_scale() -> bool {
    std::env::var("REPRO_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            time_budget_secs: 10.0,
        };
        let r = bench("noop", &cfg, |i| i * 2);
        assert!(r.samples_secs.len() >= 3);
        assert!(r.min() <= r.mean() + 1e-12);
    }

    #[test]
    fn once_returns_value() {
        let (v, s) = once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
