//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Runs a closure with warmup, then either a fixed iteration count or until
//! a time budget is exhausted, and reports min/median/mean. Used by all
//! `rust/benches/*.rs` (which are `harness = false`).

use crate::util::stats;
use crate::util::table::fmt_secs;
use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop adding iterations once the measured total exceeds this budget.
    pub time_budget_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            time_budget_secs: 2.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_secs: Vec<f64>,
}

impl BenchResult {
    pub fn min(&self) -> f64 {
        stats::min(&self.samples_secs)
    }
    pub fn median(&self) -> f64 {
        stats::median(&self.samples_secs)
    }
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples_secs)
    }
    pub fn summary(&self) -> String {
        format!(
            "{:<40} min {:>10}  med {:>10}  mean {:>10}  (n={})",
            self.name,
            fmt_secs(self.min()),
            fmt_secs(self.median()),
            fmt_secs(self.mean()),
            self.samples_secs.len()
        )
    }
}

/// Benchmark `f`, which receives the iteration index and returns a value
/// that is black-boxed to prevent dead-code elimination.
pub fn bench<T, F: FnMut(usize) -> T>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for i in 0..cfg.warmup_iters {
        std::hint::black_box(f(i));
    }
    let mut samples = Vec::with_capacity(cfg.min_iters);
    let budget_start = Instant::now();
    let mut i = 0;
    while i < cfg.max_iters
        && (i < cfg.min_iters || budget_start.elapsed().as_secs_f64() < cfg.time_budget_secs)
    {
        let t = Instant::now();
        std::hint::black_box(f(i));
        samples.push(t.elapsed().as_secs_f64());
        i += 1;
    }
    let r = BenchResult {
        name: name.to_string(),
        samples_secs: samples,
    };
    println!("{}", r.summary());
    r
}

/// Machine-readable perf trajectory: collects [`BenchResult`]s and writes
/// the `BENCH_perf.json` format documented in DESIGN.md ("Memory
/// discipline on hot paths") —
/// `{"_meta": {"format": 1}, "<name>": {"min": s, "median": s, "iters": n}, ...}`
/// with times in seconds. Keys starting with `_` are metadata, not
/// benchmarks.
#[derive(Debug, Default)]
pub struct JsonReport {
    entries: Vec<(String, f64, f64, usize)>,
}

impl JsonReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, r: &BenchResult) {
        self.entries
            .push((r.name.clone(), r.min(), r.median(), r.samples_secs.len()));
    }

    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len());
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        // comma precedes each entry so an empty report is still valid JSON
        let mut s = String::from("{\n  \"_meta\": {\"format\": 1}");
        for (name, min, median, iters) in self.entries.iter() {
            s.push_str(&format!(
                ",\n  \"{}\": {{\"min\": {min:e}, \"median\": {median:e}, \"iters\": {iters}}}",
                esc(name)
            ));
        }
        s.push_str("\n}\n");
        s
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Run `f` once and report its duration (for long end-to-end experiments
/// where repetition is driven at a higher level).
pub fn once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t = Instant::now();
    let v = f();
    let secs = t.elapsed().as_secs_f64();
    println!("{:<40} {:>10}", name, fmt_secs(secs));
    (v, secs)
}

/// Whether the full-scale (paper-sized) workloads were requested.
pub fn full_scale() -> bool {
    std::env::var("REPRO_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            time_budget_secs: 10.0,
        };
        let r = bench("noop", &cfg, |i| i * 2);
        assert!(r.samples_secs.len() >= 3);
        assert!(r.min() <= r.mean() + 1e-12);
    }

    #[test]
    fn once_returns_value() {
        let (v, s) = once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn json_report_shape() {
        let mut rep = JsonReport::new();
        rep.record(&BenchResult {
            name: "a \"quoted\" bench".into(),
            samples_secs: vec![0.5, 0.25, 1.0],
        });
        rep.record(&BenchResult {
            name: "plain".into(),
            samples_secs: vec![2.0],
        });
        let j = rep.to_json();
        assert!(j.starts_with("{\n  \"_meta\": {\"format\": 1},\n"));
        assert!(j.contains(
            "\"a \\\"quoted\\\" bench\": {\"min\": 2.5e-1, \"median\": 5e-1, \"iters\": 3}"
        ));
        assert!(j.contains("\"plain\": {\"min\": 2e0, \"median\": 2e0, \"iters\": 1}"));
        assert!(j.trim_end().ends_with('}'));
        // exactly one comma between the two benchmark entries
        assert_eq!(j.matches("},\n").count(), 2); // after _meta and entry 1
    }

    #[test]
    fn json_report_empty_is_valid_json() {
        // no entries → no trailing comma after the _meta object
        let j = JsonReport::new().to_json();
        assert_eq!(j, "{\n  \"_meta\": {\"format\": 1}\n}\n");
    }
}
