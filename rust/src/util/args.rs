//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positionals and
//! subcommands. Typed getters parse on access and report errors with the
//! flag name.

use crate::util::error::{Context, Result};
use crate::{bail, err};
use std::collections::BTreeMap;
use std::str::FromStr;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a raw token list. A token `--k` followed by a token that does
    /// not start with `--` is an option; otherwise it's a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut a = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    a.options.insert(stripped.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positionals.push(t.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// First positional = subcommand; returns it plus the remaining args.
    pub fn subcommand(mut self) -> (Option<String>, Args) {
        if self.positionals.is_empty() {
            (None, self)
        } else {
            let sub = self.positionals.remove(0);
            (Some(sub), self)
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get_str(name).unwrap_or(default)
    }

    /// Typed option with default.
    pub fn get_or<T: FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .with_context(|| format!("invalid value {v:?} for --{name}")),
        }
    }

    /// Required typed option.
    pub fn require<T: FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        let v = self
            .options
            .get(name)
            .ok_or_else(|| err!("missing required option --{name}"))?;
        v.parse::<T>()
            .with_context(|| format!("invalid value {v:?} for --{name}"))
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get_str(name)
            .map(|s| {
                s.split(',')
                    .map(|x| x.trim().to_string())
                    .filter(|x| !x.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn options_and_flags() {
        let a = parse("color --graph g.mtx --procs 8 --verbose --ratio=0.5");
        assert_eq!(a.positionals, vec!["color"]);
        assert_eq!(a.get_str("graph"), Some("g.mtx"));
        assert_eq!(a.get_or("procs", 1usize).unwrap(), 8);
        assert_eq!(a.get_or("ratio", 0.0f64).unwrap(), 0.5);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn subcommand_split() {
        let (sub, rest) = parse("bench fig4 --procs 4").subcommand();
        assert_eq!(sub.as_deref(), Some("bench"));
        assert_eq!(rest.positionals, vec!["fig4"]);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("--n 10");
        assert_eq!(a.get_or("n", 0usize).unwrap(), 10);
        assert_eq!(a.get_or("m", 7usize).unwrap(), 7);
        assert!(a.require::<usize>("missing").is_err());
    }

    #[test]
    fn invalid_value_errors() {
        let a = parse("--n abc");
        assert!(a.get_or("n", 0usize).is_err());
    }

    #[test]
    fn lists() {
        let a = parse("--procs 1,2,4,8");
        assert_eq!(a.get_list("procs"), vec!["1", "2", "4", "8"]);
        assert!(a.get_list("none").is_empty());
    }

    #[test]
    fn flag_before_option_value_ambiguity() {
        // `--a --b v`: a is a flag, b an option
        let a = parse("--a --b v");
        assert!(a.has_flag("a"));
        assert_eq!(a.get_str("b"), Some("v"));
    }
}
