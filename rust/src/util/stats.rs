//! Statistics used by the experiment harness: the paper normalizes every
//! metric per-graph against a baseline and aggregates with a geometric mean.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean; 0 for empty input. Panics on non-positive entries in
/// debug builds (normalized metrics are always > 0).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive values");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Sample standard deviation; 0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Normalize each value against its per-key baseline, then geomean — the
/// paper's aggregation for the "real-world graphs" lines.
///
/// `values[i]` corresponds to `baselines[i]`.
pub fn normalized_geomean(values: &[f64], baselines: &[f64]) -> f64 {
    assert_eq!(values.len(), baselines.len());
    let normed: Vec<f64> = values
        .iter()
        .zip(baselines)
        .map(|(v, b)| v / b)
        .collect();
    geomean(&normed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        let g = geomean(&[2.0, 2.0, 2.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_le_mean() {
        let xs = [1.0, 3.0, 7.0, 9.0];
        assert!(geomean(&xs) <= mean(&xs));
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[5.0]), 0.0);
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn normalized_geomean_identity() {
        let v = [3.0, 5.0, 7.0];
        assert!((normalized_geomean(&v, &v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn minmax() {
        let xs = [3.0, -1.0, 9.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 9.0);
    }
}
