//! Property-test driver (proptest is unavailable offline).
//!
//! `check` runs a property over many seeded random cases; on failure it
//! retries with progressively "smaller" case sizes drawn from the same seed
//! to report a minimal-ish reproduction, then panics with the seed so the
//! failure is replayable.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // DGCOLOR_PROP_CASES / DGCOLOR_PROP_SEED override for CI sweeps.
        let cases = std::env::var("DGCOLOR_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("DGCOLOR_PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xD15EA5E);
        PropConfig { cases, seed }
    }
}

/// Run `prop(rng, case_index)`; the property signals failure by returning
/// `Err(description)`. Panics with seed + case on first failure.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property {name:?} failed at case {case} (seed {:#x}): {msg}\n\
                 replay with DGCOLOR_PROP_SEED={} DGCOLOR_PROP_CASES={}",
                cfg.seed,
                cfg.seed,
                case + 1
            );
        }
    }
}

/// Convenience: run with default config.
pub fn quickcheck<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    check(name, PropConfig::default(), prop)
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check(
            "always-true",
            PropConfig { cases: 10, seed: 1 },
            |_rng, _case| {
                ran += 1;
                Ok(())
            },
        );
        assert_eq!(ran, 10);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn failing_property_panics_with_seed() {
        check(
            "fails-late",
            PropConfig { cases: 10, seed: 2 },
            |_rng, case| {
                if case == 7 {
                    Err("boom".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn prop_assert_macro() {
        check(
            "macro",
            PropConfig { cases: 4, seed: 3 },
            |rng, _case| {
                let v = rng.below(100);
                prop_assert!(v < 100, "out of range: {v}");
                Ok(())
            },
        );
    }
}
