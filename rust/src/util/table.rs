//! Aligned text tables and CSV output for the experiment harness. Every
//! bench prints a paper-style table to stdout and mirrors it as CSV under
//! `results/`.

use crate::util::error::Result;
use std::fmt::Write as _;
use std::fs;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width in table {:?}",
            self.title
        );
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
    }

    /// Render as an aligned monospace table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for i in 0..ncol {
                let _ = write!(s, "{:<w$}", cells[i], w = widths[i] + 2);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().map(|w| w + 2).sum();
        let _ = writeln!(out, "{}", "-".repeat(total.saturating_sub(2)));
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    fn csv_escape(s: &str) -> String {
        if s.contains([',', '"', '\n']) {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers
                .iter()
                .map(|h| Self::csv_escape(h))
                .collect::<Vec<_>>()
                .join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter()
                    .map(|c| Self::csv_escape(c))
                    .collect::<Vec<_>>()
                    .join(",")
            );
        }
        out
    }

    /// Write CSV under `results/<name>.csv` (creates the directory).
    pub fn save_csv(&self, name: &str) -> Result<()> {
        let dir = Path::new("results");
        fs::create_dir_all(dir)?;
        fs::write(dir.join(format!("{name}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("demo", &["name", "v"]);
        t.row(&["a", "1"]);
        t.row(&["longer", "22"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("longer"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["has,comma", "has\"quote"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
        assert!(fmt_secs(2.5e-6).ends_with("µs"));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }
}
