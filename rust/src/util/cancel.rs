//! Cooperative cancellation and per-job budgets.
//!
//! A [`CancelToken`] is the external stop signal the ROADMAP's service
//! direction asks for — the generalization of the
//! `stop_when_improvement_below` early-stop plumbing from an *internal*
//! stop rule (a pure function of allreduced quantities) to *external* ones:
//! a client pressing cancel, a wall-clock deadline, or a modeled
//! virtual-clock budget. Engines poll the token at their natural
//! checkpoints — the BSP engine once per engine step inside its uniform
//! stop-decision window, the supervised engine at the top of its
//! single-threaded loop, the data-parallel engine at the top of each
//! speculate round, the thread runner at its per-superstep consensus hook
//! — so a token raised at step *k* is observed at step *k+1* and every
//! simulated process takes the same stop decision (no rank ever stops
//! sending while a peer still waits on it).
//!
//! The first cause to fire **latches**: later polls return the same
//! [`StopCause`] forever, so a run's abort path sees one consistent
//! verdict. A token with no deadline and no budget that is never cancelled
//! reduces every poll to one relaxed atomic load — and jobs without a
//! token attached skip even that, which is how the fault-free
//! non-cancelled path stays bit-for-bit identical (the accounting fixture
//! pins it).
//!
//! Determinism: the virtual-clock budget compares *modeled* time, a pure
//! function of the run, so a budget-triggered stop is reproducible bit for
//! bit under the same seed. Wall-clock deadlines and external
//! [`cancel`](CancelToken::cancel) calls are inherently racy against the
//! run; they still stop at a deterministic *kind* of point (the next
//! checkpoint) but not a reproducible one — tests that need replayable
//! cancellation use the virtual budget.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::error::Error;

/// Why a run was stopped early. Ordered by precedence: an explicit cancel
/// wins over a deadline observed in the same poll.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopCause {
    /// [`CancelToken::cancel`] was called.
    Cancelled,
    /// The wall-clock deadline passed.
    DeadlineExceeded,
    /// The modeled virtual-clock budget was exhausted.
    BudgetExhausted,
    /// The reliable transport's retry cap tripped: a peer never
    /// acknowledged a message within the retransmission budget. Raised by
    /// the supervised engine, not by the token itself, but it flows
    /// through the same stop/abort-drain/degrade machinery.
    Unreachable,
}

impl StopCause {
    /// The typed error a run ending in this cause fails with (under the
    /// `Fail` policy; the `Degrade` policy returns a valid coloring and
    /// flags the result instead).
    pub fn to_error(self) -> Error {
        match self {
            StopCause::Cancelled => Error::cancelled("job stopped by cancel token"),
            StopCause::DeadlineExceeded => {
                Error::deadline_exceeded("wall-clock deadline passed before the job finished")
            }
            StopCause::BudgetExhausted => {
                Error::deadline_exceeded("virtual-clock budget exhausted before the job finished")
            }
            StopCause::Unreachable => Error::unreachable(
                "a peer never acknowledged a message within the retransmission retry cap",
            ),
        }
    }

    /// Short label for logs and result tables.
    pub fn name(self) -> &'static str {
        match self {
            StopCause::Cancelled => "cancelled",
            StopCause::DeadlineExceeded => "deadline",
            StopCause::BudgetExhausted => "vbudget",
            StopCause::Unreachable => "unreachable",
        }
    }
}

// The latch's atomic encoding: 0 = live, else StopCause discriminant + 1.
const LIVE: u8 = 0;

fn encode(c: StopCause) -> u8 {
    match c {
        StopCause::Cancelled => 1,
        StopCause::DeadlineExceeded => 2,
        StopCause::BudgetExhausted => 3,
        StopCause::Unreachable => 4,
    }
}

fn decode(v: u8) -> Option<StopCause> {
    match v {
        1 => Some(StopCause::Cancelled),
        2 => Some(StopCause::DeadlineExceeded),
        3 => Some(StopCause::BudgetExhausted),
        4 => Some(StopCause::Unreachable),
        _ => None,
    }
}

struct Inner {
    /// The latched verdict: `LIVE` until the first cause fires.
    state: AtomicU8,
    /// Wall-clock deadline, fixed at token creation.
    deadline: Option<Instant>,
    /// Modeled virtual-clock budget in virtual seconds.
    vbudget: Option<f64>,
}

/// Shared, cloneable stop signal. Clones observe the same latch — hand one
/// clone to the client (to [`cancel`](Self::cancel)) and thread another
/// through the run.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl CancelToken {
    /// A token with no deadline and no budget: it only ever fires via
    /// [`cancel`](Self::cancel).
    pub fn new() -> Self {
        Self::with_limits(None, None)
    }

    /// A token carrying a wall-clock deadline (measured from now) and/or a
    /// virtual-clock budget.
    pub fn with_limits(deadline: Option<Duration>, vbudget: Option<f64>) -> Self {
        CancelToken {
            inner: Arc::new(Inner {
                state: AtomicU8::new(LIVE),
                deadline: deadline.map(|d| Instant::now() + d),
                vbudget,
            }),
        }
    }

    /// Request cancellation. Idempotent; the first cause to latch wins, so
    /// cancelling an already-expired token leaves the deadline verdict.
    pub fn cancel(&self) {
        let _ = self.inner.state.compare_exchange(
            LIVE,
            encode(StopCause::Cancelled),
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
    }

    /// The latched verdict, without consulting any clock. One relaxed
    /// atomic load — safe on the hottest paths.
    pub fn stopped(&self) -> Option<StopCause> {
        decode(self.inner.state.load(Ordering::Relaxed))
    }

    /// Poll the token at a checkpoint: returns the latched verdict, or
    /// latches (and returns) a deadline/budget verdict if one expired.
    /// `vtime` is the run's current modeled virtual time (pass `0.0` from
    /// engines without a virtual clock — the budget then never fires).
    pub fn check(&self, vtime: f64) -> Option<StopCause> {
        if let Some(c) = self.stopped() {
            return Some(c);
        }
        if let Some(d) = self.inner.deadline {
            if Instant::now() >= d {
                return Some(self.latch(StopCause::DeadlineExceeded));
            }
        }
        if let Some(b) = self.inner.vbudget {
            if vtime > b {
                return Some(self.latch(StopCause::BudgetExhausted));
            }
        }
        None
    }

    /// Whether this token can ever fire without an explicit cancel call.
    pub fn has_limits(&self) -> bool {
        self.inner.deadline.is_some() || self.inner.vbudget.is_some()
    }

    fn latch(&self, cause: StopCause) -> StopCause {
        match self.inner.state.compare_exchange(
            LIVE,
            encode(cause),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => cause,
            // lost the race to another cause — the latch wins
            Err(prev) => decode(prev).unwrap_or(cause),
        }
    }
}

impl Default for CancelToken {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CancelToken")
            .field("stopped", &self.stopped())
            .field("deadline", &self.inner.deadline.is_some())
            .field("vbudget", &self.inner.vbudget)
            .finish()
    }
}

/// What a stopped run should do at its next checkpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StopPolicy {
    /// Fail with the typed error for the [`StopCause`]
    /// (`Error::Cancelled` / `Error::DeadlineExceeded`).
    #[default]
    Fail,
    /// Finalize the best-so-far coloring — fill and repair it to validity
    /// through the pipeline's repair pass — and return it flagged
    /// `degraded: true`.
    Degrade,
}

impl StopPolicy {
    pub fn name(self) -> &'static str {
        match self {
            StopPolicy::Fail => "fail",
            StopPolicy::Degrade => "degrade",
        }
    }
}

/// External run control: a stop signal plus the policy applied when it
/// fires. Passed by reference through the pipeline; absence (`None` at the
/// call sites) is the guaranteed-untouched fast path.
#[derive(Clone, Debug)]
pub struct RunControl {
    pub token: CancelToken,
    pub policy: StopPolicy,
}

impl RunControl {
    pub fn new(token: CancelToken, policy: StopPolicy) -> Self {
        RunControl { token, policy }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_token_never_fires() {
        let t = CancelToken::new();
        assert_eq!(t.stopped(), None);
        assert_eq!(t.check(1e12), None, "no budget: vtime is ignored");
        assert!(!t.has_limits());
    }

    #[test]
    fn cancel_latches_and_is_idempotent() {
        let t = CancelToken::new();
        let peer = t.clone();
        t.cancel();
        assert_eq!(peer.stopped(), Some(StopCause::Cancelled));
        t.cancel();
        assert_eq!(t.check(0.0), Some(StopCause::Cancelled));
    }

    #[test]
    fn vbudget_fires_exactly_past_the_budget_and_latches() {
        let t = CancelToken::with_limits(None, Some(5.0));
        assert!(t.has_limits());
        assert_eq!(t.check(4.9), None);
        assert_eq!(t.check(5.0), None, "budget is inclusive");
        assert_eq!(t.check(5.1), Some(StopCause::BudgetExhausted));
        // latched: even a poll with a small vtime keeps the verdict
        assert_eq!(t.check(0.0), Some(StopCause::BudgetExhausted));
        assert_eq!(t.stopped(), Some(StopCause::BudgetExhausted));
    }

    #[test]
    fn expired_deadline_fires_immediately() {
        let t = CancelToken::with_limits(Some(Duration::from_secs(0)), None);
        assert_eq!(t.check(0.0), Some(StopCause::DeadlineExceeded));
    }

    #[test]
    fn first_cause_wins() {
        let t = CancelToken::with_limits(None, Some(1.0));
        assert_eq!(t.check(2.0), Some(StopCause::BudgetExhausted));
        t.cancel(); // too late — the budget verdict is latched
        assert_eq!(t.stopped(), Some(StopCause::BudgetExhausted));
    }

    #[test]
    fn causes_map_to_typed_errors() {
        use crate::util::error::ErrorKind;
        assert_eq!(StopCause::Cancelled.to_error().kind(), ErrorKind::Cancelled);
        assert_eq!(
            StopCause::DeadlineExceeded.to_error().kind(),
            ErrorKind::DeadlineExceeded
        );
        assert_eq!(
            StopCause::BudgetExhausted.to_error().kind(),
            ErrorKind::DeadlineExceeded
        );
        assert_eq!(StopCause::Unreachable.to_error().kind(), ErrorKind::Unreachable);
        assert_eq!(StopCause::Unreachable.name(), "unreachable");
    }
}
