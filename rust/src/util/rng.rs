//! Deterministic, seedable PRNG: xoshiro256** seeded through splitmix64.
//!
//! All randomized behaviour in the library (RMAT generation, random total
//! orders for conflict tie-breaking, Random-X-Fit color selection, Knuth
//! shuffles of color classes) flows through this type so that every
//! experiment is reproducible from a single `u64` seed.

/// splitmix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Mix two values into a well-distributed 64-bit hash (stateless).
///
/// Used to derive independent per-vertex random priorities from a global
/// seed without storing per-vertex generator state.
#[inline]
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.wrapping_mul(0x9E3779B97F4A7C15);
    splitmix64(&mut s)
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per simulated process).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ mix64(stream, 0xA5A5_5A5A_DEAD_BEEF))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift, unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher-Yates (Knuth) shuffle — the paper's linear-time
    /// random permutation of color classes uses exactly this.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn permutation_uniformish() {
        // first element of a 4-permutation should be ~uniform over 4 values
        let mut r = Rng::new(11);
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[r.permutation(4)[0] as usize] += 1;
        }
        for c in counts {
            assert!((800..1200).contains(&c), "skewed: {counts:?}");
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn mix64_spreads() {
        assert_ne!(mix64(0, 0), mix64(0, 1));
        assert_ne!(mix64(1, 0), mix64(0, 1));
    }
}
