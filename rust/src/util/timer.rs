//! Wall-clock timing helpers.

use std::time::{Duration, Instant};

/// A simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates named phase durations (used for the Fig-4 prep/coloring
/// breakdown).
#[derive(Default, Debug, Clone)]
pub struct PhaseTimes {
    entries: Vec<(String, f64)>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += secs;
        } else {
            self.entries.push((name.to_string(), secs));
        }
    }

    pub fn get(&self, name: &str) -> f64 {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    pub fn merge(&mut self, other: &PhaseTimes) {
        for (n, s) in &other.entries {
            self.add(n, *s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::hint::black_box((0..10000).sum::<u64>());
        assert!(t.secs() >= 0.0);
    }

    #[test]
    fn phases_accumulate() {
        let mut p = PhaseTimes::new();
        p.add("prep", 1.0);
        p.add("color", 2.0);
        p.add("prep", 0.5);
        assert_eq!(p.get("prep"), 1.5);
        assert_eq!(p.get("missing"), 0.0);
        assert_eq!(p.total(), 3.5);
        let mut q = PhaseTimes::new();
        q.add("color", 1.0);
        p.merge(&q);
        assert_eq!(p.get("color"), 3.0);
    }
}
