//! Bit sets and the stamped color-marker used in the greedy hot loop.

/// A fixed-capacity bit set over `u64` words.
///
/// Used for forbidden-color sets outside the hot loop and for boundary /
/// interior vertex flags.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    pub fn new(len: usize) -> Self {
        BitSet {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Clear every bit (O(words)).
    pub fn clear_all(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Index of the lowest zero bit, i.e. the *first-fit* color given a
    /// forbidden set. Always returns a value `<= self.len` (the set is sized
    /// to Δ+1 by callers, and a vertex with Δ neighbors forbids at most Δ
    /// colors).
    pub fn first_zero(&self) -> usize {
        for (wi, &w) in self.words.iter().enumerate() {
            if w != u64::MAX {
                let bit = (!w).trailing_zeros() as usize;
                let idx = (wi << 6) + bit;
                return idx;
            }
        }
        self.len
    }

    /// Iterate over indices of set bits.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some((wi << 6) + b)
                }
            })
        })
    }
}

/// Stamped dense color marker: the O(1)-reset "forbidden colors" structure
/// used by every greedy coloring inner loop.
///
/// Marks live in a bit set (`u64` words, as in [`BitSet`]) whose words are
/// validated lazily by a per-word epoch stamp: advancing the epoch with
/// `next_epoch()` invalidates every mark without touching memory, and a
/// word's bits are only trusted when its stamp matches the current epoch, so
/// no per-vertex clearing ever happens. Compared to one stamp per color, the
/// palette scan (`first_unmarked`) inspects 64 colors per load instead of
/// one, which keeps first-fit cheap once palettes grow past a few dozen
/// colors (§Perf: `greedy`/`recolor_once` in `benches/perf.rs`).
#[derive(Clone, Debug)]
pub struct ColorMarker {
    /// Mark bits; word `w` is meaningful only when `word_epoch[w] == epoch`.
    words: Vec<u64>,
    /// Epoch at which each word of `words` was last written.
    word_epoch: Vec<u32>,
    epoch: u32,
    /// Colors `0..cap` are representable without growth.
    cap: usize,
}

impl ColorMarker {
    /// `capacity` must exceed any color value that will be marked (Δ+1 is
    /// always enough for first-fit; Random-X may probe up to Δ+X).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        ColorMarker {
            words: vec![0; cap.div_ceil(64)],
            word_epoch: vec![0; cap.div_ceil(64)],
            epoch: 0,
            cap,
        }
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Start marking for a new vertex.
    #[inline]
    pub fn next_epoch(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // wrapped: hard reset once every 2^32 epochs
            self.word_epoch.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        }
    }

    /// Grow capacity (amortized; preserves current epoch marks as unmarked).
    #[inline]
    pub fn ensure(&mut self, capacity: usize) {
        if capacity > self.cap {
            self.cap = capacity.next_power_of_two();
            let nw = self.cap.div_ceil(64);
            self.words.resize(nw, 0);
            self.word_epoch.resize(nw, 0);
        }
    }

    #[inline]
    pub fn mark(&mut self, color: u32) {
        self.ensure(color as usize + 1);
        let c = color as usize;
        let wi = c >> 6;
        if self.word_epoch[wi] != self.epoch {
            self.word_epoch[wi] = self.epoch;
            self.words[wi] = 0;
        }
        self.words[wi] |= 1u64 << (c & 63);
    }

    #[inline]
    pub fn is_marked(&self, color: u32) -> bool {
        let c = color as usize;
        if c >= self.cap {
            return false;
        }
        let wi = c >> 6;
        self.word_epoch[wi] == self.epoch && (self.words[wi] >> (c & 63)) & 1 == 1
    }

    /// Smallest unmarked color (first fit). Scans 64 colors per word load;
    /// a word whose stamp is stale counts as all-unmarked.
    #[inline]
    pub fn first_unmarked(&self) -> u32 {
        for (wi, (&w, &we)) in self.words.iter().zip(self.word_epoch.iter()).enumerate() {
            let marked = if we == self.epoch { w } else { 0 };
            if marked != u64::MAX {
                return ((wi << 6) + (!marked).trailing_zeros() as usize) as u32;
            }
        }
        self.cap as u32
    }

    /// The `k`-th unmarked color (0-based) — Random-X-Fit picks uniformly
    /// among the first X unmarked, i.e. `kth_unmarked(rng.below(X))`.
    #[inline]
    pub fn kth_unmarked(&self, k: u32) -> u32 {
        let mut seen = 0u32;
        let mut c = 0u32;
        loop {
            if !self.is_marked(c) {
                if seen == k {
                    return c;
                }
                seen += 1;
            }
            c += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitset_set_get_clear() {
        let mut b = BitSet::new(130);
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1) && !b.get(63) && !b.get(128));
        assert_eq!(b.count(), 3);
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn bitset_first_zero() {
        let mut b = BitSet::new(200);
        assert_eq!(b.first_zero(), 0);
        for i in 0..67 {
            b.set(i);
        }
        assert_eq!(b.first_zero(), 67);
        b.clear(3);
        assert_eq!(b.first_zero(), 3);
    }

    #[test]
    fn bitset_iter_ones() {
        let mut b = BitSet::new(300);
        for i in [0usize, 5, 63, 64, 127, 255, 299] {
            b.set(i);
        }
        let ones: Vec<usize> = b.iter_ones().collect();
        assert_eq!(ones, vec![0, 5, 63, 64, 127, 255, 299]);
    }

    #[test]
    fn bitset_clear_all() {
        let mut b = BitSet::new(100);
        (0..100).for_each(|i| b.set(i));
        b.clear_all();
        assert_eq!(b.count(), 0);
        assert_eq!(b.first_zero(), 0);
    }

    #[test]
    fn marker_epochs_reset_without_clearing() {
        let mut m = ColorMarker::new(8);
        m.next_epoch();
        m.mark(2);
        m.mark(0);
        assert_eq!(m.first_unmarked(), 1);
        m.next_epoch();
        assert!(!m.is_marked(2));
        assert_eq!(m.first_unmarked(), 0);
    }

    #[test]
    fn marker_kth_unmarked() {
        let mut m = ColorMarker::new(8);
        m.next_epoch();
        m.mark(0);
        m.mark(2);
        m.mark(3);
        // unmarked: 1,4,5,6,...
        assert_eq!(m.kth_unmarked(0), 1);
        assert_eq!(m.kth_unmarked(1), 4);
        assert_eq!(m.kth_unmarked(2), 5);
    }

    #[test]
    fn marker_grows() {
        let mut m = ColorMarker::new(2);
        m.next_epoch();
        m.mark(1000);
        assert!(m.is_marked(1000));
        assert!(!m.is_marked(999));
    }

    #[test]
    fn marker_matches_naive_reference() {
        // pin the word-backed marker against a HashSet-per-vertex reference
        // across random mark patterns, growth, and many epochs
        let mut m = ColorMarker::new(4);
        let mut x = 0x2545_F491_4F6C_DD1Du64;
        let mut rng = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        };
        for _ in 0..500 {
            m.next_epoch();
            let mut reference = std::collections::HashSet::new();
            for _ in 0..(rng() % 20) {
                let c = (rng() % 300) as u32;
                m.mark(c);
                reference.insert(c);
            }
            let first = (0..).find(|c| !reference.contains(c)).unwrap();
            assert_eq!(m.first_unmarked(), first);
            for c in 0..310u32 {
                assert_eq!(m.is_marked(c), reference.contains(&c), "color {c}");
            }
            let k = (rng() % 5) as u32;
            let kth = (0..)
                .filter(|c| !reference.contains(c))
                .nth(k as usize)
                .unwrap();
            assert_eq!(m.kth_unmarked(k), kth);
        }
    }

    #[test]
    fn marker_full_word_scans_past() {
        // 64 marked colors fill word 0 exactly; the scan must move on
        let mut m = ColorMarker::new(128);
        m.next_epoch();
        for c in 0..64 {
            m.mark(c);
        }
        assert_eq!(m.first_unmarked(), 64);
        m.mark(64);
        m.mark(65);
        assert_eq!(m.first_unmarked(), 66);
    }

    #[test]
    fn marker_epoch_wrap_resets() {
        let mut m = ColorMarker::new(4);
        m.epoch = u32::MAX - 1;
        m.next_epoch(); // -> MAX
        m.mark(1);
        m.next_epoch(); // wraps -> hard reset, epoch 1
        assert!(!m.is_marked(1));
        m.mark(2);
        assert!(m.is_marked(2));
    }
}
