//! Minimal error/result substrate (`anyhow` is unavailable offline).
//!
//! A single string-backed [`Error`] with `anyhow`-style ergonomics: the
//! [`Context`] extension trait for `Result`/`Option`, a blanket `From` for
//! every `std::error::Error` (so `?` works on io/parse errors), and the
//! [`err!`](crate::err), [`bail!`](crate::bail) and
//! [`ensure!`](crate::ensure) macros. Deliberately no source chain: every
//! layer of context is folded into the message, which is all the CLI and
//! the test harness ever print.

use std::fmt;

/// Classification of an [`Error`] for callers that need to react
/// programmatically (the supervisor, tests); the message remains the only
/// display surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Uncategorized failure — everything built via [`Error::msg`].
    Generic,
    /// A simulated process died (panic or injected crash that could not be
    /// recovered) at the given engine step.
    ProcFailed { rank: u32, step: u64 },
    /// Input parsing failed at a 1-based line number.
    Parse { line: u32 },
    /// Admission control rejected the job: the scheduler's bounded queue
    /// is full. Retry later or shed load — nothing was partially run.
    Overloaded,
    /// The job was cancelled by an external stop signal (its
    /// [`CancelToken`](crate::util::cancel::CancelToken)) before finishing.
    Cancelled,
    /// A per-job budget expired: the wall-clock deadline or the modeled
    /// virtual-clock budget.
    DeadlineExceeded,
    /// The reliable-delivery layer gave up on a peer: a message exhausted
    /// its retransmission attempts without ever being acknowledged.
    Unreachable,
}

impl ErrorKind {
    /// Stable machine-readable code for wire formats (the `"kind"` field of
    /// the `done` event's JSON encoding). Field-carrying kinds collapse to
    /// their family name — the fields stay in the message.
    pub fn code(&self) -> &'static str {
        match self {
            ErrorKind::Generic => "generic",
            ErrorKind::ProcFailed { .. } => "proc-failed",
            ErrorKind::Parse { .. } => "parse",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::Cancelled => "cancelled",
            ErrorKind::DeadlineExceeded => "deadline-exceeded",
            ErrorKind::Unreachable => "unreachable",
        }
    }
}

/// String-backed error. Does **not** implement `std::error::Error` itself —
/// exactly like `anyhow::Error`, this is what allows the blanket
/// `From<E: std::error::Error>` impl to coexist with `From<String>`.
pub struct Error {
    msg: String,
    kind: ErrorKind,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error {
            msg: m.to_string(),
            kind: ErrorKind::Generic,
        }
    }

    /// A simulated process failed at an engine step (worker panic or an
    /// unrecoverable injected crash).
    pub fn proc_failed<M: fmt::Display>(rank: u32, step: u64, detail: M) -> Self {
        Error {
            msg: format!("process {rank} failed at engine step {step}: {detail}"),
            kind: ErrorKind::ProcFailed { rank, step },
        }
    }

    /// A parse failure at a 1-based input line.
    pub fn parse_at<M: fmt::Display>(line: u32, detail: M) -> Self {
        Error {
            msg: format!("line {line}: {detail}"),
            kind: ErrorKind::Parse { line },
        }
    }

    /// Admission control rejected the job (bounded queue full).
    pub fn overloaded<M: fmt::Display>(detail: M) -> Self {
        Error {
            msg: format!("overloaded: {detail}"),
            kind: ErrorKind::Overloaded,
        }
    }

    /// The job was cancelled by an external stop signal.
    pub fn cancelled<M: fmt::Display>(detail: M) -> Self {
        Error {
            msg: format!("cancelled: {detail}"),
            kind: ErrorKind::Cancelled,
        }
    }

    /// A per-job budget (wall-clock deadline or virtual-clock budget)
    /// expired before the job finished.
    pub fn deadline_exceeded<M: fmt::Display>(detail: M) -> Self {
        Error {
            msg: format!("deadline exceeded: {detail}"),
            kind: ErrorKind::DeadlineExceeded,
        }
    }

    /// A peer never acknowledged a message within the retransmission
    /// retry cap — the reliable transport declared it unreachable.
    pub fn unreachable<M: fmt::Display>(detail: M) -> Self {
        Error {
            msg: format!("unreachable: {detail}"),
            kind: ErrorKind::Unreachable,
        }
    }

    /// The error's classification.
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Whether this error reports an external stop (cancellation or an
    /// expired deadline/budget) rather than a failure of the run itself.
    pub fn is_stop(&self) -> bool {
        matches!(
            self.kind,
            ErrorKind::Cancelled | ErrorKind::DeadlineExceeded
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like `anyhow::Error`, a blanket conversion from every std error so `?`
// works on io/parse failures. No `From<String>`/`From<&str>` impls — they
// would overlap with this blanket under coherence's future-compatibility
// rule (upstream could implement `Error` for `String`); use `Error::msg`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style extension for attaching context to failures.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

/// Return early with a formatted [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

/// Return early with an error unless the condition holds (mirrors
/// `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
    ($cond:expr) => {
        if !$cond {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail_helper()
    }
    fn bail_helper() -> Result<u32> {
        crate::bail!("boom {}", 42)
    }

    #[test]
    fn macros_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        assert_eq!(format!("{e:#}"), "boom 42");
        let e = crate::err!("x={}", 1);
        assert_eq!(format!("{e:?}"), "x=1");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(v: u32) -> Result<()> {
            crate::ensure!(v < 10, "too big: {v}");
            Ok(())
        }
        assert!(check(5).is_ok());
        assert_eq!(check(15).unwrap_err().to_string(), "too big: 15");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn kinds_classify_without_changing_display() {
        assert_eq!(Error::msg("x").kind(), ErrorKind::Generic);
        let e = Error::proc_failed(3, 17, "machine panicked");
        assert_eq!(e.kind(), ErrorKind::ProcFailed { rank: 3, step: 17 });
        assert_eq!(
            e.to_string(),
            "process 3 failed at engine step 17: machine panicked"
        );
        let e = Error::parse_at(9, "missing column index");
        assert_eq!(e.kind(), ErrorKind::Parse { line: 9 });
        assert_eq!(e.to_string(), "line 9: missing column index");
        let e = Error::overloaded("queue full (8 jobs)");
        assert_eq!(e.kind(), ErrorKind::Overloaded);
        assert_eq!(e.to_string(), "overloaded: queue full (8 jobs)");
        let e = Error::cancelled("stop requested");
        assert_eq!(e.kind(), ErrorKind::Cancelled);
        assert_eq!(e.to_string(), "cancelled: stop requested");
        let e = Error::deadline_exceeded("wall deadline passed");
        assert_eq!(e.kind(), ErrorKind::DeadlineExceeded);
        assert_eq!(e.to_string(), "deadline exceeded: wall deadline passed");
        assert!(e.is_stop());
        assert!(Error::cancelled("x").is_stop());
        assert!(!Error::overloaded("x").is_stop());
        assert!(!Error::msg("x").is_stop());
        let e = Error::unreachable("p2 never acked link seq 17 after 12 attempts");
        assert_eq!(e.kind(), ErrorKind::Unreachable);
        assert_eq!(
            e.to_string(),
            "unreachable: p2 never acked link seq 17 after 12 attempts"
        );
        assert!(!e.is_stop(), "unreachable is a run failure, not an external stop");
    }

    #[test]
    fn kind_codes_are_stable() {
        assert_eq!(ErrorKind::Generic.code(), "generic");
        assert_eq!(ErrorKind::ProcFailed { rank: 0, step: 0 }.code(), "proc-failed");
        assert_eq!(ErrorKind::Parse { line: 1 }.code(), "parse");
        assert_eq!(ErrorKind::Overloaded.code(), "overloaded");
        assert_eq!(ErrorKind::Cancelled.code(), "cancelled");
        assert_eq!(ErrorKind::DeadlineExceeded.code(), "deadline-exceeded");
        assert_eq!(ErrorKind::Unreachable.code(), "unreachable");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let r: std::result::Result<u32, String> = Err("inner".into());
        assert_eq!(
            r.with_context(|| "outer").unwrap_err().to_string(),
            "outer: inner"
        );
    }
}
