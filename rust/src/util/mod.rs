//! Self-contained substrates (this build is offline; crates like `rand`,
//! `clap`, `criterion` and `proptest` are unavailable, so the pieces of them
//! we need are implemented here).

pub mod args;
pub mod bench;
pub mod bitset;
pub mod cancel;
pub mod error;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

pub use bitset::{BitSet, ColorMarker};
pub use rng::Rng;
