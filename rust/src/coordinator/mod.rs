//! Coordinator: the user-facing layer the CLI, the examples and every
//! bench target talk to.
//!
//! * [`config`] — [`ColoringConfig`], one struct per knob of the paper's
//!   parameter space, parseable from CLI arguments.
//! * [`job`] — validated [`Job`]s and the fluent [`JobBuilder`] with the
//!   paper's speed/quality presets and the early-stop policy.
//! * [`session`] — a [`Session`] owns a graph plus cached artifacts
//!   (partitions per `(partitioner, procs, seed)` key, a calibrated cost
//!   model) and runs many jobs against them.
//! * [`event`] — the streaming [`Event`]/[`Observer`] layer: phase
//!   boundaries, supersteps, conflict rounds and recoloring iterations.
//! * [`pipeline`] — the end-to-end run (partition → initial coloring →
//!   recoloring → validation → metrics) producing a [`RunResult`].
//! * [`sweep`] — the Fig 8-10 parameter sweeps, running every job through
//!   per-graph [`Session`]s (one partition per key per sweep).
//! * [`scheduler`] — the multi-tenant service layer: admission control
//!   over a bounded queue, interactive/sweep priority classes with a
//!   starvation-free fairness rule, per-job deadlines and cooperative
//!   cancellation, typed overload shedding.
//!
//! Typical use:
//!
//! ```ignore
//! let session = Session::new(graph);
//! let r = Job::on(&session)
//!     .procs(8)
//!     .quality()
//!     .stop_when_improvement_below(0.05)
//!     .run()?;
//! ```

pub mod config;
pub mod event;
pub mod job;
pub mod pipeline;
pub mod scheduler;
pub mod session;
pub mod sweep;

pub use config::{ColoringConfig, RecolorMode};
pub use event::{DoneError, Event, EventLog, JsonLines, Observer, Phase};
pub use job::{Job, JobBuilder};
pub use pipeline::RunResult;
pub use scheduler::{JobHandle, Priority, SchedStats, Scheduler, SchedulerConfig, TenantId};
pub use session::Session;
#[allow(deprecated)]
pub use pipeline::run_job;
