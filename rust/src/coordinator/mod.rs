//! Coordinator: configuration, the end-to-end pipeline and experiment
//! drivers. This is the layer the CLI, the examples and every bench target
//! talk to.

pub mod config;
pub mod pipeline;
pub mod sweep;

pub use config::{ColoringConfig, RecolorMode};
pub use pipeline::{run_job, RunResult};
