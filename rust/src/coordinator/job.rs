//! Validated job descriptions and the fluent [`JobBuilder`].
//!
//! A [`Job`] is a [`ColoringConfig`] that has passed validation — the
//! checks that used to live inside `run_job` plus the ones new knobs need
//! (early stop requires recoloring, `RandomX(0)` is meaningless, …). Build
//! one fluently against a [`Session`]:
//!
//! ```ignore
//! let r = Job::on(&session)
//!     .procs(8)
//!     .selection(Selection::RandomX(5))
//!     .sync_recolor(nd(2))
//!     .stop_when_improvement_below(0.05)
//!     .run()?;
//! ```
//!
//! or convert an existing config (the sweep grids, CLI parsing) with
//! [`Job::from_config`]. The builder treats an explicit `.seed(s)` call
//! as the one seed knob of the run: at `build()` it is copied into the
//! sync-recoloring schedule, so `RAND` permutations follow the job seed.
//! A `RecolorConfig` whose `seed` field the caller set directly (without
//! calling `.seed()`) is kept verbatim, and `from_config` performs no
//! normalization at all — legacy configs keep their explicit recoloring
//! seed.

use super::config::{ColoringConfig, RecolorMode};
use super::event::Observer;
use super::pipeline::RunResult;
use super::session::Session;
use crate::color::recolor::{Permutation, RecolorSchedule};
use crate::color::{Ordering, Selection};
use crate::dist::cost::{CostModel, NetworkModel};
use crate::dist::recolor::{CommScheme, RecolorConfig};
use crate::dist::{Engine, FaultPlan};
use crate::partition::Partitioner;
use crate::util::cancel::{CancelToken, RunControl, StopPolicy};
use crate::util::error::Result;
use crate::{bail, ensure};
use std::time::Duration;

/// A validated distributed-coloring job.
#[derive(Debug, Clone)]
pub struct Job {
    cfg: ColoringConfig,
}

impl Job {
    /// Start building a job bound to `session` (enables `.run()`).
    pub fn on(session: &Session) -> JobBuilder<'_> {
        JobBuilder {
            session: Some(session),
            cfg: ColoringConfig::default(),
            seed_set: false,
        }
    }

    /// Start building an unbound job (pass it to [`Session::run`] later).
    pub fn builder() -> JobBuilder<'static> {
        JobBuilder {
            session: None,
            cfg: ColoringConfig::default(),
            seed_set: false,
        }
    }

    /// Validate an existing config as-is.
    pub fn from_config(cfg: ColoringConfig) -> Result<Job> {
        validate(&cfg)?;
        Ok(Job { cfg })
    }

    pub fn config(&self) -> &ColoringConfig {
        &self.cfg
    }

    /// Compact label in the paper's naming style (see
    /// [`ColoringConfig::label`]).
    pub fn label(&self) -> String {
        self.cfg.label()
    }

    /// The [`RunControl`] this job's own deadline/budget knobs imply:
    /// `Some` iff a limit is set (a fresh token each call — the deadline
    /// countdown starts now), `None` for plain jobs, which keep the
    /// token-free bit-for-bit-pinned execution path. The scheduler builds
    /// its own control instead so queue wait counts against the deadline
    /// and the client can cancel.
    pub fn control(&self) -> Option<RunControl> {
        if self.cfg.deadline_secs.is_none() && self.cfg.vclock_budget.is_none() {
            return None;
        }
        let token = CancelToken::with_limits(
            self.cfg.deadline_secs.map(Duration::from_secs_f64),
            self.cfg.vclock_budget,
        );
        Some(RunControl::new(token, self.stop_policy()))
    }

    /// The stop policy the `degrade` knob selects.
    pub fn stop_policy(&self) -> StopPolicy {
        if self.cfg.degrade {
            StopPolicy::Degrade
        } else {
            StopPolicy::Fail
        }
    }
}

/// The validation that every job passes exactly once, at build time.
fn validate(cfg: &ColoringConfig) -> Result<()> {
    ensure!(cfg.num_procs >= 1, "need at least one process");
    ensure!(cfg.superstep_size >= 1, "superstep size must be >= 1");
    if let Selection::RandomX(0) = cfg.selection {
        bail!("RandomX selection needs X >= 1 (r0 is meaningless)");
    }
    match &cfg.recolor {
        RecolorMode::None => {}
        RecolorMode::Sync(rc) => {
            ensure!(
                rc.iterations >= 1,
                "sync recoloring with 0 iterations — use RecolorMode::None"
            );
            validate_eps(rc.early_stop)?;
            ensure!(
                !(cfg.early_stop.is_some() && rc.early_stop.is_some()),
                "early stop set on both the job and its RecolorConfig — set exactly one"
            );
        }
        RecolorMode::Async { iterations, .. } => {
            ensure!(
                *iterations >= 1,
                "async recoloring with 0 iterations — use RecolorMode::None"
            );
        }
    }
    if cfg.early_stop.is_some() {
        ensure!(
            !matches!(cfg.recolor, RecolorMode::None),
            "early stop requires a recoloring mode (it bounds recoloring iterations)"
        );
        validate_eps(cfg.early_stop)?;
    }
    if cfg.engine == Engine::DataPar {
        ensure!(
            matches!(cfg.recolor, RecolorMode::None),
            "the datapar engine has no simulated transport — multi-process recolor \
             schemes (RC/aRC) require threads|bsp; datapar's speculate/resolve loop \
             already iterates to a conflict-free coloring"
        );
        ensure!(
            !cfg.faults.is_active(),
            "fault injection assumes the supervised BSP transport, which the datapar \
             engine does not have — use engine bsp (or auto) for faulted jobs"
        );
    }
    if cfg.faults.is_active() {
        ensure!(
            cfg.engine != Engine::Threads,
            "fault injection requires the supervised BSP engine — drop the explicit \
             Engine::Threads (Auto routes faulted jobs to Bsp)"
        );
        for c in &cfg.faults.crashes {
            ensure!(
                (c.rank as usize) < cfg.num_procs,
                "fault plan crashes rank {} but the job has only {} process(es)",
                c.rank,
                cfg.num_procs
            );
        }
        ensure!(
            cfg.faults.checkpoint_interval >= 1,
            "fault plan checkpoint interval must be at least 1"
        );
    }
    if let Some(d) = cfg.deadline_secs {
        ensure!(
            d.is_finite() && d > 0.0,
            "deadline must be a positive number of seconds, got {d}"
        );
    }
    if let Some(b) = cfg.vclock_budget {
        ensure!(
            b.is_finite() && b > 0.0,
            "virtual-clock budget must be a positive number of virtual seconds, got {b}"
        );
        ensure!(
            cfg.engine != Engine::DataPar,
            "the datapar engine has no virtual clock — a vclock budget can never fire \
             there; use a wall-clock deadline or a transport engine"
        );
    }
    Ok(())
}

fn validate_eps(eps: Option<f64>) -> Result<()> {
    if let Some(e) = eps {
        ensure!(
            e.is_finite() && e > 0.0 && e < 1.0,
            "early-stop threshold must be a relative improvement in (0, 1), got {e}"
        );
    }
    Ok(())
}

/// Fluent, validated construction of a [`Job`]. Every setter returns the
/// builder; `build()` runs the validation and `run()` additionally
/// executes on the bound session.
#[derive(Clone)]
pub struct JobBuilder<'s> {
    session: Option<&'s Session>,
    cfg: ColoringConfig,
    /// Whether `.seed()` was called — only then does `build()` propagate
    /// the job seed into the sync-recoloring schedule.
    seed_set: bool,
}

impl<'s> JobBuilder<'s> {
    pub fn procs(mut self, num_procs: usize) -> Self {
        self.cfg.num_procs = num_procs;
        self
    }

    /// The run's one seed: ordering/selection RNGs, partitioning, and (set
    /// at `build()`) the sync-recoloring schedule.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self.seed_set = true;
        self
    }

    pub fn partitioner(mut self, partitioner: Partitioner) -> Self {
        self.cfg.partitioner = partitioner;
        self
    }

    pub fn ordering(mut self, ordering: Ordering) -> Self {
        self.cfg.ordering = ordering;
        self
    }

    pub fn selection(mut self, selection: Selection) -> Self {
        self.cfg.selection = selection;
        self
    }

    pub fn superstep(mut self, size: usize) -> Self {
        self.cfg.superstep_size = size;
        self
    }

    /// Synchronous superstep communication in the initial coloring
    /// (the default).
    pub fn sync_comm(mut self) -> Self {
        self.cfg.sync = true;
        self
    }

    /// Asynchronous (overlapped) superstep communication.
    pub fn async_comm(mut self) -> Self {
        self.cfg.sync = false;
        self
    }

    pub fn network(mut self, network: NetworkModel) -> Self {
        self.cfg.network = network;
        self
    }

    /// Pin the compute cost model (tests/benches); overrides the session's
    /// calibrated model.
    pub fn fixed_cost(mut self, cost: CostModel) -> Self {
        self.cfg.fixed_cost = Some(cost);
        self
    }

    /// Which execution path runs the job ([`Engine::Auto`] by default:
    /// the BSP step engine for every job shape, aRC included). The
    /// transport engines (threads|bsp) never change a modeled quantity —
    /// only the simulator's wallclock. [`Engine::DataPar`] is different in
    /// kind: it skips the simulated transport (and the partition) and
    /// produces its own deterministic coloring — no messages, bytes or
    /// virtual clocks, and no recoloring/fault support (rejected at
    /// build). The path that actually ran is recorded on
    /// [`RunResult::engine`](super::pipeline::RunResult::engine).
    pub fn engine(mut self, engine: Engine) -> Self {
        self.cfg.engine = engine;
        self
    }

    /// The paper's "speed" preset (FIxxND0): First Fit, Internal-First, no
    /// recoloring. Keeps procs/seed/network/cost already set.
    pub fn speed(mut self) -> Self {
        self.cfg.ordering = Ordering::InternalFirst;
        self.cfg.selection = Selection::FirstFit;
        self.cfg.recolor = RecolorMode::None;
        self.cfg.early_stop = None;
        self
    }

    /// The paper's "quality" preset (R5IxxND1): Random-5 Fit,
    /// Internal-First, one ND synchronous recoloring iteration.
    pub fn quality(mut self) -> Self {
        self.cfg.ordering = Ordering::InternalFirst;
        self.cfg.selection = Selection::RandomX(5);
        self.cfg.recolor = RecolorMode::Sync(nd(1));
        self
    }

    /// Synchronous recoloring with the given schedule — see the [`nd`],
    /// [`ni`], [`rv`] and [`rand_perm`] shorthands.
    pub fn sync_recolor(mut self, rc: RecolorConfig) -> Self {
        self.cfg.recolor = RecolorMode::Sync(rc);
        self
    }

    /// Asynchronous (speculative) recoloring — aRC.
    pub fn async_recolor(mut self, perm: Permutation, iterations: u32) -> Self {
        self.cfg.recolor = RecolorMode::Async { perm, iterations };
        self
    }

    pub fn no_recolor(mut self) -> Self {
        self.cfg.recolor = RecolorMode::None;
        self
    }

    /// Inject seeded transport/crash faults ([`FaultPlan`]) — routes the
    /// run through the supervised BSP engine, which checkpoints, restarts
    /// and repairs; every recoloring mode (including aRC) is supervisable.
    /// Incompatible with [`Engine::Threads`].
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.cfg.faults = plan;
        self
    }

    /// Stop recoloring once an iteration's relative improvement
    /// `(k_prev - k) / k_prev` falls below `eps` — the time-quality knob
    /// the paper motivates (Figs 8-10) for workloads where later
    /// iterations stall.
    pub fn stop_when_improvement_below(mut self, eps: f64) -> Self {
        self.cfg.early_stop = Some(eps);
        self
    }

    /// Wall-clock deadline in seconds: the run stops at its next engine
    /// checkpoint once it expires (typed error, or a degraded result
    /// under [`JobBuilder::degrade`]). The countdown starts when the job
    /// starts running (or is admitted, under the scheduler).
    pub fn deadline_secs(mut self, secs: f64) -> Self {
        self.cfg.deadline_secs = Some(secs);
        self
    }

    /// Modeled virtual-clock budget in virtual seconds — the
    /// deterministic stop knob: the same job stops at the same checkpoint
    /// on every run. Transport engines only.
    pub fn vclock_budget(mut self, vsecs: f64) -> Self {
        self.cfg.vclock_budget = Some(vsecs);
        self
    }

    /// On a stop (cancel/deadline/budget), return the best-so-far
    /// coloring completed and repaired to validity — flagged
    /// `degraded: true` — instead of the typed error.
    pub fn degrade(mut self) -> Self {
        self.cfg.degrade = true;
        self
    }

    /// Scheduling class under [`Scheduler`](super::scheduler::Scheduler)
    /// submission (ignored by direct `Session::run`).
    pub fn priority(mut self, p: super::scheduler::Priority) -> Self {
        self.cfg.priority = p;
        self
    }

    /// Validate and produce the [`Job`].
    pub fn build(mut self) -> Result<Job> {
        // one seed knob: an explicit .seed() call drives the recoloring
        // schedule too; a caller-supplied RecolorConfig seed is otherwise
        // kept verbatim
        if self.seed_set {
            if let RecolorMode::Sync(ref mut rc) = self.cfg.recolor {
                rc.seed = self.cfg.seed;
            }
        }
        Job::from_config(self.cfg)
    }

    /// Build and run on the bound session.
    pub fn run(self) -> Result<RunResult> {
        let session = self.require_session()?;
        session.run(&self.build()?)
    }

    /// Build and run on the bound session, streaming events to `obs`.
    pub fn run_observed(self, obs: &dyn Observer) -> Result<RunResult> {
        let session = self.require_session()?;
        session.run_observed(&self.build()?, obs)
    }

    fn require_session(&self) -> Result<&'s Session> {
        match self.session {
            Some(s) => Ok(s),
            None => bail!("job builder is not bound to a session — use Job::on(&session)"),
        }
    }
}

/// `iterations` of synchronous Non-Decreasing recoloring (the paper's best
/// fixed permutation), piggybacked.
pub fn nd(iterations: u32) -> RecolorConfig {
    sync_rc(RecolorSchedule::Fixed(Permutation::NonDecreasing), iterations)
}

/// `iterations` of synchronous Non-Increasing recoloring, piggybacked.
pub fn ni(iterations: u32) -> RecolorConfig {
    sync_rc(RecolorSchedule::Fixed(Permutation::NonIncreasing), iterations)
}

/// `iterations` of synchronous Reverse recoloring, piggybacked.
pub fn rv(iterations: u32) -> RecolorConfig {
    sync_rc(RecolorSchedule::Fixed(Permutation::Reverse), iterations)
}

/// `iterations` of synchronous random-permutation recoloring, piggybacked.
pub fn rand_perm(iterations: u32) -> RecolorConfig {
    sync_rc(RecolorSchedule::Fixed(Permutation::Random), iterations)
}

fn sync_rc(schedule: RecolorSchedule, iterations: u32) -> RecolorConfig {
    RecolorConfig {
        schedule,
        iterations,
        scheme: CommScheme::Piggyback,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_matches_config_presets() {
        let j = Job::builder().procs(32).speed().build().unwrap();
        assert_eq!(j.label(), ColoringConfig::speed(32).label());
        let j = Job::builder().procs(32).quality().build().unwrap();
        assert_eq!(j.label(), ColoringConfig::quality(32).label());
    }

    #[test]
    fn builder_seed_flows_into_recolor_schedule() {
        let j = Job::builder().seed(99).sync_recolor(nd(2)).build().unwrap();
        match j.config().recolor {
            RecolorMode::Sync(rc) => {
                assert_eq!(rc.seed, 99);
                assert_eq!(rc.iterations, 2);
                assert_eq!(rc.scheme, CommScheme::Piggyback);
            }
            _ => panic!("expected sync recoloring"),
        }
    }

    #[test]
    fn validation_rejects_bad_configs() {
        assert!(Job::builder().procs(0).build().is_err());
        assert!(Job::builder().superstep(0).build().is_err());
        assert!(Job::builder().selection(Selection::RandomX(0)).build().is_err());
        assert!(Job::builder().sync_recolor(nd(0)).build().is_err());
        assert!(Job::builder()
            .async_recolor(Permutation::NonDecreasing, 0)
            .build()
            .is_err());
    }

    #[test]
    fn early_stop_needs_recoloring_and_sane_eps() {
        assert!(Job::builder().stop_when_improvement_below(0.1).build().is_err());
        for bad in [0.0, -0.5, 1.0, 2.0, f64::NAN, f64::INFINITY] {
            assert!(
                Job::builder()
                    .sync_recolor(nd(4))
                    .stop_when_improvement_below(bad)
                    .build()
                    .is_err(),
                "eps {bad} should be rejected"
            );
        }
        let ok = Job::builder()
            .sync_recolor(nd(4))
            .stop_when_improvement_below(0.05)
            .build()
            .unwrap();
        assert_eq!(ok.config().early_stop, Some(0.05));
        // the policy lives on exactly one knob: job-level and
        // RecolorConfig-level together are rejected
        let both = RecolorConfig {
            early_stop: Some(0.3),
            ..nd(4)
        };
        assert!(Job::builder().sync_recolor(both).build().is_ok());
        assert!(Job::builder()
            .sync_recolor(both)
            .stop_when_improvement_below(0.01)
            .build()
            .is_err());
    }

    #[test]
    fn unbound_builder_cannot_run() {
        assert!(Job::builder().run().is_err());
    }

    #[test]
    fn control_knobs_validate_and_derive_a_run_control() {
        use crate::util::cancel::StopPolicy;
        // plain jobs derive no control: the pinned token-free path
        let plain = Job::builder().build().unwrap();
        assert!(plain.control().is_none());
        assert_eq!(plain.stop_policy(), StopPolicy::Fail);
        // a limit derives a control carrying the degrade policy
        let j = Job::builder().vclock_budget(50.0).degrade().build().unwrap();
        let ctl = j.control().expect("budget implies a control");
        assert_eq!(ctl.policy, StopPolicy::Degrade);
        assert!(ctl.token.has_limits());
        assert_eq!(ctl.token.stopped(), None);
        assert!(Job::builder().deadline_secs(10.0).build().unwrap().control().is_some());
        // bad limits are rejected at build
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(Job::builder().deadline_secs(bad).build().is_err(), "deadline {bad}");
            assert!(Job::builder().vclock_budget(bad).build().is_err(), "budget {bad}");
        }
        // datapar has no virtual clock: a vbudget could never fire
        assert!(Job::builder()
            .engine(Engine::DataPar)
            .vclock_budget(1.0)
            .build()
            .is_err());
        // ... but wall-clock deadlines work there
        assert!(Job::builder()
            .engine(Engine::DataPar)
            .deadline_secs(5.0)
            .build()
            .is_ok());
    }

    #[test]
    fn every_engine_accepts_arc() {
        // the Bsp+aRC rejection is gone: aRC runs on the step engine
        for engine in [Engine::Auto, Engine::Threads, Engine::Bsp] {
            assert!(
                Job::builder()
                    .async_recolor(Permutation::NonDecreasing, 1)
                    .engine(engine)
                    .build()
                    .is_ok(),
                "{engine:?} + aRC must validate"
            );
        }
        assert!(Job::builder().engine(Engine::Bsp).sync_recolor(nd(2)).build().is_ok());
    }

    #[test]
    fn datapar_rejects_transport_shaped_jobs() {
        // plain datapar validates — procs/ordering/selection are fine
        assert!(Job::builder().engine(Engine::DataPar).build().is_ok());
        assert!(Job::builder()
            .engine(Engine::DataPar)
            .procs(8)
            .selection(Selection::RandomX(5))
            .build()
            .is_ok());
        // multi-process recolor schemes assume a transport
        assert!(
            Job::builder().engine(Engine::DataPar).sync_recolor(nd(1)).build().is_err(),
            "datapar + sync RC must be rejected"
        );
        assert!(
            Job::builder()
                .engine(Engine::DataPar)
                .async_recolor(Permutation::NonDecreasing, 1)
                .build()
                .is_err(),
            "datapar + aRC must be rejected"
        );
        // so does fault injection (supervised BSP only); the inert plan is fine
        let plan = FaultPlan::parse("seed=1,delay=0.1").unwrap();
        assert!(
            Job::builder().engine(Engine::DataPar).faults(plan).build().is_err(),
            "datapar + faults must be rejected"
        );
        assert!(Job::builder()
            .engine(Engine::DataPar)
            .faults(FaultPlan::none())
            .build()
            .is_ok());
    }

    #[test]
    fn faulted_jobs_require_the_supervised_bsp_path() {
        let plan = FaultPlan::parse("seed=1,delay=0.1").unwrap();
        assert!(Job::builder().faults(plan.clone()).build().is_ok());
        assert!(Job::builder().faults(plan.clone()).engine(Engine::Bsp).build().is_ok());
        assert!(
            Job::builder().faults(plan.clone()).engine(Engine::Threads).build().is_err(),
            "explicit thread engine + faults must be rejected"
        );
        assert!(
            Job::builder()
                .faults(plan)
                .async_recolor(Permutation::NonDecreasing, 1)
                .build()
                .is_ok(),
            "aRC + faults is supervisable (the aRC rejection is gone)"
        );
        let crash = FaultPlan::parse("seed=1,crash=7@2").unwrap();
        assert!(
            Job::builder().procs(4).faults(crash.clone()).build().is_err(),
            "crash rank beyond the process count must be rejected"
        );
        assert!(Job::builder().procs(8).faults(crash.clone()).build().is_ok());
        let multi = FaultPlan::parse("seed=1,crash=1@2+3,crash=6@4,loss=0.05").unwrap();
        assert!(
            Job::builder().procs(4).faults(multi.clone()).build().is_err(),
            "every crash rank is validated, not just the first"
        );
        assert!(Job::builder().procs(8).faults(multi).build().is_ok());
        // the inert plan changes nothing
        assert!(Job::builder()
            .faults(FaultPlan::none())
            .engine(Engine::Threads)
            .build()
            .is_ok());
    }

    #[test]
    fn explicit_recolor_seed_survives_build_without_seed_call() {
        // a caller-supplied RecolorConfig seed is only overridden by an
        // explicit .seed() call, never by the default job seed
        let rc = RecolorConfig {
            seed: 777,
            ..nd(2)
        };
        let j = Job::builder().sync_recolor(rc).build().unwrap();
        match j.config().recolor {
            RecolorMode::Sync(rc) => assert_eq!(rc.seed, 777),
            _ => unreachable!(),
        }
        let j = Job::builder().sync_recolor(rc).seed(9).build().unwrap();
        match j.config().recolor {
            RecolorMode::Sync(rc) => assert_eq!(rc.seed, 9),
            _ => unreachable!(),
        }
    }

    #[test]
    fn from_config_keeps_explicit_recolor_seed() {
        let cfg = ColoringConfig {
            seed: 5,
            recolor: RecolorMode::Sync(RecolorConfig {
                seed: 1234,
                ..Default::default()
            }),
            ..Default::default()
        };
        let j = Job::from_config(cfg).unwrap();
        match j.config().recolor {
            RecolorMode::Sync(rc) => assert_eq!(rc.seed, 1234),
            _ => unreachable!(),
        }
    }
}
