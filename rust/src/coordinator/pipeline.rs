//! The end-to-end pipeline: partition → distributed initial coloring →
//! (optional) recoloring → validation → metrics.

use super::config::{ColoringConfig, RecolorMode};
use crate::color::Coloring;
use crate::dist::framework::{self, FrameworkConfig};
use crate::dist::proc::ColorState;
use crate::dist::recolor;
use crate::dist::runner::{run_distributed, ProcResult};
use crate::dist::DistMetrics;
use crate::graph::CsrGraph;
use crate::partition::{self, PartitionMetrics};
use crate::util::error::Result;
use crate::{ensure, err};

/// Everything a run produces.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub coloring: Coloring,
    pub num_colors: usize,
    pub metrics: DistMetrics,
    pub partition_metrics: PartitionMetrics,
    /// Colors after the initial coloring (before any recoloring).
    pub initial_colors: usize,
    /// Global color count after each recoloring iteration.
    pub recolor_trace: Vec<usize>,
    pub config_label: String,
}

/// Run a full distributed coloring job and validate the result.
pub fn run_job(g: &CsrGraph, cfg: &ColoringConfig) -> Result<RunResult> {
    ensure!(cfg.num_procs >= 1, "need at least one process");
    let part = partition::partition(g, cfg.partitioner, cfg.num_procs, cfg.seed);
    let part_metrics = partition::metrics(g, &part);
    let cost = cfg.cost_model();

    let fw = FrameworkConfig {
        ordering: cfg.ordering,
        selection: cfg.selection,
        superstep_size: cfg.superstep_size,
        sync: cfg.sync,
        seed: cfg.seed,
        max_rounds: 200,
    };

    let recolor_mode = cfg.recolor;
    let outcome = run_distributed(g, &part, cfg.network, |ep, lg| {
        let mut state = ColorState::uncolored(lg);
        let to_color: Vec<u32> = (0..lg.n_owned() as u32).collect();
        let mut metrics = framework::color_process(ep, lg, &fw, &cost, &mut state, to_color, None);

        // the initial color count is the first trace entry
        let n_owned = lg.n_owned();
        let local_kmax = (0..n_owned)
            .map(|v| state.colors[v] as u64 + 1)
            .max()
            .unwrap_or(0);
        let initial_k =
            framework::comm_timed(ep, &mut metrics, |ep| ep.allreduce_max_u64(local_kmax));
        metrics.recolor_trace.push(initial_k as usize);

        match &recolor_mode {
            RecolorMode::None => {}
            RecolorMode::Sync(rc) => {
                let mut trace = Vec::new();
                let m =
                    recolor::recolor_process_sync(ep, lg, &cost, rc, &mut state, &mut trace);
                metrics.phases.merge(&m.phases);
                metrics.conflicts += m.conflicts;
                metrics.recolor_trace.extend(trace);
            }
            RecolorMode::Async { perm, iterations } => {
                for iter in 1..=*iterations {
                    let m = recolor::recolor_process_async(
                        ep, lg, &cost, &fw, *perm, iter, cfg.seed, &mut state,
                    );
                    metrics.phases.merge(&m.phases);
                    metrics.conflicts += m.conflicts;
                    metrics.rounds += m.rounds;
                    let local_kmax = (0..n_owned)
                        .map(|v| state.colors[v] as u64 + 1)
                        .max()
                        .unwrap_or(0);
                    let k = framework::comm_timed(ep, &mut metrics, |ep| {
                        ep.allreduce_max_u64(local_kmax)
                    });
                    metrics.recolor_trace.push(k as usize);
                }
            }
        }

        // final accounting comes from the endpoint (cumulative)
        metrics.vtime = ep.clock;
        metrics.sent_msgs = ep.sent_msgs;
        metrics.sent_bytes = ep.sent_bytes;
        metrics.recv_msgs = ep.recv_msgs;
        ProcResult {
            colors: state.owned_pairs(lg),
            metrics,
        }
    });

    outcome
        .coloring
        .validate(g)
        .map_err(|e| err!("invalid coloring from {}: {e}", cfg.label()))?;

    let trace = outcome.per_proc[0].recolor_trace.clone();
    Ok(RunResult {
        num_colors: outcome.coloring.num_colors(),
        initial_colors: *trace.first().unwrap_or(&outcome.coloring.num_colors()),
        recolor_trace: trace,
        coloring: outcome.coloring,
        metrics: outcome.metrics,
        partition_metrics: part_metrics,
        config_label: cfg.label(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::recolor::{Permutation, RecolorSchedule};
    use crate::color::{Ordering, Selection};
    use crate::dist::cost::CostModel;
    use crate::dist::recolor::{CommScheme, RecolorConfig};
    use crate::graph::synth;

    fn base_cfg(procs: usize) -> ColoringConfig {
        ColoringConfig {
            num_procs: procs,
            fixed_cost: Some(CostModel::fixed()),
            ..Default::default()
        }
    }

    #[test]
    fn initial_coloring_valid() {
        let g = synth::grid2d(20, 20);
        let r = run_job(&g, &base_cfg(4)).unwrap();
        assert!(r.num_colors >= 2 && r.num_colors <= g.max_degree() + 1);
        assert_eq!(r.recolor_trace.len(), 1);
        assert!(r.metrics.makespan > 0.0);
    }

    #[test]
    fn sync_recolor_reduces_or_holds() {
        let g = synth::fem_like(3000, 12.0, 30, 0.0, 7, "fem");
        let mut cfg = base_cfg(4);
        cfg.selection = Selection::RandomX(10);
        cfg.recolor = RecolorMode::Sync(RecolorConfig {
            schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
            iterations: 3,
            scheme: CommScheme::Piggyback,
            seed: 42,
        });
        let r = run_job(&g, &cfg).unwrap();
        assert_eq!(r.recolor_trace.len(), 4);
        assert!(r.recolor_trace.windows(2).all(|w| w[1] <= w[0]),
            "trace {:?}", r.recolor_trace);
        assert!(r.num_colors < r.initial_colors);
    }

    #[test]
    fn async_recolor_valid() {
        let g = synth::grid2d(30, 30);
        let mut cfg = base_cfg(4);
        cfg.recolor = RecolorMode::Async {
            perm: Permutation::NonDecreasing,
            iterations: 1,
        };
        let r = run_job(&g, &cfg).unwrap();
        assert_eq!(r.recolor_trace.len(), 2);
        assert!(r.num_colors >= 2);
    }

    #[test]
    fn async_comm_initial_coloring() {
        let g = synth::erdos_renyi(1500, 9000, 13);
        let mut cfg = base_cfg(6);
        cfg.sync = false;
        cfg.ordering = Ordering::SmallestLast;
        let r = run_job(&g, &cfg).unwrap();
        assert!(r.num_colors <= g.max_degree() + 1);
    }

    #[test]
    fn single_proc_matches_sequential_shape() {
        let g = synth::grid2d(15, 15);
        let r = run_job(&g, &base_cfg(1)).unwrap();
        // one processor, no boundary, no conflicts
        assert_eq!(r.metrics.total_conflicts, 0);
        assert!(r.num_colors <= 4);
    }
}
