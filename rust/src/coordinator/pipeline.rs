//! The end-to-end pipeline: partition → distributed initial coloring →
//! (optional) recoloring → validation → metrics.
//!
//! The entry point is [`Session::run`](super::Session::run) (via
//! [`Job::on`](super::Job::on)); the session supplies the cached partition
//! and cost model and this module drives the distributed phases, streaming
//! [`Event`]s to an optional [`Observer`]. The free function [`run_job`]
//! remains as a deprecated shim that re-partitions and re-calibrates on
//! every call.

use super::config::{ColoringConfig, RecolorMode};
use super::event::{emit_rank0, Event, Observer, Phase};
use super::job::Job;
use crate::color::Coloring;
use crate::dist::framework::{self, FrameworkConfig};
use crate::dist::proc::ColorState;
use crate::dist::recolor;
use crate::dist::runner::{run_distributed, ProcResult};
use crate::dist::{CostModel, DistMetrics};
use crate::err;
use crate::graph::CsrGraph;
use crate::partition::{self, Partition, PartitionMetrics};
use crate::util::error::Result;

/// Everything a run produces.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub coloring: Coloring,
    pub num_colors: usize,
    pub metrics: DistMetrics,
    pub partition_metrics: PartitionMetrics,
    /// Colors after the initial coloring (before any recoloring).
    pub initial_colors: usize,
    /// Global color count after the initial coloring and after each
    /// recoloring iteration that ran (early stop can make this shorter
    /// than `1 + iterations`).
    pub recolor_trace: Vec<usize>,
    pub config_label: String,
}

impl RunResult {
    /// One-line JSON summary (the CLI's `--json` result record).
    pub fn summary_json(&self) -> String {
        let trace: Vec<String> = self.recolor_trace.iter().map(|k| k.to_string()).collect();
        format!(
            "{{\"result\":\"coloring\",\"config\":\"{}\",\"colors\":{},\"initial_colors\":{},\
             \"recolor_trace\":[{}],\"makespan\":{:e},\"messages\":{},\"bytes\":{},\
             \"conflicts\":{},\"rounds\":{}}}",
            self.config_label,
            self.num_colors,
            self.initial_colors,
            trace.join(","),
            self.metrics.makespan,
            self.metrics.total_msgs,
            self.metrics.total_bytes,
            self.metrics.total_conflicts,
            self.metrics.rounds,
        )
    }
}

/// Run a validated job against pre-built artifacts. This is the shared
/// core under [`Session::run`](super::Session::run) and the [`run_job`]
/// shim: everything per-graph (partition, metrics, cost model) comes in
/// from the caller, so sessions can cache it across jobs.
pub(crate) fn execute(
    g: &CsrGraph,
    part: &Partition,
    part_metrics: &PartitionMetrics,
    cost: &CostModel,
    job: &Job,
    obs: Option<&dyn Observer>,
) -> Result<RunResult> {
    let cfg = job.config();
    if let Some(o) = obs {
        o.on_event(&Event::PhaseStarted {
            phase: Phase::InitialColoring,
        });
    }

    let fw = FrameworkConfig {
        ordering: cfg.ordering,
        selection: cfg.selection,
        superstep_size: cfg.superstep_size,
        sync: cfg.sync,
        seed: cfg.seed,
        max_rounds: 200,
    };

    // sync RC reads the early-stop policy from its own config; aRC is
    // iterated here, so the pipeline applies the policy itself below.
    // Validation rejects jobs that set both knobs, so this never
    // overrides a caller-supplied RecolorConfig policy.
    let recolor_mode = match (cfg.recolor, cfg.early_stop) {
        (RecolorMode::Sync(mut rc), Some(eps)) => {
            rc.early_stop = Some(eps);
            RecolorMode::Sync(rc)
        }
        (mode, _) => mode,
    };
    let early_stop = cfg.early_stop;
    let cost = *cost;

    let mut outcome = run_distributed(g, part, cfg.network, |ep, lg| {
        let mut state = ColorState::uncolored(lg);
        let to_color: Vec<u32> = (0..lg.n_owned() as u32).collect();
        let mut metrics =
            framework::color_process(ep, lg, &fw, &cost, &mut state, to_color, None, obs);

        // the initial color count is the first trace entry
        let n_owned = lg.n_owned();
        let local_kmax = (0..n_owned)
            .map(|v| state.colors[v] as u64 + 1)
            .max()
            .unwrap_or(0);
        let initial_k =
            framework::comm_timed(ep, &mut metrics, |ep| ep.allreduce_max_u64(local_kmax));
        metrics.recolor_trace.push(initial_k as usize);

        if !matches!(recolor_mode, RecolorMode::None) {
            emit_rank0(
                obs,
                ep.rank,
                Event::PhaseStarted {
                    phase: Phase::Recoloring,
                },
            );
        }
        match &recolor_mode {
            RecolorMode::None => {}
            RecolorMode::Sync(rc) => {
                let mut trace = Vec::new();
                let m =
                    recolor::recolor_process_sync(ep, lg, &cost, rc, &mut state, &mut trace, obs);
                metrics.phases.merge(&m.phases);
                metrics.conflicts += m.conflicts;
                metrics.recolor_trace.extend(trace);
            }
            RecolorMode::Async { perm, iterations } => {
                for iter in 1..=*iterations {
                    let m = recolor::recolor_process_async(
                        ep, lg, &cost, &fw, *perm, iter, cfg.seed, &mut state, obs,
                    );
                    metrics.phases.merge(&m.phases);
                    metrics.conflicts += m.conflicts;
                    metrics.rounds += m.rounds;
                    let local_kmax = (0..n_owned)
                        .map(|v| state.colors[v] as u64 + 1)
                        .max()
                        .unwrap_or(0);
                    let k = framework::comm_timed(ep, &mut metrics, |ep| {
                        ep.allreduce_max_u64(local_kmax)
                    });
                    let prev = *metrics.recolor_trace.last().unwrap_or(&0);
                    metrics.recolor_trace.push(k as usize);
                    emit_rank0(
                        obs,
                        ep.rank,
                        Event::RecolorIteration {
                            iter,
                            k: k as usize,
                        },
                    );
                    if let Some(eps) = early_stop {
                        // prev and k come from allreduces: every process
                        // stops at the same iteration
                        let improvement = (prev as f64 - k as f64) / (prev as f64).max(1.0);
                        if improvement < eps {
                            break;
                        }
                    }
                }
            }
        }

        // final accounting comes from the endpoint (cumulative)
        metrics.vtime = ep.clock;
        metrics.sent_msgs = ep.sent_msgs;
        metrics.sent_bytes = ep.sent_bytes;
        metrics.recv_msgs = ep.recv_msgs;
        metrics.dropped_msgs = ep.dropped_msgs;
        ProcResult {
            colors: state.owned_pairs(lg),
            metrics,
        }
    });

    if let Some(o) = obs {
        o.on_event(&Event::PhaseStarted {
            phase: Phase::Validation,
        });
    }
    outcome
        .coloring
        .validate(g)
        .map_err(|e| err!("invalid coloring from {}: {e}", cfg.label()))?;

    // every process derives the trace from the same allreduced counts —
    // take rank 0's instead of cloning it
    debug_assert!(
        outcome
            .per_proc
            .iter()
            .all(|p| p.recolor_trace == outcome.per_proc[0].recolor_trace),
        "per-process recolor traces diverged"
    );
    let trace = std::mem::take(&mut outcome.per_proc[0].recolor_trace);
    let num_colors = outcome.coloring.num_colors();
    if let Some(o) = obs {
        o.on_event(&Event::Done { colors: num_colors });
    }
    Ok(RunResult {
        num_colors,
        initial_colors: *trace.first().unwrap_or(&num_colors),
        recolor_trace: trace,
        coloring: outcome.coloring,
        metrics: outcome.metrics,
        partition_metrics: part_metrics.clone(),
        config_label: cfg.label(),
    })
}

/// Run a full distributed coloring job and validate the result.
///
/// Kept as a one-shot shim: it re-partitions the graph and re-resolves the
/// cost model on every call. Build a [`Session`](super::Session) and run
/// jobs through [`Job::on`](super::Job::on) instead — identical results,
/// cached artifacts. The shim applies the full [`Job`] validation, so
/// degenerate configs the old `run_job` silently tolerated (a zero
/// superstep size, `RandomX(0)`, zero-iteration recoloring) now error.
#[deprecated(
    since = "0.2.0",
    note = "build a coordinator::Session and run jobs via Job::on(&session)"
)]
pub fn run_job(g: &CsrGraph, cfg: &ColoringConfig) -> Result<RunResult> {
    let job = Job::from_config(*cfg)?;
    let part = partition::partition(g, cfg.partitioner, cfg.num_procs, cfg.seed);
    let part_metrics = partition::metrics(g, &part);
    let cost = cfg.cost_model();
    execute(g, &part, &part_metrics, &cost, &job, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::recolor::Permutation;
    use crate::color::{Ordering, Selection};
    use crate::coordinator::job::nd;
    use crate::coordinator::session::Session;
    use crate::dist::cost::CostModel;
    use crate::graph::synth;

    fn session(g: CsrGraph) -> Session {
        Session::new(g).with_cost_model(CostModel::fixed())
    }

    #[test]
    fn initial_coloring_valid() {
        let s = session(synth::grid2d(20, 20));
        let r = Job::on(&s).procs(4).run().unwrap();
        let dmax = s.graph().max_degree();
        assert!(r.num_colors >= 2 && r.num_colors <= dmax + 1);
        assert_eq!(r.recolor_trace.len(), 1);
        assert!(r.metrics.makespan > 0.0);
    }

    #[test]
    fn sync_recolor_reduces_or_holds() {
        let s = session(synth::fem_like(3000, 12.0, 30, 0.0, 7, "fem"));
        let r = Job::on(&s)
            .procs(4)
            .selection(Selection::RandomX(10))
            .sync_recolor(nd(3))
            .run()
            .unwrap();
        assert_eq!(r.recolor_trace.len(), 4);
        assert!(r.recolor_trace.windows(2).all(|w| w[1] <= w[0]),
            "trace {:?}", r.recolor_trace);
        assert!(r.num_colors < r.initial_colors);
    }

    #[test]
    fn async_recolor_valid() {
        let s = session(synth::grid2d(30, 30));
        let r = Job::on(&s)
            .procs(4)
            .async_recolor(Permutation::NonDecreasing, 1)
            .run()
            .unwrap();
        assert_eq!(r.recolor_trace.len(), 2);
        assert!(r.num_colors >= 2);
    }

    #[test]
    fn async_comm_initial_coloring() {
        let s = session(synth::erdos_renyi(1500, 9000, 13));
        let r = Job::on(&s)
            .procs(6)
            .async_comm()
            .ordering(Ordering::SmallestLast)
            .run()
            .unwrap();
        assert!(r.num_colors <= s.graph().max_degree() + 1);
    }

    #[test]
    fn single_proc_matches_sequential_shape() {
        let s = session(synth::grid2d(15, 15));
        let r = Job::on(&s).procs(1).run().unwrap();
        // one processor, no boundary, no conflicts
        assert_eq!(r.metrics.total_conflicts, 0);
        assert!(r.num_colors <= 4);
    }

    #[test]
    fn summary_json_shape() {
        let s = session(synth::grid2d(8, 8));
        let r = Job::on(&s).procs(2).run().unwrap();
        let j = r.summary_json();
        assert!(j.starts_with("{\"result\":\"coloring\""));
        assert!(j.contains(&format!("\"colors\":{}", r.num_colors)));
        assert!(j.ends_with('}'));
    }
}
