//! The end-to-end pipeline: partition → distributed initial coloring →
//! (optional) recoloring → validation → metrics.
//!
//! The entry point is [`Session::run`](super::Session::run) (via
//! [`Job::on`](super::Job::on)); the session supplies the cached partition
//! and cost model and this module drives the distributed phases, streaming
//! [`Event`]s to an optional [`Observer`]. The free function [`run_job`]
//! remains as a deprecated shim that re-partitions and re-calibrates on
//! every call.

use super::config::{ColoringConfig, RecolorMode};
use super::event::{emit_rank0, DoneError, Event, Observer, Phase};
use super::job::Job;
use crate::color::recolor::Permutation;
use crate::color::{Coloring, UNCOLORED};
use crate::dist::engine::{self, Engine, StepOutcome, StepProcess};
use crate::dist::framework::{self, FrameworkConfig, FrameworkStep};
use crate::dist::proc::{build_local_graphs, ColorState, LocalGraph};
use crate::dist::recolor::{self, AsyncRcStep, RecolorConfig, SyncRcStep};
use crate::dist::runner::{try_run_distributed_with, DistOutcome, ProcResult};
use crate::dist::{CostModel, DistMetrics, Endpoint, MsgKind, ProcMetrics};
use crate::err;
use crate::graph::CsrGraph;
use crate::partition::{self, PartitionMetrics};
use crate::shm::{self, DataParMetrics};
use crate::util::cancel::{RunControl, StopPolicy};
use crate::util::error::Result;
use crate::util::pool;

/// Everything a run produces.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub coloring: Coloring,
    pub num_colors: usize,
    pub metrics: DistMetrics,
    pub partition_metrics: PartitionMetrics,
    /// Colors after the initial coloring (before any recoloring).
    pub initial_colors: usize,
    /// Global color count after the initial coloring and after each
    /// recoloring iteration that ran (early stop can make this shorter
    /// than `1 + iterations`).
    pub recolor_trace: Vec<usize>,
    pub config_label: String,
    /// The execution path that actually ran ([`Engine::Auto`] resolved) —
    /// [`Engine::Bsp`], [`Engine::Threads`] or [`Engine::DataPar`], never
    /// `Auto` — so benchmark rows and bug reports are attributable.
    pub engine: Engine,
    /// DataPar's own accounting (rounds, speculated/conflicted vertices,
    /// per-round wall time) — `Some` iff the job ran on
    /// [`Engine::DataPar`]; the transport engines report through
    /// [`RunResult::metrics`] instead.
    pub datapar: Option<DataParMetrics>,
    /// `true` iff the run was stopped early (cancel/deadline/budget) under
    /// [`StopPolicy::Degrade`] and this result is the best-so-far coloring
    /// completed and repaired to validity — valid, but not what an
    /// uninterrupted run would have produced.
    pub degraded: bool,
}

impl RunResult {
    /// One-line JSON summary (the CLI's `--json` result record). DataPar
    /// runs append a `"datapar"` object with the engine's own counters.
    pub fn summary_json(&self) -> String {
        let trace: Vec<String> = self.recolor_trace.iter().map(|k| k.to_string()).collect();
        let datapar = match &self.datapar {
            Some(dp) => format!(
                ",\"datapar\":{{\"rounds\":{},\"speculated\":{},\"conflicted\":{},\
                 \"chunks\":{},\"workers\":{},\"wall_secs\":{:e}}}",
                dp.rounds, dp.speculated, dp.conflicted, dp.chunks, dp.workers, dp.wall_secs,
            ),
            None => String::new(),
        };
        // appended only when set, so undisturbed runs keep a byte-identical
        // summary line
        let degraded = if self.degraded { ",\"degraded\":true" } else { "" };
        format!(
            "{{\"result\":\"coloring\",\"config\":\"{}\",\"engine\":\"{}\",\"colors\":{},\
             \"initial_colors\":{},\"recolor_trace\":[{}],\"makespan\":{:e},\"messages\":{},\
             \"bytes\":{},\"conflicts\":{},\"rounds\":{}{}{}}}",
            self.config_label,
            self.engine.name(),
            self.num_colors,
            self.initial_colors,
            trace.join(","),
            self.metrics.makespan,
            self.metrics.total_msgs,
            self.metrics.total_bytes,
            self.metrics.total_conflicts,
            self.metrics.rounds,
            datapar,
            degraded,
        )
    }
}

/// Which execution path runs the distributed section of a job. Every job
/// shape — framework, sync RC and aRC alike — is bulk-synchronous, so
/// `Auto` always resolves to the step engine; only an explicit
/// [`Engine::Threads`] picks the thread-per-process reference oracle, and
/// only an explicit [`Engine::DataPar`] takes the shared-memory
/// speculative path (it is a different algorithm, not a faster simulation
/// of the same one, so `Auto` never routes there).
fn resolve_engine(engine: Engine) -> Engine {
    match engine {
        Engine::Threads => Engine::Threads,
        Engine::Auto | Engine::Bsp => Engine::Bsp,
        Engine::DataPar => Engine::DataPar,
    }
}

/// Run a validated job against pre-built artifacts. This is the shared
/// core under [`Session::run`](super::Session::run) and the [`run_job`]
/// shim: everything per-graph (partition metrics, local graphs, cost
/// model) comes in from the caller, so sessions can cache it across jobs.
pub(crate) fn execute(
    g: &CsrGraph,
    part_metrics: &PartitionMetrics,
    locals: &[LocalGraph],
    cost: &CostModel,
    job: &Job,
    ctl: Option<&RunControl>,
    obs: Option<&dyn Observer>,
) -> Result<RunResult> {
    let cfg = job.config();
    if let Some(o) = obs {
        o.on_event(&Event::PhaseStarted {
            phase: Phase::InitialColoring,
        });
    }

    let fw = FrameworkConfig {
        ordering: cfg.ordering,
        selection: cfg.selection,
        superstep_size: cfg.superstep_size,
        sync: cfg.sync,
        seed: cfg.seed,
        max_rounds: 200,
    };

    // sync RC reads the early-stop policy from its own config; aRC is
    // iterated here, so the pipeline applies the policy itself below.
    // Validation rejects jobs that set both knobs, so this never
    // overrides a caller-supplied RecolorConfig policy.
    let recolor_mode = match (cfg.recolor, cfg.early_stop) {
        (RecolorMode::Sync(mut rc), Some(eps)) => {
            rc.early_stop = Some(eps);
            RecolorMode::Sync(rc)
        }
        (mode, _) => mode,
    };
    let early_stop = cfg.early_stop;
    let cost = *cost;
    let engine_used = resolve_engine(cfg.engine);

    if engine_used == Engine::DataPar {
        return execute_datapar(g, part_metrics, cfg, ctl, obs);
    }

    if engine_used == Engine::Bsp {
        let rc_plan = match &recolor_mode {
            RecolorMode::None => RcPlan::None,
            RecolorMode::Sync(rc) => RcPlan::Sync(*rc),
            RecolorMode::Async { perm, iterations } => RcPlan::Async {
                perm: *perm,
                iterations: *iterations,
                seed: cfg.seed,
                early_stop,
            },
        };
        // an active fault plan needs the supervising engine (checkpoints,
        // stall-instead-of-panic, recovery); fault-free jobs keep the
        // lockstep worker-pool engine bit-for-bit unchanged
        let token = ctl.map(|c| &c.token);
        let outcome = if cfg.faults.is_active() {
            engine::run_steps_supervised_cancellable(
                g.num_vertices(),
                locals,
                cfg.network,
                cfg.faults.clone(),
                obs,
                token,
                |lg| JobMachine::new(lg, &fw, &cost, rc_plan, obs),
            )?
        } else {
            engine::run_steps_cancellable(g.num_vertices(), locals, cfg.network, token, |lg| {
                JobMachine::new(lg, &fw, &cost, rc_plan, obs)
            })
        };
        return finalize(g, part_metrics, cfg, outcome, engine_used, ctl, obs);
    }

    // The thread runner's cancellation protocol is consensus-by-allreduce:
    // every process votes its token poll at each checkpoint (framework
    // round tops inside `color_process_cancellable`, the recolor phase
    // boundary, and each aRC iteration top), so all ranks take the same
    // stop decision and nobody stops sending while a peer still waits.
    // The votes are extra collectives, so modeled quantities shift — but
    // only when a token is attached; the `ctl: None` path below is the
    // exact pre-cancellation closure, bit for bit.
    let token = ctl.map(|c| &c.token);
    let aborted = std::sync::atomic::AtomicBool::new(false);
    let outcome = try_run_distributed_with(g, locals, cfg.network, |ep, lg| {
        let mut state = ColorState::uncolored(lg);
        let to_color: Vec<u32> = (0..lg.n_owned() as u32).collect();
        let (mut metrics, mut stop) = framework::color_process_cancellable(
            ep, lg, &fw, &cost, &mut state, to_color, None, token, obs,
        );

        let n_owned = lg.n_owned();
        if stop.is_none() {
            // the initial color count is the first trace entry
            let local_kmax = (0..n_owned)
                .map(|v| state.colors[v] as u64 + 1)
                .max()
                .unwrap_or(0);
            let initial_k =
                framework::comm_timed(ep, &mut metrics, |ep| ep.allreduce_max_u64(local_kmax));
            metrics.recolor_trace.push(initial_k as usize);

            // consensus stop check at the recolor phase boundary
            if let Some(tok) = token {
                let vote = tok.check(ep.clock).is_some() as u64;
                let agreed =
                    framework::comm_timed(ep, &mut metrics, |ep| ep.allreduce_max_u64(vote));
                if agreed != 0 {
                    stop = tok.stopped();
                }
            }
            if stop.is_none() && !matches!(recolor_mode, RecolorMode::None) {
                emit_rank0(
                    obs,
                    ep.rank,
                    Event::PhaseStarted {
                        phase: Phase::Recoloring,
                    },
                );
            }
            match &recolor_mode {
                _ if stop.is_some() => {}
                RecolorMode::None => {}
                RecolorMode::Sync(rc) => {
                    // sync RC is bounded (one superstep per color class)
                    // and runs to completion once entered
                    let mut trace = Vec::new();
                    let m = recolor::recolor_process_sync(
                        ep, lg, &cost, rc, &mut state, &mut trace, obs,
                    );
                    metrics.phases.merge(&m.phases);
                    metrics.conflicts += m.conflicts;
                    metrics.recolor_trace.extend(trace);
                }
                RecolorMode::Async { perm, iterations } => {
                    for iter in 1..=*iterations {
                        // consensus stop check at each aRC iteration top
                        if let Some(tok) = token {
                            let vote = tok.check(ep.clock).is_some() as u64;
                            let agreed = framework::comm_timed(ep, &mut metrics, |ep| {
                                ep.allreduce_max_u64(vote)
                            });
                            if agreed != 0 {
                                stop = tok.stopped();
                                break;
                            }
                        }
                        let m = recolor::recolor_process_async(
                            ep, lg, &cost, &fw, *perm, iter, cfg.seed, &mut state, obs,
                        );
                        metrics.phases.merge(&m.phases);
                        metrics.conflicts += m.conflicts;
                        metrics.rounds += m.rounds;
                        let local_kmax = (0..n_owned)
                            .map(|v| state.colors[v] as u64 + 1)
                            .max()
                            .unwrap_or(0);
                        let k = framework::comm_timed(ep, &mut metrics, |ep| {
                            ep.allreduce_max_u64(local_kmax)
                        });
                        let prev = *metrics.recolor_trace.last().unwrap_or(&0);
                        metrics.recolor_trace.push(k as usize);
                        emit_rank0(
                            obs,
                            ep.rank,
                            Event::RecolorIteration {
                                iter,
                                k: k as usize,
                            },
                        );
                        if let Some(eps) = early_stop {
                            // prev and k come from allreduces: every process
                            // stops at the same iteration
                            let improvement = (prev as f64 - k as f64) / (prev as f64).max(1.0);
                            if improvement < eps {
                                break;
                            }
                        }
                    }
                }
            }
        }
        if stop.is_some() {
            aborted.store(true, std::sync::atomic::Ordering::Relaxed);
        }

        // final accounting comes from the endpoint (cumulative)
        metrics.vtime = ep.clock;
        metrics.sent_msgs = ep.sent_msgs;
        metrics.sent_bytes = ep.sent_bytes;
        metrics.recv_msgs = ep.recv_msgs;
        metrics.dropped_msgs = ep.dropped_msgs;
        metrics.non_teardown_drops = ep.non_teardown_drops;
        ProcResult {
            colors: state.owned_pairs(lg),
            metrics,
        }
    })?;
    let mut outcome = outcome;
    if aborted.load(std::sync::atomic::Ordering::Relaxed) {
        // the verdict latched before any worker voted to stop, and the
        // runner joined every thread: `stopped()` is Some here
        outcome.stopped = ctl.and_then(|c| c.token.stopped());
    }
    finalize(g, part_metrics, cfg, outcome, engine_used, ctl, obs)
}

/// The [`Engine::DataPar`] path: no transport, no partition, no cost
/// model — the shared-memory speculate/detect/resolve core runs over the
/// raw graph on the global worker pool, with each detection sweep
/// surfaced as [`Event::ConflictRound`]. The outcome is wrapped as a
/// single-proc [`DistOutcome`] (wall time standing in for the virtual
/// clock; zero messages/bytes) so [`finalize`] and the [`RunResult`]
/// surface stay uniform across engines.
fn execute_datapar(
    g: &CsrGraph,
    part_metrics: &PartitionMetrics,
    cfg: &ColoringConfig,
    ctl: Option<&RunControl>,
    obs: Option<&dyn Observer>,
) -> Result<RunResult> {
    let dp_cfg = shm::DataParConfig {
        ordering: cfg.ordering,
        selection: cfg.selection,
        seed: cfg.seed,
        ..shm::DataParConfig::default()
    };
    let (coloring, dp, stopped) = shm::datapar::color_graph_cancellable(
        pool::global(),
        g,
        &dp_cfg,
        ctl.map(|c| &c.token),
        &mut |round, conflicts| {
            if let Some(o) = obs {
                o.on_event(&Event::ConflictRound { round, conflicts });
            }
        },
    )?;
    let num_colors = coloring.num_colors();
    let per_proc = vec![ProcMetrics {
        conflicts: dp.conflicted,
        rounds: dp.rounds,
        recolor_trace: vec![num_colors],
        vtime: dp.wall_secs,
        ..ProcMetrics::default()
    }];
    let outcome = DistOutcome {
        coloring,
        metrics: DistMetrics::aggregate(&per_proc, dp.wall_secs),
        per_proc,
        stopped,
    };
    let mut res = finalize(g, part_metrics, cfg, outcome, Engine::DataPar, ctl, obs)?;
    res.datapar = Some(dp);
    Ok(res)
}

/// The engine-independent tail of a run: validate, take the trace, emit
/// the closing events, assemble the [`RunResult`].
///
/// A run the engine stopped early (`outcome.stopped`) branches on the
/// [`StopPolicy`]: `Fail` emits `Done(Err)` and returns the cause's typed
/// error; `Degrade` completes and repairs the best-so-far coloring through
/// [`repair_coloring`] and returns it flagged `degraded: true`.
fn finalize(
    g: &CsrGraph,
    part_metrics: &PartitionMetrics,
    cfg: &ColoringConfig,
    mut outcome: crate::dist::DistOutcome,
    engine_used: Engine,
    ctl: Option<&RunControl>,
    obs: Option<&dyn Observer>,
) -> Result<RunResult> {
    if let Some(o) = obs {
        o.on_event(&Event::PhaseStarted {
            phase: Phase::Validation,
        });
    }
    if let Some(cause) = outcome.stopped {
        match ctl.map(|c| c.policy).unwrap_or_default() {
            StopPolicy::Fail => {
                let e = cause.to_error();
                if let Some(o) = obs {
                    o.on_event(&Event::Done {
                        result: Err(DoneError::of(&e)),
                    });
                }
                return Err(e);
            }
            StopPolicy::Degrade => {
                // best-effort result: abort left a partial (and possibly
                // conflicted) coloring — complete and repair it. The
                // teardown-drop protocol check is skipped: stopping between
                // supersteps legitimately abandons in-flight messages.
                repair_coloring(g, &mut outcome.coloring, cfg.seed, obs)?;
                outcome.coloring.validate(g).map_err(|e| {
                    err!(
                        "invalid degraded coloring from {} after repair: {e}",
                        cfg.label()
                    )
                })?;
            }
        }
    } else {
        // fault-free mode: a drop outside acknowledged teardown is a
        // protocol bug, surfaced as a typed error (debug builds assert at
        // the drop site)
        if !cfg.faults.is_active() && outcome.metrics.total_non_teardown_drops > 0 {
            return Err(err!(
                "transport dropped {} message(s) outside teardown in fault-free mode \
                 (teardown report by rank: {:?})",
                outcome.metrics.total_non_teardown_drops,
                outcome.metrics.dropped_by_rank
            ));
        }
        // post-job validation fast path: the pool-parallel conflict count
        // covers the common (valid) case; the serial `validate` — which
        // names the offending edge in its typed error — only runs when it
        // fails
        let fast_valid = outcome.coloring.len() == g.num_vertices()
            && outcome.coloring.is_complete()
            && outcome.coloring.count_conflicts(g) == 0;
        if !fast_valid {
            if let Err(e) = outcome.coloring.validate(g) {
                if cfg.faults.is_active() {
                    // graceful degradation: injected faults left conflicts —
                    // run the localized repair pass before giving up
                    repair_coloring(g, &mut outcome.coloring, cfg.seed, obs)?;
                    outcome.coloring.validate(g).map_err(|e| {
                        err!("invalid coloring from {} after repair: {e}", cfg.label())
                    })?;
                } else {
                    return Err(err!("invalid coloring from {}: {e}", cfg.label()));
                }
            }
        }
    }

    // every process derives the trace from the same allreduced counts —
    // take rank 0's instead of cloning it (a stopped run's abort snapshots
    // can legitimately diverge, e.g. a crashed rank rolled back mid-trace)
    debug_assert!(
        outcome.stopped.is_some()
            || outcome
                .per_proc
                .iter()
                .all(|p| p.recolor_trace == outcome.per_proc[0].recolor_trace),
        "per-process recolor traces diverged"
    );
    let trace = std::mem::take(&mut outcome.per_proc[0].recolor_trace);
    let num_colors = outcome.coloring.num_colors();
    if let Some(o) = obs {
        o.on_event(&Event::Done {
            result: Ok(num_colors),
        });
    }
    Ok(RunResult {
        num_colors,
        initial_colors: *trace.first().unwrap_or(&num_colors),
        recolor_trace: trace,
        coloring: outcome.coloring,
        metrics: outcome.metrics,
        partition_metrics: part_metrics.clone(),
        config_label: cfg.label(),
        engine: engine_used,
        datapar: None,
        degraded: outcome.stopped.is_some(),
    })
}

/// Localized post-validation repair, reusing the framework's conflict
/// tie-break: every conflicting edge contributes its [`framework::loses`]
/// loser, and losers are sequentially first-fit recolored against the
/// *current* coloring — a sequential repair can therefore not introduce a
/// new conflict, so one pass normally suffices; the loop is bounded for
/// defense in depth. Uncolored vertices (an aborted run's unfinished
/// remainder) are treated as losers and first-fit completed the same way.
/// Each pass is reported as [`Event::RepairPass`]. Returns the number of
/// repair passes that ran.
pub fn repair_coloring(
    g: &CsrGraph,
    coloring: &mut Coloring,
    seed: u64,
    obs: Option<&dyn Observer>,
) -> Result<u32> {
    const MAX_PASSES: u32 = 3;
    let mut used: Vec<u32> = Vec::new();
    for pass in 1..=MAX_PASSES {
        let mut losers: Vec<u32> = Vec::new();
        for u in 0..g.num_vertices() as u32 {
            let cu = coloring.colors[u as usize];
            if cu == UNCOLORED {
                losers.push(u);
                continue;
            }
            for &v in g.neighbors(u) {
                if v > u && coloring.colors[v as usize] == cu {
                    losers.push(if framework::loses(u, v, seed) { u } else { v });
                }
            }
        }
        losers.sort_unstable();
        losers.dedup();
        if losers.is_empty() {
            return Ok(pass - 1);
        }
        if let Some(o) = obs {
            o.on_event(&Event::RepairPass {
                pass,
                conflicts: losers.len(),
            });
        }
        for &v in &losers {
            used.clear();
            used.extend(g.neighbors(v).iter().map(|&u| coloring.colors[u as usize]));
            used.sort_unstable();
            let mut c = 0u32;
            for &uc in &used {
                if uc == c {
                    c += 1;
                } else if uc > c {
                    break;
                }
            }
            coloring.colors[v as usize] = c;
        }
    }
    coloring
        .validate(g)
        .map_err(|e| err!("coloring still conflicted after {MAX_PASSES} repair passes: {e}"))?;
    Ok(MAX_PASSES)
}

/// The recoloring section a [`JobMachine`] runs after the framework —
/// [`RecolorMode`] flattened to what the step machines need (aRC carries
/// the job seed and the job-level early-stop policy).
#[derive(Clone, Copy)]
enum RcPlan {
    None,
    Sync(RecolorConfig),
    Async {
        perm: Permutation,
        iterations: u32,
        seed: u64,
        early_stop: Option<f64>,
    },
}

/// The pipeline closure above as a step machine for the BSP engine: the
/// framework port, the initial-count allreduce (booked under "comm"), the
/// recoloring phase event, the sync-RC or aRC port, and the final
/// cumulative accounting — in exactly the thread closure's order, so both
/// execution paths are bit-for-bit interchangeable.
///
/// `Clone` snapshots the whole machine — the supervising engine's crash
/// checkpoint.
#[derive(Clone)]
struct JobMachine<'a> {
    lg: &'a LocalGraph,
    cost: CostModel,
    /// Kept for constructing the aRC rerun machine after the framework.
    fw_cfg: FrameworkConfig,
    obs: Option<&'a dyn Observer>,
    rc_plan: RcPlan,
    fw: Option<FrameworkStep<'a>>,
    rc: Option<SyncRcStep<'a>>,
    arc: Option<AsyncRcStep<'a>>,
    metrics: ProcMetrics,
    colors: Option<ColorState>,
    comm_t0: f64,
    coll_seq: u32,
    coll_acc: u64,
    state: JobState,
}

#[derive(Clone, Copy)]
enum JobState {
    Framework,
    InitKSend,
    InitKReduce,
    InitKFinish,
    Recolor,
    RecolorAsync,
    Finalize,
}

impl<'a> JobMachine<'a> {
    fn new(
        lg: &'a LocalGraph,
        fw: &FrameworkConfig,
        cost: &CostModel,
        rc_plan: RcPlan,
        obs: Option<&'a dyn Observer>,
    ) -> Self {
        let to_color: Vec<u32> = (0..lg.n_owned() as u32).collect();
        let colors = ColorState::uncolored(lg);
        JobMachine {
            lg,
            cost: *cost,
            fw_cfg: *fw,
            obs,
            rc_plan,
            fw: Some(FrameworkStep::new(lg, fw, cost, colors, to_color, None, obs)),
            rc: None,
            arc: None,
            metrics: ProcMetrics::default(),
            colors: None,
            comm_t0: 0.0,
            coll_seq: 0,
            coll_acc: 0,
            state: JobState::Framework,
        }
    }
}

impl StepProcess for JobMachine<'_> {
    fn poll_ready(&mut self, ep: &mut Endpoint) -> bool {
        match self.state {
            JobState::Framework => self.fw.as_mut().expect("framework machine").ready(ep),
            JobState::InitKReduce => {
                ep.rank != 0
                    || (1..self.lg.nprocs)
                        .all(|p| ep.have_msg(p, MsgKind::Collective, self.coll_seq, 0))
            }
            JobState::InitKFinish => {
                ep.rank == 0 || ep.have_msg(0, MsgKind::Collective, self.coll_seq, 1)
            }
            JobState::Recolor => self.rc.as_mut().expect("rc machine").ready(ep),
            JobState::RecolorAsync => self.arc.as_mut().expect("arc machine").ready(ep),
            JobState::InitKSend | JobState::Finalize => true,
        }
    }

    /// Cancellation harvest: surrender the best-so-far colors from
    /// whichever sub-machine currently holds them, with the endpoint's
    /// cumulative accounting — so a stopped run's [`ProcResult`] carries a
    /// usable partial coloring for the `Degrade` policy instead of the
    /// engine's empty fallback.
    fn abort(&mut self, ep: &mut Endpoint) -> Option<ProcResult> {
        let colors = if let Some(c) = self.colors.take() {
            c
        } else if let Some(fw) = self.fw.take() {
            fw.abort_colors()
        } else if let Some(rc) = self.rc.take() {
            rc.abort_colors()
        } else if let Some(arc) = self.arc.take() {
            arc.abort_colors()
        } else {
            ColorState::uncolored(self.lg)
        };
        self.metrics.vtime = ep.clock;
        self.metrics.sent_msgs = ep.sent_msgs;
        self.metrics.sent_bytes = ep.sent_bytes;
        self.metrics.recv_msgs = ep.recv_msgs;
        self.metrics.dropped_msgs = ep.dropped_msgs;
        self.metrics.non_teardown_drops = ep.non_teardown_drops;
        Some(ProcResult {
            colors: colors.owned_pairs(self.lg),
            metrics: std::mem::take(&mut self.metrics),
        })
    }

    fn step(&mut self, ep: &mut Endpoint) -> StepOutcome {
        match self.state {
            JobState::Framework => {
                if self.fw.as_mut().expect("framework machine").step_once(ep) {
                    let (colors, metrics) = self.fw.take().unwrap().into_parts();
                    self.colors = Some(colors);
                    self.metrics = metrics;
                    self.state = JobState::InitKSend;
                }
            }
            JobState::InitKSend => {
                // the initial color count is the first trace entry; the
                // allreduce's virtual time is booked under "comm"
                self.comm_t0 = ep.clock;
                let colors = self.colors.as_ref().unwrap();
                let local_kmax = (0..self.lg.n_owned())
                    .map(|v| colors.colors[v] as u64 + 1)
                    .max()
                    .unwrap_or(0);
                self.coll_acc = local_kmax;
                self.coll_seq = ep.coll_send_u64(local_kmax);
                self.state = JobState::InitKReduce;
            }
            JobState::InitKReduce => {
                if ep.rank == 0 {
                    self.coll_acc = ep.coll_reduce_u64(self.coll_seq, self.coll_acc, u64::max);
                }
                self.state = JobState::InitKFinish;
            }
            JobState::InitKFinish => {
                let initial_k = ep.coll_finish_u64(self.coll_seq, self.coll_acc);
                self.metrics.phases.add("comm", ep.clock - self.comm_t0);
                self.metrics.recolor_trace.push(initial_k as usize);
                if !matches!(self.rc_plan, RcPlan::None) {
                    emit_rank0(
                        self.obs,
                        ep.rank,
                        Event::PhaseStarted {
                            phase: Phase::Recoloring,
                        },
                    );
                }
                match self.rc_plan {
                    RcPlan::Sync(rc) => {
                        let colors = self.colors.take().unwrap();
                        self.rc = Some(SyncRcStep::new(self.lg, &self.cost, rc, colors, self.obs));
                        self.state = JobState::Recolor;
                    }
                    RcPlan::Async {
                        perm,
                        iterations,
                        seed,
                        early_stop,
                    } => {
                        let colors = self.colors.take().unwrap();
                        self.arc = Some(AsyncRcStep::new(
                            self.lg,
                            &self.cost,
                            &self.fw_cfg,
                            perm,
                            iterations,
                            seed,
                            early_stop,
                            initial_k as usize,
                            colors,
                            self.obs,
                        ));
                        self.state = JobState::RecolorAsync;
                    }
                    RcPlan::None => self.state = JobState::Finalize,
                }
            }
            JobState::Recolor => {
                if self.rc.as_mut().expect("rc machine").step_once(ep) {
                    let (colors, trace, m) = self.rc.take().unwrap().into_parts();
                    self.colors = Some(colors);
                    self.metrics.phases.merge(&m.phases);
                    self.metrics.conflicts += m.conflicts;
                    self.metrics.recolor_trace.extend(trace);
                    self.state = JobState::Finalize;
                }
            }
            JobState::RecolorAsync => {
                if self.arc.as_mut().expect("arc machine").step_once(ep) {
                    let (colors, trace, m) = self.arc.take().unwrap().into_parts();
                    self.colors = Some(colors);
                    self.metrics.phases.merge(&m.phases);
                    self.metrics.conflicts += m.conflicts;
                    self.metrics.rounds += m.rounds;
                    self.metrics.recolor_trace.extend(trace);
                    self.state = JobState::Finalize;
                }
            }
            JobState::Finalize => {
                // final accounting comes from the endpoint (cumulative)
                self.metrics.vtime = ep.clock;
                self.metrics.sent_msgs = ep.sent_msgs;
                self.metrics.sent_bytes = ep.sent_bytes;
                self.metrics.recv_msgs = ep.recv_msgs;
                self.metrics.dropped_msgs = ep.dropped_msgs;
                self.metrics.non_teardown_drops = ep.non_teardown_drops;
                let colors = self.colors.take().unwrap();
                return StepOutcome::Done(ProcResult {
                    colors: colors.owned_pairs(self.lg),
                    metrics: std::mem::take(&mut self.metrics),
                });
            }
        }
        StepOutcome::Running
    }
}

/// Run a full distributed coloring job and validate the result.
///
/// Kept as a one-shot shim: it re-partitions the graph and re-resolves the
/// cost model on every call. Build a [`Session`](super::Session) and run
/// jobs through [`Job::on`](super::Job::on) instead — identical results,
/// cached artifacts. The shim applies the full [`Job`] validation, so
/// degenerate configs the old `run_job` silently tolerated (a zero
/// superstep size, `RandomX(0)`, zero-iteration recoloring) now error.
#[deprecated(
    since = "0.2.0",
    note = "build a coordinator::Session and run jobs via Job::on(&session)"
)]
pub fn run_job(g: &CsrGraph, cfg: &ColoringConfig) -> Result<RunResult> {
    let job = Job::from_config(cfg.clone())?;
    if cfg.engine == Engine::DataPar {
        // no transport, no partition: the datapar path only needs the graph
        return execute(
            g,
            &datapar_partition_metrics(),
            &[],
            &CostModel::fixed(),
            &job,
            None,
            None,
        );
    }
    let part = partition::partition(g, cfg.partitioner, cfg.num_procs, cfg.seed);
    let part_metrics = partition::metrics(g, &part);
    let (_, locals) = build_local_graphs(g, &part);
    let cost = cfg.cost_model();
    execute(g, &part_metrics, &locals, &cost, &job, None, None)
}

/// The synthetic (empty) partition record a DataPar run carries —
/// there is one address space, so no cut, no boundary, perfect balance.
pub(crate) fn datapar_partition_metrics() -> PartitionMetrics {
    PartitionMetrics {
        edge_cut: 0,
        boundary_vertices: 0,
        imbalance: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::recolor::Permutation;
    use crate::color::{Ordering, Selection};
    use crate::coordinator::job::nd;
    use crate::coordinator::session::Session;
    use crate::dist::cost::CostModel;
    use crate::graph::synth;

    fn session(g: CsrGraph) -> Session {
        Session::new(g).with_cost_model(CostModel::fixed())
    }

    #[test]
    fn initial_coloring_valid() {
        let s = session(synth::grid2d(20, 20));
        let r = Job::on(&s).procs(4).run().unwrap();
        let dmax = s.graph().max_degree();
        assert!(r.num_colors >= 2 && r.num_colors <= dmax + 1);
        assert_eq!(r.recolor_trace.len(), 1);
        assert!(r.metrics.makespan > 0.0);
    }

    #[test]
    fn sync_recolor_reduces_or_holds() {
        let s = session(synth::fem_like(3000, 12.0, 30, 0.0, 7, "fem"));
        let r = Job::on(&s)
            .procs(4)
            .selection(Selection::RandomX(10))
            .sync_recolor(nd(3))
            .run()
            .unwrap();
        assert_eq!(r.recolor_trace.len(), 4);
        assert!(r.recolor_trace.windows(2).all(|w| w[1] <= w[0]),
            "trace {:?}", r.recolor_trace);
        assert!(r.num_colors < r.initial_colors);
    }

    #[test]
    fn async_recolor_valid() {
        let s = session(synth::grid2d(30, 30));
        let r = Job::on(&s)
            .procs(4)
            .async_recolor(Permutation::NonDecreasing, 1)
            .run()
            .unwrap();
        assert_eq!(r.recolor_trace.len(), 2);
        assert!(r.num_colors >= 2);
    }

    #[test]
    fn async_comm_initial_coloring() {
        let s = session(synth::erdos_renyi(1500, 9000, 13));
        let r = Job::on(&s)
            .procs(6)
            .async_comm()
            .ordering(Ordering::SmallestLast)
            .run()
            .unwrap();
        assert!(r.num_colors <= s.graph().max_degree() + 1);
    }

    #[test]
    fn single_proc_matches_sequential_shape() {
        let s = session(synth::grid2d(15, 15));
        let r = Job::on(&s).procs(1).run().unwrap();
        // one processor, no boundary, no conflicts
        assert_eq!(r.metrics.total_conflicts, 0);
        assert!(r.num_colors <= 4);
    }

    /// Thread runner and BSP step engine must be interchangeable: same
    /// colors, traces, accounting bits, and the same event stream.
    #[test]
    fn thread_and_bsp_engines_are_bit_for_bit_interchangeable() {
        use crate::coordinator::EventLog;
        use crate::dist::Engine;
        let s = session(synth::fem_like(1200, 10.0, 26, 0.004, 2, "fem"));
        let builders: Vec<Job> = vec![
            Job::on(&s).procs(6).speed().build().unwrap(),
            Job::on(&s).procs(5).quality().build().unwrap(),
            Job::on(&s)
                .procs(4)
                .selection(Selection::RandomX(7))
                .superstep(32)
                .sync_recolor(nd(3))
                .build()
                .unwrap(),
            Job::on(&s).procs(3).async_comm().build().unwrap(),
            Job::on(&s).procs(1).quality().build().unwrap(),
            Job::on(&s)
                .procs(4)
                .selection(Selection::RandomX(7))
                .async_recolor(Permutation::NonDecreasing, 2)
                .build()
                .unwrap(),
            Job::on(&s)
                .procs(3)
                .async_recolor(Permutation::NonIncreasing, 3)
                .stop_when_improvement_below(0.05)
                .build()
                .unwrap(),
        ];
        for job in builders {
            let mut cfg = job.config().clone();
            cfg.engine = Engine::Threads;
            let log_t = EventLog::new();
            let t = s
                .run_observed(&Job::from_config(cfg.clone()).unwrap(), &log_t)
                .unwrap();
            cfg.engine = Engine::Bsp;
            let log_e = EventLog::new();
            let e = s
                .run_observed(&Job::from_config(cfg.clone()).unwrap(), &log_e)
                .unwrap();
            assert_eq!(t.coloring.colors, e.coloring.colors, "{}", cfg.label());
            assert_eq!(t.recolor_trace, e.recolor_trace, "{}", cfg.label());
            assert_eq!(t.num_colors, e.num_colors);
            assert_eq!(t.metrics.total_msgs, e.metrics.total_msgs, "{}", cfg.label());
            assert_eq!(t.metrics.total_bytes, e.metrics.total_bytes);
            assert_eq!(t.metrics.total_conflicts, e.metrics.total_conflicts);
            assert_eq!(t.metrics.total_dropped, 0);
            assert_eq!(e.metrics.total_dropped, 0);
            assert_eq!(
                t.metrics.makespan.to_bits(),
                e.metrics.makespan.to_bits(),
                "makespan diverged for {}",
                cfg.label()
            );
            assert_eq!(log_t.take(), log_e.take(), "event streams must match");
        }
    }

    #[test]
    fn arc_jobs_run_on_the_engine_under_auto() {
        // aRC under the default Auto engine resolves to the step engine
        // (the thread fallback is gone), and the result records it
        let s = session(synth::grid2d(16, 16));
        let r = Job::on(&s)
            .procs(4)
            .async_recolor(Permutation::NonDecreasing, 2)
            .run()
            .unwrap();
        assert_eq!(r.recolor_trace.len(), 3);
        assert_eq!(r.engine, Engine::Bsp, "Auto must resolve aRC to the engine");
        // explicit engines resolve to themselves
        let b = Job::on(&s)
            .procs(4)
            .async_recolor(Permutation::NonDecreasing, 1)
            .engine(Engine::Bsp)
            .run()
            .unwrap();
        assert_eq!(b.engine, Engine::Bsp);
        let t = Job::on(&s)
            .procs(4)
            .async_recolor(Permutation::NonDecreasing, 1)
            .engine(Engine::Threads)
            .run()
            .unwrap();
        assert_eq!(t.engine, Engine::Threads);
        assert_eq!(b.coloring.colors, t.coloring.colors);
    }

    #[test]
    fn datapar_engine_end_to_end() {
        use crate::coordinator::EventLog;
        use crate::dist::Engine;
        let s = session(synth::fem_like(2000, 10.0, 26, 0.01, 4, "dp"));
        let log = EventLog::new();
        let r = Job::on(&s)
            .engine(Engine::DataPar)
            .selection(Selection::RandomX(5))
            .run_observed(&log)
            .unwrap();
        r.coloring.validate(s.graph()).unwrap();
        assert_eq!(r.engine, Engine::DataPar);
        let dp = r.datapar.as_ref().expect("datapar metrics must be recorded");
        assert!(dp.rounds >= 1);
        assert_eq!(dp.per_round.len() as u32, dp.rounds);
        assert_eq!(dp.speculated, 2000 + dp.conflicted, "round 1 is n, the rest losers");
        assert_eq!(r.metrics.rounds, dp.rounds);
        assert_eq!(r.metrics.total_conflicts, dp.conflicted);
        assert_eq!(r.metrics.total_msgs, 0, "no transport, no messages");
        assert_eq!(r.recolor_trace, vec![r.num_colors], "no recoloring: trace is one entry");
        assert_eq!(r.initial_colors, r.num_colors);
        // events: normal phase stream, one ConflictRound per datapar round
        let events = log.take();
        assert!(events
            .iter()
            .any(|e| matches!(e, Event::PhaseStarted { phase: Phase::InitialColoring })));
        let rounds_seen = events
            .iter()
            .filter(|e| matches!(e, Event::ConflictRound { .. }))
            .count() as u32;
        assert_eq!(rounds_seen, dp.rounds);
        assert!(events
            .iter()
            .any(|e| matches!(e, Event::Done { result: Ok(k) } if *k == r.num_colors)));
        // deterministic: a re-run through the same session is bit-identical
        let r2 = Job::on(&s)
            .engine(Engine::DataPar)
            .selection(Selection::RandomX(5))
            .run()
            .unwrap();
        assert_eq!(r.coloring.colors, r2.coloring.colors);
        // and the summary names both the engine and the datapar block
        let j = r.summary_json();
        assert!(j.contains("\"engine\":\"datapar\""), "{j}");
        assert!(j.contains("\"datapar\":{\"rounds\":"), "{j}");
        assert!(j.ends_with('}'));
    }

    #[test]
    fn summary_json_shape() {
        let s = session(synth::grid2d(8, 8));
        let r = Job::on(&s).procs(2).run().unwrap();
        let j = r.summary_json();
        assert!(j.starts_with("{\"result\":\"coloring\""));
        assert!(j.contains(&format!("\"colors\":{}", r.num_colors)));
        assert!(j.contains("\"engine\":\"bsp\""), "summary must name the engine: {j}");
        assert!(j.ends_with('}'));
    }
}
