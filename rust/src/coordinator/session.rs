//! Reusable run state: a [`Session`] owns a graph plus cached, keyed
//! artifacts and runs many jobs against them.
//!
//! The deprecated `run_job` shim re-partitions the graph, rebuilds every
//! per-process local view and re-calibrates the cost model on every call,
//! so a 64-config sweep pays for identical preparation work 64 times. A
//! session does each only once:
//!
//! * **Partitions** are cached per `(partitioner, num_procs, seed)` key —
//!   every job that shares the key reuses the `Partition` and its
//!   [`PartitionMetrics`] (both deterministic functions of the key).
//! * **Local graphs** — the per-process views with ghosts the distributed
//!   phases run on — are built lazily per cached partition, in parallel on
//!   the worker pool ([`build_local_graphs_parallel`]), and shared as
//!   `Arc<[LocalGraph]>` + `Arc<GlobalMap>` by every subsequent run of the
//!   same key ([`PartitionHandle::locals`]).
//! * **The cost model** is calibrated at most once per session (jobs with
//!   an explicit `fixed_cost` bypass it).
//!
//! A cached run is bit-for-bit identical to a fresh `run_job` call with
//! the same config (`tests/session_api.rs` pins this), so sessions are a
//! pure speedup. `partition_calls()` exposes the cache's miss count; the
//! sweep tests pin "one partition per key per sweep" with it. Sessions
//! are `Send + Sync`, so a multi-graph sweep can run one session per
//! thread.
//!
//! The cache holds at most [`Session::partition_cache_cap`] keys
//! (default [`DEFAULT_PARTITION_CACHE_CAP`]); inserting past the cap
//! evicts the least-recently-used entry and counts it in
//! [`Session::partition_evictions`], so a long process-count sweep on a
//! huge graph does not hold every scale's ghosts alive. Handles already
//! held by callers stay valid after eviction (they are `Arc`s);
//! re-requesting an evicted key recomputes it.
//! [`Session::clear_cached_partitions`] still drops everything at once.

use super::event::{DoneError, Event, Observer, Phase};
use super::job::Job;
use super::pipeline::{self, RunResult};
use crate::dist::cost::CostModel;
use crate::util::cancel::RunControl;
use crate::dist::proc::{build_local_graphs_parallel, GlobalMap, LocalGraph};
use crate::dist::Engine;
use crate::graph::CsrGraph;
use crate::partition::{self, Partition, PartitionMetrics, Partitioner};
use crate::util::error::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Lock a session mutex, tolerating poison: a panicking job thread must
/// not wedge every later job on the shared session (the protected state
/// is only a cache plus a calibrated cost model, both valid at every
/// point the lock is held).
fn lock_tolerant<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Default bound on cached partition keys per session.
pub const DEFAULT_PARTITION_CACHE_CAP: usize = 32;

/// The distributed-run artifacts derived from one partition: the shared
/// vertex directory and every process's local view, both `Arc`-shared
/// across runs (and across the simulated processes of each run).
#[derive(Debug, Clone)]
pub struct LocalArtifacts {
    pub gmap: Arc<GlobalMap>,
    pub locals: Arc<[LocalGraph]>,
}

/// A partition together with its quality metrics and lazily-built local
/// graphs, cached per key.
#[derive(Debug)]
pub struct PartitionHandle {
    pub partition: Partition,
    pub metrics: PartitionMetrics,
    locals: OnceLock<LocalArtifacts>,
}

impl PartitionHandle {
    fn new(partition: Partition, metrics: PartitionMetrics) -> PartitionHandle {
        PartitionHandle {
            partition,
            metrics,
            locals: OnceLock::new(),
        }
    }

    /// The per-process local views of this partition, built on first use
    /// (in parallel on the worker pool) and shared by every later run of
    /// the same key.
    pub fn locals(&self, g: &CsrGraph) -> &LocalArtifacts {
        self.locals.get_or_init(|| {
            let (gmap, locals) = build_local_graphs_parallel(g, &self.partition);
            LocalArtifacts {
                gmap,
                locals: locals.into(),
            }
        })
    }

    /// Whether the local views were already built.
    pub fn has_locals(&self) -> bool {
        self.locals.get().is_some()
    }
}

type PartKey = (Partitioner, usize, u64);

struct CacheEntry {
    handle: Arc<PartitionHandle>,
    last_used: u64,
}

#[derive(Default)]
struct PartitionCache {
    map: HashMap<PartKey, CacheEntry>,
    tick: u64,
}

/// Owns a graph and the per-graph artifacts jobs share. See the module
/// docs; construct with [`Session::new`], run with [`Session::run`] or the
/// fluent [`Job::on`](super::Job::on).
pub struct Session {
    graph: CsrGraph,
    partitions: Mutex<PartitionCache>,
    cost: Mutex<Option<CostModel>>,
    partition_calls: AtomicUsize,
    evictions: AtomicUsize,
    cache_cap: usize,
}

impl Session {
    pub fn new(graph: CsrGraph) -> Session {
        Session {
            graph,
            partitions: Mutex::new(PartitionCache::default()),
            cost: Mutex::new(None),
            partition_calls: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            cache_cap: DEFAULT_PARTITION_CACHE_CAP,
        }
    }

    /// Pin the session's cost model (tests/benches) instead of calibrating
    /// on first use. Jobs with their own `fixed_cost` still take
    /// precedence.
    pub fn with_cost_model(self, cost: CostModel) -> Session {
        *lock_tolerant(&self.cost) = Some(cost);
        self
    }

    /// Bound the partition/local-graph cache at `cap` keys (>= 1); the
    /// least-recently-used entry is evicted past it.
    pub fn with_partition_cache_cap(mut self, cap: usize) -> Session {
        assert!(cap >= 1, "partition cache cap must be at least 1");
        self.cache_cap = cap;
        self
    }

    pub fn graph(&self) -> &CsrGraph {
        &self.graph
    }

    /// The session cost model, calibrating on this host at most once (the
    /// lock is held through calibration so concurrent callers wait
    /// instead of recalibrating).
    pub fn cost_model(&self) -> CostModel {
        let mut cost = lock_tolerant(&self.cost);
        *cost.get_or_insert_with(CostModel::calibrated)
    }

    /// The partition for `(partitioner, num_procs, seed)`, computed on
    /// first use and cached (bounded LRU — see the module docs).
    pub fn partition(
        &self,
        partitioner: Partitioner,
        num_procs: usize,
        seed: u64,
    ) -> Arc<PartitionHandle> {
        let key = (partitioner, num_procs, seed);
        let mut cache = lock_tolerant(&self.partitions);
        cache.tick += 1;
        let tick = cache.tick;
        if let Some(e) = cache.map.get_mut(&key) {
            e.last_used = tick;
            return Arc::clone(&e.handle);
        }
        self.partition_calls.fetch_add(1, Ordering::Relaxed);
        let p = partition::partition(&self.graph, partitioner, num_procs, seed);
        let metrics = partition::metrics(&self.graph, &p);
        let h = Arc::new(PartitionHandle::new(p, metrics));
        if cache.map.len() >= self.cache_cap {
            if let Some(lru) = cache
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            {
                cache.map.remove(&lru);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        cache.map.insert(
            key,
            CacheEntry {
                handle: Arc::clone(&h),
                last_used: tick,
            },
        );
        h
    }

    /// How many times the session actually partitioned (cache misses).
    pub fn partition_calls(&self) -> usize {
        self.partition_calls.load(Ordering::Relaxed)
    }

    /// How many cached partitions were evicted by the LRU bound.
    pub fn partition_evictions(&self) -> usize {
        self.evictions.load(Ordering::Relaxed)
    }

    /// The cache bound (see [`Session::with_partition_cache_cap`]).
    pub fn partition_cache_cap(&self) -> usize {
        self.cache_cap
    }

    /// How many distinct partition keys are cached.
    pub fn cached_partitions(&self) -> usize {
        lock_tolerant(&self.partitions).map.len()
    }

    /// Drop every cached partition (the miss counter keeps counting).
    /// Useful mid-session when sweeping keys that are never revisited —
    /// e.g. one job per process count on a huge graph.
    pub fn clear_cached_partitions(&self) {
        lock_tolerant(&self.partitions).map.clear();
    }

    /// Run one job against the session's cached artifacts.
    pub fn run(&self, job: &Job) -> Result<RunResult> {
        self.run_inner(job, None, None)
    }

    /// Run one job, streaming [`Event`]s to `obs`.
    pub fn run_observed(&self, job: &Job, obs: &dyn Observer) -> Result<RunResult> {
        self.run_inner(job, None, Some(obs))
    }

    /// Run one job under an explicit [`RunControl`] — the scheduler's
    /// entry point: the control's token (cancel/deadline/budget) is polled
    /// at every engine checkpoint, and its policy decides whether a stop
    /// fails typed or degrades to a best-so-far coloring. An explicit
    /// control overrides whatever the job's own deadline/budget knobs
    /// would derive.
    pub fn run_with_control(
        &self,
        job: &Job,
        ctl: &RunControl,
        obs: Option<&dyn Observer>,
    ) -> Result<RunResult> {
        self.run_inner(job, Some(ctl), obs)
    }

    /// Run a batch of jobs in order, returning a per-job `Result` — one
    /// invalid or cancelled job must not discard its completed siblings.
    /// (`sweep::run_sweep` loops [`Session::run`] instead so it can reduce
    /// each result to two scalars without retaining the colorings.)
    pub fn run_many(&self, jobs: &[Job]) -> Vec<Result<RunResult>> {
        jobs.iter().map(|j| self.run(j)).collect()
    }

    fn run_inner(
        &self,
        job: &Job,
        ctl: Option<&RunControl>,
        obs: Option<&dyn Observer>,
    ) -> Result<RunResult> {
        let cfg = job.config();
        // jobs carrying their own deadline/budget knobs derive a control
        // when the caller supplied none; plain jobs keep the untouched
        // (token-free, bit-for-bit pinned) path
        let derived = if ctl.is_none() { job.control() } else { None };
        let ctl = ctl.or(derived.as_ref());
        let res = if cfg.engine == Engine::DataPar {
            // the shared-memory engine has no transport: skip the
            // partition phase (and its cache) and the cost model entirely —
            // a DataPar job must not trigger host calibration
            let part_metrics = pipeline::datapar_partition_metrics();
            pipeline::execute(
                &self.graph,
                &part_metrics,
                &[],
                &CostModel::fixed(),
                job,
                ctl,
                obs,
            )
        } else {
            if let Some(o) = obs {
                o.on_event(&Event::PhaseStarted {
                    phase: Phase::Partition,
                });
            }
            let part = self.partition(cfg.partitioner, cfg.num_procs, cfg.seed);
            let cost = cfg.fixed_cost.unwrap_or_else(|| self.cost_model());
            let arts = part.locals(&self.graph);
            pipeline::execute(&self.graph, &part.metrics, &arts.locals, &cost, job, ctl, obs)
        };
        if let (Some(o), Err(e)) = (obs, &res) {
            // A failed job still terminates its event stream: observers
            // watching for `Done` never hang on an error path. The
            // pipeline's stop path already emitted its own `Done(Err)`;
            // this covers failures before/outside `finalize` — the kinds
            // differ, so double emission cannot occur.
            if !e.is_stop() {
                o.on_event(&Event::Done {
                    result: Err(DoneError::of(e)),
                });
            }
        }
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    #[test]
    fn partition_cache_hits_by_key() {
        let s = Session::new(synth::grid2d(12, 12)).with_cost_model(CostModel::fixed());
        let a = s.partition(Partitioner::Block, 4, 1);
        let b = s.partition(Partitioner::Block, 4, 1);
        assert_eq!(s.partition_calls(), 1, "second lookup must hit the cache");
        assert!(Arc::ptr_eq(&a, &b));
        s.partition(Partitioner::Block, 8, 1);
        s.partition(Partitioner::BfsGrow, 4, 1);
        s.partition(Partitioner::Block, 4, 2);
        assert_eq!(s.partition_calls(), 4);
        assert_eq!(s.cached_partitions(), 4);
        // clearing bounds retention; the miss counter keeps its history
        s.clear_cached_partitions();
        assert_eq!(s.cached_partitions(), 0);
        s.partition(Partitioner::Block, 4, 1);
        assert_eq!(s.partition_calls(), 5);
    }

    #[test]
    fn lru_bound_evicts_and_counts() {
        let s = Session::new(synth::grid2d(10, 10)).with_partition_cache_cap(2);
        assert_eq!(s.partition_cache_cap(), 2);
        let h1 = s.partition(Partitioner::Block, 2, 1);
        s.partition(Partitioner::Block, 3, 1);
        assert_eq!(s.partition_evictions(), 0);
        // touch key 1 so key 2 is the LRU, then insert a third
        s.partition(Partitioner::Block, 2, 1);
        s.partition(Partitioner::Block, 4, 1);
        assert_eq!(s.cached_partitions(), 2);
        assert_eq!(s.partition_evictions(), 1);
        // key 1 survived (recently used), key 2 was evicted
        assert_eq!(s.partition_calls(), 3);
        s.partition(Partitioner::Block, 2, 1);
        assert_eq!(s.partition_calls(), 3, "key 1 must still be cached");
        s.partition(Partitioner::Block, 3, 1);
        assert_eq!(s.partition_calls(), 4, "key 2 was evicted, recomputes");
        // an evicted handle held by the caller keeps working
        assert_eq!(h1.partition.num_parts, 2);
    }

    #[test]
    fn locals_are_built_once_per_key_and_shared() {
        let g = synth::grid2d(14, 14);
        let s = Session::new(g).with_cost_model(CostModel::fixed());
        let h = s.partition(Partitioner::Block, 4, 1);
        assert!(!h.has_locals(), "locals are lazy");
        let a = h.locals(s.graph());
        assert!(h.has_locals());
        assert_eq!(a.locals.len(), 4);
        let b = h.locals(s.graph());
        assert!(
            Arc::ptr_eq(&a.locals, &b.locals) && Arc::ptr_eq(&a.gmap, &b.gmap),
            "locals must be built once and shared"
        );
        // a run through the same key reuses the same artifacts
        let job = Job::on(&s).procs(4).build().unwrap();
        s.run(&job).unwrap();
        let c = s.partition(Partitioner::Block, 4, 1);
        assert!(Arc::ptr_eq(&h, &c));
        assert!(Arc::ptr_eq(&a.locals, &c.locals(s.graph()).locals));
    }

    #[test]
    fn sessions_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Session>();
    }

    #[test]
    fn pinned_cost_model_is_returned_verbatim() {
        let s = Session::new(synth::grid2d(4, 4)).with_cost_model(CostModel::fixed());
        assert_eq!(s.cost_model(), CostModel::fixed());
    }

    #[test]
    fn datapar_jobs_skip_partitioning_and_calibration() {
        use crate::coordinator::EventLog;
        // no pinned cost model: a DataPar run must not trigger calibration
        let s = Session::new(synth::grid2d(20, 20));
        let log = EventLog::new();
        let job = Job::on(&s).engine(Engine::DataPar).build().unwrap();
        let r = s.run_observed(&job, &log).unwrap();
        r.coloring.validate(s.graph()).unwrap();
        assert_eq!(s.partition_calls(), 0, "datapar must not partition");
        assert_eq!(s.cached_partitions(), 0);
        assert_eq!(r.partition_metrics.edge_cut, 0);
        assert!(
            !log.take().iter().any(|e| matches!(
                e,
                Event::PhaseStarted {
                    phase: Phase::Partition
                }
            )),
            "no partition phase for datapar"
        );
    }

    #[test]
    fn run_many_matches_individual_runs() {
        let s = Session::new(synth::grid2d(15, 15)).with_cost_model(CostModel::fixed());
        let jobs = [
            Job::on(&s).procs(2).speed().build().unwrap(),
            Job::on(&s).procs(4).quality().build().unwrap(),
        ];
        let batch = s.run_many(&jobs);
        assert_eq!(batch.len(), 2);
        for (job, r) in jobs.iter().zip(&batch) {
            let r = r.as_ref().expect("both jobs are valid");
            let single = s.run(job).unwrap();
            assert_eq!(single.coloring.colors, r.coloring.colors);
            assert_eq!(single.recolor_trace, r.recolor_trace);
        }
        // speed@2 and quality@4 use different keys; reruns hit the cache
        assert_eq!(s.partition_calls(), 2);
    }

    #[test]
    fn run_many_keeps_siblings_of_a_stopped_job() {
        use crate::util::cancel::{CancelToken, StopPolicy};
        use crate::util::error::ErrorKind;
        let s = Session::new(synth::grid2d(12, 12)).with_cost_model(CostModel::fixed());
        let jobs = [
            Job::on(&s).procs(2).build().unwrap(),
            // a pre-exhausted virtual budget stops this one at its first
            // checkpoint, deterministically
            Job::on(&s).procs(2).vclock_budget(f64::MIN_POSITIVE).build().unwrap(),
            Job::on(&s).procs(3).build().unwrap(),
        ];
        let batch = s.run_many(&jobs);
        assert_eq!(batch.len(), 3);
        assert!(batch[0].is_ok(), "sibling before the stopped job survives");
        assert_eq!(
            batch[1].as_ref().unwrap_err().kind(),
            ErrorKind::DeadlineExceeded
        );
        assert!(batch[2].is_ok(), "sibling after the stopped job survives");
        // the same stop under Degrade yields a valid flagged coloring
        let ctl = RunControl::new(
            CancelToken::with_limits(None, Some(f64::MIN_POSITIVE)),
            StopPolicy::Degrade,
        );
        let r = s.run_with_control(&jobs[0], &ctl, None).unwrap();
        assert!(r.degraded);
        r.coloring.validate(s.graph()).unwrap();
    }
}
