//! Reusable run state: a [`Session`] owns a graph plus cached, keyed
//! artifacts and runs many jobs against them.
//!
//! `run_job` re-partitions the graph and re-calibrates the cost model on
//! every call, so a 64-config sweep pays for identical partitioning work
//! 64 times. A session does each only once:
//!
//! * **Partitions** are cached per `(partitioner, num_procs, seed)` key —
//!   every job that shares the key reuses the `Partition` and its
//!   [`PartitionMetrics`] (both deterministic functions of the key).
//! * **The cost model** is calibrated at most once per session (jobs with
//!   an explicit `fixed_cost` bypass it).
//!
//! A cached run is bit-for-bit identical to a fresh `run_job` call with
//! the same config (`tests/session_api.rs` pins this), so sessions are a
//! pure speedup. `partition_calls()` exposes the cache's miss count; the
//! sweep tests pin "one partition per key per sweep" with it. Sessions
//! are `Send + Sync`, so a multi-graph sweep can run one session per
//! thread. The cache never evicts on its own — a proc-count sweep on a
//! huge graph touches each key once, so call
//! [`Session::clear_cached_partitions`] between scales to bound
//! retention.

use super::event::{Event, Observer, Phase};
use super::job::Job;
use super::pipeline::{self, RunResult};
use crate::dist::cost::CostModel;
use crate::graph::CsrGraph;
use crate::partition::{self, Partition, PartitionMetrics, Partitioner};
use crate::util::error::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A partition together with its quality metrics, cached per key.
#[derive(Debug)]
pub struct PartitionHandle {
    pub partition: Partition,
    pub metrics: PartitionMetrics,
}

type PartKey = (Partitioner, usize, u64);

/// Owns a graph and the per-graph artifacts jobs share. See the module
/// docs; construct with [`Session::new`], run with [`Session::run`] or the
/// fluent [`Job::on`](super::Job::on).
pub struct Session {
    graph: CsrGraph,
    partitions: Mutex<HashMap<PartKey, Arc<PartitionHandle>>>,
    cost: Mutex<Option<CostModel>>,
    partition_calls: AtomicUsize,
}

impl Session {
    pub fn new(graph: CsrGraph) -> Session {
        Session {
            graph,
            partitions: Mutex::new(HashMap::new()),
            cost: Mutex::new(None),
            partition_calls: AtomicUsize::new(0),
        }
    }

    /// Pin the session's cost model (tests/benches) instead of calibrating
    /// on first use. Jobs with their own `fixed_cost` still take
    /// precedence.
    pub fn with_cost_model(self, cost: CostModel) -> Session {
        *self.cost.lock().unwrap() = Some(cost);
        self
    }

    pub fn graph(&self) -> &CsrGraph {
        &self.graph
    }

    /// The session cost model, calibrating on this host at most once (the
    /// lock is held through calibration so concurrent callers wait
    /// instead of recalibrating).
    pub fn cost_model(&self) -> CostModel {
        let mut cost = self.cost.lock().unwrap();
        *cost.get_or_insert_with(CostModel::calibrated)
    }

    /// The partition for `(partitioner, num_procs, seed)`, computed on
    /// first use and cached.
    pub fn partition(
        &self,
        partitioner: Partitioner,
        num_procs: usize,
        seed: u64,
    ) -> Arc<PartitionHandle> {
        let key = (partitioner, num_procs, seed);
        let mut map = self.partitions.lock().unwrap();
        if let Some(h) = map.get(&key) {
            return Arc::clone(h);
        }
        self.partition_calls.fetch_add(1, Ordering::Relaxed);
        let p = partition::partition(&self.graph, partitioner, num_procs, seed);
        let metrics = partition::metrics(&self.graph, &p);
        let h = Arc::new(PartitionHandle {
            partition: p,
            metrics,
        });
        map.insert(key, Arc::clone(&h));
        h
    }

    /// How many times the session actually partitioned (cache misses).
    pub fn partition_calls(&self) -> usize {
        self.partition_calls.load(Ordering::Relaxed)
    }

    /// How many distinct partition keys are cached.
    pub fn cached_partitions(&self) -> usize {
        self.partitions.lock().unwrap().len()
    }

    /// Drop every cached partition (the miss counter keeps counting).
    /// Useful mid-session when sweeping keys that are never revisited —
    /// e.g. one job per process count on a huge graph.
    pub fn clear_cached_partitions(&self) {
        self.partitions.lock().unwrap().clear();
    }

    /// Run one job against the session's cached artifacts.
    pub fn run(&self, job: &Job) -> Result<RunResult> {
        self.run_inner(job, None)
    }

    /// Run one job, streaming [`Event`]s to `obs`.
    pub fn run_observed(&self, job: &Job, obs: &dyn Observer) -> Result<RunResult> {
        self.run_inner(job, Some(obs))
    }

    /// Run a batch of jobs in order, returning every full [`RunResult`].
    /// (`sweep::run_sweep` loops [`Session::run`] instead so it can reduce
    /// each result to two scalars without retaining the colorings.)
    pub fn run_many(&self, jobs: &[Job]) -> Result<Vec<RunResult>> {
        jobs.iter().map(|j| self.run(j)).collect()
    }

    fn run_inner(&self, job: &Job, obs: Option<&dyn Observer>) -> Result<RunResult> {
        let cfg = job.config();
        if let Some(o) = obs {
            o.on_event(&Event::PhaseStarted {
                phase: Phase::Partition,
            });
        }
        let part = self.partition(cfg.partitioner, cfg.num_procs, cfg.seed);
        let cost = cfg.fixed_cost.unwrap_or_else(|| self.cost_model());
        pipeline::execute(&self.graph, &part.partition, &part.metrics, &cost, job, obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    #[test]
    fn partition_cache_hits_by_key() {
        let s = Session::new(synth::grid2d(12, 12)).with_cost_model(CostModel::fixed());
        let a = s.partition(Partitioner::Block, 4, 1);
        let b = s.partition(Partitioner::Block, 4, 1);
        assert_eq!(s.partition_calls(), 1, "second lookup must hit the cache");
        assert!(Arc::ptr_eq(&a, &b));
        s.partition(Partitioner::Block, 8, 1);
        s.partition(Partitioner::BfsGrow, 4, 1);
        s.partition(Partitioner::Block, 4, 2);
        assert_eq!(s.partition_calls(), 4);
        assert_eq!(s.cached_partitions(), 4);
        // clearing bounds retention; the miss counter keeps its history
        s.clear_cached_partitions();
        assert_eq!(s.cached_partitions(), 0);
        s.partition(Partitioner::Block, 4, 1);
        assert_eq!(s.partition_calls(), 5);
    }

    #[test]
    fn sessions_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Session>();
    }

    #[test]
    fn pinned_cost_model_is_returned_verbatim() {
        let s = Session::new(synth::grid2d(4, 4)).with_cost_model(CostModel::fixed());
        assert_eq!(s.cost_model(), CostModel::fixed());
    }

    #[test]
    fn run_many_matches_individual_runs() {
        let s = Session::new(synth::grid2d(15, 15)).with_cost_model(CostModel::fixed());
        let jobs = [
            Job::on(&s).procs(2).speed().build().unwrap(),
            Job::on(&s).procs(4).quality().build().unwrap(),
        ];
        let batch = s.run_many(&jobs).unwrap();
        assert_eq!(batch.len(), 2);
        for (job, r) in jobs.iter().zip(&batch) {
            let single = s.run(job).unwrap();
            assert_eq!(single.coloring.colors, r.coloring.colors);
            assert_eq!(single.recolor_trace, r.recolor_trace);
        }
        // speed@2 and quality@4 use different keys; reruns hit the cache
        assert_eq!(s.partition_calls(), 2);
    }
}
