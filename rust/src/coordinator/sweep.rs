//! Parameter sweeps for Figs 8-10: run a grid of configurations on a set of
//! graphs and report normalized (colors, runtime) per configuration.

use super::config::{ColoringConfig, RecolorMode};
use super::pipeline::run_job;
use crate::color::recolor::{Permutation, RecolorSchedule};
use crate::color::{Ordering, Selection};
use crate::dist::recolor::{CommScheme, RecolorConfig};
use crate::graph::CsrGraph;
use crate::util::error::Result;
use crate::util::stats;

/// One sweep point, aggregated over the graph set.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub label: String,
    /// geometric mean of per-graph colors normalized to the baseline.
    pub norm_colors: f64,
    /// geometric mean of per-graph virtual runtime normalized to baseline.
    pub norm_time: f64,
    pub recolor_iters: u32,
}

/// The paper's Fig-8/9 grid. `recolor_iters` ∈ {0,1,2} selects the figure.
pub fn paper_grid(recolor_iters: u32, seed: u64) -> Vec<ColoringConfig> {
    let supersteps = [500usize, 1000, 5000, 10000];
    let orderings = [Ordering::InternalFirst, Ordering::SmallestLast];
    let syncs = [true, false];
    let selections = [
        Selection::FirstFit,
        Selection::RandomX(5),
        Selection::RandomX(10),
        Selection::RandomX(50),
    ];
    let mut out = Vec::new();
    for &ss in &supersteps {
        for &ord in &orderings {
            for &sync in &syncs {
                for &sel in &selections {
                    let recolor = if recolor_iters == 0 {
                        RecolorMode::None
                    } else {
                        RecolorMode::Sync(RecolorConfig {
                            schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
                            iterations: recolor_iters,
                            scheme: CommScheme::Piggyback,
                            seed,
                        })
                    };
                    out.push(ColoringConfig {
                        superstep_size: ss,
                        ordering: ord,
                        sync,
                        selection: sel,
                        recolor,
                        seed,
                        ..Default::default()
                    });
                }
            }
        }
    }
    out
}

/// Run every configuration over every graph; normalize each metric per
/// graph against `baseline` and aggregate by geometric mean.
pub fn run_sweep(
    graphs: &[CsrGraph],
    mut configs: Vec<ColoringConfig>,
    baseline: &ColoringConfig,
    num_procs: usize,
) -> Result<Vec<SweepPoint>> {
    let mut base_colors = Vec::new();
    let mut base_time = Vec::new();
    let mut bl = *baseline;
    bl.num_procs = num_procs;
    for g in graphs {
        let r = run_job(g, &bl)?;
        base_colors.push(r.num_colors as f64);
        base_time.push(r.metrics.makespan.max(1e-12));
    }
    let mut points = Vec::new();
    for cfg in configs.iter_mut() {
        cfg.num_procs = num_procs;
        let mut colors = Vec::new();
        let mut time = Vec::new();
        for g in graphs {
            let r = run_job(g, cfg)?;
            colors.push(r.num_colors as f64);
            time.push(r.metrics.makespan.max(1e-12));
        }
        points.push(SweepPoint {
            label: cfg.label(),
            norm_colors: stats::normalized_geomean(&colors, &base_colors),
            norm_time: stats::normalized_geomean(&time, &base_time),
            recolor_iters: cfg.recolor.iterations(),
        });
    }
    Ok(points)
}

/// Pareto frontier (min colors, min time) of a sweep — Fig 10's view.
pub fn pareto(points: &[SweepPoint]) -> Vec<SweepPoint> {
    let mut front: Vec<SweepPoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.norm_colors < p.norm_colors && q.norm_time <= p.norm_time)
                || (q.norm_colors <= p.norm_colors && q.norm_time < p.norm_time)
        });
        if !dominated {
            front.push(p.clone());
        }
    }
    front.sort_by(|a, b| a.norm_time.partial_cmp(&b.norm_time).unwrap());
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::cost::CostModel;
    use crate::graph::synth;

    #[test]
    fn grid_has_64_points() {
        assert_eq!(paper_grid(0, 1).len(), 4 * 2 * 2 * 4);
        assert!(paper_grid(1, 1)
            .iter()
            .all(|c| c.recolor.iterations() == 1));
    }

    #[test]
    fn sweep_runs_and_normalizes() {
        let graphs = vec![synth::grid2d(12, 12), synth::fem_like(600, 8.0, 20, 0.0, 2, "f")];
        let mut cfgs = vec![ColoringConfig::default(), ColoringConfig::quality(4)];
        for c in cfgs.iter_mut() {
            c.fixed_cost = Some(CostModel::fixed());
        }
        let mut baseline = ColoringConfig::default();
        baseline.fixed_cost = Some(CostModel::fixed());
        let pts = run_sweep(&graphs, cfgs, &baseline, 4).unwrap();
        assert_eq!(pts.len(), 2);
        // the baseline config normalizes to exactly 1
        assert!((pts[0].norm_colors - 1.0).abs() < 1e-9);
        assert!((pts[0].norm_time - 1.0).abs() < 1e-9);
    }

    #[test]
    fn pareto_removes_dominated() {
        let mk = |c: f64, t: f64| SweepPoint {
            label: String::new(),
            norm_colors: c,
            norm_time: t,
            recolor_iters: 0,
        };
        let pts = vec![mk(1.0, 1.0), mk(0.8, 2.0), mk(1.2, 1.5), mk(0.9, 0.9)];
        let front = pareto(&pts);
        assert_eq!(front.len(), 2); // (0.9,0.9) and (0.8,2.0)
    }
}
