//! Parameter sweeps for Figs 8-10: run a grid of configurations on a set
//! of graphs and report normalized (colors, runtime) per configuration.
//!
//! Sweeps run through [`Session`]s: each graph's session partitions every
//! distinct `(partitioner, procs, seed)` key exactly once for the whole
//! sweep — the paper grid shares one key, so a 64-config sweep does 1
//! partition per graph instead of 65 (the unit tests pin the call count).
//! Runs are reduced to scalars on the fly; use [`Session::run_many`] when
//! the full [`RunResult`](super::RunResult)s are wanted.

use super::config::{ColoringConfig, RecolorMode};
use super::job::Job;
use super::session::Session;
use crate::color::recolor::{Permutation, RecolorSchedule};
use crate::color::{Ordering, Selection};
use crate::dist::recolor::{CommScheme, RecolorConfig};
use crate::util::error::Result;
use crate::util::stats;

/// One sweep point, aggregated over the graph set.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub label: String,
    /// geometric mean of per-graph colors normalized to the baseline.
    pub norm_colors: f64,
    /// geometric mean of per-graph virtual runtime normalized to baseline.
    pub norm_time: f64,
    pub recolor_iters: u32,
}

/// The paper's Fig-8/9 grid. `recolor_iters` ∈ {0,1,2} selects the figure.
pub fn paper_grid(recolor_iters: u32, seed: u64) -> Vec<ColoringConfig> {
    let supersteps = [500usize, 1000, 5000, 10000];
    let orderings = [Ordering::InternalFirst, Ordering::SmallestLast];
    let syncs = [true, false];
    let selections = [
        Selection::FirstFit,
        Selection::RandomX(5),
        Selection::RandomX(10),
        Selection::RandomX(50),
    ];
    let mut out = Vec::new();
    for &ss in &supersteps {
        for &ord in &orderings {
            for &sync in &syncs {
                for &sel in &selections {
                    let recolor = if recolor_iters == 0 {
                        RecolorMode::None
                    } else {
                        RecolorMode::Sync(RecolorConfig {
                            schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
                            iterations: recolor_iters,
                            scheme: CommScheme::Piggyback,
                            seed,
                            ..Default::default()
                        })
                    };
                    out.push(ColoringConfig {
                        superstep_size: ss,
                        ordering: ord,
                        sync,
                        selection: sel,
                        recolor,
                        seed,
                        ..Default::default()
                    });
                }
            }
        }
    }
    out
}

/// Run every configuration over every graph session; normalize each metric
/// per graph against `baseline` and aggregate by geometric mean. All jobs
/// of a graph go through its session, so partitioning work is shared per
/// `(partitioner, procs, seed)` key.
pub fn run_sweep(
    sessions: &[Session],
    configs: Vec<ColoringConfig>,
    baseline: &ColoringConfig,
    num_procs: usize,
) -> Result<Vec<SweepPoint>> {
    // jobs[0] is the baseline, jobs[1..] the grid
    let mut jobs = Vec::with_capacity(configs.len() + 1);
    let mut bl = baseline.clone();
    bl.num_procs = num_procs;
    jobs.push(Job::from_config(bl)?);
    for mut cfg in configs {
        cfg.num_procs = num_procs;
        jobs.push(Job::from_config(cfg)?);
    }

    // reduce each run to (colors, makespan) immediately — a sweep holds
    // two floats per (graph, job), never the per-vertex colorings
    let mut per_graph: Vec<Vec<(f64, f64)>> = Vec::with_capacity(sessions.len());
    for s in sessions {
        let mut rows = Vec::with_capacity(jobs.len());
        for job in &jobs {
            let r = s.run(job)?;
            rows.push((r.num_colors as f64, r.metrics.makespan.max(1e-12)));
        }
        per_graph.push(rows);
    }

    let base_colors: Vec<f64> = per_graph.iter().map(|rs| rs[0].0).collect();
    let base_time: Vec<f64> = per_graph.iter().map(|rs| rs[0].1).collect();

    let mut points = Vec::with_capacity(jobs.len() - 1);
    for (ji, job) in jobs.iter().enumerate().skip(1) {
        let colors: Vec<f64> = per_graph.iter().map(|rs| rs[ji].0).collect();
        let time: Vec<f64> = per_graph.iter().map(|rs| rs[ji].1).collect();
        points.push(SweepPoint {
            label: job.label(),
            norm_colors: stats::normalized_geomean(&colors, &base_colors),
            norm_time: stats::normalized_geomean(&time, &base_time),
            recolor_iters: job.config().recolor.iterations(),
        });
    }
    Ok(points)
}

/// Pareto frontier (min colors, min time) of a sweep — Fig 10's view.
pub fn pareto(points: &[SweepPoint]) -> Vec<SweepPoint> {
    let mut front: Vec<SweepPoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.norm_colors < p.norm_colors && q.norm_time <= p.norm_time)
                || (q.norm_colors <= p.norm_colors && q.norm_time < p.norm_time)
        });
        if !dominated {
            front.push(p.clone());
        }
    }
    // total_cmp, not partial_cmp().unwrap(): a degenerate baseline (zero
    // time) yields NaN norm_time, which must sort (last) instead of panic.
    front.sort_by(|a, b| a.norm_time.total_cmp(&b.norm_time));
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::cost::CostModel;
    use crate::graph::synth;

    fn sessions() -> Vec<Session> {
        vec![
            Session::new(synth::grid2d(12, 12)).with_cost_model(CostModel::fixed()),
            Session::new(synth::fem_like(600, 8.0, 20, 0.0, 2, "f"))
                .with_cost_model(CostModel::fixed()),
        ]
    }

    #[test]
    fn grid_has_64_points() {
        assert_eq!(paper_grid(0, 1).len(), 4 * 2 * 2 * 4);
        assert!(paper_grid(1, 1)
            .iter()
            .all(|c| c.recolor.iterations() == 1));
    }

    #[test]
    fn sweep_runs_and_normalizes() {
        let sessions = sessions();
        let cfgs = vec![ColoringConfig::default(), ColoringConfig::quality(4)];
        let baseline = ColoringConfig::default();
        let pts = run_sweep(&sessions, cfgs, &baseline, 4).unwrap();
        assert_eq!(pts.len(), 2);
        // the baseline config normalizes to exactly 1
        assert!((pts[0].norm_colors - 1.0).abs() < 1e-9);
        assert!((pts[0].norm_time - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sweep_partitions_each_key_exactly_once() {
        // baseline + both configs share (BfsGrow, 4, 42): one partition
        // call per graph for the whole sweep — the acceptance pin
        let sessions = sessions();
        let cfgs = vec![
            ColoringConfig::default(),
            ColoringConfig::speed(4),
            ColoringConfig::quality(4),
        ];
        let baseline = ColoringConfig::default();
        run_sweep(&sessions, cfgs, &baseline, 4).unwrap();
        for s in &sessions {
            assert_eq!(s.partition_calls(), 1, "on {}", s.graph().name);
        }
        // a second sweep with a different seed adds exactly one more key
        let reseeded = ColoringConfig {
            seed: 7,
            ..Default::default()
        };
        run_sweep(&sessions, vec![reseeded], &baseline, 4).unwrap();
        for s in &sessions {
            assert_eq!(s.partition_calls(), 2);
            assert_eq!(s.cached_partitions(), 2);
        }
    }

    #[test]
    fn pareto_removes_dominated() {
        let mk = |c: f64, t: f64| SweepPoint {
            label: String::new(),
            norm_colors: c,
            norm_time: t,
            recolor_iters: 0,
        };
        let pts = vec![mk(1.0, 1.0), mk(0.8, 2.0), mk(1.2, 1.5), mk(0.9, 0.9)];
        let front = pareto(&pts);
        assert_eq!(front.len(), 2); // (0.9,0.9) and (0.8,2.0)
    }

    #[test]
    fn pareto_survives_nan_from_degenerate_baseline() {
        // A zero-time baseline normalizes to NaN norm_time; the old
        // partial_cmp(..).unwrap() sort panicked here. NaN compares false
        // against everything, so such a point is never dominated — it must
        // come back (sorted last under total_cmp), not take the sweep down.
        let mk = |c: f64, t: f64| SweepPoint {
            label: String::new(),
            norm_colors: c,
            norm_time: t,
            recolor_iters: 0,
        };
        let pts = vec![mk(1.0, f64::NAN), mk(0.9, 0.9), mk(0.8, 2.0)];
        let front = pareto(&pts);
        assert_eq!(front.len(), 3);
        assert!(front.last().unwrap().norm_time.is_nan(), "NaN sorts last");
        assert!(front[..2].windows(2).all(|w| w[0].norm_time <= w[1].norm_time));
    }
}
