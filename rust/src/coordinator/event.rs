//! The streaming event/observer layer of the coordinator.
//!
//! A run of the pipeline is a sequence of paper phases (partition →
//! initial coloring → recoloring → validation); an [`Observer`] passed to
//! [`Session::run_observed`](super::Session::run_observed) receives an
//! [`Event`] at every phase boundary, superstep, conflict-resolution round
//! and recoloring iteration. Events carry only *globally agreed* values —
//! color counts and loser totals come straight off allreduces, and
//! superstep indices run over the round's allreduced per-rank step-count
//! maximum — and only rank 0 emits, so the stream is deterministic and
//! well ordered:
//!
//! ```text
//! PhaseStarted(Partition)
//! PhaseStarted(InitialColoring)
//!   SuperstepDone*  ConflictRound*        (per resolution round)
//! PhaseStarted(Recoloring)?               (when recoloring is configured)
//!   RecolorIteration*                     (sync RC)
//!   SuperstepDone* ConflictRound* RecolorIteration*   (aRC)
//! PhaseStarted(Validation)
//! Done
//! ```
//!
//! Observers must not mutate run state; emission never touches the virtual
//! clocks, so an observed run is bit-for-bit identical to an unobserved
//! one (`tests/session_api.rs` pins both properties).
//!
//! Layering note: `dist::framework` and `dist::recolor` import these types
//! to emit superstep/iteration events — a deliberate inversion of the
//! usual coordinator→dist direction, kept because the phases are
//! pipeline-level concepts and a single event vocabulary beats a parallel
//! dist-level one. If `dist` ever needs to stand alone, move the enum down
//! and re-export it here.

use crate::util::error::{Error, ErrorKind};
use std::sync::Mutex;

/// The pipeline phases, in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Graph partitioning (or a session cache hit).
    Partition,
    /// Speculative distributed initial coloring (paper §2.2).
    InitialColoring,
    /// Iterative recoloring, RC or aRC (paper §3).
    Recoloring,
    /// Global validation of the merged coloring.
    Validation,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Partition => "partition",
            Phase::InitialColoring => "initial_coloring",
            Phase::Recoloring => "recoloring",
            Phase::Validation => "validation",
        }
    }
}

/// One observable step of a coordinator run.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A pipeline phase begins.
    PhaseStarted { phase: Phase },
    /// One superstep of the Bozdağ framework finished its boundary
    /// exchange (`round` is the conflict-resolution round, 1-based).
    SuperstepDone { round: u32, step: u32 },
    /// An end-of-round conflict sweep completed; `conflicts` is the global
    /// number of losers that will recolor next round (0 terminates).
    ConflictRound { round: u32, conflicts: u64 },
    /// A recoloring iteration finished; `k` is the global color count
    /// after it — the same value appended to `RunResult::recolor_trace`.
    RecolorIteration { iter: u32, k: usize },
    /// The supervising engine injected a crash-stop: `rank` went down at
    /// engine step `step`. Emitted once per crash in the plan, so
    /// multi-crash plans produce one event per firing crash
    /// (delays/reorders/losses are counted in `DistMetrics`, not evented).
    FaultInjected { rank: u32, step: u64 },
    /// The supervising engine restarted `rank` at engine step `step` from
    /// its last *periodic* checkpoint — with `checkpoint_interval > 1` the
    /// rank then replays the steps since that checkpoint (receiver-side
    /// dedup absorbs the replayed sends).
    ProcRestarted { rank: u32, step: u64 },
    /// A post-validation repair pass ran over `conflicts` conflicting
    /// vertices (only after an active fault plan left conflicts behind).
    RepairPass { pass: u32, conflicts: usize },
    /// The run finished: `Ok(colors)` after validation, or the job's
    /// typed error as a structured [`DoneError`] (kind + message), so
    /// observers can react to overload/cancellation/deadline without
    /// string matching.
    Done { result: Result<usize, DoneError> },
}

/// The failure payload of [`Event::Done`]: the job error's classification
/// plus its rendered message. The JSON encoding keeps the legacy
/// `"error"` message field and adds `"kind"` with the stable
/// [`ErrorKind::code`], so existing consumers keep parsing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DoneError {
    pub kind: ErrorKind,
    pub msg: String,
}

impl DoneError {
    /// Capture a job error as the `Done` payload.
    pub fn of(e: &Error) -> Self {
        DoneError {
            kind: e.kind(),
            msg: e.to_string(),
        }
    }
}

impl std::fmt::Display for DoneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Receives the event stream of a run. Implementations must be `Sync`:
/// events originating inside the distributed section are delivered from a
/// simulated-process thread (always rank 0's).
pub trait Observer: Sync {
    fn on_event(&self, event: &Event);
}

/// Emit `event` once globally: only rank 0 forwards, everyone else drops.
/// Call sites place this directly after a collective so the payload is
/// identical on every rank and the choice of emitter is immaterial.
#[inline]
pub fn emit_rank0(obs: Option<&dyn Observer>, rank: usize, event: Event) {
    if rank == 0 {
        if let Some(o) = obs {
            o.on_event(&event);
        }
    }
}

/// An [`Observer`] that records every event, for tests and programmatic
/// consumers.
#[derive(Debug, Default)]
pub struct EventLog {
    events: Mutex<Vec<Event>>,
}

impl EventLog {
    pub fn new() -> Self {
        EventLog::default()
    }

    /// Snapshot of the events received so far.
    pub fn events(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    /// Drain the log.
    pub fn take(&self) -> Vec<Event> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }
}

impl Observer for EventLog {
    fn on_event(&self, event: &Event) {
        self.events
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(event.clone());
    }
}

/// An [`Observer`] that prints one JSON object per event to stdout — the
/// CLI's `--json` mode. Machine-readable without serde: every payload is
/// numeric or a fixed identifier, so the encoding is trivial.
#[derive(Debug, Default)]
pub struct JsonLines;

impl Observer for JsonLines {
    fn on_event(&self, event: &Event) {
        println!("{}", event_json(event));
    }
}

/// Encode one event as a single-line JSON object.
pub fn event_json(event: &Event) -> String {
    match event {
        Event::PhaseStarted { phase } => {
            format!("{{\"event\":\"phase_started\",\"phase\":\"{}\"}}", phase.name())
        }
        Event::SuperstepDone { round, step } => {
            format!("{{\"event\":\"superstep_done\",\"round\":{round},\"step\":{step}}}")
        }
        Event::ConflictRound { round, conflicts } => {
            format!("{{\"event\":\"conflict_round\",\"round\":{round},\"conflicts\":{conflicts}}}")
        }
        Event::RecolorIteration { iter, k } => {
            format!("{{\"event\":\"recolor_iteration\",\"iter\":{iter},\"k\":{k}}}")
        }
        Event::FaultInjected { rank, step } => {
            format!("{{\"event\":\"fault_injected\",\"rank\":{rank},\"step\":{step}}}")
        }
        Event::ProcRestarted { rank, step } => {
            format!("{{\"event\":\"proc_restarted\",\"rank\":{rank},\"step\":{step}}}")
        }
        Event::RepairPass { pass, conflicts } => {
            format!("{{\"event\":\"repair_pass\",\"pass\":{pass},\"conflicts\":{conflicts}}}")
        }
        Event::Done { result: Ok(colors) } => {
            format!("{{\"event\":\"done\",\"colors\":{colors}}}")
        }
        Event::Done { result: Err(e) } => {
            format!(
                "{{\"event\":\"done\",\"error\":\"{}\",\"kind\":\"{}\"}}",
                json_escape(&e.msg),
                e.kind.code()
            )
        }
    }
}

/// Minimal JSON string escaping for error messages (quotes, backslashes
/// and control characters — everything our errors can contain).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_log_records_in_order() {
        let log = EventLog::new();
        log.on_event(&Event::PhaseStarted { phase: Phase::Partition });
        log.on_event(&Event::Done { result: Ok(3) });
        assert_eq!(
            log.events(),
            vec![
                Event::PhaseStarted { phase: Phase::Partition },
                Event::Done { result: Ok(3) },
            ]
        );
        assert_eq!(log.take().len(), 2);
        assert!(log.events().is_empty());
    }

    #[test]
    fn emit_rank0_only_rank_zero_forwards() {
        let log = EventLog::new();
        emit_rank0(Some(&log), 1, Event::Done { result: Ok(1) });
        emit_rank0(Some(&log), 3, Event::Done { result: Ok(1) });
        assert!(log.events().is_empty());
        emit_rank0(Some(&log), 0, Event::Done { result: Ok(1) });
        assert_eq!(log.events().len(), 1);
        emit_rank0(None, 0, Event::Done { result: Ok(1) }); // no observer: no-op
    }

    #[test]
    fn json_encoding_is_stable() {
        assert_eq!(
            event_json(&Event::PhaseStarted { phase: Phase::InitialColoring }),
            "{\"event\":\"phase_started\",\"phase\":\"initial_coloring\"}"
        );
        assert_eq!(
            event_json(&Event::SuperstepDone { round: 2, step: 7 }),
            "{\"event\":\"superstep_done\",\"round\":2,\"step\":7}"
        );
        assert_eq!(
            event_json(&Event::ConflictRound { round: 1, conflicts: 0 }),
            "{\"event\":\"conflict_round\",\"round\":1,\"conflicts\":0}"
        );
        assert_eq!(
            event_json(&Event::RecolorIteration { iter: 1, k: 12 }),
            "{\"event\":\"recolor_iteration\",\"iter\":1,\"k\":12}"
        );
        assert_eq!(
            event_json(&Event::Done { result: Ok(9) }),
            "{\"event\":\"done\",\"colors\":9}"
        );
        assert_eq!(
            event_json(&Event::FaultInjected { rank: 1, step: 4 }),
            "{\"event\":\"fault_injected\",\"rank\":1,\"step\":4}"
        );
        assert_eq!(
            event_json(&Event::ProcRestarted { rank: 1, step: 6 }),
            "{\"event\":\"proc_restarted\",\"rank\":1,\"step\":6}"
        );
        assert_eq!(
            event_json(&Event::RepairPass { pass: 1, conflicts: 2 }),
            "{\"event\":\"repair_pass\",\"pass\":1,\"conflicts\":2}"
        );
        let err = DoneError {
            kind: ErrorKind::ProcFailed { rank: 2, step: 5 },
            msg: "bad \"x\"\n".into(),
        };
        assert_eq!(
            event_json(&Event::Done { result: Err(err) }),
            "{\"event\":\"done\",\"error\":\"bad \\\"x\\\"\\n\",\"kind\":\"proc-failed\"}"
        );
        let cancelled = DoneError::of(&Error::cancelled("job 3 stopped"));
        assert_eq!(
            event_json(&Event::Done { result: Err(cancelled) }),
            "{\"event\":\"done\",\"error\":\"cancelled: job 3 stopped\",\"kind\":\"cancelled\"}"
        );
    }

    #[test]
    fn phase_names_cover_all_phases() {
        let names: Vec<_> = [
            Phase::Partition,
            Phase::InitialColoring,
            Phase::Recoloring,
            Phase::Validation,
        ]
        .iter()
        .map(|p| p.name())
        .collect();
        assert_eq!(
            names,
            vec!["partition", "initial_coloring", "recoloring", "validation"]
        );
    }
}
