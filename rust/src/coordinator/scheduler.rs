//! Multi-tenant job scheduler: admission control, two priority classes
//! with a deficit-style fairness rule, per-job deadlines and cooperative
//! cancellation, and overload shedding.
//!
//! The coordinator so far is a library: every caller owns a [`Session`]
//! and blocks on [`Session::run`]. A *service* multiplexes many tenants
//! over one machine — one process-wide worker pool, many sessions, jobs
//! arriving faster than they finish. The [`Scheduler`] is that layer:
//!
//! * **Admission control** — a bounded queue ([`SchedulerConfig::queue_cap`]
//!   across both classes). A full queue rejects the submission with the
//!   typed [`Error::overloaded`] *before* any work happens; nothing is
//!   partially run, the caller can retry or shed load.
//! * **Two priority classes** — [`Priority::Interactive`] (latency-bound
//!   point jobs) and [`Priority::Sweep`] (throughput batch work). The
//!   dispatcher serves interactive first but never starves sweeps: after
//!   [`SchedulerConfig::interactive_quantum`] consecutive interactive
//!   dispatches it forces one sweep through. An interactive job entering
//!   at queue position *p* is therefore passed by at most
//!   `p / quantum + 1` sweep jobs — the provable max-wait bound
//!   ([`SchedStats::max_sweeps_before_interactive`] tracks the observed
//!   maximum, `examples/scheduler_soak.rs` asserts the bound).
//! * **Deadlines and cancellation** — each submission gets a
//!   [`CancelToken`] carrying the job's wall-clock deadline and/or
//!   virtual-clock budget, created *at submit time* so queue wait counts
//!   against the deadline. [`JobHandle::cancel`] latches the same token.
//!   A job whose token fires while still queued is completed with the
//!   typed error without ever running; a running job stops at its next
//!   engine checkpoint (see `util::cancel`), failing typed or — under the
//!   job's `degrade` knob — returning a best-so-far coloring flagged
//!   `degraded`.
//! * **Tenant cache quotas** — [`Scheduler::with_tenant_cache_quota`]
//!   clamps every subsequently registered session's partition-cache cap,
//!   so no tenant's sweep can pin an unbounded set of partitions and
//!   local graphs in memory; the churn each tenant pays for its quota is
//!   surfaced per tenant in [`SchedStats::tenant_evictions`].
//!
//! One dispatcher thread executes jobs in admission order (within the
//! fairness rule); each job is internally parallel on the process-wide
//! worker pool, so serializing jobs keeps the pool unsaturated instead of
//! thrashing it with competing fan-outs. Shutdown drains the queue: every
//! still-queued job completes with a typed cancellation error — a waiting
//! client never hangs.

use super::job::Job;
use super::pipeline::RunResult;
use super::session::Session;
use crate::util::cancel::{CancelToken, RunControl};
use crate::util::error::{Error, Result};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// The scheduling class of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-bound point jobs; served first, within the fairness rule.
    #[default]
    Interactive,
    /// Throughput batch work (parameter sweeps); never starved — the
    /// dispatcher forces one through after every quantum of interactive
    /// dispatches.
    Sweep,
}

impl Priority {
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Sweep => "sweep",
        }
    }
}

impl std::str::FromStr for Priority {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "interactive" | "i" => Ok(Priority::Interactive),
            "sweep" | "s" => Ok(Priority::Sweep),
            other => Err(format!(
                "unknown priority {other:?} (expected interactive|sweep)"
            )),
        }
    }
}

/// Scheduler tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Bound on queued (admitted, not yet dispatched) jobs across both
    /// classes; submissions past it are rejected with
    /// [`Error::overloaded`].
    pub queue_cap: usize,
    /// Consecutive interactive dispatches before one sweep job is forced
    /// through (values below 1 behave as 1).
    pub interactive_quantum: u32,
    /// Start with the dispatcher paused — jobs queue but nothing runs
    /// until [`Scheduler::resume`]. Tests use this to stage deterministic
    /// queue states; a service normally starts live.
    pub start_paused: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            queue_cap: 64,
            interactive_quantum: 4,
            start_paused: false,
        }
    }
}

/// Handle to a registered tenant (an index into the scheduler's sessions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantId(usize);

/// Counters the scheduler maintains under its lock; snapshot via
/// [`Scheduler::stats`].
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    /// Jobs admitted to the queue.
    pub submitted: u64,
    /// Submissions rejected by admission control (queue full).
    pub rejected: u64,
    /// Jobs that ran to a successful result (including degraded ones).
    pub completed: u64,
    /// Jobs completed with an error — run failures, typed stops, and
    /// queued-cancelled jobs alike.
    pub failed: u64,
    /// Jobs whose token fired while still queued — completed with the
    /// typed error without running.
    pub cancelled_queued: u64,
    /// Dispatches per class.
    pub dispatched_interactive: u64,
    pub dispatched_sweep: u64,
    /// The most sweep jobs that overtook any single interactive job while
    /// it waited — observed fairness; bounded by `pos/quantum + 1`.
    pub max_sweeps_before_interactive: u64,
    /// Longest observed queue wait (admission to dispatch).
    pub max_queue_wait: Duration,
    /// Partition-cache evictions per tenant, indexed by [`TenantId`] —
    /// read from each tenant's session at snapshot time. Nonzero entries
    /// mean that tenant churned past its cache bound (e.g. the
    /// [`Scheduler::with_tenant_cache_quota`] clamp) and re-partitioned.
    pub tenant_evictions: Vec<u64>,
}

/// One admitted job waiting for dispatch.
struct QueuedJob {
    id: u64,
    tenant: usize,
    job: Job,
    ctl: RunControl,
    handle: Arc<HandleInner>,
    admitted: Instant,
    /// Sweep dispatches that happened while this (interactive) job waited.
    sweeps_passed: u64,
}

struct HandleInner {
    slot: Mutex<Option<Result<RunResult>>>,
    done: Condvar,
}

impl HandleInner {
    fn deliver(&self, r: Result<RunResult>) {
        *self.slot.lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
        self.done.notify_all();
    }
}

/// The client's end of a submission: cancel it, wait for the result.
pub struct JobHandle {
    id: u64,
    token: CancelToken,
    inner: Arc<HandleInner>,
}

impl JobHandle {
    /// The scheduler-assigned job id (monotone per scheduler).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation: queued jobs complete with the typed error
    /// without running; a running job stops at its next engine checkpoint.
    pub fn cancel(&self) {
        self.token.cancel();
    }

    /// This submission's stop token (e.g. to share with a watchdog).
    pub fn token(&self) -> &CancelToken {
        &self.token
    }

    /// Whether the result is already available (never blocks).
    pub fn is_done(&self) -> bool {
        self.inner
            .slot
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .is_some()
    }

    /// Block until the job completes and take its result. The scheduler
    /// completes every admitted job — run, stopped, or drained at
    /// shutdown — so this cannot hang on a live scheduler.
    pub fn wait(self) -> Result<RunResult> {
        let mut slot = self.inner.slot.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self
                .inner
                .done
                .wait(slot)
                .unwrap_or_else(|e| e.into_inner());
        }
    }
}

struct SchedState {
    tenants: Vec<Arc<Session>>,
    /// Upper bound clamped onto every tenant session's partition-cache
    /// cap at registration time (`None` = tenants keep their own cap).
    tenant_cache_quota: Option<usize>,
    interactive: VecDeque<QueuedJob>,
    sweep: VecDeque<QueuedJob>,
    /// Consecutive interactive dispatches since the last sweep dispatch.
    interactive_run: u32,
    paused: bool,
    shutdown: bool,
    next_id: u64,
    stats: SchedStats,
}

impl SchedState {
    fn queued(&self) -> usize {
        self.interactive.len() + self.sweep.len()
    }
}

struct Shared {
    state: Mutex<SchedState>,
    /// Signaled on submit, resume and shutdown; the dispatcher waits here.
    work: Condvar,
    cfg: SchedulerConfig,
}

fn lock_state(shared: &Shared) -> MutexGuard<'_, SchedState> {
    shared.state.lock().unwrap_or_else(|e| e.into_inner())
}

/// Clone the counters and fold in the per-tenant partition-cache
/// eviction counts, which live on the tenant sessions rather than in
/// [`SchedState`].
fn snapshot_stats(shared: &Shared) -> SchedStats {
    let st = lock_state(shared);
    let mut stats = st.stats.clone();
    stats.tenant_evictions = st
        .tenants
        .iter()
        .map(|s| s.partition_evictions() as u64)
        .collect();
    stats
}

/// The multi-tenant service layer over [`Session`]s — see the module docs.
pub struct Scheduler {
    shared: Arc<Shared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        assert!(cfg.queue_cap >= 1, "queue cap must be at least 1");
        let shared = Arc::new(Shared {
            state: Mutex::new(SchedState {
                tenants: Vec::new(),
                tenant_cache_quota: None,
                interactive: VecDeque::new(),
                sweep: VecDeque::new(),
                interactive_run: 0,
                paused: cfg.start_paused,
                shutdown: false,
                next_id: 0,
                stats: SchedStats::default(),
            }),
            work: Condvar::new(),
            cfg,
        });
        let worker = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("dgcolor-sched".into())
            .spawn(move || dispatch_loop(&worker))
            .expect("spawn scheduler dispatcher");
        Scheduler {
            shared,
            dispatcher: Some(dispatcher),
        }
    }

    /// Quota every tenant registered *after* this call: each session's
    /// partition-cache cap is clamped to at most `cap` keys (a session
    /// that already asked for less keeps its tighter bound). A shared
    /// service uses this so no single tenant's sweep can pin an unbounded
    /// set of partitions and local graphs in memory; the per-tenant churn
    /// this causes is visible in [`SchedStats::tenant_evictions`].
    pub fn with_tenant_cache_quota(self, cap: usize) -> Scheduler {
        assert!(cap >= 1, "tenant cache quota must be at least 1");
        lock_state(&self.shared).tenant_cache_quota = Some(cap);
        self
    }

    /// Register a tenant's session; jobs are submitted against the id.
    /// A configured [`Scheduler::with_tenant_cache_quota`] is applied
    /// here, clamping the session's partition-cache cap.
    pub fn add_tenant(&self, session: Session) -> TenantId {
        let mut st = lock_state(&self.shared);
        let session = match st.tenant_cache_quota {
            Some(cap) => {
                let clamped = session.partition_cache_cap().min(cap);
                session.with_partition_cache_cap(clamped)
            }
            None => session,
        };
        st.tenants.push(Arc::new(session));
        TenantId(st.tenants.len() - 1)
    }

    /// Submit a job for `tenant`. Admission is all-or-nothing: a full
    /// queue (or an unknown tenant, or a shut-down scheduler) rejects
    /// with a typed error and nothing runs. The job's deadline/budget
    /// knobs become the submission's [`CancelToken`] limits, counting
    /// from *now* — queue wait spends deadline.
    pub fn submit(&self, tenant: TenantId, job: Job) -> Result<JobHandle> {
        let mut st = lock_state(&self.shared);
        if st.shutdown {
            return Err(Error::cancelled("scheduler is shut down"));
        }
        if tenant.0 >= st.tenants.len() {
            return Err(Error::msg(format!("unknown tenant id {}", tenant.0)));
        }
        if st.queued() >= self.shared.cfg.queue_cap {
            st.stats.rejected += 1;
            return Err(Error::overloaded(format!(
                "scheduler queue full ({} of {} slots)",
                st.queued(),
                self.shared.cfg.queue_cap
            )));
        }
        let priority = job.config().priority;
        let token = CancelToken::with_limits(
            job.config().deadline_secs.map(Duration::from_secs_f64),
            job.config().vclock_budget,
        );
        let ctl = RunControl::new(token.clone(), job.stop_policy());
        let handle = Arc::new(HandleInner {
            slot: Mutex::new(None),
            done: Condvar::new(),
        });
        let id = st.next_id;
        st.next_id += 1;
        st.stats.submitted += 1;
        let queued = QueuedJob {
            id,
            tenant: tenant.0,
            job,
            ctl,
            handle: Arc::clone(&handle),
            admitted: Instant::now(),
            sweeps_passed: 0,
        };
        match priority {
            Priority::Interactive => st.interactive.push_back(queued),
            Priority::Sweep => st.sweep.push_back(queued),
        }
        drop(st);
        self.shared.work.notify_all();
        Ok(JobHandle {
            id,
            token,
            inner: handle,
        })
    }

    /// Jobs admitted but not yet dispatched.
    pub fn queue_depth(&self) -> usize {
        lock_state(&self.shared).queued()
    }

    /// Snapshot of the scheduler counters (per-tenant eviction counts are
    /// read from the tenant sessions at snapshot time).
    pub fn stats(&self) -> SchedStats {
        snapshot_stats(&self.shared)
    }

    /// Start dispatching (no-op unless constructed with `start_paused`).
    pub fn resume(&self) {
        lock_state(&self.shared).paused = false;
        self.shared.work.notify_all();
    }

    /// Stop accepting work, drain the queue (every still-queued job
    /// completes with a typed cancellation error), finish the running job
    /// if any, and join the dispatcher.
    pub fn shutdown(mut self) -> SchedStats {
        self.begin_shutdown();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        snapshot_stats(&self.shared)
    }

    fn begin_shutdown(&self) {
        let mut st = lock_state(&self.shared);
        st.shutdown = true;
        st.paused = false;
        drop(st);
        self.shared.work.notify_all();
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.begin_shutdown();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

/// Pick the next job under the deficit rule. Interactive goes first,
/// except that after `quantum` consecutive interactive dispatches a
/// waiting sweep job is forced through; a sweep dispatch bumps every
/// still-waiting interactive job's overtake count (the fairness
/// statistic). Returns `None` when both queues are empty.
fn pick_next(st: &mut SchedState, quantum: u32) -> Option<QueuedJob> {
    let quantum = quantum.max(1);
    let force_sweep = st.interactive_run >= quantum && !st.sweep.is_empty();
    let take_interactive = !force_sweep && !st.interactive.is_empty();
    if take_interactive {
        let q = st.interactive.pop_front()?;
        st.interactive_run += 1;
        st.stats.dispatched_interactive += 1;
        st.stats.max_sweeps_before_interactive =
            st.stats.max_sweeps_before_interactive.max(q.sweeps_passed);
        Some(q)
    } else if let Some(q) = st.sweep.pop_front() {
        st.interactive_run = 0;
        st.stats.dispatched_sweep += 1;
        for waiting in st.interactive.iter_mut() {
            waiting.sweeps_passed += 1;
        }
        Some(q)
    } else {
        None
    }
}

fn dispatch_loop(shared: &Shared) {
    loop {
        let mut st = lock_state(shared);
        let next = loop {
            if st.shutdown {
                // drain: every still-queued job completes typed, so
                // clients blocked in `wait` are released
                let mut drained: Vec<QueuedJob> = st.interactive.drain(..).collect();
                drained.extend(st.sweep.drain(..));
                st.stats.failed += drained.len() as u64;
                drop(st);
                for q in drained {
                    q.handle
                        .deliver(Err(Error::cancelled("scheduler shut down before the job ran")));
                }
                return;
            }
            if !st.paused {
                if let Some(q) = pick_next(&mut st, shared.cfg.interactive_quantum) {
                    break q;
                }
            }
            st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
        };
        let wait = next.admitted.elapsed();
        if wait > st.stats.max_queue_wait {
            st.stats.max_queue_wait = wait;
        }
        let session = Arc::clone(&st.tenants[next.tenant]);
        drop(st);

        // a token that fired while the job was queued — check(0.0) also
        // latches a deadline the job spent entirely in the queue —
        // completes typed without running (a queued job has no
        // best-so-far to degrade to)
        let result = match next.ctl.token.check(0.0) {
            Some(cause) => {
                let mut st = lock_state(shared);
                st.stats.cancelled_queued += 1;
                drop(st);
                Err(cause.to_error())
            }
            None => session.run_with_control(&next.job, &next.ctl, None),
        };
        let mut st = lock_state(shared);
        match &result {
            Ok(_) => st.stats.completed += 1,
            Err(_) => st.stats.failed += 1,
        }
        drop(st);
        next.handle.deliver(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::cost::CostModel;
    use crate::graph::synth;
    use crate::util::error::ErrorKind;

    fn session() -> Session {
        Session::new(synth::grid2d(12, 12)).with_cost_model(CostModel::fixed())
    }

    fn sched(queue_cap: usize, quantum: u32, paused: bool) -> (Scheduler, TenantId) {
        let s = Scheduler::new(SchedulerConfig {
            queue_cap,
            interactive_quantum: quantum,
            start_paused: paused,
        });
        let t = s.add_tenant(session());
        (s, t)
    }

    fn job(priority: Priority) -> Job {
        Job::builder().procs(2).priority(priority).build().unwrap()
    }

    #[test]
    fn runs_jobs_and_reports_results() {
        let (s, t) = sched(8, 4, false);
        let h1 = s.submit(t, job(Priority::Interactive)).unwrap();
        let h2 = s.submit(t, job(Priority::Sweep)).unwrap();
        assert!(h1.id() != h2.id());
        let r1 = h1.wait().unwrap();
        let r2 = h2.wait().unwrap();
        assert_eq!(r1.coloring.colors, r2.coloring.colors, "same job, same bits");
        assert!(!r1.degraded);
        let stats = s.shutdown();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn full_queue_rejects_with_typed_overload() {
        let (s, t) = sched(2, 4, true); // paused: nothing drains
        let h1 = s.submit(t, job(Priority::Interactive)).unwrap();
        let h2 = s.submit(t, job(Priority::Sweep)).unwrap();
        let err = s.submit(t, job(Priority::Interactive)).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Overloaded);
        assert_eq!(s.queue_depth(), 2, "rejected submission was not queued");
        assert_eq!(s.stats().rejected, 1);
        // draining frees slots: the same scheduler accepts work again
        s.resume();
        h1.wait().unwrap();
        h2.wait().unwrap();
        let h3 = s.submit(t, job(Priority::Interactive)).unwrap();
        h3.wait().unwrap();
        let stats = s.shutdown();
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn fairness_forces_a_sweep_after_each_quantum() {
        let (s, t) = sched(16, 2, true);
        let sweeps: Vec<_> = (0..2)
            .map(|_| s.submit(t, job(Priority::Sweep)).unwrap())
            .collect();
        let inter: Vec<_> = (0..6)
            .map(|_| s.submit(t, job(Priority::Interactive)).unwrap())
            .collect();
        s.resume();
        for h in inter {
            h.wait().unwrap();
        }
        for h in sweeps {
            h.wait().unwrap();
        }
        let stats = s.shutdown();
        assert_eq!(stats.dispatched_interactive, 6);
        assert_eq!(stats.dispatched_sweep, 2);
        // quantum 2: the last interactive job (position 5) can be passed
        // by at most 5/2 + 1 = 3 sweeps; only 2 exist
        assert!(
            stats.max_sweeps_before_interactive <= 3,
            "fairness bound violated: {} sweeps overtook an interactive job",
            stats.max_sweeps_before_interactive
        );
        // paused admission means every job measurably waited
        assert!(stats.max_queue_wait > Duration::ZERO);
    }

    #[test]
    fn cancelling_a_queued_job_completes_it_typed_without_running() {
        let (s, t) = sched(8, 4, true);
        let h = s.submit(t, job(Priority::Interactive)).unwrap();
        h.cancel();
        assert!(!h.is_done(), "paused scheduler has not delivered yet");
        s.resume();
        let err = h.wait().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Cancelled);
        let stats = s.shutdown();
        assert_eq!(stats.cancelled_queued, 1);
        assert_eq!(stats.completed, 0);
    }

    #[test]
    fn deadline_spent_in_queue_fires_before_running() {
        let (s, t) = sched(8, 4, true);
        let j = Job::builder().procs(2).deadline_secs(1e-9).build().unwrap();
        let h = s.submit(t, j).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        s.resume();
        let err = h.wait().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::DeadlineExceeded);
    }

    #[test]
    fn degrade_policy_returns_flagged_best_effort_under_budget_stop() {
        let (s, t) = sched(8, 4, false);
        let j = Job::builder()
            .procs(2)
            .vclock_budget(f64::MIN_POSITIVE)
            .degrade()
            .build()
            .unwrap();
        let h = s.submit(t, j).unwrap();
        let r = h.wait().unwrap();
        assert!(r.degraded, "budget stop under Degrade must flag the result");
        assert!(r.summary_json().contains("\"degraded\":true"));
    }

    #[test]
    fn shutdown_drains_queued_jobs_typed() {
        let (s, t) = sched(8, 4, true);
        let h1 = s.submit(t, job(Priority::Interactive)).unwrap();
        let h2 = s.submit(t, job(Priority::Sweep)).unwrap();
        let stats = s.shutdown(); // never resumed: both still queued
        assert_eq!(stats.completed, 0);
        assert_eq!(h1.wait().unwrap_err().kind(), ErrorKind::Cancelled);
        assert_eq!(h2.wait().unwrap_err().kind(), ErrorKind::Cancelled);
    }

    #[test]
    fn tenant_cache_quota_clamps_the_lru_and_counts_per_tenant_evictions() {
        let s = Scheduler::new(SchedulerConfig::default()).with_tenant_cache_quota(1);
        let t0 = s.add_tenant(session());
        let t1 = s.add_tenant(session());
        // tenant 0 churns through two partition keys under its one-slot
        // quota: every key change evicts the previous entry
        for procs in [2, 3, 2] {
            let j = Job::builder().procs(procs).build().unwrap();
            s.submit(t0, j).unwrap().wait().unwrap();
        }
        // tenant 1 stays on a single key: no churn
        s.submit(t1, job(Priority::Interactive)).unwrap().wait().unwrap();
        let stats = s.stats();
        assert_eq!(
            stats.tenant_evictions,
            vec![2, 0],
            "evictions are attributed to the tenant that churned"
        );
        assert_eq!(s.shutdown().tenant_evictions, vec![2, 0]);

        // a session that asked for a tighter bound than the quota keeps it
        let s2 = Scheduler::new(SchedulerConfig::default()).with_tenant_cache_quota(8);
        let t = s2.add_tenant(session().with_partition_cache_cap(1));
        for procs in [2, 3] {
            let j = Job::builder().procs(procs).build().unwrap();
            s2.submit(t, j).unwrap().wait().unwrap();
        }
        assert_eq!(s2.shutdown().tenant_evictions, vec![1]);
    }

    #[test]
    fn unknown_tenant_is_rejected_without_queueing() {
        let (s, _t) = sched(8, 4, false);
        let err = s.submit(TenantId(99), job(Priority::Interactive)).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Generic);
        assert_eq!(s.queue_depth(), 0);
        let stats = s.shutdown();
        assert_eq!(stats.submitted, 0);
        assert_eq!(stats.rejected, 0, "tenant errors are not overload shedding");
    }
}
