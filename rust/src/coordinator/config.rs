//! The configuration system: one struct capturing every knob of the paper's
//! parameter space, parseable from CLI arguments.

use super::scheduler::Priority;
use crate::color::recolor::{Permutation, RecolorSchedule};
use crate::color::{Ordering, Selection};
use crate::dist::cost::CostModel;
use crate::dist::recolor::{CommScheme, RecolorConfig};
use crate::dist::{Engine, FaultPlan, NetworkModel};
use crate::partition::Partitioner;
use crate::util::args::Args;
use crate::util::error::{Context, Error, Result};

/// What recoloring (if any) follows the initial distributed coloring.
#[derive(Debug, Clone, Copy)]
pub enum RecolorMode {
    None,
    /// Synchronous recoloring (RC) — conflict-free, step per color class.
    Sync(RecolorConfig),
    /// Asynchronous recoloring (aRC) — speculative rerun with a
    /// class-derived order.
    Async { perm: Permutation, iterations: u32 },
}

impl RecolorMode {
    pub fn iterations(&self) -> u32 {
        match self {
            RecolorMode::None => 0,
            RecolorMode::Sync(c) => c.iterations,
            RecolorMode::Async { iterations, .. } => *iterations,
        }
    }
}

/// Full job description for a distributed coloring run.
#[derive(Debug, Clone)]
pub struct ColoringConfig {
    pub num_procs: usize,
    pub partitioner: Partitioner,
    pub ordering: Ordering,
    pub selection: Selection,
    pub superstep_size: usize,
    /// Synchronous superstep communication in the *initial* coloring.
    pub sync: bool,
    pub recolor: RecolorMode,
    pub seed: u64,
    pub network: NetworkModel,
    /// `None` → calibrate on this host; `Some` → fixed rates (tests).
    pub fixed_cost: Option<CostModel>,
    /// Stop recoloring once an iteration's relative improvement
    /// `(k_prev - k) / k_prev` falls below this threshold — the builder's
    /// `stop_when_improvement_below`. Requires a recoloring mode; not
    /// encoded in [`ColoringConfig::label`].
    pub early_stop: Option<f64>,
    /// Which execution path runs the job. The transport engines
    /// (`Threads`/`Bsp`) never change a modeled quantity (colors,
    /// messages, bytes, clocks) — only the simulator's wallclock — so the
    /// engine is not encoded in the label. [`Engine::DataPar`] is the
    /// exception: it is a different (shared-memory speculative) algorithm
    /// whose colorings legitimately differ from the transport engines',
    /// though they stay deterministic per seed. `Auto` never selects it.
    pub engine: Engine,
    /// Seeded transport/crash faults to inject ([`FaultPlan::none`] by
    /// default). An active plan requires the supervised BSP engine; the
    /// job validator enforces that.
    pub faults: FaultPlan,
    /// Wall-clock deadline in seconds, measured from run (or queue-admit)
    /// start. Expiry stops the run at its next engine checkpoint. Not
    /// encoded in [`ColoringConfig::label`] — none of the control knobs
    /// change what an uninterrupted run computes.
    pub deadline_secs: Option<f64>,
    /// Modeled virtual-clock budget in virtual seconds. Deterministic:
    /// the same job stops at the same checkpoint on every run. Requires a
    /// transport engine (DataPar has no virtual clock).
    pub vclock_budget: Option<f64>,
    /// What a stop (cancel/deadline/budget) returns: `false` → the typed
    /// error ([`StopPolicy::Fail`](crate::util::cancel::StopPolicy)),
    /// `true` → the best-so-far coloring repaired to validity and flagged
    /// `degraded` ([`StopPolicy::Degrade`](crate::util::cancel::StopPolicy)).
    pub degrade: bool,
    /// Scheduling class when the job is submitted through
    /// [`Scheduler`](super::scheduler::Scheduler); direct `Session::run`
    /// calls ignore it.
    pub priority: Priority,
}

impl Default for ColoringConfig {
    fn default() -> Self {
        ColoringConfig {
            num_procs: 4,
            partitioner: Partitioner::BfsGrow,
            ordering: Ordering::InternalFirst,
            selection: Selection::FirstFit,
            superstep_size: 1000,
            sync: true,
            recolor: RecolorMode::None,
            seed: 42,
            network: NetworkModel::default(),
            fixed_cost: None,
            early_stop: None,
            engine: Engine::Auto,
            faults: FaultPlan::none(),
            deadline_secs: None,
            vclock_budget: None,
            degrade: false,
            priority: Priority::default(),
        }
    }
}

impl ColoringConfig {
    /// The paper's "speed" setting: FIxxND0 — First Fit, Internal-First,
    /// no recoloring.
    pub fn speed(num_procs: usize) -> Self {
        ColoringConfig {
            num_procs,
            ordering: Ordering::InternalFirst,
            selection: Selection::FirstFit,
            recolor: RecolorMode::None,
            ..Default::default()
        }
    }

    /// The paper's "quality" setting: R(5-10)IxxND1 — Random-5 Fit,
    /// Internal-First, one ND synchronous recoloring iteration.
    pub fn quality(num_procs: usize) -> Self {
        ColoringConfig {
            num_procs,
            ordering: Ordering::InternalFirst,
            selection: Selection::RandomX(5),
            recolor: RecolorMode::Sync(RecolorConfig {
                schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
                iterations: 1,
                scheme: CommScheme::Piggyback,
                seed: 42,
                ..Default::default()
            }),
            ..Default::default()
        }
    }

    pub fn cost_model(&self) -> CostModel {
        self.fixed_cost.unwrap_or_else(CostModel::calibrated)
    }

    /// Parse from CLI arguments (`--procs`, `--ordering`, `--selection`,
    /// `--superstep`, `--async`, `--recolor <n>`, `--arc`, `--schedule`,
    /// `--scheme`, `--partitioner`, `--seed`, `--ideal-net`,
    /// `--stop-eps <f>`, `--engine auto|threads|bsp|datapar`,
    /// `--faults <spec>` — see [`FaultPlan::parse`] — with
    /// `--ckpt-interval <n>` overriding the plan's supervised checkpoint
    /// cadence, plus the service
    /// knobs `--deadline <secs>`, `--vbudget <vsecs>`, `--degrade` and
    /// `--priority interactive|sweep`). Parse-only: validation happens
    /// when the config becomes a [`Job`](super::Job).
    pub fn from_args(a: &Args) -> Result<Self> {
        let mut cfg = ColoringConfig {
            num_procs: a.get_or("procs", 4usize)?,
            seed: a.get_or("seed", 42u64)?,
            superstep_size: a.get_or("superstep", 1000usize)?,
            sync: !a.has_flag("async"),
            ..Default::default()
        };
        if let Some(s) = a.get_str("ordering") {
            cfg.ordering = s.parse().map_err(Error::msg)?;
        }
        if let Some(s) = a.get_str("selection") {
            cfg.selection = s.parse().map_err(Error::msg)?;
        }
        if let Some(s) = a.get_str("partitioner") {
            cfg.partitioner = s.parse().map_err(Error::msg)?;
        }
        if a.has_flag("ideal-net") {
            cfg.network = NetworkModel::ideal();
        }
        if let Some(s) = a.get_str("engine") {
            cfg.engine = s.parse().map_err(Error::msg)?;
        }
        if let Some(s) = a.get_str("faults") {
            cfg.faults = FaultPlan::parse(s)?;
        }
        if let Some(s) = a.get_str("ckpt-interval") {
            let n: u64 = s
                .parse()
                .with_context(|| format!("invalid value {s:?} for --ckpt-interval"))?;
            cfg.faults.checkpoint_interval = n;
        }
        if let Some(s) = a.get_str("stop-eps") {
            let eps: f64 = s
                .parse()
                .with_context(|| format!("invalid value {s:?} for --stop-eps"))?;
            cfg.early_stop = Some(eps);
        }
        if let Some(s) = a.get_str("deadline") {
            let secs: f64 = s
                .parse()
                .with_context(|| format!("invalid value {s:?} for --deadline"))?;
            cfg.deadline_secs = Some(secs);
        }
        if let Some(s) = a.get_str("vbudget") {
            let vs: f64 = s
                .parse()
                .with_context(|| format!("invalid value {s:?} for --vbudget"))?;
            cfg.vclock_budget = Some(vs);
        }
        cfg.degrade = a.has_flag("degrade");
        if let Some(s) = a.get_str("priority") {
            cfg.priority = s.parse().map_err(Error::msg)?;
        }
        let iters: u32 = a.get_or("recolor", 0u32)?;
        if iters > 0 {
            let schedule: RecolorSchedule = a
                .str_or("schedule", "nd")
                .parse()
                .map_err(Error::msg)?;
            if a.has_flag("arc") {
                let perm = match schedule {
                    RecolorSchedule::Fixed(p) => p,
                    _ => Permutation::NonDecreasing,
                };
                cfg.recolor = RecolorMode::Async {
                    perm,
                    iterations: iters,
                };
            } else {
                let scheme: CommScheme = a
                    .str_or("scheme", "piggyback")
                    .parse()
                    .map_err(Error::msg)?;
                cfg.recolor = RecolorMode::Sync(RecolorConfig {
                    schedule,
                    iterations: iters,
                    scheme,
                    seed: cfg.seed,
                    ..Default::default()
                });
            }
        }
        Ok(cfg)
    }

    /// Compact label in the paper's naming style, e.g. `FI1000s-ND1`.
    pub fn label(&self) -> String {
        let sel = self.selection.short_name();
        let ord = match self.ordering {
            Ordering::InternalFirst => "I",
            Ordering::SmallestLast => "S",
            Ordering::Natural => "N",
            Ordering::LargestFirst => "L",
            Ordering::BoundaryFirst => "B",
            Ordering::IncidenceDegree => "D",
            Ordering::Random => "R",
        };
        let comm = if self.sync { "s" } else { "a" };
        let rc = match &self.recolor {
            RecolorMode::None => "0".to_string(),
            RecolorMode::Sync(c) => format!("{}{}", c.schedule.label(), c.iterations),
            // the permutation schedule is part of the config: two aRC
            // jobs differing only in `perm` must not collide in sweep
            // rows keyed by the label
            RecolorMode::Async { perm, iterations } => {
                format!("aRC-{}{iterations}", perm.short_name())
            }
        };
        format!("{sel}{ord}{}{comm}-{rc}{}", self.superstep_size, self.faults.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn default_roundtrip() {
        let cfg = ColoringConfig::from_args(&parse("")).unwrap();
        assert_eq!(cfg.num_procs, 4);
        assert!(cfg.sync);
        assert!(matches!(cfg.recolor, RecolorMode::None));
    }

    #[test]
    fn full_parse() {
        let cfg = ColoringConfig::from_args(&parse(
            "--procs 8 --ordering sl --selection r5 --superstep 500 --async --recolor 2 --schedule nd --scheme base --seed 7",
        ))
        .unwrap();
        assert_eq!(cfg.num_procs, 8);
        assert_eq!(cfg.ordering, Ordering::SmallestLast);
        assert_eq!(cfg.selection, Selection::RandomX(5));
        assert!(!cfg.sync);
        match cfg.recolor {
            RecolorMode::Sync(rc) => {
                assert_eq!(rc.iterations, 2);
                assert_eq!(rc.scheme, CommScheme::Base);
            }
            _ => panic!("expected sync recolor"),
        }
    }

    #[test]
    fn arc_parse() {
        let cfg = ColoringConfig::from_args(&parse("--recolor 1 --arc")).unwrap();
        assert!(matches!(cfg.recolor, RecolorMode::Async { iterations: 1, .. }));
        // the label encodes the permutation schedule (default ND)
        assert_eq!(cfg.label(), "FI1000s-aRC-ND1");
        let cfg =
            ColoringConfig::from_args(&parse("--recolor 2 --arc --schedule ni")).unwrap();
        assert!(matches!(
            cfg.recolor,
            RecolorMode::Async {
                perm: Permutation::NonIncreasing,
                iterations: 2,
            }
        ));
        assert_eq!(cfg.label(), "FI1000s-aRC-NI2");
    }

    #[test]
    fn stop_eps_parse() {
        let cfg = ColoringConfig::from_args(&parse("--recolor 4 --stop-eps 0.05")).unwrap();
        assert_eq!(cfg.early_stop, Some(0.05));
        assert!(ColoringConfig::from_args(&parse("--stop-eps nope")).is_err());
        assert_eq!(ColoringConfig::from_args(&parse("")).unwrap().early_stop, None);
    }

    #[test]
    fn service_knobs_parse_without_touching_the_label() {
        let cfg = ColoringConfig::from_args(&parse(
            "--deadline 2.5 --vbudget 100 --degrade --priority sweep",
        ))
        .unwrap();
        assert_eq!(cfg.deadline_secs, Some(2.5));
        assert_eq!(cfg.vclock_budget, Some(100.0));
        assert!(cfg.degrade);
        assert_eq!(cfg.priority, Priority::Sweep);
        // none of the control knobs change what the run computes, so the
        // label — the sweep/bench row key — stays byte-identical
        assert_eq!(cfg.label(), ColoringConfig::default().label());
        let cfg = ColoringConfig::from_args(&parse("")).unwrap();
        assert_eq!(cfg.deadline_secs, None);
        assert_eq!(cfg.vclock_budget, None);
        assert!(!cfg.degrade);
        assert_eq!(cfg.priority, Priority::Interactive);
        assert!(ColoringConfig::from_args(&parse("--deadline soon")).is_err());
        assert!(ColoringConfig::from_args(&parse("--vbudget lots")).is_err());
        assert!(ColoringConfig::from_args(&parse("--priority urgent")).is_err());
    }

    #[test]
    fn engine_parse() {
        assert_eq!(ColoringConfig::from_args(&parse("")).unwrap().engine, Engine::Auto);
        let cfg = ColoringConfig::from_args(&parse("--engine threads")).unwrap();
        assert_eq!(cfg.engine, Engine::Threads);
        let cfg = ColoringConfig::from_args(&parse("--engine bsp")).unwrap();
        assert_eq!(cfg.engine, Engine::Bsp);
        let cfg = ColoringConfig::from_args(&parse("--engine datapar")).unwrap();
        assert_eq!(cfg.engine, Engine::DataPar);
        assert!(ColoringConfig::from_args(&parse("--engine warp")).is_err());
    }

    #[test]
    fn faults_parse_and_label() {
        let cfg = ColoringConfig::from_args(&parse("--faults seed=3,crash=1@4")).unwrap();
        assert!(cfg.faults.is_active());
        assert!(cfg.label().ends_with("+faults[seed=3,crash=1@4]"));
        assert!(ColoringConfig::from_args(&parse("--faults seed=3")).is_err());
        // inert plans leave fault-free labels byte-identical
        assert_eq!(ColoringConfig::default().label(), "FI1000s-0");
    }

    #[test]
    fn loss_crashes_and_checkpoint_interval_parse() {
        let cfg = ColoringConfig::from_args(&parse(
            "--faults seed=3,loss=0.1,crash=1@4,crash=2@6+3 --ckpt-interval 4",
        ))
        .unwrap();
        assert!(cfg.faults.is_active());
        assert!(cfg.faults.reliable());
        assert_eq!(cfg.faults.loss_prob, 0.1);
        assert_eq!(cfg.faults.crashes.len(), 2);
        assert_eq!(cfg.faults.checkpoint_interval, 4);
        assert!(ColoringConfig::from_args(&parse("--ckpt-interval often")).is_err());
        // the interval override alone leaves the plan inert
        let cfg = ColoringConfig::from_args(&parse("--ckpt-interval 4")).unwrap();
        assert!(!cfg.faults.is_active());
        assert!(!cfg.faults.reliable());
    }

    #[test]
    fn labels() {
        assert_eq!(ColoringConfig::speed(32).label(), "FI1000s-0");
        assert!(ColoringConfig::quality(32).label().starts_with("R5I1000s-ND1"));
        // aRC labels differing only in the permutation stay distinct
        let arc = |perm| ColoringConfig {
            recolor: RecolorMode::Async {
                perm,
                iterations: 2,
            },
            ..Default::default()
        };
        assert_eq!(arc(Permutation::NonDecreasing).label(), "FI1000s-aRC-ND2");
        assert_ne!(
            arc(Permutation::NonDecreasing).label(),
            arc(Permutation::Random).label()
        );
    }

    #[test]
    fn presets_match_paper() {
        let s = ColoringConfig::speed(32);
        assert!(matches!(s.recolor, RecolorMode::None));
        assert_eq!(s.selection, Selection::FirstFit);
        let q = ColoringConfig::quality(32);
        assert!(matches!(q.selection, Selection::RandomX(5)));
        assert_eq!(q.recolor.iterations(), 1);
    }
}
