//! The process runner: one OS thread per simulated process.
//!
//! `run_distributed` builds the local graphs and the endpoint network,
//! spawns a scoped thread per process running the caller's process
//! function, merges the reported owned colors into one global [`Coloring`],
//! and aggregates the per-process [`ProcMetrics`] into [`DistMetrics`].
//! Real parallelism only affects wallclock; every virtual quantity
//! (messages, bytes, conflicts, clocks) is deterministic.

use crate::color::Coloring;
use crate::dist::comm::{self, Endpoint};
use crate::dist::cost::NetworkModel;
use crate::dist::proc::{build_local_graphs, LocalGraph};
use crate::dist::{DistMetrics, ProcMetrics};
use crate::graph::CsrGraph;
use crate::partition::Partition;
use crate::util::cancel::StopCause;
use crate::util::error::{Error, Result};
use crate::util::timer::Timer;

/// What one process function returns.
pub struct ProcResult {
    /// `(global id, color)` of every vertex the process owns.
    pub colors: Vec<(u32, u32)>,
    pub metrics: ProcMetrics,
}

/// A finished distributed run.
pub struct DistOutcome {
    pub coloring: Coloring,
    pub metrics: DistMetrics,
    pub per_proc: Vec<ProcMetrics>,
    /// `Some(cause)` when the run was stopped early by its
    /// [`CancelToken`](crate::util::cancel::CancelToken) — the coloring is
    /// then whatever the abort drain harvested (possibly partial or
    /// conflicted) and the pipeline decides between failing with the
    /// cause's typed error and repairing to a degraded-but-valid result.
    /// `None` for every run that finished on its own.
    pub stopped: Option<StopCause>,
}

/// Run `f` once per partition part on its own thread and merge the results.
/// Builds the local graphs itself; callers holding cached locals (a
/// [`Session`](crate::coordinator::Session)) use [`run_distributed_with`].
pub fn run_distributed<F>(g: &CsrGraph, part: &Partition, net: NetworkModel, f: F) -> DistOutcome
where
    F: Fn(&mut Endpoint, &LocalGraph) -> ProcResult + Sync,
{
    let (_, locals) = build_local_graphs(g, part);
    run_distributed_with(g, &locals, net, f)
}

/// [`run_distributed`] over pre-built local graphs (one thread per local
/// graph); `g` only sizes the merged coloring.
pub fn run_distributed_with<F>(
    g: &CsrGraph,
    locals: &[LocalGraph],
    net: NetworkModel,
    f: F,
) -> DistOutcome
where
    F: Fn(&mut Endpoint, &LocalGraph) -> ProcResult + Sync,
{
    match try_run_distributed_with(g, locals, net, f) {
        Ok(out) => out,
        Err(e) => panic!("{e}"),
    }
}

/// [`run_distributed_with`] with a panicking process thread reported as
/// [`ErrorKind::ProcFailed`](crate::util::error::ErrorKind) instead of
/// re-panicking the caller. All threads are joined either way, so no
/// worker is left touching caller data.
pub fn try_run_distributed_with<F>(
    g: &CsrGraph,
    locals: &[LocalGraph],
    net: NetworkModel,
    f: F,
) -> Result<DistOutcome>
where
    F: Fn(&mut Endpoint, &LocalGraph) -> ProcResult + Sync,
{
    let wall = Timer::start();
    let procs = locals.len();
    let eps = comm::network(procs, net);
    let mut slots: Vec<Option<ProcResult>> = (0..procs).map(|_| None).collect();
    let mut failed: Option<Error> = None;
    std::thread::scope(|s| {
        let fref = &f;
        let mut handles = Vec::with_capacity(procs);
        for (ep, lg) in eps.into_iter().zip(locals.iter()) {
            handles.push(s.spawn(move || {
                let mut ep = ep;
                let mut r = fref(&mut ep, lg);
                r.metrics.rank = ep.rank;
                r
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(r) => slots[i] = Some(r),
                Err(p) => {
                    let detail = panic_detail(&p);
                    if failed.is_none() {
                        failed = Some(Error::proc_failed(i as u32, 0, &detail));
                    }
                }
            }
        }
    });
    if let Some(e) = failed {
        return Err(e);
    }
    let mut coloring = Coloring::uncolored(g.num_vertices());
    let mut per_proc = Vec::with_capacity(procs);
    for r in slots.into_iter().map(|r| r.unwrap()) {
        for (gid, c) in r.colors {
            coloring.set(gid, c);
        }
        per_proc.push(r.metrics);
    }
    let metrics = DistMetrics::aggregate(&per_proc, wall.secs());
    Ok(DistOutcome {
        coloring,
        metrics,
        per_proc,
        stopped: None,
    })
}

/// Best-effort human-readable payload of a caught panic.
pub(crate) fn panic_detail(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "process thread panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::UNCOLORED;
    use crate::dist::proc::ColorState;
    use crate::graph::synth;
    use crate::partition::{self, Partitioner};

    #[test]
    fn runner_merges_all_owned_colors() {
        let g = synth::grid2d(6, 6);
        let part = partition::partition(&g, Partitioner::Block, 4, 1);
        let out = run_distributed(&g, &part, NetworkModel::ideal(), |ep, lg| {
            // trivially color everything with the owner's rank
            let mut state = ColorState::uncolored(lg);
            for v in 0..lg.n_owned() {
                state.colors[v] = lg.rank;
            }
            ep.clock += 1.0 + lg.rank as f64;
            ProcResult {
                colors: state.owned_pairs(lg),
                metrics: ProcMetrics {
                    vtime: ep.clock,
                    ..Default::default()
                },
            }
        });
        assert!(out.coloring.colors.iter().all(|&c| c != UNCOLORED));
        assert_eq!(out.per_proc.len(), 4);
        assert_eq!(out.metrics.num_procs, 4);
        // ranks recorded, makespan = slowest virtual clock
        assert_eq!(out.per_proc[2].rank, 2);
        assert!((out.metrics.makespan - 4.0).abs() < 1e-12);
        assert!(out.metrics.wall_secs >= 0.0);
        // every vertex got its owner's rank
        for v in 0..g.num_vertices() {
            assert_eq!(out.coloring.colors[v], part.parts[v]);
        }
    }
}
