//! Per-process local views: owned vertices, ghost copies of remote
//! neighbors, and the exchange lists the framework and recoloring use.
//!
//! Local index space: owned vertices first (ascending global id, so local
//! order == global order within a process), ghosts after (also ascending
//! global id). The local CSR stores the full adjacency of owned vertices
//! (to owned and ghost neighbors alike, in local ids); ghosts have empty
//! adjacency — a process never iterates a remote vertex's neighborhood,
//! exactly as in the MPI original.
//!
//! Global→local lookup ([`LocalGraph::local_of`]) is dense: owned vertices
//! resolve in O(1) through the shared [`GlobalMap`], ghosts by binary
//! search over the sorted ghost tail of `global_ids` — no per-process hash
//! map, no hashing on the boundary receive path.
//!
//! Local graphs are immutable during a run and shared by reference into
//! the engines — which is what makes supervised crash *replay* sound: a
//! revived machine is rebuilt from a checkpoint against the same
//! `LocalGraph`, so only machine state and transport state need
//! snapshotting, never the graph.

use crate::color::{Color, Coloring, UNCOLORED};
use crate::graph::{CsrGraph, VertexId};
use crate::partition::Partition;
use std::sync::{Arc, Mutex};

/// Global vertex → (owner process, local index on the owner). Built once
/// per partition and shared read-only by every [`LocalGraph`] — 8 bytes per
/// global vertex total, instead of a per-process hash map over its locals.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GlobalMap {
    pub owner: Vec<u32>,
    pub local: Vec<u32>,
}

/// One process's share of the graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LocalGraph {
    pub rank: u32,
    pub nprocs: usize,
    /// Local CSR: owned vertices `0..n_owned()` with full adjacency in
    /// local ids; ghosts `n_owned()..n_local()` with empty adjacency.
    pub csr: CsrGraph,
    owned_count: usize,
    /// Global id of every local vertex (owned, then ghosts).
    pub global_ids: Vec<VertexId>,
    /// Whether the vertex (by its *global* neighborhood) has any neighbor
    /// outside this process's part.
    pub is_boundary: Vec<bool>,
    /// Owning process of every local vertex.
    pub owner: Vec<u32>,
    /// Processes this one shares at least one cut edge with, sorted.
    pub neighbor_procs: Vec<usize>,
    /// Per entry of `neighbor_procs`: owned local ids (ascending) whose
    /// colors that process needs (it holds them as ghosts).
    pub send_lists: Vec<Vec<u32>>,
    /// The partition-wide vertex directory, shared across processes.
    pub gmap: Arc<GlobalMap>,
}

impl LocalGraph {
    #[inline]
    pub fn n_owned(&self) -> usize {
        self.owned_count
    }

    #[inline]
    pub fn n_local(&self) -> usize {
        self.global_ids.len()
    }

    /// Local id of a global vertex present on this process: O(1) through
    /// the shared [`GlobalMap`] for owned vertices, binary search over the
    /// sorted ghost tail of `global_ids` otherwise. This is the boundary
    /// receive path's lookup — dense reads instead of a hash probe per
    /// ghost update.
    #[inline]
    pub fn local_of(&self, gid: VertexId) -> u32 {
        if self.gmap.owner[gid as usize] == self.rank {
            return self.gmap.local[gid as usize];
        }
        let ghosts = &self.global_ids[self.owned_count..];
        match ghosts.binary_search(&gid) {
            Ok(j) => (self.owned_count + j) as u32,
            Err(_) => panic!("vertex {gid} is not present on process {}", self.rank),
        }
    }
}

/// The shared global→(owner, local) directory of a partition.
fn build_global_map(g: &CsrGraph, members: &[Vec<VertexId>]) -> Arc<GlobalMap> {
    let mut owner = vec![0u32; g.num_vertices()];
    let mut local = vec![0u32; g.num_vertices()];
    for (p, ms) in members.iter().enumerate() {
        for (i, &v) in ms.iter().enumerate() {
            owner[v as usize] = p as u32;
            local[v as usize] = i as u32;
        }
    }
    Arc::new(GlobalMap { owner, local })
}

/// Build process `p`'s local view — the per-rank body shared by the serial
/// and pool-parallel builders. Pure per rank: reads only shared inputs.
fn build_one_local(
    g: &CsrGraph,
    part: &Partition,
    members: &[Vec<VertexId>],
    gmap: &Arc<GlobalMap>,
    p: usize,
) -> LocalGraph {
    let owned = &members[p];
    let rank = p as u32;
    let n_owned = owned.len();

    let mut ghosts: Vec<VertexId> = Vec::new();
    for &u in owned {
        for &v in g.neighbors(u) {
            if part.part_of(v) != rank {
                ghosts.push(v);
            }
        }
    }
    ghosts.sort_unstable();
    ghosts.dedup();

    let n_local = n_owned + ghosts.len();
    let mut global_ids: Vec<VertexId> = Vec::with_capacity(n_local);
    global_ids.extend_from_slice(owned);
    global_ids.extend_from_slice(&ghosts);
    // same lookup LocalGraph::local_of performs once constructed
    let lid = |v: VertexId| -> u32 {
        if gmap.owner[v as usize] == rank {
            gmap.local[v as usize]
        } else {
            let j = ghosts.binary_search(&v).expect("neighbor is owned or ghost");
            (n_owned + j) as u32
        }
    };

    let mut xadj = vec![0u64; n_local + 1];
    for (i, &u) in owned.iter().enumerate() {
        xadj[i + 1] = xadj[i] + g.degree(u) as u64;
    }
    for j in n_owned..n_local {
        xadj[j + 1] = xadj[j];
    }
    let mut adjncy: Vec<VertexId> = Vec::with_capacity(xadj[n_owned] as usize);
    for &u in owned {
        for &v in g.neighbors(u) {
            adjncy.push(lid(v));
        }
    }
    let csr = CsrGraph::new(xadj, adjncy, format!("{}@p{p}", g.name));

    let is_boundary: Vec<bool> = global_ids
        .iter()
        .map(|&v| g.neighbors(v).iter().any(|&u| part.part_of(u) != rank))
        .collect();
    let owner_l: Vec<u32> = global_ids.iter().map(|&v| gmap.owner[v as usize]).collect();

    let mut neighbor_procs: Vec<usize> = ghosts
        .iter()
        .map(|&v| gmap.owner[v as usize] as usize)
        .collect();
    neighbor_procs.sort_unstable();
    neighbor_procs.dedup();

    let mut send_lists: Vec<Vec<u32>> = vec![Vec::new(); neighbor_procs.len()];
    let mut scratch: Vec<usize> = Vec::new();
    for (i, &u) in owned.iter().enumerate() {
        scratch.clear();
        for &v in g.neighbors(u) {
            let q = part.part_of(v) as usize;
            if q != p {
                scratch.push(q);
            }
        }
        scratch.sort_unstable();
        scratch.dedup();
        for &q in scratch.iter() {
            let qi = neighbor_procs.binary_search(&q).unwrap();
            send_lists[qi].push(i as u32);
        }
    }

    LocalGraph {
        rank,
        nprocs: part.num_parts,
        csr,
        owned_count: n_owned,
        global_ids,
        is_boundary,
        owner: owner_l,
        neighbor_procs,
        send_lists,
        gmap: Arc::clone(gmap),
    }
}

/// Split `g` into per-process local views according to `part`. The
/// returned [`GlobalMap`] is the same shared directory every local graph
/// holds through [`LocalGraph::gmap`].
pub fn build_local_graphs(g: &CsrGraph, part: &Partition) -> (Arc<GlobalMap>, Vec<LocalGraph>) {
    assert_eq!(g.num_vertices(), part.parts.len());
    let members = part.members();
    let gmap = build_global_map(g, &members);
    let locals = (0..part.num_parts)
        .map(|p| build_one_local(g, part, &members, &gmap, p))
        .collect();
    (gmap, locals)
}

/// [`build_local_graphs`] with the per-rank builds spread over the global
/// worker pool ([`util::pool`](crate::util::pool)) — each rank's view is
/// an independent function of the shared inputs, so the outputs are
/// identical to the serial builder's (`parallel_build_matches_serial`
/// pins this). Used by `Session`s, whose cached builds happen once per
/// partition key.
pub fn build_local_graphs_parallel(
    g: &CsrGraph,
    part: &Partition,
) -> (Arc<GlobalMap>, Vec<LocalGraph>) {
    assert_eq!(g.num_vertices(), part.parts.len());
    let nprocs = part.num_parts;
    let pool = crate::util::pool::global();
    let shards = pool.workers().min(nprocs).max(1);
    if shards <= 1 {
        return build_local_graphs(g, part);
    }
    let members = part.members();
    let gmap = build_global_map(g, &members);
    let slots: Vec<Mutex<Option<LocalGraph>>> = (0..nprocs).map(|_| Mutex::new(None)).collect();
    pool.scoped_run(shards, &|w| {
        let mut p = w;
        while p < nprocs {
            let lg = build_one_local(g, part, &members, &gmap, p);
            *slots[p].lock().unwrap() = Some(lg);
            p += shards;
        }
    });
    let locals = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("rank build missing"))
        .collect();
    (gmap, locals)
}

/// Per-process color state over the local index space (owned + ghosts).
#[derive(Debug, Clone)]
pub struct ColorState {
    pub colors: Vec<Color>,
}

impl ColorState {
    /// Everything uncolored — the initial-coloring entry state.
    pub fn uncolored(lg: &LocalGraph) -> Self {
        ColorState {
            colors: vec![UNCOLORED; lg.n_local()],
        }
    }

    /// Project a global coloring onto this process's local vertices —
    /// the recoloring entry state.
    pub fn from_global(lg: &LocalGraph, c: &Coloring) -> Self {
        ColorState {
            colors: lg.global_ids.iter().map(|&v| c.get(v)).collect(),
        }
    }

    /// `(global id, color)` of every owned vertex — what a process reports
    /// back to the coordinator.
    pub fn owned_pairs(&self, lg: &LocalGraph) -> Vec<(u32, u32)> {
        (0..lg.n_owned())
            .map(|i| (lg.global_ids[i], self.colors[i]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;
    use crate::partition::{self, Partitioner};

    fn split(g: &CsrGraph, procs: usize) -> Vec<LocalGraph> {
        let part = partition::partition(g, Partitioner::Block, procs, 1);
        build_local_graphs(g, &part).1
    }

    #[test]
    fn owned_and_ghost_layout() {
        let g = synth::path(6); // 0-1-2-3-4-5, block into [0,1,2] [3,4,5]
        let locals = split(&g, 2);
        assert_eq!(locals[0].n_owned(), 3);
        assert_eq!(locals[0].n_local(), 4); // ghost: 3
        assert_eq!(locals[0].global_ids, vec![0, 1, 2, 3]);
        assert_eq!(locals[1].global_ids, vec![3, 4, 5, 2]);
        assert_eq!(locals[0].neighbor_procs, vec![1]);
        assert_eq!(locals[1].neighbor_procs, vec![0]);
        // only vertex 2 (resp. 3) is boundary among owned
        assert_eq!(locals[0].is_boundary[..3], [false, false, true]);
        assert_eq!(locals[0].send_lists, vec![vec![2]]);
        assert_eq!(locals[1].send_lists, vec![vec![0]]);
        // ghost has empty adjacency
        assert_eq!(locals[0].csr.degree(3), 0);
        // owned adjacency is complete: local 2 sees local 1 and ghost 3
        assert_eq!(locals[0].csr.neighbors(2), &[1, 3]);
    }

    #[test]
    fn local_of_resolves_every_local_vertex() {
        let g = synth::erdos_renyi(200, 900, 4);
        let part = partition::partition(&g, Partitioner::Block, 4, 1);
        let (gmap, locals) = build_local_graphs(&g, &part);
        for l in &locals {
            for (i, &gid) in l.global_ids.iter().enumerate() {
                assert_eq!(l.local_of(gid), i as u32, "p{} gid {gid}", l.rank);
            }
            // owned lookups come straight from the shared directory
            for i in 0..l.n_owned() {
                let gid = l.global_ids[i] as usize;
                assert_eq!(gmap.owner[gid], l.rank);
                assert_eq!(gmap.local[gid], i as u32);
            }
        }
    }

    #[test]
    #[should_panic(expected = "not present")]
    fn local_of_panics_for_absent_vertex() {
        let g = synth::path(6); // blocks [0,1,2] [3,4,5]; vertex 5 not on p0
        let locals = split(&g, 2);
        locals[0].local_of(5);
    }

    #[test]
    fn degree_conservation() {
        let g = synth::fem_like(500, 9.0, 24, 0.01, 3, "f");
        for procs in [1, 2, 5] {
            let locals = split(&g, procs);
            let owned: usize = locals.iter().map(|l| l.n_owned()).sum();
            assert_eq!(owned, g.num_vertices());
            let deg: u64 = locals.iter().map(|l| l.csr.xadj[l.n_owned()]).sum();
            assert_eq!(deg, 2 * g.num_edges() as u64);
        }
    }

    #[test]
    fn neighbor_relation_is_symmetric() {
        let g = synth::erdos_renyi(300, 1500, 9);
        let locals = split(&g, 5);
        for l in &locals {
            for &q in &l.neighbor_procs {
                assert!(
                    locals[q].neighbor_procs.contains(&(l.rank as usize)),
                    "p{} lists p{q} but not vice versa",
                    l.rank
                );
                assert_ne!(q, l.rank as usize);
            }
            assert_eq!(l.neighbor_procs.len(), l.send_lists.len());
        }
    }

    #[test]
    fn send_lists_cover_exactly_the_ghost_copies() {
        let g = synth::grid2d(8, 8);
        let locals = split(&g, 4);
        for l in &locals {
            for (qi, &q) in l.neighbor_procs.iter().enumerate() {
                // what q holds as ghosts owned by l
                let ghosts_on_q: Vec<u32> = locals[q].global_ids[locals[q].n_owned()..]
                    .iter()
                    .copied()
                    .filter(|&v| locals[q].owner[locals[q].local_of(v) as usize] == l.rank)
                    .collect();
                let sent: Vec<u32> = l.send_lists[qi]
                    .iter()
                    .map(|&i| l.global_ids[i as usize])
                    .collect();
                let mut a = ghosts_on_q.clone();
                a.sort_unstable();
                assert_eq!(sent, a, "p{}→p{q}", l.rank);
            }
        }
    }

    #[test]
    fn color_state_roundtrip() {
        let g = synth::cycle(10);
        let locals = split(&g, 3);
        let c = Coloring::from_vec((0..10).map(|v| v % 3).collect());
        let mut merged = Coloring::uncolored(10);
        for l in &locals {
            let st = ColorState::from_global(l, &c);
            for (gid, col) in st.owned_pairs(l) {
                merged.set(gid, col);
            }
            // ghosts projected too
            for i in l.n_owned()..l.n_local() {
                assert_eq!(st.colors[i], c.get(l.global_ids[i]));
            }
        }
        assert_eq!(merged.colors, c.colors);
        let st = ColorState::uncolored(&locals[0]);
        assert!(st.colors.iter().all(|&c| c == UNCOLORED));
    }

    /// The pool-parallel builder is a pure speedup: identical outputs to
    /// the serial builder on every rank, for every partitioner and scale.
    #[test]
    fn parallel_build_matches_serial() {
        let g = synth::fem_like(900, 10.0, 26, 0.01, 7, "par");
        for (partitioner, procs) in [
            (Partitioner::Block, 1usize),
            (Partitioner::Block, 5),
            (Partitioner::BfsGrow, 16),
            (Partitioner::Block, 64),
        ] {
            let part = partition::partition(&g, partitioner, procs, 3);
            let (gs, ls) = build_local_graphs(&g, &part);
            let (gp, lp) = build_local_graphs_parallel(&g, &part);
            assert_eq!(*gs, *gp, "global map diverged ({partitioner:?}, {procs})");
            assert_eq!(ls.len(), lp.len());
            for (a, b) in ls.iter().zip(lp.iter()) {
                assert_eq!(a, b, "p{} local view diverged", a.rank);
            }
        }
    }

    #[test]
    fn empty_parts_are_fine() {
        let g = synth::path(3);
        // 5 parts over 3 vertices → at least two empty parts
        let part = partition::partition(&g, Partitioner::Block, 5, 1);
        let (_, locals) = build_local_graphs(&g, &part);
        assert_eq!(locals.len(), 5);
        let owned: usize = locals.iter().map(|l| l.n_owned()).sum();
        assert_eq!(owned, 3);
        for l in &locals {
            if l.n_owned() == 0 {
                assert!(l.neighbor_procs.is_empty());
                assert_eq!(l.n_local(), 0);
            }
        }
    }
}
