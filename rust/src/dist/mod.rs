//! The distributed-memory runtime (paper §3).
//!
//! * [`comm`] — in-process message transport with exact per-endpoint
//!   message/byte accounting and α-β virtual clocks.
//! * [`cost`] — compute [`CostModel`](cost::CostModel) and the
//!   [`NetworkModel`] driving those clocks.
//! * [`proc`] — per-process local graphs with ghost vertices and exchange
//!   lists.
//! * [`framework`] — the Bozdağ superstep framework: speculative coloring,
//!   boundary conflict detection and re-resolution rounds, sync/async.
//! * [`recolor`] — distributed synchronous recoloring (RC, conflict-free,
//!   one superstep per color class) with the paper's piggybacked
//!   communication scheme, and asynchronous recoloring (aRC).
//! * [`runner`] — one thread per virtual process; merges results and
//!   aggregates [`ProcMetrics`] into [`DistMetrics`].
//! * [`engine`] — the BSP step engine: processes as step state machines
//!   executed in lockstep on a fixed pool of worker threads; bit-for-bit
//!   identical modeled quantities, no per-run thread spawns.

pub mod comm;
pub mod cost;
pub mod engine;
pub mod fault;
pub mod framework;
pub mod proc;
pub mod recolor;
pub mod runner;

pub use comm::{network, network_faulted, Endpoint, MsgKind};
pub use cost::{CostModel, NetworkModel};
pub use engine::{
    run_steps, run_steps_cancellable, run_steps_supervised, run_steps_supervised_cancellable,
    Engine, StepOutcome, StepProcess,
};
pub use fault::{Crash, FaultPlan};
pub use runner::{run_distributed, run_distributed_with, DistOutcome, ProcResult};

use crate::util::timer::PhaseTimes;

/// What one simulated process reports after its part of a job.
#[derive(Debug, Clone, Default)]
pub struct ProcMetrics {
    pub rank: usize,
    /// Virtual seconds per phase ("color", "recolor", "plan", "comm").
    pub phases: PhaseTimes,
    /// Boundary conflicts this process lost (each conflicting cut edge is
    /// counted exactly once globally, on its losing side).
    pub conflicts: u64,
    /// Conflict-resolution rounds executed.
    pub rounds: u32,
    /// Global color count after the initial coloring and after every
    /// recoloring iteration (filled by the coordinator pipeline).
    pub recolor_trace: Vec<usize>,
    /// Final virtual clock.
    pub vtime: f64,
    pub sent_msgs: u64,
    pub sent_bytes: u64,
    pub recv_msgs: u64,
    /// Messages whose receiver endpoint was already gone (see
    /// [`Endpoint::dropped_msgs`]); nonzero only during acknowledged
    /// teardown, and always zero for a completed job.
    pub dropped_msgs: u64,
    /// Drops outside an acknowledged teardown — always a protocol bug; the
    /// pipeline turns a nonzero count into a typed error in fault-free mode.
    pub non_teardown_drops: u64,
    /// Fault injection: messages whose arrival the plan delayed.
    pub injected_delays: u64,
    /// Fault injection: messages the plan held back at the sender.
    pub injected_reorders: u64,
    /// Fault injection: wire transmissions the plan lost (the reliable
    /// layer re-covers them; each loss was charged like a real send).
    pub injected_losses: u64,
    /// Reliable delivery: retransmissions this process performed.
    pub retransmits: u64,
    /// Reliable delivery: standalone cumulative acks this process sent.
    pub acks_sent: u64,
    /// Reliable delivery: received duplicates discarded before delivery.
    pub dup_discards: u64,
    /// Supervised recovery: times this process was restarted from a
    /// checkpoint after an injected crash.
    pub restarts: u64,
}

/// Job-level aggregation over all processes.
#[derive(Debug, Clone, Default)]
pub struct DistMetrics {
    pub num_procs: usize,
    /// Sum of messages sent by all processes (collectives included).
    pub total_msgs: u64,
    /// Sum of bytes sent (payload + per-message header).
    pub total_bytes: u64,
    /// Total conflicts (one per conflicting cut edge per round).
    pub total_conflicts: u64,
    /// Sum of teardown-dropped messages (zero for any completed job).
    pub total_dropped: u64,
    /// Structured teardown report: `(rank, dropped)` for every process
    /// that dropped at least one message, in rank order.
    pub dropped_by_rank: Vec<(usize, u64)>,
    /// Sum of drops outside an acknowledged teardown (protocol bugs).
    pub total_non_teardown_drops: u64,
    /// Sum of fault-injected message delays.
    pub total_injected_delays: u64,
    /// Sum of fault-injected message reorders (sender hold-backs).
    pub total_injected_reorders: u64,
    /// Sum of fault-injected wire-transmission losses.
    pub total_injected_losses: u64,
    /// Sum of reliable-layer retransmissions.
    pub total_retransmits: u64,
    /// Sum of reliable-layer standalone acks.
    pub total_acks_sent: u64,
    /// Sum of reliable-layer duplicate discards.
    pub total_dup_discards: u64,
    /// Sum of checkpoint restarts performed by the supervising engine.
    pub total_restarts: u64,
    /// Max conflict-resolution rounds over processes.
    pub rounds: u32,
    /// Virtual makespan: max final clock over processes.
    pub makespan: f64,
    /// Real wallclock of the simulation itself (diagnostics only).
    pub wall_secs: f64,
    /// Per-phase virtual time summed over processes.
    pub phase_sums: PhaseTimes,
    /// Per-phase virtual time maxed over processes (critical-path view).
    pub phase_max: PhaseTimes,
}

impl DistMetrics {
    /// Aggregate per-process metrics; `wall_secs` is the simulation's real
    /// elapsed time (pass 0.0 when irrelevant).
    pub fn aggregate(per: &[ProcMetrics], wall_secs: f64) -> DistMetrics {
        let mut m = DistMetrics {
            num_procs: per.len(),
            wall_secs,
            ..Default::default()
        };
        use std::collections::BTreeMap;
        let mut sums: BTreeMap<&str, f64> = BTreeMap::new();
        let mut maxes: BTreeMap<&str, f64> = BTreeMap::new();
        for p in per {
            m.total_msgs += p.sent_msgs;
            m.total_bytes += p.sent_bytes;
            m.total_conflicts += p.conflicts;
            m.total_dropped += p.dropped_msgs;
            if p.dropped_msgs > 0 {
                m.dropped_by_rank.push((p.rank, p.dropped_msgs));
            }
            m.total_non_teardown_drops += p.non_teardown_drops;
            m.total_injected_delays += p.injected_delays;
            m.total_injected_reorders += p.injected_reorders;
            m.total_injected_losses += p.injected_losses;
            m.total_retransmits += p.retransmits;
            m.total_acks_sent += p.acks_sent;
            m.total_dup_discards += p.dup_discards;
            m.total_restarts += p.restarts;
            m.rounds = m.rounds.max(p.rounds);
            if p.vtime > m.makespan {
                m.makespan = p.vtime;
            }
            for (name, secs) in p.phases.entries() {
                *sums.entry(name).or_insert(0.0) += secs;
                let e = maxes.entry(name).or_insert(0.0);
                if *secs > *e {
                    *e = *secs;
                }
            }
        }
        for (name, secs) in sums {
            m.phase_sums.add(name, secs);
        }
        for (name, secs) in maxes {
            m.phase_max.add(name, secs);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn proc(vtime: f64, msgs: u64, bytes: u64, conflicts: u64, rounds: u32) -> ProcMetrics {
        ProcMetrics {
            vtime,
            sent_msgs: msgs,
            sent_bytes: bytes,
            conflicts,
            rounds,
            ..Default::default()
        }
    }

    #[test]
    fn aggregate_sums_and_maxes_exactly() {
        let mut a = proc(1.5, 10, 1000, 3, 2);
        a.phases.add("color", 1.0);
        a.phases.add("plan", 0.25);
        let mut b = proc(2.5, 7, 500, 0, 5);
        b.phases.add("color", 2.0);
        let m = DistMetrics::aggregate(&[a, b], 0.125);
        assert_eq!(m.num_procs, 2);
        assert_eq!(m.total_msgs, 17);
        assert_eq!(m.total_bytes, 1500);
        assert_eq!(m.total_conflicts, 3);
        assert_eq!(m.rounds, 5);
        assert!((m.makespan - 2.5).abs() < 1e-15, "makespan = max vtime");
        assert!((m.wall_secs - 0.125).abs() < 1e-15);
        assert!((m.phase_sums.get("color") - 3.0).abs() < 1e-15);
        assert!((m.phase_max.get("color") - 2.0).abs() < 1e-15);
        assert!((m.phase_sums.get("plan") - 0.25).abs() < 1e-15);
        assert!((m.phase_max.get("plan") - 0.25).abs() < 1e-15);
        assert_eq!(m.phase_sums.get("absent"), 0.0);
    }

    #[test]
    fn aggregate_tracks_fault_and_drop_reports() {
        let mut a = proc(1.0, 1, 10, 0, 1);
        a.rank = 0;
        a.dropped_msgs = 2;
        a.injected_delays = 3;
        let mut b = proc(2.0, 1, 10, 0, 1);
        b.rank = 1;
        b.dropped_msgs = 5;
        b.non_teardown_drops = 5;
        b.injected_reorders = 4;
        b.restarts = 1;
        a.injected_losses = 6;
        a.retransmits = 5;
        b.acks_sent = 9;
        b.dup_discards = 2;
        let m = DistMetrics::aggregate(&[a, b], 0.0);
        assert_eq!(m.dropped_by_rank, vec![(0, 2), (1, 5)]);
        assert_eq!(m.total_dropped, 7);
        assert_eq!(m.total_non_teardown_drops, 5);
        assert_eq!(m.total_injected_delays, 3);
        assert_eq!(m.total_injected_reorders, 4);
        assert_eq!(m.total_injected_losses, 6);
        assert_eq!(m.total_retransmits, 5);
        assert_eq!(m.total_acks_sent, 9);
        assert_eq!(m.total_dup_discards, 2);
        assert_eq!(m.total_restarts, 1);
    }

    #[test]
    fn aggregate_of_nothing_is_zero() {
        let m = DistMetrics::aggregate(&[], 0.0);
        assert_eq!(m.num_procs, 0);
        assert_eq!(m.total_msgs, 0);
        assert_eq!(m.makespan, 0.0);
        assert_eq!(m.rounds, 0);
    }

    #[test]
    fn aggregate_single_proc_is_identity() {
        let mut a = proc(0.75, 4, 64, 1, 3);
        a.phases.add("recolor", 0.5);
        let m = DistMetrics::aggregate(std::slice::from_ref(&a), 0.0);
        assert_eq!(m.total_msgs, a.sent_msgs);
        assert_eq!(m.total_bytes, a.sent_bytes);
        assert_eq!(m.makespan, a.vtime);
        assert_eq!(m.phase_sums.get("recolor"), m.phase_max.get("recolor"));
    }
}
