//! The BSP step engine: `p` simulated processes on `W` pooled worker
//! threads.
//!
//! The thread-per-process runner ([`runner`](crate::dist::runner)) is a
//! faithful oracle but oversubscribes the host exactly where the paper's
//! scaling story gets interesting: at p=1024 simulated ranks a 4-core box
//! pays for a thousand blocked OS threads and their context-switch storms.
//! Both the superstep framework and synchronous recoloring are
//! bulk-synchronous by construction — rounds of independent local compute
//! separated by bulk exchanges and collectives — so no process ever needs
//! to *block* on a message: it only needs the messages of earlier rounds
//! to have been delivered.
//!
//! The engine exploits that. A process is an explicit state machine
//! ([`StepProcess`]): each [`step`](StepProcess::step) call runs one
//! non-blocking slice — local compute plus sends, or the receives of a
//! slice that completed everywhere in an earlier engine step — against the
//! process's endpoint (whose channel *is* the inbox). [`run_steps`]
//! executes engine steps in lockstep: a fixed pool of
//! `W = min(available_parallelism, p)` persistent workers
//! ([`util::pool`](crate::util::pool)) steps every live process once, then
//! a barrier makes the step's messages visible before anyone runs the next
//! step. Receives therefore use the non-blocking
//! [`Endpoint::try_recv_from`] (a miss panics instead of deadlocking), and
//! collectives use the split `coll_*` phases.
//!
//! **Equivalence.** Every machine executes the *same* endpoint operations,
//! in the same per-process order, with the same payloads as its blocking
//! counterpart — the step boundaries only reorder wallclock, which no
//! modeled quantity observes. Colorings, per-process message/byte counts,
//! conflict counts and virtual clocks are bit-for-bit identical to the
//! thread runner (`tests/accounting_fixture.rs` and
//! `tests/dist_props.rs::prop_step_engine_matches_thread_runner` pin
//! this). Asynchronous *recoloring* (aRC) reruns the speculative framework
//! with data-dependent blocking structure owned by the thread path — jobs
//! that use it fall back to the thread runner (see [`Engine`]).

use crate::color::Coloring;
use crate::dist::comm::{self, Endpoint};
use crate::dist::cost::NetworkModel;
use crate::dist::proc::LocalGraph;
use crate::dist::runner::ProcResult;
use crate::dist::{DistMetrics, DistOutcome};
use crate::util::pool;
use crate::util::timer::Timer;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

/// What one engine step of a process produced.
pub enum StepOutcome {
    /// More steps to run.
    Running,
    /// The process finished; its owned colors and metrics.
    Done(ProcResult),
}

/// A simulated process as an explicit step state machine. Contract:
///
/// * every receive in a step must target a message sent in a *strictly
///   earlier* engine step (use [`Endpoint::try_recv_from`] /
///   [`Endpoint::try_recv_into`], which panic on a violation);
/// * collectives are split across three consecutive steps via the
///   endpoint's `coll_send_*` / `coll_reduce_*` / `coll_finish_*` phases;
/// * all processes must walk state sequences of equal length per global
///   phase (the algorithms here guarantee it: superstep counts, class
///   counts and round continuation are all allreduced).
pub trait StepProcess: Send {
    fn step(&mut self, ep: &mut Endpoint) -> StepOutcome;
}

/// Which execution path runs a job's distributed section.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// BSP step engine for the framework and sync RC; thread runner for
    /// aRC. The default.
    #[default]
    Auto,
    /// Always one OS thread per simulated process (the reference oracle).
    Threads,
    /// Always the BSP step engine; jobs with aRC are rejected at build.
    Bsp,
}

impl std::str::FromStr for Engine {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(Engine::Auto),
            "threads" | "thread" => Ok(Engine::Threads),
            "bsp" | "steps" | "engine" => Ok(Engine::Bsp),
            other => Err(format!("unknown engine {other:?} (auto|threads|bsp)")),
        }
    }
}

struct Slot<M> {
    ep: Endpoint,
    machine: M,
    out: Option<ProcResult>,
}

/// Run one step machine per local graph to completion on the global worker
/// pool and merge the results — the engine counterpart of
/// [`run_distributed_with`](crate::dist::runner::run_distributed_with).
/// `num_vertices` sizes the merged coloring; machines are constructed on
/// the calling thread, in rank order.
pub fn run_steps<'a, M, F>(
    num_vertices: usize,
    locals: &'a [LocalGraph],
    net: NetworkModel,
    make: F,
) -> DistOutcome
where
    M: StepProcess + 'a,
    F: Fn(&'a LocalGraph) -> M,
{
    let wall = Timer::start();
    let procs = locals.len();
    let eps = comm::network(procs, net);
    let slots: Vec<Mutex<Slot<M>>> = eps
        .into_iter()
        .zip(locals.iter())
        .map(|(ep, lg)| {
            Mutex::new(Slot {
                machine: make(lg),
                ep,
                out: None,
            })
        })
        .collect();

    let pool = pool::global();
    let shards = pool.workers().min(procs).max(1);
    let barrier = Barrier::new(shards);
    let done = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    pool.scoped_run(shards, &|w| {
        loop {
            // one engine step: this worker's shard of live processes
            let stepped = catch_unwind(AssertUnwindSafe(|| {
                let mut newly = 0usize;
                let mut i = w;
                while i < procs {
                    let mut guard = slots[i].lock().unwrap();
                    let slot = &mut *guard;
                    if slot.out.is_none() {
                        if let StepOutcome::Done(r) = slot.machine.step(&mut slot.ep) {
                            slot.out = Some(r);
                            newly += 1;
                        }
                    }
                    i += shards;
                }
                newly
            }));
            let panicked = match stepped {
                Ok(newly) => {
                    done.fetch_add(newly, Ordering::SeqCst);
                    None
                }
                Err(p) => {
                    failed.store(true, Ordering::SeqCst);
                    Some(p)
                }
            };
            // barrier 1: this step's sends and `done` updates are visible
            barrier.wait();
            let stop = failed.load(Ordering::SeqCst) || done.load(Ordering::SeqCst) == procs;
            // barrier 2: everyone has read the stop decision before any
            // worker can mutate `done` again — the decision is uniform
            barrier.wait();
            if let Some(p) = panicked {
                resume_unwind(p);
            }
            if stop {
                break;
            }
        }
    });

    let mut coloring = Coloring::uncolored(num_vertices);
    let mut per_proc = Vec::with_capacity(procs);
    for slot in slots {
        let slot = slot.into_inner().unwrap();
        let mut r = slot.out.expect("step machine ended without finishing");
        r.metrics.rank = slot.ep.rank;
        for (gid, c) in r.colors {
            coloring.set(gid, c);
        }
        per_proc.push(r.metrics);
    }
    let metrics = DistMetrics::aggregate(&per_proc, wall.secs());
    DistOutcome {
        coloring,
        metrics,
        per_proc,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::proc::build_local_graphs;
    use crate::dist::ProcMetrics;
    use crate::graph::synth;
    use crate::partition::{self, Partitioner};

    /// A toy machine exercising the engine contract: one split collective,
    /// then a message to the next rank received one step later.
    struct Toy {
        rank: usize,
        nprocs: usize,
        seq: u32,
        acc: u64,
        sum: u64,
        state: u8,
    }

    impl StepProcess for Toy {
        fn step(&mut self, ep: &mut Endpoint) -> StepOutcome {
            use crate::dist::comm::MsgKind;
            match self.state {
                0 => {
                    self.acc = self.rank as u64 + 1;
                    self.seq = ep.coll_send_u64(self.acc);
                }
                1 => {
                    if ep.rank == 0 {
                        self.acc = ep.coll_reduce_u64(self.seq, self.acc, u64::wrapping_add);
                    }
                }
                2 => {
                    self.sum = ep.coll_finish_u64(self.seq, self.acc);
                }
                3 => {
                    let to = (self.rank + 1) % self.nprocs;
                    ep.send(to, MsgKind::Colors, 0, 0, self.sum.to_le_bytes().to_vec());
                }
                4 => {
                    let from = (self.rank + self.nprocs - 1) % self.nprocs;
                    let got = comm::decode_u64(&ep.try_recv_from(from, MsgKind::Colors, 0, 0));
                    assert_eq!(got, self.sum, "ring neighbor disagrees on the sum");
                }
                _ => {
                    return StepOutcome::Done(ProcResult {
                        colors: Vec::new(),
                        metrics: ProcMetrics {
                            sent_msgs: ep.sent_msgs,
                            vtime: self.sum as f64,
                            ..Default::default()
                        },
                    });
                }
            }
            self.state += 1;
            StepOutcome::Running
        }
    }

    #[test]
    fn engine_runs_collectives_and_deferred_messages() {
        for procs in [1usize, 3, 8, 33] {
            let g = synth::path(procs.max(2));
            let part = partition::partition(&g, Partitioner::Block, procs, 1);
            let (_, locals) = build_local_graphs(&g, &part);
            let out = run_steps(g.num_vertices(), &locals, NetworkModel::ideal(), |lg| Toy {
                rank: lg.rank as usize,
                nprocs: procs,
                seq: 0,
                acc: 0,
                sum: 0,
                state: 0,
            });
            let expect = (procs * (procs + 1) / 2) as f64;
            assert_eq!(out.per_proc.len(), procs);
            for (r, m) in out.per_proc.iter().enumerate() {
                assert_eq!(m.rank, r, "rank stamped by the engine");
                assert_eq!(m.vtime, expect, "p{r} allreduce sum");
            }
            assert_eq!(out.metrics.num_procs, procs);
            assert_eq!(out.metrics.total_dropped, 0);
        }
    }

    #[test]
    fn machine_panics_propagate() {
        struct Boom;
        impl StepProcess for Boom {
            fn step(&mut self, ep: &mut Endpoint) -> StepOutcome {
                if ep.rank == 1 {
                    panic!("machine boom");
                }
                StepOutcome::Running
            }
        }
        let g = synth::path(4);
        let part = partition::partition(&g, Partitioner::Block, 4, 1);
        let (_, locals) = build_local_graphs(&g, &part);
        let r = catch_unwind(AssertUnwindSafe(|| {
            run_steps(g.num_vertices(), &locals, NetworkModel::ideal(), |_| Boom)
        }));
        assert!(r.is_err(), "a machine panic must fail the run loudly");
    }

    #[test]
    fn engine_parses() {
        assert_eq!("auto".parse::<Engine>().unwrap(), Engine::Auto);
        assert_eq!("threads".parse::<Engine>().unwrap(), Engine::Threads);
        assert_eq!("bsp".parse::<Engine>().unwrap(), Engine::Bsp);
        assert!("x".parse::<Engine>().is_err());
        assert_eq!(Engine::default(), Engine::Auto);
    }
}
