//! The BSP step engine: `p` simulated processes on `W` pooled worker
//! threads.
//!
//! The thread-per-process runner ([`runner`](crate::dist::runner)) is a
//! faithful oracle but oversubscribes the host exactly where the paper's
//! scaling story gets interesting: at p=1024 simulated ranks a 4-core box
//! pays for a thousand blocked OS threads and their context-switch storms.
//! Both the superstep framework and synchronous recoloring are
//! bulk-synchronous by construction — rounds of independent local compute
//! separated by bulk exchanges and collectives — so no process ever needs
//! to *block* on a message: it only needs the messages of earlier rounds
//! to have been delivered.
//!
//! The engine exploits that. A process is an explicit state machine
//! ([`StepProcess`]): each [`step`](StepProcess::step) call runs one
//! non-blocking slice — local compute plus sends, or the receives of a
//! slice that completed everywhere in an earlier engine step — against the
//! process's endpoint (whose channel *is* the inbox). [`run_steps`]
//! executes engine steps in lockstep: a fixed pool of
//! `W = min(available_parallelism, p)` persistent workers
//! ([`util::pool`](crate::util::pool)) steps every live process once, then
//! a barrier makes the step's messages visible before anyone runs the next
//! step. Receives therefore use the non-blocking
//! [`Endpoint::try_recv_from`] (a miss panics instead of deadlocking), and
//! collectives use the split `coll_*` phases.
//!
//! **Equivalence.** Every machine executes the *same* endpoint operations,
//! in the same per-process order, with the same payloads as its blocking
//! counterpart — the step boundaries only reorder wallclock, which no
//! modeled quantity observes. Colorings, per-process message/byte counts,
//! conflict counts and virtual clocks are bit-for-bit identical to the
//! thread runner (`tests/accounting_fixture.rs` and
//! `tests/dist_props.rs::prop_step_engine_matches_thread_runner` pin
//! this). Asynchronous *recoloring* (aRC) is a speculative framework rerun
//! per iteration — bulk-synchronous like everything else — and runs here
//! too ([`AsyncRcStep`](crate::dist::recolor::AsyncRcStep) embeds a
//! [`FrameworkStep`](crate::dist::framework::FrameworkStep) between its
//! split collectives), so every job shape shares one engine (see
//! [`Engine`]).

use crate::color::Coloring;
use crate::coordinator::event::{Event, Observer};
use crate::dist::comm::{self, Endpoint};
use crate::dist::cost::NetworkModel;
use crate::dist::fault::FaultPlan;
use crate::dist::proc::LocalGraph;
use crate::dist::runner::ProcResult;
use crate::dist::{DistMetrics, DistOutcome};
use crate::err;
use crate::util::cancel::{CancelToken, StopCause};
use crate::util::error::{Error, Result};
use crate::util::pool;
use crate::util::timer::Timer;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

/// What one engine step of a process produced.
pub enum StepOutcome {
    /// More steps to run.
    Running,
    /// The process finished; its owned colors and metrics.
    Done(ProcResult),
}

/// A simulated process as an explicit step state machine. Contract:
///
/// * every receive in a step must target a message sent in a *strictly
///   earlier* engine step (use [`Endpoint::try_recv_from`] /
///   [`Endpoint::try_recv_into`], which panic on a violation);
/// * collectives are split across three consecutive steps via the
///   endpoint's `coll_send_*` / `coll_reduce_*` / `coll_finish_*` phases;
/// * all processes must walk state sequences of equal length per global
///   phase (the algorithms here guarantee it: superstep counts, class
///   counts and round continuation are all allreduced).
pub trait StepProcess: Send {
    fn step(&mut self, ep: &mut Endpoint) -> StepOutcome;

    /// Whether the next [`step`](StepProcess::step) can run without
    /// violating the delivery contract — i.e. every message that step
    /// will consume is already available on `ep`. The supervising engine
    /// ([`run_steps_supervised`]) polls this to *stall* a process whose
    /// inputs were delayed or held back by a [`FaultPlan`] instead of
    /// letting its `try_recv` panic; the lockstep engine ([`run_steps`])
    /// never calls it. The default is "always ready", which is correct
    /// for any machine whose receives are protected by the BSP delivery
    /// invariant alone.
    fn poll_ready(&mut self, _ep: &mut Endpoint) -> bool {
        true
    }

    /// Harvest the best-so-far result from a machine the engine is about
    /// to abandon because its run's [`CancelToken`] fired. Called exactly
    /// once, after the uniform stop decision, on machines that have not
    /// reached [`StepOutcome::Done`]; the machine may be anywhere between
    /// two steps. Return `Some` with whatever owned colors exist right now
    /// (possibly partial or conflicted — the pipeline's repair pass
    /// finishes the job under the `Degrade` policy), or `None` if the
    /// machine has nothing to offer; the engine then reports the rank with
    /// empty colors and endpoint-level accounting only.
    fn abort(&mut self, _ep: &mut Endpoint) -> Option<ProcResult> {
        None
    }
}

/// Which execution path runs a job's distributed section.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// The BSP step engine, for every job shape (framework, sync RC and
    /// aRC alike). The default.
    #[default]
    Auto,
    /// Always one OS thread per simulated process (the reference oracle).
    Threads,
    /// The BSP step engine, explicitly.
    Bsp,
    /// The shared-memory data-parallel speculative engine
    /// (`shm::datapar`): no simulated transport, no partition — chunked
    /// speculate/detect/resolve over the worker pool. The raw-speed path;
    /// its colorings differ from the transport engines' but are
    /// deterministic and worker-count independent.
    DataPar,
}

impl Engine {
    /// The CLI/JSON spelling ("auto" | "threads" | "bsp" | "datapar") —
    /// also what [`FromStr`](std::str::FromStr) parses back.
    pub fn name(self) -> &'static str {
        match self {
            Engine::Auto => "auto",
            Engine::Threads => "threads",
            Engine::Bsp => "bsp",
            Engine::DataPar => "datapar",
        }
    }
}

impl std::str::FromStr for Engine {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(Engine::Auto),
            "threads" | "thread" => Ok(Engine::Threads),
            "bsp" | "steps" | "engine" => Ok(Engine::Bsp),
            "datapar" | "dp" => Ok(Engine::DataPar),
            other => Err(format!("unknown engine {other:?} (auto|threads|bsp|datapar)")),
        }
    }
}

struct Slot<M> {
    ep: Endpoint,
    machine: M,
    out: Option<ProcResult>,
}

// StopCause on an atomic: 0 = live, else cause + 1 (uniform across workers
// because the writer stores strictly before barrier 1 of the step at which
// readers observe it).
fn cause_to_u8(c: StopCause) -> u8 {
    match c {
        StopCause::Cancelled => 1,
        StopCause::DeadlineExceeded => 2,
        StopCause::BudgetExhausted => 3,
        StopCause::Unreachable => 4,
    }
}

fn cause_from_u8(v: u8) -> Option<StopCause> {
    match v {
        1 => Some(StopCause::Cancelled),
        2 => Some(StopCause::DeadlineExceeded),
        3 => Some(StopCause::BudgetExhausted),
        4 => Some(StopCause::Unreachable),
        _ => None,
    }
}

/// Run one step machine per local graph to completion on the global worker
/// pool and merge the results — the engine counterpart of
/// [`run_distributed_with`](crate::dist::runner::run_distributed_with).
/// `num_vertices` sizes the merged coloring; machines are constructed on
/// the calling thread, in rank order.
pub fn run_steps<'a, M, F>(
    num_vertices: usize,
    locals: &'a [LocalGraph],
    net: NetworkModel,
    make: F,
) -> DistOutcome
where
    M: StepProcess + 'a,
    F: Fn(&'a LocalGraph) -> M,
{
    run_steps_cancellable(num_vertices, locals, net, None, make)
}

/// [`run_steps`] with an optional [`CancelToken`]. The cancellation
/// protocol keeps the stop decision uniform without adding a barrier:
///
/// * while stepping (when a token is attached), every worker folds the
///   stepped endpoints' virtual clocks into a shared monotone max;
/// * worker 0, after stepping its shard and **before barrier 1**, polls the
///   token against that max and stores any verdict;
/// * in the window between the barriers — where nobody writes — all
///   workers read the same verdict along with `done`/`failed`, so a token
///   raised during engine step *k* is applied by every worker at step
///   *k+1*, never by some workers earlier than others.
///
/// On a cancel stop, unfinished machines are drained via
/// [`StepProcess::abort`] on the calling thread in rank order and the
/// outcome carries `stopped: Some(cause)` with whatever colors the aborts
/// harvested. Without a token the stepping loop is byte-for-byte the
/// uncancellable one (the clock fold and the poll are both gated).
pub fn run_steps_cancellable<'a, M, F>(
    num_vertices: usize,
    locals: &'a [LocalGraph],
    net: NetworkModel,
    cancel: Option<&CancelToken>,
    make: F,
) -> DistOutcome
where
    M: StepProcess + 'a,
    F: Fn(&'a LocalGraph) -> M,
{
    let wall = Timer::start();
    let procs = locals.len();
    let eps = comm::network(procs, net);
    let slots: Vec<Mutex<Slot<M>>> = eps
        .into_iter()
        .zip(locals.iter())
        .map(|(ep, lg)| {
            Mutex::new(Slot {
                machine: make(lg),
                ep,
                out: None,
            })
        })
        .collect();

    let pool = pool::global();
    let shards = pool.workers().min(procs).max(1);
    let barrier = Barrier::new(shards);
    let done = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    // f64 bits of the max virtual clock seen so far — monotone max is
    // order-preserving on the bit patterns of non-negative floats
    let max_clock = AtomicU64::new(0);
    let cancel_cause = AtomicU8::new(0);
    pool.scoped_run(shards, &|w| {
        loop {
            // one engine step: this worker's shard of live processes
            let stepped = catch_unwind(AssertUnwindSafe(|| {
                let mut newly = 0usize;
                let mut i = w;
                while i < procs {
                    let mut guard = slots[i].lock().unwrap();
                    let slot = &mut *guard;
                    if slot.out.is_none() {
                        if let StepOutcome::Done(r) = slot.machine.step(&mut slot.ep) {
                            slot.out = Some(r);
                            newly += 1;
                        }
                        if cancel.is_some() {
                            max_clock.fetch_max(slot.ep.clock.to_bits(), Ordering::Relaxed);
                        }
                    }
                    i += shards;
                }
                newly
            }));
            let panicked = match stepped {
                Ok(newly) => {
                    done.fetch_add(newly, Ordering::SeqCst);
                    None
                }
                Err(p) => {
                    failed.store(true, Ordering::SeqCst);
                    Some(p)
                }
            };
            // the cancel poll: one worker, before barrier 1, so the verdict
            // is either visible to every worker in the next window or to
            // none — the barrier makes the store happen-before all reads
            if w == 0 && cancel_cause.load(Ordering::Relaxed) == 0 {
                if let Some(tok) = cancel {
                    let vtime = f64::from_bits(max_clock.load(Ordering::Relaxed));
                    if let Some(c) = tok.check(vtime) {
                        cancel_cause.store(cause_to_u8(c), Ordering::Relaxed);
                    }
                }
            }
            // barrier 1: this step's sends and `done` updates are visible
            barrier.wait();
            let stop = failed.load(Ordering::SeqCst)
                || done.load(Ordering::SeqCst) == procs
                || cancel_cause.load(Ordering::Relaxed) != 0;
            // barrier 2: everyone has read the stop decision before any
            // worker can mutate `done` again — the decision is uniform
            barrier.wait();
            if let Some(p) = panicked {
                resume_unwind(p);
            }
            if stop {
                break;
            }
        }
    });

    // a run that finished everywhere in the same step as the verdict is
    // simply finished — cancellation only applies to unfinished machines
    let stopped = if done.load(Ordering::SeqCst) == procs {
        None
    } else {
        cause_from_u8(cancel_cause.load(Ordering::Relaxed))
    };

    let mut coloring = Coloring::uncolored(num_vertices);
    let mut per_proc = Vec::with_capacity(procs);
    for slot in slots {
        let mut slot = slot.into_inner().unwrap();
        if stopped.is_some() {
            // in-flight messages die with the run on every rank, finished
            // or not — an aborted peer's sends must not count as drops
            slot.ep.teardown = true;
        }
        let mut r = match (slot.out.take(), stopped) {
            (Some(r), _) => r,
            (None, Some(_)) => {
                // deterministic rank-order drain on the calling thread
                let harvested = slot.machine.abort(&mut slot.ep);
                harvested.unwrap_or_else(|| ProcResult {
                    colors: Vec::new(),
                    metrics: crate::dist::ProcMetrics {
                        vtime: slot.ep.clock,
                        sent_msgs: slot.ep.sent_msgs,
                        sent_bytes: slot.ep.sent_bytes,
                        recv_msgs: slot.ep.recv_msgs,
                        ..Default::default()
                    },
                })
            }
            (None, None) => panic!("step machine ended without finishing"),
        };
        r.metrics.rank = slot.ep.rank;
        for (gid, c) in r.colors {
            coloring.set(gid, c);
        }
        per_proc.push(r.metrics);
    }
    let metrics = DistMetrics::aggregate(&per_proc, wall.secs());
    DistOutcome {
        coloring,
        metrics,
        per_proc,
        stopped,
    }
}

/// Runaway guard for the supervised loop: orders of magnitude above any
/// legitimate engine-step count, so hitting it means livelock.
const MAX_SUPERVISED_STEPS: u64 = 10_000_000;

/// [`run_steps`] under supervision: a single-threaded engine that weaves a
/// [`FaultPlan`] into the transport and *recovers* from the faults it
/// injects, instead of trusting the BSP delivery invariant.
///
/// Per engine step, machines are stepped **in rank order on the calling
/// thread** — full determinism is the point here (same plan, same graph,
/// same seed ⇒ the same recovery trace, twice), and chaos runs are not on
/// any performance path. The supervisor:
///
/// * **checkpoints every live rank** (a `Clone` of the machine's full
///   state: colors, RNG, scratch, state tag) whenever
///   `step % plan.checkpoint_interval == 0`. At the default interval of 1
///   this is the original per-step cadence; a larger interval additionally
///   snapshots each endpoint's transport state
///   ([`Endpoint::checkpoint`]), because revival then *replays* steps;
/// * at each crash in `plan.crashes` (any number of ranks, repeat crashes
///   allowed), the live machine is destroyed *before* executing that step
///   and the rank goes down for `down_steps` engine steps (peers stall via
///   [`StepProcess::poll_ready`] when they need its messages), emitting
///   [`Event::FaultInjected`]. Crashes whose step passes while the rank is
///   already down (or finished) are coalesced;
/// * on revival the machine is **replayed from its last periodic
///   checkpoint**, emitting [`Event::ProcRestarted`]. At interval 1 the
///   checkpoint is exactly the pre-crash state, so no message is consumed
///   or sent twice; at larger intervals the endpoint is rolled back with
///   it and the replayed sends reuse their original link seqs, so every
///   peer's reliable-layer dedup absorbs them while
///   [`Endpoint::restore`] re-feeds the replayed receives;
/// * when the plan activates the reliable layer (loss, or interval
///   checkpointing with crashes), every endpoint gets a
///   [`reliable_sweep`](Endpoint::reliable_sweep) at the top of each step:
///   standalone acks, intake, and backoff retransmission. A peer
///   exhausting its retry budget stops the run with
///   [`StopCause::Unreachable`] — unfinished machines are drained in rank
///   order exactly like a cancel stop, and the pipeline's `Degrade` policy
///   can still repair the partial coloring;
/// * a step on which *no* live machine is ready releases held (reordered)
///   messages via [`Endpoint::flush_held`]; if nothing was released, no
///   process is down, and no retransmission is pending, the run is
///   deadlocked and returns a typed error;
/// * a machine panic (including a fault-starved receive) becomes
///   [`Error::proc_failed`] instead of unwinding through the caller.
///
/// A plan that crashes a rank the run does not have is a typed validation
/// error (matching the CLI-side check), not a silent no-op.
///
/// With `FaultPlan::none()` the schedule is the lockstep engine's and every
/// modeled quantity is bit-for-bit identical to [`run_steps`]
/// (`tests/fault_injection.rs` pins this); any loss-free plan with the
/// default checkpoint interval behaves exactly as it did before the
/// reliable layer existed.
pub fn run_steps_supervised<'a, M, F>(
    num_vertices: usize,
    locals: &'a [LocalGraph],
    net: NetworkModel,
    plan: FaultPlan,
    obs: Option<&dyn Observer>,
    make: F,
) -> Result<DistOutcome>
where
    M: StepProcess + Clone + 'a,
    F: Fn(&'a LocalGraph) -> M,
{
    run_steps_supervised_cancellable(num_vertices, locals, net, plan, obs, None, make)
}

/// [`run_steps_supervised`] with an optional [`CancelToken`], polled once
/// at the top of every engine step against the max virtual clock — the
/// supervisor is single-threaded, so the decision is trivially uniform and
/// (for virtual-budget tokens) fully deterministic: cancelling a faulted
/// run, even mid-recovery, replays bit-for-bit under the same seed. On a
/// verdict the unfinished machines (including a crashed rank's stale or
/// checkpointed machine) are drained via [`StepProcess::abort`] in rank
/// order and the outcome carries `stopped: Some(cause)`.
#[allow(clippy::too_many_arguments)]
pub fn run_steps_supervised_cancellable<'a, M, F>(
    num_vertices: usize,
    locals: &'a [LocalGraph],
    net: NetworkModel,
    plan: FaultPlan,
    obs: Option<&dyn Observer>,
    cancel: Option<&CancelToken>,
    make: F,
) -> Result<DistOutcome>
where
    M: StepProcess + Clone + 'a,
    F: Fn(&'a LocalGraph) -> M,
{
    let wall = Timer::start();
    let procs = locals.len();
    for c in &plan.crashes {
        if c.rank as usize >= procs {
            return Err(err!(
                "fault plan crashes rank {} but the run has only {procs} process(es)",
                c.rank
            ));
        }
    }
    if plan.checkpoint_interval == 0 {
        return Err(err!("fault plan checkpoint interval must be at least 1"));
    }
    let mut eps = comm::network_faulted(procs, net, plan.clone());
    let mut machines: Vec<M> = locals.iter().map(&make).collect();
    let mut outs: Vec<Option<ProcResult>> = (0..procs).map(|_| None).collect();
    let mut stopped: Option<StopCause> = None;

    let has_crashes = !plan.crashes.is_empty();
    let reliable = plan.reliable();
    let interval = plan.checkpoint_interval;
    if interval > 1 && has_crashes {
        // interval checkpointing replays steps on revival: log consumed
        // messages so `restore` can re-feed them
        for ep in eps.iter_mut() {
            ep.enable_replay_log();
        }
    }
    let mut down_until: Vec<Option<u64>> = vec![None; procs];
    let mut checkpoints: Vec<Option<(M, Option<comm::EndpointSnapshot>)>> =
        (0..procs).map(|_| None).collect();
    let mut crash_cursor: Vec<u64> = vec![0; procs];
    let mut restarts: Vec<u64> = vec![0; procs];
    let mut n_done = 0usize;
    let mut step: u64 = 0;

    let emit = |ev: Event| {
        if let Some(o) = obs {
            o.on_event(&ev);
        }
    };

    // drain every unfinished machine, in rank order, after a stop verdict
    let drain = |machines: &mut [M], eps: &mut [Endpoint], outs: &mut [Option<ProcResult>]| {
        for r in 0..machines.len() {
            if outs[r].is_none() {
                let harvested = machines[r].abort(&mut eps[r]);
                outs[r] = Some(harvested.unwrap_or_else(|| ProcResult {
                    colors: Vec::new(),
                    metrics: crate::dist::ProcMetrics {
                        vtime: eps[r].clock,
                        sent_msgs: eps[r].sent_msgs,
                        sent_bytes: eps[r].sent_bytes,
                        recv_msgs: eps[r].recv_msgs,
                        ..Default::default()
                    },
                }));
            }
        }
    };

    while n_done < procs {
        if step >= MAX_SUPERVISED_STEPS {
            return Err(err!(
                "supervised engine exceeded {MAX_SUPERVISED_STEPS} steps ({} of {procs} \
                 processes finished) — livelock",
                n_done
            ));
        }
        if let Some(tok) = cancel {
            let vtime = eps.iter().map(|e| e.clock).fold(0.0f64, f64::max);
            if let Some(cause) = tok.check(vtime) {
                // uniform by construction (one thread decides); drain the
                // unfinished machines in rank order for determinism
                stopped = Some(cause);
                drain(&mut machines, &mut eps, &mut outs);
                break;
            }
        }
        if reliable {
            // standalone acks, intake, and overdue retransmissions — for
            // every rank whose NIC is up (done ranks included: their
            // unacked messages must still reach live peers). A crashed
            // rank neither acks nor retransmits until its revival turn
            // restores it (`down_until` clears then).
            let mut unreachable = false;
            for r in 0..procs {
                if down_until[r].is_some() {
                    continue;
                }
                if eps[r].reliable_sweep(step).is_err() {
                    unreachable = true;
                    break;
                }
            }
            if unreachable {
                stopped = Some(StopCause::Unreachable);
                drain(&mut machines, &mut eps, &mut outs);
                break;
            }
        }
        let mut progressed = false;
        for r in 0..procs {
            if outs[r].is_some() {
                continue;
            }
            match down_until[r] {
                Some(until) if step < until => continue, // still down
                Some(_) => {
                    // revive: deterministic replay from the last periodic
                    // checkpoint (at interval 1, the top of the crash step)
                    let (m, snap) = checkpoints[r]
                        .as_ref()
                        .expect("crash checkpoint missing")
                        .clone();
                    machines[r] = m;
                    if let Some(s) = snap {
                        eps[r].restore(&s);
                    }
                    restarts[r] += 1;
                    down_until[r] = None;
                    emit(Event::ProcRestarted { rank: r as u32, step });
                }
                None => {}
            }
            if has_crashes && step % interval == 0 {
                // periodic checkpoint: the recovery image is the state at
                // the top of the step, i.e. exactly between two steps
                checkpoints[r] = Some((
                    machines[r].clone(),
                    if interval > 1 { Some(eps[r].checkpoint()) } else { None },
                ));
            }
            // coalesce crashes whose step passed while the rank was down
            while let Some(c) = plan.next_crash_for(r, crash_cursor[r]) {
                if c.step < step {
                    crash_cursor[r] = c.step + 1;
                } else {
                    break;
                }
            }
            if let Some(c) = plan.next_crash_for(r, crash_cursor[r]) {
                if c.step == step {
                    crash_cursor[r] = step + 1;
                    down_until[r] = Some(step + c.down_steps);
                    emit(Event::FaultInjected { rank: r as u32, step });
                    continue;
                }
            }
            if !machines[r].poll_ready(&mut eps[r]) {
                continue; // stalled on a delayed/held message
            }
            let (m, ep) = (&mut machines[r], &mut eps[r]);
            match catch_unwind(AssertUnwindSafe(|| m.step(ep))) {
                Ok(StepOutcome::Running) => progressed = true,
                Ok(StepOutcome::Done(out)) => {
                    progressed = true;
                    outs[r] = Some(out);
                    n_done += 1;
                }
                Err(p) => {
                    let detail = p
                        .downcast_ref::<String>()
                        .map(|s| s.as_str())
                        .or_else(|| p.downcast_ref::<&str>().copied())
                        .unwrap_or("machine panicked");
                    return Err(Error::proc_failed(r as u32, step, detail));
                }
            }
        }
        if !progressed && n_done < procs {
            let any_down = (0..procs).any(|r| down_until[r].is_some_and(|until| step < until));
            if !any_down {
                let released: usize = eps.iter_mut().map(|ep| ep.flush_held()).sum();
                if released == 0 && !eps.iter().any(|e| e.has_unacked()) {
                    return Err(err!(
                        "supervised engine deadlocked at step {step}: every live process \
                         is stalled, no process is down, and no held or unacked message \
                         remains"
                    ));
                }
            }
        }
        step += 1;
    }

    // deliver any messages still held at finished senders, then tear down
    for ep in eps.iter_mut() {
        ep.flush_held();
        ep.teardown = true;
    }

    let mut coloring = Coloring::uncolored(num_vertices);
    let mut per_proc = Vec::with_capacity(procs);
    for (r, (out, ep)) in outs.into_iter().zip(eps.into_iter()).enumerate() {
        let mut res = out.expect("supervised machine ended without finishing");
        res.metrics.rank = r;
        res.metrics.dropped_msgs = ep.dropped_msgs;
        res.metrics.non_teardown_drops = ep.non_teardown_drops;
        res.metrics.injected_delays = ep.injected_delays;
        res.metrics.injected_reorders = ep.injected_reorders;
        res.metrics.injected_losses = ep.injected_losses;
        res.metrics.retransmits = ep.retransmits;
        res.metrics.acks_sent = ep.acks_sent;
        res.metrics.dup_discards = ep.dup_discards;
        res.metrics.restarts = restarts[r];
        for (gid, c) in std::mem::take(&mut res.colors) {
            coloring.set(gid, c);
        }
        per_proc.push(res.metrics);
    }
    let metrics = DistMetrics::aggregate(&per_proc, wall.secs());
    Ok(DistOutcome {
        coloring,
        metrics,
        per_proc,
        stopped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::proc::build_local_graphs;
    use crate::dist::ProcMetrics;
    use crate::graph::synth;
    use crate::partition::{self, Partitioner};

    /// A toy machine exercising the engine contract: one split collective,
    /// then a message to the next rank received one step later.
    #[derive(Clone)]
    struct Toy {
        rank: usize,
        nprocs: usize,
        seq: u32,
        acc: u64,
        sum: u64,
        state: u8,
    }

    impl StepProcess for Toy {
        fn poll_ready(&mut self, ep: &mut Endpoint) -> bool {
            use crate::dist::comm::MsgKind;
            match self.state {
                1 => {
                    ep.rank != 0
                        || (1..self.nprocs)
                            .all(|p| ep.have_msg(p, MsgKind::Collective, self.seq, 0))
                }
                2 => ep.rank == 0 || ep.have_msg(0, MsgKind::Collective, self.seq, 1),
                4 => {
                    let from = (self.rank + self.nprocs - 1) % self.nprocs;
                    ep.have_msg(from, MsgKind::Colors, 0, 0)
                }
                _ => true,
            }
        }

        fn step(&mut self, ep: &mut Endpoint) -> StepOutcome {
            use crate::dist::comm::MsgKind;
            match self.state {
                0 => {
                    self.acc = self.rank as u64 + 1;
                    self.seq = ep.coll_send_u64(self.acc);
                }
                1 => {
                    if ep.rank == 0 {
                        self.acc = ep.coll_reduce_u64(self.seq, self.acc, u64::wrapping_add);
                    }
                }
                2 => {
                    self.sum = ep.coll_finish_u64(self.seq, self.acc);
                }
                3 => {
                    let to = (self.rank + 1) % self.nprocs;
                    ep.send(to, MsgKind::Colors, 0, 0, self.sum.to_le_bytes().to_vec());
                }
                4 => {
                    let from = (self.rank + self.nprocs - 1) % self.nprocs;
                    let got = comm::decode_u64(&ep.try_recv_from(from, MsgKind::Colors, 0, 0));
                    assert_eq!(got, self.sum, "ring neighbor disagrees on the sum");
                }
                _ => {
                    return StepOutcome::Done(ProcResult {
                        colors: Vec::new(),
                        metrics: ProcMetrics {
                            sent_msgs: ep.sent_msgs,
                            vtime: self.sum as f64,
                            ..Default::default()
                        },
                    });
                }
            }
            self.state += 1;
            StepOutcome::Running
        }
    }

    #[test]
    fn engine_runs_collectives_and_deferred_messages() {
        for procs in [1usize, 3, 8, 33] {
            let g = synth::path(procs.max(2));
            let part = partition::partition(&g, Partitioner::Block, procs, 1);
            let (_, locals) = build_local_graphs(&g, &part);
            let out = run_steps(g.num_vertices(), &locals, NetworkModel::ideal(), |lg| Toy {
                rank: lg.rank as usize,
                nprocs: procs,
                seq: 0,
                acc: 0,
                sum: 0,
                state: 0,
            });
            let expect = (procs * (procs + 1) / 2) as f64;
            assert_eq!(out.per_proc.len(), procs);
            for (r, m) in out.per_proc.iter().enumerate() {
                assert_eq!(m.rank, r, "rank stamped by the engine");
                assert_eq!(m.vtime, expect, "p{r} allreduce sum");
            }
            assert_eq!(out.metrics.num_procs, procs);
            assert_eq!(out.metrics.total_dropped, 0);
        }
    }

    #[test]
    fn machine_panics_propagate() {
        struct Boom;
        impl StepProcess for Boom {
            fn step(&mut self, ep: &mut Endpoint) -> StepOutcome {
                if ep.rank == 1 {
                    panic!("machine boom");
                }
                StepOutcome::Running
            }
        }
        let g = synth::path(4);
        let part = partition::partition(&g, Partitioner::Block, 4, 1);
        let (_, locals) = build_local_graphs(&g, &part);
        let r = catch_unwind(AssertUnwindSafe(|| {
            run_steps(g.num_vertices(), &locals, NetworkModel::ideal(), |_| Boom)
        }));
        assert!(r.is_err(), "a machine panic must fail the run loudly");
    }

    fn toy_fleet(procs: usize) -> (crate::graph::CsrGraph, Vec<LocalGraph>) {
        let g = synth::path(procs.max(2));
        let part = partition::partition(&g, Partitioner::Block, procs, 1);
        let (_, locals) = build_local_graphs(&g, &part);
        (g, locals)
    }

    fn toy_of(lg: &LocalGraph, nprocs: usize) -> Toy {
        Toy {
            rank: lg.rank as usize,
            nprocs,
            seq: 0,
            acc: 0,
            sum: 0,
            state: 0,
        }
    }

    #[test]
    fn supervised_with_inert_plan_matches_run_steps() {
        for procs in [1usize, 3, 8] {
            let (g, locals) = toy_fleet(procs);
            let base = run_steps(g.num_vertices(), &locals, NetworkModel::default(), |lg| {
                toy_of(lg, procs)
            });
            let sup = run_steps_supervised(
                g.num_vertices(),
                &locals,
                NetworkModel::default(),
                FaultPlan::none(),
                None,
                |lg| toy_of(lg, procs),
            )
            .unwrap();
            for (a, b) in base.per_proc.iter().zip(sup.per_proc.iter()) {
                assert_eq!(a.rank, b.rank);
                assert_eq!(a.sent_msgs, b.sent_msgs, "p{} msgs", a.rank);
                assert_eq!(a.vtime.to_bits(), b.vtime.to_bits(), "p{} clock", a.rank);
            }
            assert_eq!(sup.metrics.total_restarts, 0);
            assert_eq!(sup.metrics.total_injected_delays, 0);
            assert_eq!(sup.metrics.total_non_teardown_drops, 0);
        }
    }

    #[test]
    fn supervised_machine_panic_is_a_typed_error() {
        use crate::util::error::ErrorKind;
        #[derive(Clone)]
        struct Boom;
        impl StepProcess for Boom {
            fn step(&mut self, ep: &mut Endpoint) -> StepOutcome {
                if ep.rank == 1 {
                    panic!("machine boom");
                }
                StepOutcome::Running
            }
        }
        let (g, locals) = toy_fleet(4);
        // active plan so the panic path is exercised under supervision
        let plan = FaultPlan {
            delay_prob: 1e-9,
            delay_secs: 1e-6,
            ..FaultPlan::none()
        };
        let err = run_steps_supervised(
            g.num_vertices(),
            &locals,
            NetworkModel::ideal(),
            plan,
            None,
            |_| Boom,
        )
        .expect_err("a machine panic must become a typed error");
        assert_eq!(err.kind(), ErrorKind::ProcFailed { rank: 1, step: 0 });
        assert!(err.to_string().contains("machine boom"), "{err}");
    }

    #[test]
    fn supervised_crash_recovery_is_deterministic() {
        use crate::coordinator::event::EventLog;
        use crate::dist::fault::Crash;
        let procs = 4usize;
        let plan = FaultPlan {
            seed: 5,
            crashes: vec![Crash {
                rank: 1,
                step: 2,
                down_steps: 2,
            }],
            ..FaultPlan::none()
        };
        let run = || {
            let (g, locals) = toy_fleet(procs);
            let log = EventLog::new();
            let out = run_steps_supervised(
                g.num_vertices(),
                &locals,
                NetworkModel::default(),
                plan.clone(),
                Some(&log),
                |lg| toy_of(lg, procs),
            )
            .unwrap();
            (out, log.take())
        };
        let (a, ev_a) = run();
        let (b, ev_b) = run();
        assert_eq!(ev_a, ev_b, "recovery trace must replay identically");
        assert_eq!(
            ev_a,
            vec![
                Event::FaultInjected { rank: 1, step: 2 },
                Event::ProcRestarted { rank: 1, step: 4 },
            ]
        );
        assert_eq!(a.metrics.total_restarts, 1);
        assert_eq!(a.per_proc[1].restarts, 1);
        let expect = (procs * (procs + 1) / 2) as f64;
        for m in &a.per_proc {
            assert_eq!(m.vtime, expect, "p{} allreduce sum survives the crash", m.rank);
        }
        for (x, y) in a.per_proc.iter().zip(b.per_proc.iter()) {
            assert_eq!(x.sent_msgs, y.sent_msgs);
            assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
        }
    }

    /// An endless machine advancing its virtual clock by exactly 1.0 per
    /// engine step — the cancellation-latency probe. `abort` reports how
    /// many steps actually ran (in `metrics.rounds`).
    #[derive(Clone)]
    struct Ticker;

    impl StepProcess for Ticker {
        fn step(&mut self, ep: &mut Endpoint) -> StepOutcome {
            ep.clock += 1.0;
            StepOutcome::Running
        }

        fn abort(&mut self, ep: &mut Endpoint) -> Option<ProcResult> {
            Some(ProcResult {
                colors: Vec::new(),
                metrics: ProcMetrics {
                    rounds: ep.clock as u32,
                    vtime: ep.clock,
                    ..Default::default()
                },
            })
        }
    }

    #[test]
    fn lockstep_vbudget_stop_is_observed_one_step_after_crossing() {
        use crate::util::cancel::CancelToken;
        for procs in [1usize, 4, 9] {
            let (g, locals) = toy_fleet(procs);
            let tok = CancelToken::with_limits(None, Some(5.0));
            let out = run_steps_cancellable(
                g.num_vertices(),
                &locals,
                NetworkModel::ideal(),
                Some(&tok),
                |_| Ticker,
            );
            assert_eq!(out.stopped, Some(StopCause::BudgetExhausted));
            // the clock first exceeds 5.0 during step 6; the verdict lands
            // in that step's decision window, so exactly 6 steps ran —
            // bounded by one engine step past the crossing
            for m in &out.per_proc {
                assert_eq!(m.rounds, 6, "p{} stepped past the bound", m.rank);
            }
        }
    }

    #[test]
    fn lockstep_pre_cancelled_token_stops_after_one_step() {
        let (g, locals) = toy_fleet(4);
        let tok = crate::util::cancel::CancelToken::new();
        tok.cancel(); // raised "at step 0"
        let out = run_steps_cancellable(
            g.num_vertices(),
            &locals,
            NetworkModel::ideal(),
            Some(&tok),
            |_| Ticker,
        );
        assert_eq!(out.stopped, Some(StopCause::Cancelled));
        for m in &out.per_proc {
            assert_eq!(m.rounds, 1, "observed at step 1, not later");
        }
    }

    #[test]
    fn lockstep_live_token_changes_nothing() {
        let procs = 4usize;
        let (g, locals) = toy_fleet(procs);
        let base = run_steps(g.num_vertices(), &locals, NetworkModel::default(), |lg| {
            toy_of(lg, procs)
        });
        let tok = crate::util::cancel::CancelToken::new();
        let ctl = run_steps_cancellable(
            g.num_vertices(),
            &locals,
            NetworkModel::default(),
            Some(&tok),
            |lg| toy_of(lg, procs),
        );
        assert_eq!(ctl.stopped, None);
        for (a, b) in base.per_proc.iter().zip(ctl.per_proc.iter()) {
            assert_eq!(a.sent_msgs, b.sent_msgs);
            assert_eq!(a.vtime.to_bits(), b.vtime.to_bits());
        }
    }

    #[test]
    fn supervised_vbudget_stop_is_deterministic_and_bounded() {
        use crate::util::cancel::CancelToken;
        let (g, locals) = toy_fleet(4);
        let run = || {
            let tok = CancelToken::with_limits(None, Some(5.0));
            run_steps_supervised_cancellable(
                g.num_vertices(),
                &locals,
                NetworkModel::ideal(),
                FaultPlan::none(),
                None,
                Some(&tok),
                |_| Ticker,
            )
            .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.stopped, Some(StopCause::BudgetExhausted));
        for (x, y) in a.per_proc.iter().zip(b.per_proc.iter()) {
            // loop-top poll: clocks reach 6.0 after step 6, the 7th
            // iteration's poll aborts — 6 steps, reproducibly
            assert_eq!(x.rounds, 6);
            assert_eq!(x.rounds, y.rounds);
            assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
        }
    }

    #[test]
    fn supervised_cancel_mid_crash_recovery_still_drains_cleanly() {
        use crate::dist::fault::Crash;
        use crate::util::cancel::CancelToken;
        let (g, locals) = toy_fleet(4);
        let plan = FaultPlan {
            seed: 3,
            crashes: vec![Crash {
                rank: 1,
                step: 2,
                down_steps: 1_000, // still down when the budget fires
            }],
            ..FaultPlan::none()
        };
        let tok = CancelToken::with_limits(None, Some(4.0));
        let out = run_steps_supervised_cancellable(
            g.num_vertices(),
            &locals,
            NetworkModel::ideal(),
            plan,
            None,
            Some(&tok),
            |_| Ticker,
        )
        .unwrap();
        assert_eq!(out.stopped, Some(StopCause::BudgetExhausted));
        assert_eq!(out.per_proc.len(), 4, "every rank reported, downed one included");
    }

    #[test]
    fn supervised_rejects_invalid_crash_plans_with_typed_errors() {
        use crate::dist::fault::Crash;
        let (g, locals) = toy_fleet(4);
        let oob = FaultPlan {
            crashes: vec![Crash {
                rank: 7,
                step: 1,
                down_steps: 1,
            }],
            ..FaultPlan::none()
        };
        let err = run_steps_supervised(
            g.num_vertices(),
            &locals,
            NetworkModel::ideal(),
            oob,
            None,
            |lg| toy_of(lg, 4),
        )
        .expect_err("an out-of-range crash rank must not be a silent no-op");
        assert!(err.to_string().contains("crashes rank 7"), "{err}");

        let zero = FaultPlan {
            checkpoint_interval: 0,
            ..FaultPlan::none()
        };
        let err = run_steps_supervised(
            g.num_vertices(),
            &locals,
            NetworkModel::ideal(),
            zero,
            None,
            |lg| toy_of(lg, 4),
        )
        .expect_err("a zero checkpoint interval must be rejected");
        assert!(err.to_string().contains("checkpoint interval"), "{err}");
    }

    #[test]
    fn supervised_multi_crash_with_interval_checkpoints_replays_to_the_same_answer() {
        use crate::coordinator::event::EventLog;
        use crate::dist::fault::Crash;
        let procs = 4usize;
        let plan = FaultPlan {
            seed: 9,
            crashes: vec![
                Crash {
                    rank: 1,
                    step: 2,
                    down_steps: 2,
                },
                Crash {
                    rank: 2,
                    step: 3,
                    down_steps: 2,
                },
            ],
            checkpoint_interval: 2,
            ..FaultPlan::none()
        };
        let run = || {
            let (g, locals) = toy_fleet(procs);
            let log = EventLog::new();
            let out = run_steps_supervised(
                g.num_vertices(),
                &locals,
                NetworkModel::default(),
                plan.clone(),
                Some(&log),
                |lg| toy_of(lg, procs),
            )
            .unwrap();
            (out, log.take())
        };
        let (a, ev_a) = run();
        let (b, ev_b) = run();
        assert_eq!(ev_a, ev_b, "multi-crash recovery trace must replay identically");
        assert_eq!(
            ev_a,
            vec![
                Event::FaultInjected { rank: 1, step: 2 },
                Event::FaultInjected { rank: 2, step: 3 },
                Event::ProcRestarted { rank: 1, step: 4 },
                Event::ProcRestarted { rank: 2, step: 5 },
            ]
        );
        assert_eq!(a.metrics.total_restarts, 2);
        assert_eq!(a.per_proc[1].restarts, 1);
        assert_eq!(a.per_proc[2].restarts, 1);
        assert_eq!(a.stopped, None);
        let expect = (procs * (procs + 1) / 2) as f64;
        for m in &a.per_proc {
            assert_eq!(m.vtime, expect, "p{} allreduce sum survives both crashes", m.rank);
        }
        assert_eq!(a.metrics.total_non_teardown_drops, 0);
        for (x, y) in a.per_proc.iter().zip(b.per_proc.iter()) {
            assert_eq!(x.sent_msgs, y.sent_msgs);
            assert_eq!(x.retransmits, y.retransmits);
            assert_eq!(x.acks_sent, y.acks_sent);
            assert_eq!(x.dup_discards, y.dup_discards);
            assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
        }
    }

    #[test]
    fn supervised_lossy_links_still_reach_the_exact_answer_deterministically() {
        let procs = 4usize;
        let plan = FaultPlan {
            seed: 21,
            loss_prob: 0.35,
            ..FaultPlan::none()
        };
        let run = || {
            let (g, locals) = toy_fleet(procs);
            run_steps_supervised(
                g.num_vertices(),
                &locals,
                NetworkModel::default(),
                plan.clone(),
                None,
                |lg| toy_of(lg, procs),
            )
            .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.stopped, None, "retry budget is ample at loss 0.35");
        let expect = (procs * (procs + 1) / 2) as f64;
        for m in &a.per_proc {
            assert_eq!(m.vtime, expect, "p{} exact answer under loss", m.rank);
        }
        assert!(
            a.metrics.total_injected_losses > 0,
            "0.35 loss over dozens of transmissions fires with overwhelming probability"
        );
        assert_eq!(
            a.metrics.total_retransmits, b.metrics.total_retransmits,
            "same seed, same retransmission schedule"
        );
        assert_eq!(a.metrics.total_injected_losses, b.metrics.total_injected_losses);
        assert_eq!(a.metrics.total_acks_sent, b.metrics.total_acks_sent);
        assert_eq!(a.metrics.total_dup_discards, b.metrics.total_dup_discards);
        assert_eq!(a.metrics.total_non_teardown_drops, 0, "losses are not drops");
        for (x, y) in a.per_proc.iter().zip(b.per_proc.iter()) {
            assert_eq!(x.sent_msgs, y.sent_msgs);
            assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
        }
    }

    #[test]
    fn engine_parses() {
        assert_eq!("auto".parse::<Engine>().unwrap(), Engine::Auto);
        assert_eq!("threads".parse::<Engine>().unwrap(), Engine::Threads);
        assert_eq!("bsp".parse::<Engine>().unwrap(), Engine::Bsp);
        assert_eq!("datapar".parse::<Engine>().unwrap(), Engine::DataPar);
        assert_eq!("dp".parse::<Engine>().unwrap(), Engine::DataPar);
        assert_eq!(Engine::DataPar.name(), "datapar");
        assert!("x".parse::<Engine>().is_err());
        assert_eq!(Engine::default(), Engine::Auto);
    }
}
