//! Distributed recoloring (paper §3, §3.1): synchronous RC — provably
//! identical to sequential Culberson iterated greedy — and asynchronous aRC.
//!
//! **RC.** One recoloring iteration walks the previous coloring's color
//! classes in a globally-agreed permutation, one superstep per class. A
//! color class of a valid coloring is an independent set, so every process
//! can recolor its owned members of the current class concurrently with
//! first-fit against the *new* colors of earlier classes — no conflicts,
//! and exactly the sequential result for any process count
//! (`rust/tests/recoloring.rs` pins this equivalence).
//!
//! **Communication schemes (§3.1, Fig 4).** The base scheme sends one
//! boundary-update message per neighbor per class step — `k` messages per
//! ordered process pair, most of them empty because per-pair boundaries are
//! tiny relative to `k`. The piggybacked scheme first exchanges a *plan*
//! per pair (the schedule of class steps that will actually carry data —
//! the receiver's deadlines), then sends only nonempty messages; each data
//! message implicitly flushes everything up to its step, and the plan tells
//! the receiver how far it may run ahead without waiting. Preparation cost
//! is booked under the "plan" phase (Fig 4's `prep` bar).
//!
//! **aRC (§2.2.2, §4.2.3).** Asynchronous recoloring reruns the
//! speculative superstep framework with the visit order induced by the
//! class permutation: cheaper, conflict-prone, quality between FSS and RC.

use crate::color::recolor::{Permutation, RecolorSchedule};
use crate::color::select::Selection;
use crate::color::{Color, UNCOLORED};
use crate::coordinator::event::{emit_rank0, Event, Observer};
use crate::dist::comm::{self, Endpoint, MsgKind};
use crate::dist::cost::CostModel;
use crate::dist::framework::{self, FrameworkConfig, FrameworkStep};
use crate::dist::proc::{ColorState, LocalGraph};
use crate::dist::ProcMetrics;
use crate::util::bitset::ColorMarker;
use crate::util::rng::{mix64, Rng};

/// Boundary-update communication scheme for synchronous recoloring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommScheme {
    /// One message per neighbor per class step, empty or not.
    Base,
    /// Plan/deadline exchange up front, then only nonempty messages.
    Piggyback,
}

impl std::str::FromStr for CommScheme {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "base" => Ok(CommScheme::Base),
            "piggyback" | "pb" | "improved" => Ok(CommScheme::Piggyback),
            other => Err(format!("unknown comm scheme {other:?} (base|piggyback)")),
        }
    }
}

/// Configuration of distributed synchronous recoloring.
#[derive(Debug, Clone, Copy)]
pub struct RecolorConfig {
    pub schedule: RecolorSchedule,
    pub iterations: u32,
    pub scheme: CommScheme,
    /// Seeds the class permutation for `RAND` schedules — identical on
    /// every process, so the permutation (and therefore the result) is
    /// independent of the process count.
    pub seed: u64,
    /// Stop before `iterations` once an iteration's relative improvement
    /// `(k_prev - k) / k_prev` falls below this threshold. Both counts are
    /// allreduced, so every process takes the same decision.
    pub early_stop: Option<f64>,
}

impl Default for RecolorConfig {
    fn default() -> Self {
        RecolorConfig {
            schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
            iterations: 1,
            scheme: CommScheme::Piggyback,
            seed: 42,
            early_stop: None,
        }
    }
}

/// The class permutation RNG for iteration `iter` — a pure function of
/// `(seed, iter)` so every process, at every process count, agrees.
fn perm_rng(seed: u64, iter: u32) -> Rng {
    Rng::new(mix64(seed, 0x9C1A_55E5 ^ iter as u64))
}

/// Per-pair piggyback plan: for each neighbor (in `neighbor_procs` order),
/// the sorted class steps at which this process will send a nonempty
/// update. A pure function of the send lists, the old colors and the class
/// permutation — the unit tests pin it against the base scheme's schedule.
pub fn build_plans(
    lg: &LocalGraph,
    old_colors: &[u32],
    step_of_class: &[u32],
) -> Vec<Vec<u32>> {
    lg.send_lists
        .iter()
        .map(|list| {
            let mut steps: Vec<u32> = list
                .iter()
                .filter(|&&v| old_colors[v as usize] != crate::color::UNCOLORED)
                .map(|&v| step_of_class[old_colors[v as usize] as usize])
                .collect();
            steps.sort_unstable();
            steps.dedup();
            steps
        })
        .collect()
}

/// Per-process staging for synchronous recoloring, reused across
/// iterations so the per-class supersteps are allocation-free in steady
/// state. Class-indexed vectors are resized per iteration (k changes as
/// recoloring shrinks the palette) but keep their capacity.
#[derive(Clone)]
struct SyncScratch {
    /// Global class sizes (allreduced).
    sizes: Vec<u64>,
    /// `sizes` as `usize` for the permutation API.
    sizes_usize: Vec<usize>,
    /// class → superstep of the current permutation.
    step_of_class: Vec<u32>,
    /// Counting-sort class offsets over owned vertices (`k + 1` entries).
    class_start: Vec<usize>,
    /// Scatter cursor of the counting sort.
    cursor: Vec<usize>,
    /// Owned members, class-consecutive, ascending id within a class.
    members: Vec<u32>,
    /// Per neighbor, per superstep: send-list members to update.
    pair_sched: Vec<Vec<Vec<u32>>>,
    /// Per neighbor: which supersteps the peer announced data for.
    plans_in: Vec<Vec<bool>>,
    /// The next coloring, staged over the local index space.
    newc: Vec<Color>,
    /// Receive/decode staging.
    dec: Vec<u8>,
}

impl SyncScratch {
    fn new(n_local: usize, npairs: usize) -> Self {
        SyncScratch {
            sizes: Vec::new(),
            sizes_usize: Vec::new(),
            step_of_class: Vec::new(),
            class_start: Vec::new(),
            cursor: Vec::new(),
            members: Vec::new(),
            pair_sched: vec![Vec::new(); npairs],
            plans_in: vec![Vec::new(); npairs],
            newc: vec![UNCOLORED; n_local],
            dec: Vec::new(),
        }
    }
}

/// One process's share of synchronous recoloring. Appends the global color
/// count after every iteration to `trace`; rank 0 mirrors each entry to
/// `obs` as [`Event::RecolorIteration`]. With `cfg.early_stop` set, the
/// loop exits early once improvement stalls (identically on every
/// process — the decision is a function of allreduced counts only).
pub fn recolor_process_sync(
    ep: &mut Endpoint,
    lg: &LocalGraph,
    cost: &CostModel,
    cfg: &RecolorConfig,
    state: &mut ColorState,
    trace: &mut Vec<usize>,
    obs: Option<&dyn Observer>,
) -> ProcMetrics {
    let mut m = ProcMetrics {
        rank: ep.rank,
        ..Default::default()
    };
    ep.wait_on_recv = true;
    let n_owned = lg.n_owned();
    let n_local = lg.n_local();
    let npairs = lg.neighbor_procs.len();
    let mut marker = ColorMarker::new(64);

    // Staging reused across iterations (class counts resize per iteration,
    // but capacity is retained): steady-state class supersteps allocate
    // nothing (DESIGN.md "Memory discipline on hot paths").
    let mut scratch = SyncScratch::new(n_local, npairs);

    for iter in 1..=cfg.iterations {
        let t0 = ep.clock;
        let mut plan_dt = 0.0;

        // --- global class structure of the current coloring
        let local_k = (0..n_owned)
            .map(|v| state.colors[v])
            .filter(|&c| c != UNCOLORED)
            .map(|c| c as u64 + 1)
            .max()
            .unwrap_or(0);
        let k = ep.allreduce_max_u64(local_k) as usize;
        if k == 0 {
            trace.push(0);
            emit_rank0(obs, ep.rank, Event::RecolorIteration { iter, k: 0 });
            continue;
        }
        scratch.sizes.clear();
        scratch.sizes.resize(k, 0);
        for v in 0..n_owned {
            let c = state.colors[v];
            if c != UNCOLORED {
                scratch.sizes[c as usize] += 1;
            }
        }
        ep.allreduce_sum_vec_u64(&mut scratch.sizes);
        scratch.sizes_usize.clear();
        scratch.sizes_usize.extend(scratch.sizes.iter().map(|&s| s as usize));
        let perm = cfg.schedule.permutation_at(iter);
        let mut prng = perm_rng(cfg.seed, iter);
        let class_order = perm.permute_classes(&scratch.sizes_usize, &mut prng);
        scratch.step_of_class.clear();
        scratch.step_of_class.resize(k, 0);
        for (t, &c) in class_order.iter().enumerate() {
            scratch.step_of_class[c as usize] = t as u32;
        }

        // owned members per class, ascending local id (== ascending global
        // id), via counting sort — the sequential visit order, sharded
        scratch.class_start.clear();
        scratch.class_start.resize(k + 1, 0);
        for v in 0..n_owned {
            let c = state.colors[v];
            if c != UNCOLORED {
                scratch.class_start[c as usize + 1] += 1;
            }
        }
        for c in 0..k {
            scratch.class_start[c + 1] += scratch.class_start[c];
        }
        scratch.members.clear();
        scratch.members.resize(scratch.class_start[k], 0);
        scratch.cursor.clear();
        scratch.cursor.extend_from_slice(&scratch.class_start);
        for v in 0..n_owned {
            let c = state.colors[v];
            if c != UNCOLORED {
                scratch.members[scratch.cursor[c as usize]] = v as u32;
                scratch.cursor[c as usize] += 1;
            }
        }
        ep.clock += cost.color_cost(n_owned as u64, 0);

        // per-pair, per-step update lists from the old classes
        for buckets in scratch.pair_sched.iter_mut() {
            for b in buckets.iter_mut() {
                b.clear();
            }
            if buckets.len() < k {
                buckets.resize_with(k, Vec::new);
            }
        }
        for (qi, list) in lg.send_lists.iter().enumerate() {
            for &v in list {
                let c = state.colors[v as usize];
                if c != UNCOLORED {
                    let t = scratch.step_of_class[c as usize] as usize;
                    scratch.pair_sched[qi][t].push(v);
                }
            }
        }

        // --- piggyback plan/deadline exchange
        if cfg.scheme == CommScheme::Piggyback {
            let tp0 = ep.clock;
            // derive each pair's plan from the same buckets that gate the
            // data sends below, so plan and schedule agree by construction
            // (build_plans is the pure spec of this, pinned by unit tests)
            let planned_entries: u64 =
                lg.send_lists.iter().map(|l| l.len() as u64).sum::<u64>() + k as u64;
            ep.clock += cost.color_cost(planned_entries, 0);
            for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                let mut payload = ep.take_buf();
                for (t, b) in scratch.pair_sched[qi][..k].iter().enumerate() {
                    if !b.is_empty() {
                        payload.extend_from_slice(&(t as u32).to_le_bytes());
                    }
                }
                ep.clock += cost.pack_cost(payload.len() as u64);
                ep.send(q, MsgKind::Plan, iter, 0, payload);
            }
            for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                ep.recv_into(q, MsgKind::Plan, iter, 0, &mut scratch.dec);
                ep.clock += cost.pack_cost(scratch.dec.len() as u64);
                let flags = &mut scratch.plans_in[qi];
                flags.clear();
                flags.resize(k, false);
                for t in comm::decode_u32s_iter(&scratch.dec) {
                    flags[t as usize] = true;
                }
            }
            plan_dt = ep.clock - tp0;
            m.phases.add("plan", plan_dt);
        }

        // --- class supersteps: first-fit against the new coloring only
        let newc = &mut scratch.newc;
        newc.fill(UNCOLORED);
        for (t, &c) in class_order.iter().enumerate() {
            let lo = scratch.class_start[c as usize];
            let hi = scratch.class_start[c as usize + 1];
            let batch = &scratch.members[lo..hi];
            let mut scans: u64 = 0;
            for &v in batch {
                marker.next_epoch();
                let s = lg.csr.xadj[v as usize] as usize;
                let e = lg.csr.xadj[v as usize + 1] as usize;
                scans += (e - s) as u64;
                for &u in &lg.csr.adjncy[s..e] {
                    let cu = newc[u as usize];
                    if cu != UNCOLORED {
                        marker.mark(cu);
                    }
                }
                newc[v as usize] = marker.first_unmarked();
            }
            ep.clock += cost.color_cost(batch.len() as u64, scans);

            for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                let vs = &scratch.pair_sched[qi][t];
                if cfg.scheme == CommScheme::Piggyback && vs.is_empty() {
                    continue; // the plan told the receiver to skip this step
                }
                let mut payload = ep.take_buf();
                for &v in vs {
                    comm::push_pair(&mut payload, lg.global_ids[v as usize], newc[v as usize]);
                }
                ep.clock += cost.pack_cost(payload.len() as u64);
                ep.send(q, MsgKind::Recolor, iter, t as u32, payload);
            }
            for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                let expected = match cfg.scheme {
                    CommScheme::Base => true,
                    CommScheme::Piggyback => scratch.plans_in[qi][t],
                };
                if !expected {
                    continue;
                }
                ep.recv_into(q, MsgKind::Recolor, iter, t as u32, &mut scratch.dec);
                ep.clock += cost.pack_cost(scratch.dec.len() as u64);
                for (gid, c) in comm::decode_pairs_iter(&scratch.dec) {
                    newc[lg.local_of(gid) as usize] = c;
                }
            }
        }
        state.colors.copy_from_slice(newc);

        // --- trace: global color count after this iteration
        let local_new_k = (0..n_owned)
            .map(|v| state.colors[v])
            .filter(|&c| c != UNCOLORED)
            .map(|c| c as u64 + 1)
            .max()
            .unwrap_or(0);
        let kk = ep.allreduce_max_u64(local_new_k);
        trace.push(kk as usize);
        m.phases.add("recolor", (ep.clock - t0) - plan_dt);
        emit_rank0(
            obs,
            ep.rank,
            Event::RecolorIteration {
                iter,
                k: kk as usize,
            },
        );
        if let Some(eps) = cfg.early_stop {
            // k (before) and kk (after) are allreduced: every process
            // computes the same improvement and stops at the same
            // iteration, keeping traces and schedules aligned.
            let improvement = (k as f64 - kk as f64) / (k as f64).max(1.0);
            if improvement < eps {
                break;
            }
        }
    }

    m.vtime = ep.clock;
    m.sent_msgs = ep.sent_msgs;
    m.sent_bytes = ep.sent_bytes;
    m.recv_msgs = ep.recv_msgs;
    m.dropped_msgs = ep.dropped_msgs;
    m
}

/// [`recolor_process_sync`] as an explicit step state machine for the BSP
/// step engine ([`dist::engine`](crate::dist::engine)): every
/// [`step_once`](SyncRcStep::step_once) call runs one non-blocking slice —
/// a split-collective phase, the plan exchange halves, or one class
/// superstep's compute+send / receive half. The machine performs the same
/// endpoint operations in the same per-process order as the blocking
/// function, so colorings, traces, message/byte counts and virtual clocks
/// are bit-for-bit identical; keep the two in lockstep when either
/// changes. Works for both [`CommScheme`]s.
///
/// `Clone` snapshots the whole machine (colors, scratch, collective
/// cursors) — the supervising engine's checkpoint for crash recovery.
#[derive(Clone)]
pub struct SyncRcStep<'a> {
    lg: &'a LocalGraph,
    cost: CostModel,
    cfg: RecolorConfig,
    obs: Option<&'a dyn Observer>,
    colors: ColorState,
    trace: Vec<usize>,
    m: ProcMetrics,
    marker: ColorMarker,
    scratch: SyncScratch,
    /// Current iteration, 1-based (as the blocking loop counts).
    iter: u32,
    t0: f64,
    tp0: f64,
    plan_dt: f64,
    k: usize,
    class_order: Vec<u32>,
    coll_seq: u32,
    coll_acc: u64,
    state: RcState,
}

/// Which slice of `recolor_process_sync` the next `step_once` executes.
#[derive(Clone, Copy)]
enum RcState {
    /// Iteration entry: palette-size collective phase 1 (or finish).
    IterBegin,
    /// Palette-size collective phase 2 (rank 0).
    KReduce,
    /// Palette-size collective phase 3; class-size collective phase 1.
    KFinish,
    /// Class-size vector collective phase 2 (rank 0).
    SizesReduce,
    /// Class-size phase 3, permutation + counting sort + schedule build.
    SizesFinish,
    /// Piggyback plan build + send.
    PlanSend,
    /// Piggyback plan receive (one engine step later).
    PlanRecv,
    /// Class superstep `t`: recolor the class, send boundary updates.
    ClassColor(usize),
    /// Class superstep `t`: receive + apply the peers' updates.
    ClassRecv(usize),
    /// Commit the new coloring; new-palette collective phase 1.
    IterEnd,
    /// New-palette collective phase 2 (rank 0).
    NewKReduce,
    /// New-palette phase 3: trace, events, early stop, next iteration.
    NewKFinish,
    Finished,
}

impl<'a> SyncRcStep<'a> {
    /// `colors` is the recoloring entry state
    /// ([`ColorState::from_global`] or a finished framework machine's).
    pub fn new(
        lg: &'a LocalGraph,
        cost: &CostModel,
        cfg: RecolorConfig,
        colors: ColorState,
        obs: Option<&'a dyn Observer>,
    ) -> Self {
        SyncRcStep {
            lg,
            cost: *cost,
            cfg,
            obs,
            colors,
            trace: Vec::new(),
            m: ProcMetrics {
                rank: lg.rank as usize,
                ..Default::default()
            },
            marker: ColorMarker::new(64),
            scratch: SyncScratch::new(lg.n_local(), lg.neighbor_procs.len()),
            iter: 1,
            t0: 0.0,
            tp0: 0.0,
            plan_dt: 0.0,
            k: 0,
            class_order: Vec::new(),
            coll_seq: 0,
            coll_acc: 0,
            state: RcState::IterBegin,
        }
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, RcState::Finished)
    }

    /// The finished machine's colors, per-iteration trace, and metrics
    /// (phase times; the endpoint's cumulative accounting is the caller's
    /// to read, as with the blocking function's tail).
    pub fn into_parts(self) -> (ColorState, Vec<usize>, ProcMetrics) {
        assert!(self.is_finished(), "sync RC step machine still running");
        (self.colors, self.trace, self.m)
    }

    /// Best-so-far harvest for a cancelled run: the color state as the
    /// machine last left it. Sync recoloring is conflict-free by
    /// construction, so this is always a *valid* coloring — mid-iteration
    /// it is simply a mix of old and new classes. No finished assertion.
    pub fn abort_colors(self) -> ColorState {
        self.colors
    }

    /// Whether the next [`step_once`](Self::step_once) slice can run
    /// without a blocking-receive miss (see
    /// [`FrameworkStep::ready`](crate::dist::framework::FrameworkStep::ready)).
    pub fn ready(&mut self, ep: &mut Endpoint) -> bool {
        let lg = self.lg;
        match self.state {
            RcState::KReduce | RcState::SizesReduce | RcState::NewKReduce => {
                ep.rank != 0
                    || (1..lg.nprocs)
                        .all(|p| ep.have_msg(p, MsgKind::Collective, self.coll_seq, 0))
            }
            RcState::KFinish | RcState::SizesFinish | RcState::NewKFinish => {
                ep.rank == 0 || ep.have_msg(0, MsgKind::Collective, self.coll_seq, 1)
            }
            RcState::PlanRecv => lg
                .neighbor_procs
                .iter()
                .all(|&q| ep.have_msg(q, MsgKind::Plan, self.iter, 0)),
            RcState::ClassRecv(t) => {
                lg.neighbor_procs.iter().enumerate().all(|(qi, &q)| {
                    let expected = match self.cfg.scheme {
                        CommScheme::Base => true,
                        CommScheme::Piggyback => self.scratch.plans_in[qi][t],
                    };
                    !expected || ep.have_msg(q, MsgKind::Recolor, self.iter, t as u32)
                })
            }
            _ => true,
        }
    }

    /// Run one engine step; `true` once the machine reached `Finished`.
    pub fn step_once(&mut self, ep: &mut Endpoint) -> bool {
        let lg = self.lg;
        let n_owned = lg.n_owned();
        match self.state {
            RcState::IterBegin => {
                ep.wait_on_recv = true;
                if self.iter > self.cfg.iterations {
                    self.state = RcState::Finished;
                } else {
                    self.t0 = ep.clock;
                    self.plan_dt = 0.0;
                    let local_k = (0..n_owned)
                        .map(|v| self.colors.colors[v])
                        .filter(|&c| c != UNCOLORED)
                        .map(|c| c as u64 + 1)
                        .max()
                        .unwrap_or(0);
                    self.coll_acc = local_k;
                    self.coll_seq = ep.coll_send_u64(local_k);
                    self.state = RcState::KReduce;
                }
            }
            RcState::KReduce => {
                if ep.rank == 0 {
                    self.coll_acc = ep.coll_reduce_u64(self.coll_seq, self.coll_acc, u64::max);
                }
                self.state = RcState::KFinish;
            }
            RcState::KFinish => {
                self.k = ep.coll_finish_u64(self.coll_seq, self.coll_acc) as usize;
                if self.k == 0 {
                    self.trace.push(0);
                    emit_rank0(
                        self.obs,
                        ep.rank,
                        Event::RecolorIteration {
                            iter: self.iter,
                            k: 0,
                        },
                    );
                    self.iter += 1;
                    self.state = RcState::IterBegin;
                } else {
                    self.scratch.sizes.clear();
                    self.scratch.sizes.resize(self.k, 0);
                    for v in 0..n_owned {
                        let c = self.colors.colors[v];
                        if c != UNCOLORED {
                            self.scratch.sizes[c as usize] += 1;
                        }
                    }
                    self.coll_seq = ep.coll_send_vec_u64(&self.scratch.sizes);
                    self.state = RcState::SizesReduce;
                }
            }
            RcState::SizesReduce => {
                if ep.rank == 0 {
                    ep.coll_reduce_vec_u64(self.coll_seq, &mut self.scratch.sizes);
                }
                self.state = RcState::SizesFinish;
            }
            RcState::SizesFinish => {
                ep.coll_finish_vec_u64(self.coll_seq, &mut self.scratch.sizes);
                let k = self.k;
                self.scratch.sizes_usize.clear();
                self.scratch
                    .sizes_usize
                    .extend(self.scratch.sizes.iter().map(|&s| s as usize));
                let perm = self.cfg.schedule.permutation_at(self.iter);
                let mut prng = perm_rng(self.cfg.seed, self.iter);
                self.class_order = perm.permute_classes(&self.scratch.sizes_usize, &mut prng);
                self.scratch.step_of_class.clear();
                self.scratch.step_of_class.resize(k, 0);
                for (t, &c) in self.class_order.iter().enumerate() {
                    self.scratch.step_of_class[c as usize] = t as u32;
                }

                // owned members per class, ascending local id, counting sort
                self.scratch.class_start.clear();
                self.scratch.class_start.resize(k + 1, 0);
                for v in 0..n_owned {
                    let c = self.colors.colors[v];
                    if c != UNCOLORED {
                        self.scratch.class_start[c as usize + 1] += 1;
                    }
                }
                for c in 0..k {
                    self.scratch.class_start[c + 1] += self.scratch.class_start[c];
                }
                self.scratch.members.clear();
                self.scratch.members.resize(self.scratch.class_start[k], 0);
                self.scratch.cursor.clear();
                self.scratch
                    .cursor
                    .extend_from_slice(&self.scratch.class_start);
                for v in 0..n_owned {
                    let c = self.colors.colors[v];
                    if c != UNCOLORED {
                        self.scratch.members[self.scratch.cursor[c as usize]] = v as u32;
                        self.scratch.cursor[c as usize] += 1;
                    }
                }
                ep.clock += self.cost.color_cost(n_owned as u64, 0);

                // per-pair, per-step update lists from the old classes
                for buckets in self.scratch.pair_sched.iter_mut() {
                    for b in buckets.iter_mut() {
                        b.clear();
                    }
                    if buckets.len() < k {
                        buckets.resize_with(k, Vec::new);
                    }
                }
                for (qi, list) in lg.send_lists.iter().enumerate() {
                    for &v in list {
                        let c = self.colors.colors[v as usize];
                        if c != UNCOLORED {
                            let t = self.scratch.step_of_class[c as usize] as usize;
                            self.scratch.pair_sched[qi][t].push(v);
                        }
                    }
                }
                if self.cfg.scheme == CommScheme::Piggyback {
                    self.state = RcState::PlanSend;
                } else {
                    self.scratch.newc.fill(UNCOLORED);
                    self.state = RcState::ClassColor(0);
                }
            }
            RcState::PlanSend => {
                self.tp0 = ep.clock;
                let planned_entries: u64 =
                    lg.send_lists.iter().map(|l| l.len() as u64).sum::<u64>() + self.k as u64;
                ep.clock += self.cost.color_cost(planned_entries, 0);
                for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                    let mut payload = ep.take_buf();
                    for (t, b) in self.scratch.pair_sched[qi][..self.k].iter().enumerate() {
                        if !b.is_empty() {
                            payload.extend_from_slice(&(t as u32).to_le_bytes());
                        }
                    }
                    ep.clock += self.cost.pack_cost(payload.len() as u64);
                    ep.send(q, MsgKind::Plan, self.iter, 0, payload);
                }
                self.state = RcState::PlanRecv;
            }
            RcState::PlanRecv => {
                for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                    ep.try_recv_into(q, MsgKind::Plan, self.iter, 0, &mut self.scratch.dec);
                    ep.clock += self.cost.pack_cost(self.scratch.dec.len() as u64);
                    let flags = &mut self.scratch.plans_in[qi];
                    flags.clear();
                    flags.resize(self.k, false);
                    for t in comm::decode_u32s_iter(&self.scratch.dec) {
                        flags[t as usize] = true;
                    }
                }
                self.plan_dt = ep.clock - self.tp0;
                self.m.phases.add("plan", self.plan_dt);
                self.scratch.newc.fill(UNCOLORED);
                self.state = RcState::ClassColor(0);
            }
            RcState::ClassColor(t) => {
                let c = self.class_order[t] as usize;
                let lo = self.scratch.class_start[c];
                let hi = self.scratch.class_start[c + 1];
                let mut scans: u64 = 0;
                for &v in &self.scratch.members[lo..hi] {
                    self.marker.next_epoch();
                    let s = lg.csr.xadj[v as usize] as usize;
                    let e = lg.csr.xadj[v as usize + 1] as usize;
                    scans += (e - s) as u64;
                    for &u in &lg.csr.adjncy[s..e] {
                        let cu = self.scratch.newc[u as usize];
                        if cu != UNCOLORED {
                            self.marker.mark(cu);
                        }
                    }
                    self.scratch.newc[v as usize] = self.marker.first_unmarked();
                }
                ep.clock += self.cost.color_cost((hi - lo) as u64, scans);

                for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                    let vs = &self.scratch.pair_sched[qi][t];
                    if self.cfg.scheme == CommScheme::Piggyback && vs.is_empty() {
                        continue; // the plan told the receiver to skip this step
                    }
                    let mut payload = ep.take_buf();
                    for &v in vs {
                        comm::push_pair(
                            &mut payload,
                            lg.global_ids[v as usize],
                            self.scratch.newc[v as usize],
                        );
                    }
                    ep.clock += self.cost.pack_cost(payload.len() as u64);
                    ep.send(q, MsgKind::Recolor, self.iter, t as u32, payload);
                }
                self.state = RcState::ClassRecv(t);
            }
            RcState::ClassRecv(t) => {
                for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                    let expected = match self.cfg.scheme {
                        CommScheme::Base => true,
                        CommScheme::Piggyback => self.scratch.plans_in[qi][t],
                    };
                    if !expected {
                        continue;
                    }
                    let (iter, dec) = (self.iter, &mut self.scratch.dec);
                    ep.try_recv_into(q, MsgKind::Recolor, iter, t as u32, dec);
                    ep.clock += self.cost.pack_cost(self.scratch.dec.len() as u64);
                    for (gid, c) in comm::decode_pairs_iter(&self.scratch.dec) {
                        self.scratch.newc[lg.local_of(gid) as usize] = c;
                    }
                }
                let next = t + 1;
                self.state = if next < self.class_order.len() {
                    RcState::ClassColor(next)
                } else {
                    RcState::IterEnd
                };
            }
            RcState::IterEnd => {
                self.colors.colors.copy_from_slice(&self.scratch.newc);
                let local_new_k = (0..n_owned)
                    .map(|v| self.colors.colors[v])
                    .filter(|&c| c != UNCOLORED)
                    .map(|c| c as u64 + 1)
                    .max()
                    .unwrap_or(0);
                self.coll_acc = local_new_k;
                self.coll_seq = ep.coll_send_u64(local_new_k);
                self.state = RcState::NewKReduce;
            }
            RcState::NewKReduce => {
                if ep.rank == 0 {
                    self.coll_acc = ep.coll_reduce_u64(self.coll_seq, self.coll_acc, u64::max);
                }
                self.state = RcState::NewKFinish;
            }
            RcState::NewKFinish => {
                let kk = ep.coll_finish_u64(self.coll_seq, self.coll_acc);
                self.trace.push(kk as usize);
                self.m
                    .phases
                    .add("recolor", (ep.clock - self.t0) - self.plan_dt);
                emit_rank0(
                    self.obs,
                    ep.rank,
                    Event::RecolorIteration {
                        iter: self.iter,
                        k: kk as usize,
                    },
                );
                let mut stop = false;
                if let Some(eps) = self.cfg.early_stop {
                    let improvement = (self.k as f64 - kk as f64) / (self.k as f64).max(1.0);
                    if improvement < eps {
                        stop = true;
                    }
                }
                if stop {
                    self.state = RcState::Finished;
                } else {
                    self.iter += 1;
                    self.state = RcState::IterBegin;
                }
            }
            RcState::Finished => {}
        }
        self.is_finished()
    }
}

impl crate::dist::engine::StepProcess for SyncRcStep<'_> {
    fn poll_ready(&mut self, ep: &mut Endpoint) -> bool {
        self.ready(ep)
    }

    /// Standalone use on the engine: once finished, the result carries the
    /// endpoint's cumulative accounting and the trace (in
    /// `metrics.recolor_trace`), as a thread-runner closure wrapping
    /// [`recolor_process_sync`] would report.
    fn step(&mut self, ep: &mut Endpoint) -> crate::dist::engine::StepOutcome {
        use crate::dist::engine::StepOutcome;
        if !self.step_once(ep) {
            return StepOutcome::Running;
        }
        let colors = std::mem::replace(&mut self.colors, ColorState { colors: Vec::new() });
        let mut metrics = std::mem::take(&mut self.m);
        metrics.recolor_trace = std::mem::take(&mut self.trace);
        metrics.vtime = ep.clock;
        metrics.sent_msgs = ep.sent_msgs;
        metrics.sent_bytes = ep.sent_bytes;
        metrics.recv_msgs = ep.recv_msgs;
        metrics.dropped_msgs = ep.dropped_msgs;
        metrics.non_teardown_drops = ep.non_teardown_drops;
        StepOutcome::Done(crate::dist::ProcResult {
            colors: colors.owned_pairs(self.lg),
            metrics,
        })
    }
}

/// One asynchronous recoloring iteration (aRC): rerun the speculative
/// framework with the class-permutation-induced visit order.
#[allow(clippy::too_many_arguments)]
pub fn recolor_process_async(
    ep: &mut Endpoint,
    lg: &LocalGraph,
    cost: &CostModel,
    fw: &FrameworkConfig,
    perm: Permutation,
    iter: u32,
    seed: u64,
    state: &mut ColorState,
    obs: Option<&dyn Observer>,
) -> ProcMetrics {
    let mut m = ProcMetrics {
        rank: ep.rank,
        ..Default::default()
    };
    let t0 = ep.clock;
    let n_owned = lg.n_owned();

    // global class structure, as in RC
    let local_k = (0..n_owned)
        .map(|v| state.colors[v])
        .filter(|&c| c != UNCOLORED)
        .map(|c| c as u64 + 1)
        .max()
        .unwrap_or(0);
    let k = ep.allreduce_max_u64(local_k) as usize;
    if k == 0 {
        return m;
    }
    let mut sizes = vec![0u64; k];
    for v in 0..n_owned {
        let c = state.colors[v];
        if c != UNCOLORED {
            sizes[c as usize] += 1;
        }
    }
    ep.allreduce_sum_vec_u64(&mut sizes);
    let sizes_usize: Vec<usize> = sizes.iter().map(|&s| s as usize).collect();
    let mut prng = perm_rng(seed, iter);
    let class_order = perm.permute_classes(&sizes_usize, &mut prng);

    // owned visit order: classes in permuted order, ascending ids within
    let mut local_counts = vec![0usize; k];
    let mut n_colored = 0usize;
    for v in 0..n_owned {
        let c = state.colors[v];
        if c != UNCOLORED {
            local_counts[c as usize] += 1;
            n_colored += 1;
        }
    }
    let mut start = vec![0usize; k];
    let mut a = 0usize;
    for &c in &class_order {
        start[c as usize] = a;
        a += local_counts[c as usize];
    }
    let mut order = vec![0u32; n_colored];
    let mut cur = start;
    for v in 0..n_owned {
        let c = state.colors[v];
        if c != UNCOLORED {
            order[cur[c as usize]] = v as u32;
            cur[c as usize] += 1;
        }
    }
    ep.clock += cost.color_cost(n_owned as u64, 0);

    // speculative rerun from scratch with first-fit
    for c in state.colors.iter_mut() {
        *c = UNCOLORED;
    }
    let mut fw2 = *fw;
    fw2.selection = Selection::FirstFit;
    fw2.seed = mix64(seed, 0xA12C ^ iter as u64);
    let fm = framework::color_process(ep, lg, &fw2, cost, state, Vec::new(), Some(order), obs);
    m.conflicts = fm.conflicts;
    m.rounds = fm.rounds;
    // keep the rerun's per-phase breakdown (its "color" bucket) so aRC
    // phase accounting is comparable with sync RC, then book the whole
    // iteration under "recolor" as before
    m.phases.merge(&fm.phases);
    m.phases.add("recolor", ep.clock - t0);
    m
}

/// The aRC pipeline section as an explicit step state machine for the BSP
/// step engine ([`dist::engine`](crate::dist::engine)): the multi-iteration
/// loop around [`recolor_process_async`] — the palette/class-size
/// allreduces as split `coll_*` phases, the permuted visit-order build, an
/// embedded [`FrameworkStep`] rerun, and the pipeline's post-iteration
/// allreduce (booked under "comm"), trace entry,
/// [`Event::RecolorIteration`] and early-stop decision. The machine
/// performs the same endpoint operations in the same per-process order as
/// the blocking loop, so colorings, traces, message/byte counts and
/// virtual clocks are bit-for-bit identical; keep the two in lockstep when
/// either changes.
///
/// `Clone` snapshots the whole machine (colors, the embedded rerun, the
/// collective cursors) — the supervising engine's checkpoint for crash
/// recovery.
#[derive(Clone)]
pub struct AsyncRcStep<'a> {
    lg: &'a LocalGraph,
    cost: CostModel,
    fw: FrameworkConfig,
    perm: Permutation,
    iterations: u32,
    seed: u64,
    early_stop: Option<f64>,
    obs: Option<&'a dyn Observer>,
    /// Held here between reruns; inside the embedded [`FrameworkStep`]
    /// while one is running.
    colors: Option<ColorState>,
    inner: Option<FrameworkStep<'a>>,
    trace: Vec<usize>,
    m: ProcMetrics,
    /// Current iteration, 1-based (as the blocking loop counts).
    iter: u32,
    t0: f64,
    comm_t0: f64,
    /// The color count before the first iteration (the caller's last trace
    /// entry) — the early-stop baseline until `trace` has entries.
    prev_k: usize,
    k: usize,
    sizes: Vec<u64>,
    coll_seq: u32,
    coll_acc: u64,
    state: ArcState,
}

/// Which slice of the aRC loop the next `step_once` executes.
#[derive(Clone, Copy)]
enum ArcState {
    /// Iteration entry: palette-size collective phase 1 (or finish).
    IterBegin,
    /// Palette-size collective phase 2 (rank 0).
    KReduce,
    /// Palette-size phase 3; class-size vector collective phase 1 (or, on
    /// an empty palette, skip straight to the post-iteration allreduce).
    KFinish,
    /// Class-size vector collective phase 2 (rank 0).
    SizesReduce,
    /// Class-size phase 3: permutation, visit-order build, color reset,
    /// embedded framework construction.
    SizesFinish,
    /// One step of the embedded speculative [`FrameworkStep`] rerun.
    Rerun,
    /// Post-iteration palette allreduce phase 1 (booked under "comm").
    PostKSend,
    /// Post-iteration allreduce phase 2 (rank 0).
    PostKReduce,
    /// Post-iteration phase 3: trace, event, early stop, next iteration.
    PostKFinish,
    Finished,
}

impl<'a> AsyncRcStep<'a> {
    /// `colors` is the recoloring entry state (a finished framework
    /// machine's, or [`ColorState::from_global`]); `prev_k` is the global
    /// color count it encodes (the caller's last trace entry — the first
    /// iteration's early-stop baseline). `fw` is rerun with first-fit
    /// selection and a per-iteration seed, exactly as
    /// [`recolor_process_async`] does.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        lg: &'a LocalGraph,
        cost: &CostModel,
        fw: &FrameworkConfig,
        perm: Permutation,
        iterations: u32,
        seed: u64,
        early_stop: Option<f64>,
        prev_k: usize,
        colors: ColorState,
        obs: Option<&'a dyn Observer>,
    ) -> Self {
        AsyncRcStep {
            lg,
            cost: *cost,
            fw: *fw,
            perm,
            iterations,
            seed,
            early_stop,
            obs,
            colors: Some(colors),
            inner: None,
            trace: Vec::new(),
            m: ProcMetrics {
                rank: lg.rank as usize,
                ..Default::default()
            },
            iter: 1,
            t0: 0.0,
            comm_t0: 0.0,
            prev_k,
            k: 0,
            sizes: Vec::new(),
            coll_seq: 0,
            coll_acc: 0,
            state: ArcState::IterBegin,
        }
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, ArcState::Finished)
    }

    /// The finished machine's colors, per-iteration trace, and metrics
    /// (phase times, conflicts and rounds accumulated over every rerun;
    /// the endpoint's cumulative accounting is the caller's to read).
    pub fn into_parts(self) -> (ColorState, Vec<usize>, ProcMetrics) {
        assert!(self.is_finished(), "async RC step machine still running");
        (
            self.colors.expect("colors held outside reruns"),
            self.trace,
            self.m,
        )
    }

    /// Best-so-far harvest for a cancelled run. Between reruns the colors
    /// are held here (a valid coloring); mid-rerun they live inside the
    /// embedded [`FrameworkStep`] and may be partially uncolored or
    /// conflicted — the pipeline's repair pass finishes the job. No
    /// finished assertion.
    pub fn abort_colors(self) -> ColorState {
        match (self.colors, self.inner) {
            (Some(c), _) => c,
            (None, Some(fw)) => fw.abort_colors(),
            (None, None) => unreachable!("colors are always held here or in the rerun"),
        }
    }

    /// Whether the next [`step_once`](Self::step_once) slice can run
    /// without a blocking-receive miss (see
    /// [`FrameworkStep::ready`]).
    pub fn ready(&mut self, ep: &mut Endpoint) -> bool {
        match self.state {
            ArcState::KReduce | ArcState::SizesReduce | ArcState::PostKReduce => {
                ep.rank != 0
                    || (1..self.lg.nprocs)
                        .all(|p| ep.have_msg(p, MsgKind::Collective, self.coll_seq, 0))
            }
            ArcState::KFinish | ArcState::SizesFinish | ArcState::PostKFinish => {
                ep.rank == 0 || ep.have_msg(0, MsgKind::Collective, self.coll_seq, 1)
            }
            ArcState::Rerun => self.inner.as_mut().expect("framework rerun").ready(ep),
            _ => true,
        }
    }

    /// Run one engine step; `true` once the machine reached `Finished`.
    pub fn step_once(&mut self, ep: &mut Endpoint) -> bool {
        let lg = self.lg;
        let n_owned = lg.n_owned();
        match self.state {
            ArcState::IterBegin => {
                if self.iter > self.iterations {
                    self.state = ArcState::Finished;
                } else {
                    self.t0 = ep.clock;
                    let colors = self.colors.as_ref().expect("colors held outside reruns");
                    let local_k = (0..n_owned)
                        .map(|v| colors.colors[v])
                        .filter(|&c| c != UNCOLORED)
                        .map(|c| c as u64 + 1)
                        .max()
                        .unwrap_or(0);
                    self.coll_acc = local_k;
                    self.coll_seq = ep.coll_send_u64(local_k);
                    self.state = ArcState::KReduce;
                }
            }
            ArcState::KReduce => {
                if ep.rank == 0 {
                    self.coll_acc = ep.coll_reduce_u64(self.coll_seq, self.coll_acc, u64::max);
                }
                self.state = ArcState::KFinish;
            }
            ArcState::KFinish => {
                self.k = ep.coll_finish_u64(self.coll_seq, self.coll_acc) as usize;
                if self.k == 0 {
                    // the blocking helper returns early on an empty
                    // palette; the pipeline loop still runs its
                    // post-iteration allreduce, trace entry and event
                    self.state = ArcState::PostKSend;
                } else {
                    let colors = self.colors.as_ref().expect("colors held outside reruns");
                    self.sizes.clear();
                    self.sizes.resize(self.k, 0);
                    for v in 0..n_owned {
                        let c = colors.colors[v];
                        if c != UNCOLORED {
                            self.sizes[c as usize] += 1;
                        }
                    }
                    self.coll_seq = ep.coll_send_vec_u64(&self.sizes);
                    self.state = ArcState::SizesReduce;
                }
            }
            ArcState::SizesReduce => {
                if ep.rank == 0 {
                    ep.coll_reduce_vec_u64(self.coll_seq, &mut self.sizes);
                }
                self.state = ArcState::SizesFinish;
            }
            ArcState::SizesFinish => {
                ep.coll_finish_vec_u64(self.coll_seq, &mut self.sizes);
                let k = self.k;
                let sizes_usize: Vec<usize> = self.sizes.iter().map(|&s| s as usize).collect();
                let mut prng = perm_rng(self.seed, self.iter);
                let class_order = self.perm.permute_classes(&sizes_usize, &mut prng);

                // owned visit order: classes in permuted order, ascending
                // ids within — as the blocking helper builds it
                let colors = self.colors.as_mut().expect("colors held outside reruns");
                let mut local_counts = vec![0usize; k];
                let mut n_colored = 0usize;
                for v in 0..n_owned {
                    let c = colors.colors[v];
                    if c != UNCOLORED {
                        local_counts[c as usize] += 1;
                        n_colored += 1;
                    }
                }
                let mut start = vec![0usize; k];
                let mut a = 0usize;
                for &c in &class_order {
                    start[c as usize] = a;
                    a += local_counts[c as usize];
                }
                let mut order = vec![0u32; n_colored];
                let mut cur = start;
                for v in 0..n_owned {
                    let c = colors.colors[v];
                    if c != UNCOLORED {
                        order[cur[c as usize]] = v as u32;
                        cur[c as usize] += 1;
                    }
                }
                ep.clock += self.cost.color_cost(n_owned as u64, 0);

                // speculative rerun from scratch with first-fit
                for c in colors.colors.iter_mut() {
                    *c = UNCOLORED;
                }
                let mut fw2 = self.fw;
                fw2.selection = Selection::FirstFit;
                fw2.seed = mix64(self.seed, 0xA12C ^ self.iter as u64);
                let colors = self.colors.take().expect("colors held outside reruns");
                self.inner = Some(FrameworkStep::new(
                    lg,
                    &fw2,
                    &self.cost,
                    colors,
                    Vec::new(),
                    Some(order),
                    self.obs,
                ));
                self.state = ArcState::Rerun;
            }
            ArcState::Rerun => {
                if self.inner.as_mut().expect("framework rerun").step_once(ep) {
                    let (colors, fm) = self.inner.take().expect("framework rerun").into_parts();
                    self.colors = Some(colors);
                    self.m.conflicts += fm.conflicts;
                    self.m.rounds += fm.rounds;
                    // same bookkeeping as recolor_process_async: keep the
                    // rerun's phase breakdown, then the "recolor" bucket
                    self.m.phases.merge(&fm.phases);
                    self.m.phases.add("recolor", ep.clock - self.t0);
                    self.state = ArcState::PostKSend;
                }
            }
            ArcState::PostKSend => {
                // the pipeline's post-iteration allreduce, booked under
                // "comm" (framework::comm_timed in the thread path)
                self.comm_t0 = ep.clock;
                let colors = self.colors.as_ref().expect("colors held outside reruns");
                let local_kmax = (0..n_owned)
                    .map(|v| colors.colors[v] as u64 + 1)
                    .max()
                    .unwrap_or(0);
                self.coll_acc = local_kmax;
                self.coll_seq = ep.coll_send_u64(local_kmax);
                self.state = ArcState::PostKReduce;
            }
            ArcState::PostKReduce => {
                if ep.rank == 0 {
                    self.coll_acc = ep.coll_reduce_u64(self.coll_seq, self.coll_acc, u64::max);
                }
                self.state = ArcState::PostKFinish;
            }
            ArcState::PostKFinish => {
                let kk = ep.coll_finish_u64(self.coll_seq, self.coll_acc) as usize;
                self.m.phases.add("comm", ep.clock - self.comm_t0);
                let prev = *self.trace.last().unwrap_or(&self.prev_k);
                self.trace.push(kk);
                emit_rank0(
                    self.obs,
                    ep.rank,
                    Event::RecolorIteration {
                        iter: self.iter,
                        k: kk,
                    },
                );
                let mut stop = false;
                if let Some(eps) = self.early_stop {
                    // prev and kk come from allreduces: every process
                    // stops at the same iteration
                    let improvement = (prev as f64 - kk as f64) / (prev as f64).max(1.0);
                    if improvement < eps {
                        stop = true;
                    }
                }
                if stop {
                    self.state = ArcState::Finished;
                } else {
                    self.iter += 1;
                    self.state = ArcState::IterBegin;
                }
            }
            ArcState::Finished => {}
        }
        self.is_finished()
    }
}

impl crate::dist::engine::StepProcess for AsyncRcStep<'_> {
    fn poll_ready(&mut self, ep: &mut Endpoint) -> bool {
        self.ready(ep)
    }

    /// Standalone use on the engine: once finished, the result carries the
    /// endpoint's cumulative accounting and the trace (in
    /// `metrics.recolor_trace`), as a thread-runner closure wrapping the
    /// pipeline's aRC loop would report.
    fn step(&mut self, ep: &mut Endpoint) -> crate::dist::engine::StepOutcome {
        use crate::dist::engine::StepOutcome;
        if !self.step_once(ep) {
            return StepOutcome::Running;
        }
        let colors = self
            .colors
            .take()
            .expect("colors held outside reruns");
        let mut metrics = std::mem::take(&mut self.m);
        metrics.recolor_trace = std::mem::take(&mut self.trace);
        metrics.vtime = ep.clock;
        metrics.sent_msgs = ep.sent_msgs;
        metrics.sent_bytes = ep.sent_bytes;
        metrics.recv_msgs = ep.recv_msgs;
        metrics.dropped_msgs = ep.dropped_msgs;
        metrics.non_teardown_drops = ep.non_teardown_drops;
        StepOutcome::Done(crate::dist::ProcResult {
            colors: colors.owned_pairs(self.lg),
            metrics,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::color::{greedy_color, Coloring, Ordering};
    use crate::dist::cost::NetworkModel;
    use crate::dist::proc::build_local_graphs;
    use crate::dist::DistMetrics;
    use crate::graph::synth;
    use crate::graph::CsrGraph;
    use crate::partition::{self, Partitioner};

    fn run(
        g: &CsrGraph,
        init: &Coloring,
        procs: usize,
        scheme: CommScheme,
    ) -> (Coloring, DistMetrics, Vec<usize>) {
        let part = partition::partition(g, Partitioner::Block, procs, 1);
        let (_, locals) = build_local_graphs(g, &part);
        let cost = CostModel::fixed();
        let eps = comm::network(procs, NetworkModel::default());
        let cfg = RecolorConfig {
            scheme,
            ..Default::default()
        };
        let mut outs: Vec<Option<(Vec<(u32, u32)>, Vec<usize>, ProcMetrics)>> =
            (0..procs).map(|_| None).collect();
        std::thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .zip(locals.iter())
                .map(|(ep, lg)| {
                    let cost = &cost;
                    let cfg = &cfg;
                    s.spawn(move || {
                        let mut ep = ep;
                        let mut state = ColorState::from_global(lg, init);
                        let mut trace = Vec::new();
                        let m = recolor_process_sync(
                            &mut ep, lg, cost, cfg, &mut state, &mut trace, None,
                        );
                        (state.owned_pairs(lg), trace, m)
                    })
                })
                .collect();
            for (i, h) in hs.into_iter().enumerate() {
                outs[i] = Some(h.join().unwrap());
            }
        });
        let mut coloring = Coloring::uncolored(g.num_vertices());
        let mut per = Vec::new();
        let mut trace = Vec::new();
        for (pairs, t, m) in outs.into_iter().map(|o| o.unwrap()) {
            for (gid, c) in pairs {
                coloring.set(gid, c);
            }
            trace = t;
            per.push(m);
        }
        (coloring, DistMetrics::aggregate(&per, 0.0), trace)
    }

    fn workload() -> (CsrGraph, Coloring) {
        let g = synth::fem_like(800, 10.0, 26, 0.01, 5, "fem");
        let init = greedy_color(&g, Ordering::Natural, crate::color::Selection::RandomX(8), 3);
        (g, init)
    }

    #[test]
    fn plan_matches_base_schedule_and_has_no_empty_steps() {
        let (g, init) = workload();
        let part = partition::partition(&g, Partitioner::Block, 4, 1);
        let (_, locals) = build_local_graphs(&g, &part);
        let k = init.num_colors();
        // identity permutation for a direct schedule comparison
        let step_of_class: Vec<u32> = (0..k as u32).collect();
        for lg in &locals {
            let old: Vec<u32> = lg.global_ids[..lg.n_owned()]
                .iter()
                .map(|&v| init.get(v))
                .collect();
            let plans = build_plans(lg, &old, &step_of_class);
            assert_eq!(plans.len(), lg.neighbor_procs.len());
            for (qi, plan) in plans.iter().enumerate() {
                // sorted, unique, in range
                assert!(plan.windows(2).all(|w| w[0] < w[1]));
                assert!(plan.iter().all(|&t| (t as usize) < k));
                // a step is planned iff the base scheme would have data:
                // some send-list member's old class maps to that step
                let base_nonempty: Vec<u32> = {
                    let mut s: Vec<u32> = lg.send_lists[qi]
                        .iter()
                        .map(|&v| step_of_class[old[v as usize] as usize])
                        .collect();
                    s.sort_unstable();
                    s.dedup();
                    s
                };
                assert_eq!(plan, &base_nonempty, "deadline bookkeeping drifted");
            }
        }
    }

    #[test]
    fn piggyback_never_sends_empty_data_messages() {
        // base sends pairs*k data messages; piggyback exactly the nonempty
        // schedule + one plan message per pair — strictly fewer whenever
        // any (pair, class) combination is empty.
        let (g, init) = workload();
        let (cb, mb, _) = run(&g, &init, 5, CommScheme::Base);
        let (cp, mp, _) = run(&g, &init, 5, CommScheme::Piggyback);
        assert_eq!(cb.colors, cp.colors, "schemes must agree exactly");
        cb.validate(&g).unwrap();
        let part = partition::partition(&g, Partitioner::Block, 5, 1);
        let (_, locals) = build_local_graphs(&g, &part);
        let pairs: u64 = locals.iter().map(|l| l.neighbor_procs.len() as u64).sum();
        let k = init.num_colors() as u64;
        assert!(mb.total_msgs >= pairs * k, "base sends every (pair, class)");
        // nonempty data steps, computed independently from the plans
        let step_of_class: Vec<u32> = {
            let sizes = init.class_sizes();
            let mut prng = perm_rng(42, 1);
            let order = Permutation::NonDecreasing.permute_classes(&sizes, &mut prng);
            let mut inv = vec![0u32; sizes.len()];
            for (t, &c) in order.iter().enumerate() {
                inv[c as usize] = t as u32;
            }
            inv
        };
        let mut nonempty: u64 = 0;
        for lg in &locals {
            let old: Vec<u32> = lg.global_ids[..lg.n_owned()]
                .iter()
                .map(|&v| init.get(v))
                .collect();
            nonempty += build_plans(lg, &old, &step_of_class)
                .iter()
                .map(|p| p.len() as u64)
                .sum::<u64>();
        }
        let collectives = mb.total_msgs - pairs * k;
        assert_eq!(
            mp.total_msgs,
            nonempty + pairs + collectives,
            "piggyback = nonempty data + one plan per pair + collectives"
        );
        assert!(mp.total_msgs < mb.total_msgs);
    }

    #[test]
    fn multi_iteration_schemes_agree() {
        let (g, init) = workload();
        let part = partition::partition(&g, Partitioner::Block, 3, 1);
        let (_, locals) = build_local_graphs(&g, &part);
        let cost = CostModel::fixed();
        let mut results = Vec::new();
        for scheme in [CommScheme::Base, CommScheme::Piggyback] {
            let cfg = RecolorConfig {
                iterations: 4,
                scheme,
                ..Default::default()
            };
            let eps = comm::network(3, NetworkModel::ideal());
            let mut outs: Vec<Option<(Vec<(u32, u32)>, Vec<usize>)>> = vec![None, None, None];
            std::thread::scope(|s| {
                let hs: Vec<_> = eps
                    .into_iter()
                    .zip(locals.iter())
                    .map(|(ep, lg)| {
                        let cost = &cost;
                        let cfg = &cfg;
                        let init = &init;
                        s.spawn(move || {
                            let mut ep = ep;
                            let mut state = ColorState::from_global(lg, init);
                            let mut trace = Vec::new();
                            recolor_process_sync(
                                &mut ep, lg, cost, cfg, &mut state, &mut trace, None,
                            );
                            (state.owned_pairs(lg), trace)
                        })
                    })
                    .collect();
                for (i, h) in hs.into_iter().enumerate() {
                    outs[i] = Some(h.join().unwrap());
                }
            });
            let mut coloring = Coloring::uncolored(g.num_vertices());
            let mut trace = Vec::new();
            for (pairs, t) in outs.into_iter().map(|o| o.unwrap()) {
                for (gid, c) in pairs {
                    coloring.set(gid, c);
                }
                trace = t;
            }
            assert_eq!(trace.len(), 4);
            assert!(trace.windows(2).all(|w| w[1] <= w[0]), "monotone: {trace:?}");
            results.push((coloring, trace));
        }
        assert_eq!(results[0].0.colors, results[1].0.colors);
        assert_eq!(results[0].1, results[1].1);
        results[0].0.validate(&g).unwrap();
    }

    /// The step-machine port must match `recolor_process_sync` bit for
    /// bit on both schemes: colors, traces, per-proc counters and clocks.
    #[test]
    fn sync_rc_step_machine_matches_thread_runner_bit_for_bit() {
        use crate::dist::{engine, runner};
        let (g, init) = workload();
        for (procs, scheme, iters, early_stop) in [
            (1usize, CommScheme::Piggyback, 2u32, None),
            (4, CommScheme::Base, 3, None),
            (5, CommScheme::Piggyback, 3, None),
            (3, CommScheme::Piggyback, 6, Some(0.02)),
        ] {
            let part = partition::partition(&g, Partitioner::Block, procs, 1);
            let (_, locals) = build_local_graphs(&g, &part);
            let cost = CostModel::fixed();
            let net = NetworkModel::default();
            let cfg = RecolorConfig {
                iterations: iters,
                scheme,
                early_stop,
                ..Default::default()
            };
            let by_threads = runner::run_distributed_with(&g, &locals, net, |ep, lg| {
                let mut state = ColorState::from_global(lg, &init);
                let mut trace = Vec::new();
                let mut m =
                    recolor_process_sync(ep, lg, &cost, &cfg, &mut state, &mut trace, None);
                m.recolor_trace = trace;
                crate::dist::ProcResult {
                    colors: state.owned_pairs(lg),
                    metrics: m,
                }
            });
            let by_engine = engine::run_steps(g.num_vertices(), &locals, net, |lg| {
                SyncRcStep::new(lg, &cost, cfg, ColorState::from_global(lg, &init), None)
            });
            assert_eq!(
                by_threads.coloring.colors, by_engine.coloring.colors,
                "colors diverged (procs={procs} scheme={scheme:?})"
            );
            for (a, b) in by_threads.per_proc.iter().zip(by_engine.per_proc.iter()) {
                assert_eq!(a.recolor_trace, b.recolor_trace, "p{} trace", a.rank);
                assert_eq!(a.sent_msgs, b.sent_msgs, "p{} msgs", a.rank);
                assert_eq!(a.sent_bytes, b.sent_bytes, "p{} bytes", a.rank);
                assert_eq!(a.recv_msgs, b.recv_msgs, "p{} recvs", a.rank);
                assert_eq!(
                    a.vtime.to_bits(),
                    b.vtime.to_bits(),
                    "p{} virtual clock diverged (procs={procs} scheme={scheme:?})",
                    a.rank
                );
                assert_eq!(a.dropped_msgs, 0);
                assert_eq!(b.dropped_msgs, 0);
            }
        }
    }

    /// The aRC step-machine port must match the pipeline's thread-path
    /// loop (recolor_process_async + post-iteration allreduce) bit for
    /// bit: colors, traces, per-proc counters and clocks — across
    /// permutation schedules, iteration counts and the early-stop knob.
    #[test]
    fn async_rc_step_machine_matches_thread_runner_bit_for_bit() {
        use crate::dist::{engine, runner};
        let (g, init) = workload();
        let seed = 42u64;
        // the early-stop baseline the pipeline would pass (its initial
        // trace entry)
        let init_k = init.num_colors();
        for (procs, perm, iters, early_stop) in [
            (1usize, Permutation::NonDecreasing, 2u32, None),
            (4, Permutation::NonDecreasing, 3, None),
            (5, Permutation::NonIncreasing, 2, None),
            (3, Permutation::Reverse, 4, Some(0.05)),
        ] {
            let part = partition::partition(&g, Partitioner::Block, procs, 1);
            let (_, locals) = build_local_graphs(&g, &part);
            let cost = CostModel::fixed();
            let net = NetworkModel::default();
            let fw = FrameworkConfig {
                ordering: crate::color::Ordering::InternalFirst,
                selection: Selection::RandomX(8),
                superstep_size: 64,
                sync: true,
                seed,
                max_rounds: 200,
            };
            let by_threads = runner::run_distributed_with(&g, &locals, net, |ep, lg| {
                let mut state = ColorState::from_global(lg, &init);
                let mut m = ProcMetrics {
                    rank: ep.rank,
                    ..Default::default()
                };
                let mut trace = Vec::new();
                for iter in 1..=iters {
                    let im = recolor_process_async(
                        ep, lg, &cost, &fw, perm, iter, seed, &mut state, None,
                    );
                    m.phases.merge(&im.phases);
                    m.conflicts += im.conflicts;
                    m.rounds += im.rounds;
                    let local_kmax = (0..lg.n_owned())
                        .map(|v| state.colors[v] as u64 + 1)
                        .max()
                        .unwrap_or(0);
                    let k = framework::comm_timed(ep, &mut m, |ep| {
                        ep.allreduce_max_u64(local_kmax)
                    });
                    let prev = *trace.last().unwrap_or(&init_k);
                    trace.push(k as usize);
                    if let Some(eps) = early_stop {
                        let improvement = (prev as f64 - k as f64) / (prev as f64).max(1.0);
                        if improvement < eps {
                            break;
                        }
                    }
                }
                m.recolor_trace = trace;
                m.vtime = ep.clock;
                m.sent_msgs = ep.sent_msgs;
                m.sent_bytes = ep.sent_bytes;
                m.recv_msgs = ep.recv_msgs;
                m.dropped_msgs = ep.dropped_msgs;
                m.non_teardown_drops = ep.non_teardown_drops;
                crate::dist::ProcResult {
                    colors: state.owned_pairs(lg),
                    metrics: m,
                }
            });
            let by_engine = engine::run_steps(g.num_vertices(), &locals, net, |lg| {
                AsyncRcStep::new(
                    lg,
                    &cost,
                    &fw,
                    perm,
                    iters,
                    seed,
                    early_stop,
                    init_k,
                    ColorState::from_global(lg, &init),
                    None,
                )
            });
            assert_eq!(
                by_threads.coloring.colors, by_engine.coloring.colors,
                "colors diverged (procs={procs} perm={perm:?})"
            );
            for (a, b) in by_threads.per_proc.iter().zip(by_engine.per_proc.iter()) {
                assert_eq!(a.recolor_trace, b.recolor_trace, "p{} trace", a.rank);
                assert_eq!(a.conflicts, b.conflicts, "p{} conflicts", a.rank);
                assert_eq!(a.rounds, b.rounds, "p{} rounds", a.rank);
                assert_eq!(a.sent_msgs, b.sent_msgs, "p{} msgs", a.rank);
                assert_eq!(a.sent_bytes, b.sent_bytes, "p{} bytes", a.rank);
                assert_eq!(a.recv_msgs, b.recv_msgs, "p{} recvs", a.rank);
                assert_eq!(
                    a.vtime.to_bits(),
                    b.vtime.to_bits(),
                    "p{} virtual clock diverged (procs={procs} perm={perm:?})",
                    a.rank
                );
                assert_eq!(a.dropped_msgs, 0);
                assert_eq!(b.dropped_msgs, 0);
            }
        }
    }

    #[test]
    fn comm_scheme_parses() {
        assert_eq!("base".parse::<CommScheme>().unwrap(), CommScheme::Base);
        assert_eq!(
            "piggyback".parse::<CommScheme>().unwrap(),
            CommScheme::Piggyback
        );
        assert!("x".parse::<CommScheme>().is_err());
    }
}
