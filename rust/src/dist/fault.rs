//! Seeded, deterministic fault injection for the simulated transport.
//!
//! A [`FaultPlan`] describes which faults to inject into a run: per-message
//! delivery delays, per-message reordering (sender-side hold-back until the
//! supervisor flushes), per-transmission message **loss** (covered by the
//! reliable-delivery layer in [`comm`](crate::dist::comm)), and any number
//! of crash-stops — multiple ranks, repeat crashes of the same rank — at
//! engine supersteps. Every decision is a **pure function of the plan seed
//! and the message identity** `(from, to, kind, round, seq)` (plus the
//! transmission attempt, for loss — retransmissions of the same message
//! re-flip the coin) — never of wall-clock time, scheduling, or any
//! mutable RNG state — so the same plan injects the same faults into the
//! same run twice, regardless of thread interleaving. That is what makes
//! recovery traces replayable and the chaos property tests
//! (`rust/tests/fault_injection.rs`) meaningful.
//!
//! `FaultPlan::none()` is the default everywhere; every consumer gates its
//! fault branches on [`FaultPlan::is_active`], so a fault-free run takes
//! bit-for-bit the same path it took before this module existed (pinned by
//! the accounting fixture). The reliable-delivery layer has its own,
//! stricter gate — [`FaultPlan::reliable`] — so even an active plan
//! without loss (and without interval checkpointing) keeps sequence-free
//! envelopes and the exact pre-reliability accounting.

use crate::dist::comm::MsgKind;
use crate::util::error::Result;
use crate::util::rng::mix64;
use crate::{bail, err};

/// Crash-stop of one process: at the start of engine superstep `step` the
/// process goes down (it does not execute that step) and stays down for
/// `down_steps` supersteps before the supervisor restarts it from its last
/// periodic checkpoint. A crash whose step passes while the rank is
/// already down (or after the rank finished) is coalesced — it never
/// fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Crash {
    pub rank: u32,
    pub step: u64,
    /// Supersteps the process stays down before restarting (≥ 1).
    pub down_steps: u64,
}

/// Default downtime of a `crash=r@s` spec without an explicit `+d` suffix.
pub const DEFAULT_DOWN_STEPS: u64 = 2;

/// Default checkpoint cadence: every engine step, the pre-interval
/// behavior.
pub const DEFAULT_CHECKPOINT_INTERVAL: u64 = 1;

/// A seeded, deterministic plan of transport faults. See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seeds the per-message delay/reorder/loss coins.
    pub seed: u64,
    /// Probability that a message's arrival is delayed by `delay_secs`.
    pub delay_prob: f64,
    /// Virtual seconds added to a delayed message's arrival time.
    pub delay_secs: f64,
    /// Probability that a message is held back at the sender until the
    /// supervisor flushes (delivered out of program order).
    pub reorder_prob: f64,
    /// Probability that one wire transmission of a message is lost.
    /// Nonzero loss activates the reliable-delivery layer (sequence
    /// numbers, acks, retransmission) in every endpoint.
    pub loss_prob: f64,
    /// Crash-stops, in any order; multiple ranks and repeat crashes of the
    /// same rank are allowed.
    pub crashes: Vec<Crash>,
    /// The supervised engine checkpoints every live rank whenever
    /// `step % checkpoint_interval == 0` (so step 0 is always covered).
    /// `1` (the default) is the original per-step cadence; larger
    /// intervals make revived ranks *replay* the steps since their last
    /// checkpoint, relying on receiver-side dedup to absorb the replayed
    /// sends — which is why an interval > 1 with crashes also activates
    /// the reliable layer.
    pub checkpoint_interval: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The inert plan: no faults, zero behavior change anywhere.
    pub const fn none() -> Self {
        FaultPlan {
            seed: 0,
            delay_prob: 0.0,
            delay_secs: 0.0,
            reorder_prob: 0.0,
            loss_prob: 0.0,
            crashes: Vec::new(),
            checkpoint_interval: DEFAULT_CHECKPOINT_INTERVAL,
        }
    }

    /// Whether any fault can fire. Every fault branch in the runtime is
    /// gated on this, keeping the fault-free fast path untouched.
    pub fn is_active(&self) -> bool {
        self.delay_prob > 0.0
            || self.reorder_prob > 0.0
            || self.loss_prob > 0.0
            || !self.crashes.is_empty()
    }

    /// Whether the reliable-delivery layer (sequence-numbered envelopes,
    /// cumulative acks, retransmission, receiver dedup) must be active.
    /// True under loss — messages can vanish from the wire — and under
    /// interval checkpointing with crashes, where a revived rank *replays*
    /// steps and its re-sent messages must be absorbed by dedup. Loss-free
    /// per-step-checkpoint plans keep the layer fully inert, so their
    /// accounting is bit-for-bit the pre-reliability transport's.
    pub fn reliable(&self) -> bool {
        self.loss_prob > 0.0 || (self.checkpoint_interval > 1 && !self.crashes.is_empty())
    }

    /// The earliest crash scheduled for `rank` at or after `from_step`,
    /// if any — the supervised engine's per-rank crash cursor.
    pub fn next_crash_for(&self, rank: usize, from_step: u64) -> Option<Crash> {
        self.crashes
            .iter()
            .filter(|c| c.rank as usize == rank && c.step >= from_step)
            .min_by_key(|c| c.step)
            .copied()
    }

    /// A uniform coin in `[0, 1)` for one (fault-kind, message) pair —
    /// stateless, so decisions are independent of delivery interleaving.
    fn coin(&self, salt: u64, from: usize, to: usize, kind: MsgKind, round: u32, seq: u32) -> f64 {
        let mut h = mix64(self.seed, salt);
        h = mix64(h, ((from as u64) << 32) | to as u64);
        h = mix64(h, ((kind as u64) << 48) | ((round as u64) << 16) | seq as u64);
        (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Virtual-time delay to add to this message's arrival, if any.
    pub fn delay_of(
        &self,
        from: usize,
        to: usize,
        kind: MsgKind,
        round: u32,
        seq: u32,
    ) -> Option<f64> {
        if self.delay_prob > 0.0 && self.coin(0xDE1A, from, to, kind, round, seq) < self.delay_prob
        {
            Some(self.delay_secs)
        } else {
            None
        }
    }

    /// Whether this message is held back at the sender (reordered).
    pub fn reorders(&self, from: usize, to: usize, kind: MsgKind, round: u32, seq: u32) -> bool {
        self.reorder_prob > 0.0 && self.coin(0x2E0D, from, to, kind, round, seq) < self.reorder_prob
    }

    /// Whether transmission `attempt` (1-based) of this message is lost on
    /// the wire. The attempt number is mixed into the coin, so each
    /// retransmission re-flips it independently — a finite retry budget
    /// eventually gets any message through under any loss < 1.
    pub fn loses(
        &self,
        from: usize,
        to: usize,
        kind: MsgKind,
        round: u32,
        seq: u32,
        attempt: u32,
    ) -> bool {
        self.loss_prob > 0.0
            && self.coin(0x105E ^ ((attempt as u64) << 32), from, to, kind, round, seq)
                < self.loss_prob
    }

    /// Parse a `--faults` spec: comma-separated `key=value` pairs.
    ///
    /// * `seed=N` — coin seed (default 1)
    /// * `delay=P` — delay probability in `[0, 1]`
    /// * `delay-secs=S` — delay magnitude in virtual seconds (default 1e-4)
    /// * `reorder=P` — hold-back probability in `[0, 1]`
    /// * `loss=P` — per-transmission loss probability in `[0, 1)` (1.0
    ///   would defeat retransmission by construction)
    /// * `crash=R@S` or `crash=R@S+D` — crash rank R at engine step S,
    ///   down for D steps (default [`DEFAULT_DOWN_STEPS`]); may be
    ///   repeated to crash several ranks or the same rank again
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan {
            seed: 1,
            delay_secs: 1e-4,
            ..FaultPlan::none()
        };
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| err!("--faults: expected key=value, got {part:?}"))?;
            match key {
                "seed" => plan.seed = val.parse().map_err(|e| err!("--faults seed: {e}"))?,
                "delay" => {
                    plan.delay_prob = parse_prob("delay", val)?;
                }
                "delay-secs" | "delay_secs" => {
                    plan.delay_secs = val.parse().map_err(|e| err!("--faults delay-secs: {e}"))?;
                }
                "reorder" => {
                    plan.reorder_prob = parse_prob("reorder", val)?;
                }
                "loss" => {
                    let p = parse_prob("loss", val)?;
                    if p >= 1.0 {
                        bail!("--faults loss: probability must be < 1 (no retry can beat loss=1)");
                    }
                    plan.loss_prob = p;
                }
                "crash" => {
                    let (rank, rest) = val
                        .split_once('@')
                        .ok_or_else(|| err!("--faults crash: expected R@S, got {val:?}"))?;
                    let (step, down) = match rest.split_once('+') {
                        Some((s, d)) => (
                            s.parse().map_err(|e| err!("--faults crash step: {e}"))?,
                            d.parse().map_err(|e| err!("--faults crash downtime: {e}"))?,
                        ),
                        None => (
                            rest.parse().map_err(|e| err!("--faults crash step: {e}"))?,
                            DEFAULT_DOWN_STEPS,
                        ),
                    };
                    if down == 0 {
                        bail!("--faults crash: downtime must be >= 1 step");
                    }
                    plan.crashes.push(Crash {
                        rank: rank.parse().map_err(|e| err!("--faults crash rank: {e}"))?,
                        step,
                        down_steps: down,
                    });
                }
                other => bail!(
                    "--faults: unknown key {other:?} (seed|delay|delay-secs|reorder|loss|crash)"
                ),
            }
        }
        if !plan.is_active() {
            bail!("--faults: spec {spec:?} enables no fault (set delay=, reorder=, loss= or crash=)");
        }
        Ok(plan)
    }

    /// Short label fragment for config labels and logs; empty when inert
    /// so fault-free labels are unchanged.
    pub fn label(&self) -> String {
        if !self.is_active() {
            return String::new();
        }
        let mut parts = vec![format!("seed={}", self.seed)];
        if self.delay_prob > 0.0 {
            parts.push(format!("delay={}", self.delay_prob));
        }
        if self.reorder_prob > 0.0 {
            parts.push(format!("reorder={}", self.reorder_prob));
        }
        if self.loss_prob > 0.0 {
            parts.push(format!("loss={}", self.loss_prob));
        }
        for c in &self.crashes {
            parts.push(format!("crash={}@{}", c.rank, c.step));
        }
        if self.checkpoint_interval > 1 {
            parts.push(format!("ckpt={}", self.checkpoint_interval));
        }
        format!("+faults[{}]", parts.join(","))
    }
}

fn parse_prob(key: &str, val: &str) -> Result<f64> {
    let p: f64 = val.parse().map_err(|e| err!("--faults {key}: {e}"))?;
    if !(0.0..=1.0).contains(&p) {
        bail!("--faults {key}: probability {p} outside [0, 1]");
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_inert_and_default() {
        let p = FaultPlan::none();
        assert!(!p.is_active());
        assert!(!p.reliable());
        assert_eq!(p, FaultPlan::default());
        assert_eq!(p.label(), "");
        assert_eq!(p.delay_of(0, 1, MsgKind::Colors, 3, 4), None);
        assert!(!p.reorders(0, 1, MsgKind::Colors, 3, 4));
        assert!(!p.loses(0, 1, MsgKind::Colors, 3, 4, 1));
        assert_eq!(p.checkpoint_interval, DEFAULT_CHECKPOINT_INTERVAL);
    }

    #[test]
    fn coins_are_deterministic_and_message_dependent() {
        let p = FaultPlan {
            seed: 7,
            delay_prob: 0.5,
            delay_secs: 1e-3,
            reorder_prob: 0.5,
            loss_prob: 0.5,
            ..FaultPlan::none()
        };
        // pure: same message, same answer
        for kind in [MsgKind::Colors, MsgKind::Recolor, MsgKind::Plan] {
            for round in 0..8 {
                assert_eq!(
                    p.delay_of(0, 1, kind, round, 0),
                    p.delay_of(0, 1, kind, round, 0)
                );
                assert_eq!(
                    p.reorders(1, 0, kind, round, 2),
                    p.reorders(1, 0, kind, round, 2)
                );
                assert_eq!(
                    p.loses(1, 0, kind, round, 2, 1),
                    p.loses(1, 0, kind, round, 2, 1)
                );
            }
        }
        // with p=0.5, some messages are hit and some are not
        let hits = (0..64)
            .filter(|&r| p.delay_of(0, 1, MsgKind::Colors, r, 0).is_some())
            .count();
        assert!(hits > 0 && hits < 64, "degenerate coin: {hits}/64");
        // a different seed flips some decisions
        let q = FaultPlan { seed: 8, ..p.clone() };
        assert!(
            (0..64).any(|r| p.reorders(0, 1, MsgKind::Colors, r, 0)
                != q.reorders(0, 1, MsgKind::Colors, r, 0)),
            "seed does not influence the coins"
        );
        // the attempt number re-flips the loss coin: a message lost on
        // attempt 1 is not doomed on every retransmission
        assert!(
            (0..64).any(|r| p.loses(0, 1, MsgKind::Colors, r, 0, 1)
                != p.loses(0, 1, MsgKind::Colors, r, 0, 2)),
            "attempt does not influence the loss coin"
        );
    }

    #[test]
    fn reliable_gate_is_loss_or_interval_with_crashes() {
        let lossy = FaultPlan {
            loss_prob: 0.1,
            ..FaultPlan::none()
        };
        assert!(lossy.is_active() && lossy.reliable());
        let crash = Crash { rank: 0, step: 1, down_steps: 1 };
        let per_step = FaultPlan {
            crashes: vec![crash],
            ..FaultPlan::none()
        };
        assert!(per_step.is_active());
        assert!(!per_step.reliable(), "interval=1 crash plans stay on the plain transport");
        let interval = FaultPlan {
            crashes: vec![crash],
            checkpoint_interval: 4,
            ..FaultPlan::none()
        };
        assert!(interval.reliable(), "replay after interval checkpoints needs dedup");
        let interval_no_crash = FaultPlan {
            checkpoint_interval: 4,
            delay_prob: 0.1,
            ..FaultPlan::none()
        };
        assert!(!interval_no_crash.reliable(), "no crash, nothing to replay");
    }

    #[test]
    fn next_crash_cursor_walks_multi_crash_plans() {
        let p = FaultPlan {
            crashes: vec![
                Crash { rank: 1, step: 8, down_steps: 2 },
                Crash { rank: 0, step: 3, down_steps: 1 },
                Crash { rank: 1, step: 2, down_steps: 2 },
            ],
            ..FaultPlan::none()
        };
        assert_eq!(p.next_crash_for(1, 0).unwrap().step, 2);
        assert_eq!(p.next_crash_for(1, 3).unwrap().step, 8);
        assert_eq!(p.next_crash_for(1, 9), None);
        assert_eq!(p.next_crash_for(0, 0).unwrap().step, 3);
        assert_eq!(p.next_crash_for(2, 0), None);
    }

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse(
            "seed=9,delay=0.25,delay-secs=0.002,reorder=0.1,loss=0.05,crash=2@5+3",
        )
        .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.delay_prob, 0.25);
        assert_eq!(p.delay_secs, 0.002);
        assert_eq!(p.reorder_prob, 0.1);
        assert_eq!(p.loss_prob, 0.05);
        assert_eq!(
            p.crashes,
            vec![Crash {
                rank: 2,
                step: 5,
                down_steps: 3
            }]
        );
        assert!(p.is_active());
        assert!(p.reliable());
        assert!(p.label().contains("crash=2@5"));
        assert!(p.label().contains("loss=0.05"));
    }

    #[test]
    fn parse_repeated_crashes_and_labels_each() {
        let p = FaultPlan::parse("seed=2,crash=1@4,crash=3@9+5,crash=1@20").unwrap();
        assert_eq!(
            p.crashes,
            vec![
                Crash { rank: 1, step: 4, down_steps: DEFAULT_DOWN_STEPS },
                Crash { rank: 3, step: 9, down_steps: 5 },
                Crash { rank: 1, step: 20, down_steps: DEFAULT_DOWN_STEPS },
            ]
        );
        let label = p.label();
        assert!(label.contains("crash=1@4"), "{label}");
        assert!(label.contains("crash=3@9"), "{label}");
        assert!(label.contains("crash=1@20"), "{label}");
    }

    #[test]
    fn parse_defaults_and_rejects() {
        let p = FaultPlan::parse("seed=3,crash=1@4").unwrap();
        assert_eq!(p.crashes[0].down_steps, DEFAULT_DOWN_STEPS);
        assert!(FaultPlan::parse("seed=3").is_err(), "no fault enabled");
        assert!(FaultPlan::parse("delay=1.5").is_err(), "prob out of range");
        assert!(FaultPlan::parse("loss=1.0").is_err(), "loss=1 defeats retries");
        assert!(FaultPlan::parse("loss=-0.1").is_err(), "negative loss");
        assert!(FaultPlan::parse("crash=1").is_err(), "missing @step");
        assert!(FaultPlan::parse("crash=1@2+0").is_err(), "zero downtime");
        assert!(FaultPlan::parse("bogus=1").is_err(), "unknown key");
        assert!(FaultPlan::parse("delay").is_err(), "missing value");
    }
}
