//! In-process message transport with exact per-endpoint accounting.
//!
//! `network(P, model)` builds `P` fully-connected [`Endpoint`]s over
//! unbounded channels; one OS thread drives each endpoint (see
//! [`runner`](crate::dist::runner)). Every send is counted (messages and
//! bytes, including a fixed per-message header) and charged to the sender's
//! virtual clock through the α-β [`NetworkModel`]; a synchronous receive
//! advances the receiver's clock to the message's arrival time, which is
//! how supersteps, collectives and the recoloring deadline protocol cost
//! virtual time. Matching is exact on `(from, kind, round, seq)` with an
//! out-of-order buffer, so processes may run arbitrarily far apart in real
//! time while the virtual schedule stays deterministic.
//!
//! Payload buffers are pooled per endpoint ([`Endpoint::take_buf`] /
//! [`Endpoint::send_from`] / [`Endpoint::recv_into`]): buffers ride the
//! messages that carry them and are recycled on receive, so steady-state
//! supersteps and collectives allocate nothing (DESIGN.md "Memory
//! discipline on hot paths"). Pooling never changes a modeled quantity —
//! `sent_msgs`, `sent_bytes` and the clocks are functions of payload
//! lengths only.

use crate::dist::cost::NetworkModel;
use crate::dist::fault::FaultPlan;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Fixed accounting overhead per message (envelope: kind/round/seq/len —
/// the reliable layer's link sequence number and cumulative ack ride in
/// this same fixed header).
pub const MSG_HEADER_BYTES: usize = 16;

/// Bounded-backoff attempts a receive makes under an active [`FaultPlan`]
/// before declaring itself starved (the fault-free paths never retry —
/// there the BSP invariant is a hard oracle).
const FAULT_RECV_RETRIES: usize = 16;

/// Wire transmissions the reliable layer attempts per message (the first
/// send included) before declaring the peer unreachable.
pub const MAX_SEND_ATTEMPTS: u32 = 12;

/// Exponential retransmission backoff in engine-step ticks, capped so a
/// long-lived entry still retries within a bounded window.
fn retry_backoff(attempt: u32) -> u64 {
    1u64 << attempt.min(6) // 2, 4, 8, ..., capped at 64 ticks
}

/// Upper bound on buffers a pool retains; beyond it returned buffers are
/// dropped so a burst (e.g. a serialized cleanup round) can't pin memory.
const POOL_MAX_BUFFERS: usize = 1024;

/// Free list of payload buffers. Buffers migrate with the messages that
/// carry them: a send takes from the sender's pool, `recv_into` returns the
/// transported buffer to the *receiver's* pool. Exchanges are symmetric
/// (every data/collective message is answered within a round), so after
/// warm-up each endpoint's pool is self-sustaining and steady-state sends
/// allocate nothing.
#[derive(Default)]
struct BufferPool {
    free: Vec<Vec<u8>>,
}

impl BufferPool {
    #[inline]
    fn take(&mut self) -> Vec<u8> {
        self.free.pop().unwrap_or_default()
    }

    #[inline]
    fn put(&mut self, mut buf: Vec<u8>) {
        if self.free.len() < POOL_MAX_BUFFERS {
            buf.clear();
            self.free.push(buf);
        }
    }
}

/// Message classes; part of the match key so phases can never steal each
/// other's traffic even when processes drift apart in real time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgKind {
    /// Boundary color updates of the superstep framework.
    Colors,
    /// Color-class updates of distributed recoloring.
    Recolor,
    /// Piggyback plan (per-pair nonempty-step schedule / deadlines).
    Plan,
    /// Internal collectives (allreduce / barrier).
    Collective,
    /// Standalone cumulative acknowledgment of the reliable layer —
    /// consumed at transport intake, never visible to a machine.
    Ack,
}

#[derive(Clone)]
struct Message {
    from: usize,
    kind: MsgKind,
    round: u32,
    seq: u32,
    payload: Vec<u8>,
    /// Sender's virtual clock when the message finished injecting — the
    /// earliest virtual time the receiver can observe it.
    arrival: f64,
    /// Per-(src,dst)-link sequence number of the reliable layer, 1-based;
    /// 0 marks unsequenced traffic (inert plans, self-sends, acks).
    link_seq: u64,
    /// Piggybacked cumulative ack: every link seq from this sender's peer
    /// up to and including `ack` has been received. 0 = nothing acked.
    ack: u64,
}

/// One unacknowledged reliable send, kept for retransmission until the
/// peer's cumulative ack covers its `link_seq`.
#[derive(Clone)]
struct Unacked {
    link_seq: u64,
    kind: MsgKind,
    round: u32,
    seq: u32,
    payload: Vec<u8>,
    /// Wire transmissions so far (1 after the original send).
    attempt: u32,
    /// Engine-step tick at/after which the next retransmission fires.
    next_retry: u64,
}

/// Restorable image of an endpoint's transport state, taken by the
/// supervised engine at periodic checkpoints. Covers the rank's *own*
/// modeled work — clock, send/receive accounting, fault counters, the
/// collective cursor — plus the reliable layer's **sender** state
/// (`next_link_seq`, the retransmit buffer), so a revived rank's replayed
/// sends reuse their original link sequence numbers and are absorbed by
/// receiver-side dedup at every peer. Receiver-side dedup state is
/// deliberately *not* part of the image: it is transport-level, not
/// machine-level — rolling it back would let retransmissions of
/// already-buffered messages through as duplicates.
#[derive(Clone)]
pub struct EndpointSnapshot {
    clock: f64,
    sent_msgs: u64,
    sent_bytes: u64,
    recv_msgs: u64,
    dropped_msgs: u64,
    non_teardown_drops: u64,
    injected_delays: u64,
    injected_reorders: u64,
    injected_losses: u64,
    retransmits: u64,
    acks_sent: u64,
    dup_discards: u64,
    coll_seq: u32,
    next_link_seq: Vec<u64>,
    unacked: Vec<VecDeque<Unacked>>,
}

/// One simulated process's communication endpoint.
pub struct Endpoint {
    pub rank: usize,
    pub nprocs: usize,
    pub model: NetworkModel,
    /// Virtual clock in seconds.
    pub clock: f64,
    pub sent_msgs: u64,
    pub sent_bytes: u64,
    pub recv_msgs: u64,
    /// `true` (synchronous): a receive waits — the clock advances to the
    /// arrival time. `false` (asynchronous): data is consumed without
    /// advancing the clock, modeling fully overlapped communication.
    pub wait_on_recv: bool,
    /// Messages whose receiver endpoint was already gone. Legal only during
    /// an acknowledged shutdown (`teardown`); anywhere else a drop means a
    /// protocol or pooling bug, so `send` debug-asserts it never happens.
    pub dropped_msgs: u64,
    /// Set by a caller that is intentionally racing its peers' shutdown;
    /// silences the dropped-message debug assertion.
    pub teardown: bool,
    /// Drops that happened with `teardown` unset — always a protocol bug.
    /// The debug assertion in `send` still fires in debug builds; release
    /// builds surface this counter as a typed error through the pipeline.
    pub non_teardown_drops: u64,
    /// The fault plan woven into this endpoint (inert by default).
    pub faults: FaultPlan,
    /// Messages whose arrival the plan delayed.
    pub injected_delays: u64,
    /// Messages the plan held back at the sender (reordered).
    pub injected_reorders: u64,
    /// Wire transmissions the plan lost (each charged like a real send —
    /// the injection cost was paid before the wire dropped it).
    pub injected_losses: u64,
    /// Reliable layer: retransmissions performed (beyond each message's
    /// first transmission), all charged to the α-β model.
    pub retransmits: u64,
    /// Reliable layer: standalone cumulative acks sent (piggybacked acks
    /// ride regular traffic for free).
    pub acks_sent: u64,
    /// Reliable layer: received duplicates discarded before delivery.
    pub dup_discards: u64,
    txs: Vec<Sender<Message>>,
    rx: Receiver<Message>,
    pending: VecDeque<Message>,
    /// Reordered messages held back until [`flush_held`](Endpoint::flush_held).
    held: Vec<(usize, Message)>,
    pool: BufferPool,
    /// Private staging for collective payloads (never escapes the endpoint).
    coll_buf: Vec<u8>,
    coll_seq: u32,
    /// Whether the reliable-delivery layer is active
    /// ([`FaultPlan::reliable`], computed once at construction). When
    /// false every reliable branch is skipped and the transport is
    /// bit-for-bit the pre-reliability one.
    reliable: bool,
    /// Current engine-step tick, advanced by [`reliable_sweep`]
    /// (retransmission timeouts are modeled in engine steps).
    ///
    /// [`reliable_sweep`]: Endpoint::reliable_sweep
    tick: u64,
    /// Sender state per peer: next link sequence number to assign (1-based).
    next_link_seq: Vec<u64>,
    /// Sender state per peer: sent-but-unacked entries, in link-seq order.
    unacked: Vec<VecDeque<Unacked>>,
    /// Receiver state per peer: highest link seq `n` with 1..=n all seen.
    cum_recv: Vec<u64>,
    /// Receiver state per peer: out-of-order link seqs above `cum_recv`,
    /// kept sorted.
    seen_ahead: Vec<Vec<u64>>,
    /// Receiver state per peer: a standalone ack is owed (a duplicate
    /// arrived, or fresh traffic advanced `cum_recv`).
    ack_owed: Vec<bool>,
    /// The highest cumulative ack actually transmitted to each peer.
    last_ack_sent: Vec<u64>,
    /// Identity counter for standalone acks (gives each its own loss coin).
    ack_seq: Vec<u32>,
    /// When set (interval checkpointing with crashes), every consumed
    /// message is logged so [`restore`](Endpoint::restore) can re-insert
    /// it into `pending` for deterministic replay.
    log_consumed: bool,
    consumed_log: Vec<Message>,
}

/// Build a fully-connected network of `procs` endpoints.
pub fn network(procs: usize, model: NetworkModel) -> Vec<Endpoint> {
    network_faulted(procs, model, FaultPlan::none())
}

/// [`network`] with a [`FaultPlan`] woven into every endpoint. With
/// `FaultPlan::none()` this is exactly `network` — every fault branch is
/// gated on [`FaultPlan::is_active`].
pub fn network_faulted(procs: usize, model: NetworkModel, faults: FaultPlan) -> Vec<Endpoint> {
    let mut txs = Vec::with_capacity(procs);
    let mut rxs = Vec::with_capacity(procs);
    for _ in 0..procs {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    let reliable = faults.reliable();
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| Endpoint {
            rank,
            nprocs: procs,
            model,
            clock: 0.0,
            sent_msgs: 0,
            sent_bytes: 0,
            recv_msgs: 0,
            wait_on_recv: true,
            dropped_msgs: 0,
            teardown: false,
            non_teardown_drops: 0,
            faults: faults.clone(),
            injected_delays: 0,
            injected_reorders: 0,
            injected_losses: 0,
            retransmits: 0,
            acks_sent: 0,
            dup_discards: 0,
            txs: txs.clone(),
            rx,
            pending: VecDeque::new(),
            held: Vec::new(),
            pool: BufferPool::default(),
            coll_buf: Vec::new(),
            coll_seq: 0,
            reliable,
            tick: 0,
            next_link_seq: if reliable { vec![1; procs] } else { Vec::new() },
            unacked: if reliable {
                (0..procs).map(|_| VecDeque::new()).collect()
            } else {
                Vec::new()
            },
            cum_recv: if reliable { vec![0; procs] } else { Vec::new() },
            seen_ahead: if reliable {
                (0..procs).map(|_| Vec::new()).collect()
            } else {
                Vec::new()
            },
            ack_owed: if reliable { vec![false; procs] } else { Vec::new() },
            last_ack_sent: if reliable { vec![0; procs] } else { Vec::new() },
            ack_seq: if reliable { vec![0; procs] } else { Vec::new() },
            log_consumed: false,
            consumed_log: Vec::new(),
        })
        .collect()
}

impl Endpoint {
    /// Send `payload` to `to`. Counted exactly; the sender's clock pays the
    /// α-β injection cost, which is also the receiver-visible arrival time.
    /// Under an active fault plan the message may additionally be delayed
    /// (later arrival) or held back at the sender (reordered) — the send
    /// cost and counters are charged either way.
    pub fn send(&mut self, to: usize, kind: MsgKind, round: u32, seq: u32, payload: Vec<u8>) {
        let bytes = payload.len() + MSG_HEADER_BYTES;
        self.sent_msgs += 1;
        self.sent_bytes += bytes as u64;
        self.clock += self.model.transfer_secs(bytes);
        let mut arrival = self.clock;
        let mut link_seq = 0u64;
        if self.reliable && to != self.rank {
            // sequence the envelope and park a copy for retransmission
            // until the peer's cumulative ack covers it
            link_seq = self.next_link_seq[to];
            self.next_link_seq[to] += 1;
            let mut copy = self.pool.take();
            copy.extend_from_slice(&payload);
            self.unacked[to].push_back(Unacked {
                link_seq,
                kind,
                round,
                seq,
                payload: copy,
                attempt: 1,
                next_retry: self.tick + retry_backoff(1),
            });
        }
        if self.faults.is_active() {
            if let Some(d) = self.faults.delay_of(self.rank, to, kind, round, seq) {
                arrival += d;
                self.injected_delays += 1;
            }
            if to != self.rank && self.faults.reorders(self.rank, to, kind, round, seq) {
                self.injected_reorders += 1;
                self.held.push((
                    to,
                    Message {
                        from: self.rank,
                        kind,
                        round,
                        seq,
                        payload,
                        arrival,
                        link_seq,
                        ack: 0,
                    },
                ));
                return;
            }
        }
        let msg = Message {
            from: self.rank,
            kind,
            round,
            seq,
            payload,
            arrival,
            link_seq,
            ack: 0,
        };
        if to == self.rank {
            self.pending.push_back(msg);
        } else {
            self.transmit(to, msg, 1);
        }
    }

    /// One wire transmission through the reliable layer: the loss coin is
    /// flipped **before** the ack bookkeeping, so a lost transmission never
    /// records its piggybacked ack as delivered. With the layer inert this
    /// is exactly [`put_on_wire`](Endpoint::put_on_wire).
    fn transmit(&mut self, to: usize, mut msg: Message, attempt: u32) {
        if self.reliable {
            if self
                .faults
                .loses(self.rank, to, msg.kind, msg.round, msg.seq, attempt)
            {
                self.injected_losses += 1;
                self.pool.put(msg.payload);
                return;
            }
            msg.ack = self.cum_recv[to];
            if msg.ack > self.last_ack_sent[to] {
                self.last_ack_sent[to] = msg.ack;
            }
            self.ack_owed[to] = false;
        }
        self.put_on_wire(to, msg);
    }

    /// Deliver a message to a peer's channel, accounting for a gone
    /// receiver: counted as sent (the wire cost was paid), and legal only
    /// during an acknowledged teardown.
    fn put_on_wire(&mut self, to: usize, msg: Message) {
        let kind = msg.kind;
        if self.txs[to].send(msg).is_err() {
            self.dropped_msgs += 1;
            if !self.teardown {
                self.non_teardown_drops += 1;
            }
            debug_assert!(
                self.teardown,
                "p{} dropped a {kind:?} message to p{to} outside teardown",
                self.rank
            );
        }
    }

    /// Put every held-back (reordered) message on the wire, in hold order;
    /// returns how many were released. The supervising engine calls this
    /// when progress stalls, so reordered messages arrive out of program
    /// order but are never lost.
    pub fn flush_held(&mut self) -> usize {
        let held = std::mem::take(&mut self.held);
        let n = held.len();
        for (to, msg) in held {
            // a released message is one wire transmission: under loss the
            // coin fires here, and retransmission recovers the casualty
            self.transmit(to, msg, 1);
        }
        n
    }

    /// Whether the message matching `(from, kind, round, seq)` is already
    /// available, without consuming it — the supervising engine's readiness
    /// peek behind [`StepProcess::poll_ready`].
    ///
    /// [`StepProcess::poll_ready`]: crate::dist::engine::StepProcess::poll_ready
    pub fn have_msg(&mut self, from: usize, kind: MsgKind, round: u32, seq: u32) -> bool {
        while let Ok(m) = self.rx.try_recv() {
            self.intake(m);
        }
        self.pending
            .iter()
            .any(|m| m.from == from && m.kind == kind && m.round == round && m.seq == seq)
    }

    /// Route one message pulled off the channel through the reliable layer:
    /// harvest its piggybacked ack, swallow standalone acks, discard
    /// duplicate link seqs (re-owing an ack so the sender's retransmissions
    /// converge even when the original ack was lost), and buffer everything
    /// else for matching. With the layer inert this is a plain buffer push.
    fn intake(&mut self, m: Message) {
        if !self.reliable {
            self.pending.push_back(m);
            return;
        }
        if m.ack > 0 {
            self.process_ack(m.from, m.ack);
        }
        if m.kind == MsgKind::Ack {
            self.pool.put(m.payload);
            return;
        }
        if m.link_seq > 0 && !self.record_link_seq(m.from, m.link_seq) {
            self.dup_discards += 1;
            self.ack_owed[m.from] = true;
            self.pool.put(m.payload);
            return;
        }
        self.pending.push_back(m);
    }

    /// The peer confirmed every link seq up to `ack`: release the covered
    /// entries of the retransmit buffer (kept in link-seq order).
    fn process_ack(&mut self, from: usize, ack: u64) {
        while self.unacked[from].front().is_some_and(|u| u.link_seq <= ack) {
            let u = self.unacked[from].pop_front().unwrap();
            self.pool.put(u.payload);
        }
    }

    /// Record an incoming link seq from `from`; `false` means duplicate.
    /// Fresh seqs advance the cumulative cursor (draining any now-contiguous
    /// out-of-order seqs) and mark an ack owed.
    fn record_link_seq(&mut self, from: usize, s: u64) -> bool {
        let mut cum = self.cum_recv[from];
        if s <= cum {
            return false;
        }
        let ahead = &mut self.seen_ahead[from];
        match ahead.binary_search(&s) {
            Ok(_) => return false,
            Err(i) => ahead.insert(i, s),
        }
        while ahead.first() == Some(&(cum + 1)) {
            cum += 1;
            ahead.remove(0);
        }
        self.cum_recv[from] = cum;
        self.ack_owed[from] = true;
        true
    }

    /// Take an empty pooled payload buffer. Fill it and pass it to [`send`]
    /// (zero-copy); the transport hands it to the receiver's pool once
    /// consumed via [`recv_into`]. Buffers not sent go back via [`put_buf`].
    ///
    /// [`send`]: Endpoint::send
    /// [`recv_into`]: Endpoint::recv_into
    /// [`put_buf`]: Endpoint::put_buf
    #[inline]
    pub fn take_buf(&mut self) -> Vec<u8> {
        self.pool.take()
    }

    /// Return an unsent buffer to the pool.
    #[inline]
    pub fn put_buf(&mut self, buf: Vec<u8>) {
        self.pool.put(buf);
    }

    /// Send a copy of `payload` in a pooled buffer — the allocation-free
    /// counterpart of building a fresh `Vec` per [`send`](Endpoint::send).
    /// Accounting and virtual-clock behavior are identical to `send`.
    pub fn send_from(&mut self, to: usize, kind: MsgKind, round: u32, seq: u32, payload: &[u8]) {
        let mut buf = self.pool.take();
        buf.extend_from_slice(payload);
        self.send(to, kind, round, seq, buf);
    }

    /// Receive the matching message into `out` (cleared first) and recycle
    /// the transported buffer into this endpoint's pool — the steady-state
    /// receive path: one `memcpy`, zero allocations.
    pub fn recv_into(
        &mut self,
        from: usize,
        kind: MsgKind,
        round: u32,
        seq: u32,
        out: &mut Vec<u8>,
    ) {
        let payload = self.recv_from(from, kind, round, seq);
        out.clear();
        out.extend_from_slice(&payload);
        self.pool.put(payload);
    }

    /// Blocking receive of the message matching `(from, kind, round, seq)`
    /// exactly; non-matching messages are buffered for later receives.
    /// Under an active fault plan the wait is a timeout-then-retry loop
    /// with bounded backoff instead of an unbounded block, so a reordered
    /// message that nobody will flush starves loudly instead of hanging.
    pub fn recv_from(&mut self, from: usize, kind: MsgKind, round: u32, seq: u32) -> Vec<u8> {
        loop {
            if let Some(i) = self
                .pending
                .iter()
                .position(|m| m.from == from && m.kind == kind && m.round == round && m.seq == seq)
            {
                let m = self.pending.remove(i).unwrap();
                return self.consume(m);
            }
            if self.faults.is_active() {
                self.recv_one_with_backoff(from, kind, round, seq);
            } else {
                let m = self
                    .rx
                    .recv()
                    .expect("transport channel closed with a receive outstanding");
                self.intake(m);
            }
        }
    }

    /// Pull one message off the channel with bounded exponential backoff —
    /// the faulted counterpart of a blocking `recv`. Panics once starved;
    /// the supervising engine's `catch_unwind` turns that into a typed
    /// `ProcFailed` error instead of a hung worker.
    fn recv_one_with_backoff(&mut self, from: usize, kind: MsgKind, round: u32, seq: u32) {
        use std::sync::mpsc::RecvTimeoutError;
        let mut wait_us = 50u64;
        for _ in 0..FAULT_RECV_RETRIES {
            match self.rx.recv_timeout(std::time::Duration::from_micros(wait_us)) {
                Ok(m) => {
                    // a duplicate intake leaves `pending` unchanged; the
                    // caller's loop simply pulls again
                    self.intake(m);
                    return;
                }
                Err(RecvTimeoutError::Timeout) => wait_us = (wait_us * 2).min(20_000),
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        panic!(
            "fault-injected receive starved: p{} waited for {kind:?} round {round} seq {seq} \
             from p{from}",
            self.rank
        );
    }

    /// Non-blocking receive of the message matching `(from, kind, round,
    /// seq)`, which **must already have been sent**. This is the BSP step
    /// engine's receive path: the engine's delivery discipline guarantees
    /// every message consumed in engine step *k* was sent in an earlier
    /// step, so a miss is a protocol bug and panics loudly instead of
    /// deadlocking a pooled worker. Accounting and clock behavior are
    /// identical to [`recv_from`](Endpoint::recv_from).
    pub fn try_recv_from(&mut self, from: usize, kind: MsgKind, round: u32, seq: u32) -> Vec<u8> {
        while let Ok(m) = self.rx.try_recv() {
            self.intake(m);
        }
        if let Some(i) = self
            .pending
            .iter()
            .position(|m| m.from == from && m.kind == kind && m.round == round && m.seq == seq)
        {
            let m = self.pending.remove(i).unwrap();
            return self.consume(m);
        }
        if self.faults.is_active() {
            // a miss may be a reordered message still in flight; retry with
            // bounded backoff instead of trusting the delivery invariant
            // (recv_one_with_backoff panics once starved)
            loop {
                self.recv_one_with_backoff(from, kind, round, seq);
                if let Some(i) = self.pending.iter().position(|m| {
                    m.from == from && m.kind == kind && m.round == round && m.seq == seq
                }) {
                    let m = self.pending.remove(i).unwrap();
                    return self.consume(m);
                }
            }
        }
        panic!(
            "BSP delivery invariant violated: p{} expected {kind:?} round {round} seq {seq} \
             from p{from} but it was never delivered",
            self.rank
        );
    }

    /// [`try_recv_from`](Endpoint::try_recv_from) into a reusable buffer,
    /// recycling the transported buffer — the engine counterpart of
    /// [`recv_into`](Endpoint::recv_into).
    pub fn try_recv_into(
        &mut self,
        from: usize,
        kind: MsgKind,
        round: u32,
        seq: u32,
        out: &mut Vec<u8>,
    ) {
        let payload = self.try_recv_from(from, kind, round, seq);
        out.clear();
        out.extend_from_slice(&payload);
        self.pool.put(payload);
    }

    fn consume(&mut self, m: Message) -> Vec<u8> {
        self.recv_msgs += 1;
        if self.wait_on_recv && m.arrival > self.clock {
            self.clock = m.arrival;
        }
        if self.log_consumed {
            // interval checkpointing: a revived rank gets every message
            // consumed since its last checkpoint back into `pending`
            self.consumed_log.push(m.clone());
        }
        m.payload
    }

    fn next_coll(&mut self) -> u32 {
        let s = self.coll_seq;
        self.coll_seq += 1;
        s
    }

    fn allreduce_u64(&mut self, v: u64, op: fn(u64, u64) -> u64) -> u64 {
        let seq = self.next_coll();
        if self.nprocs == 1 {
            return v;
        }
        // stage through the endpoint-owned collective buffer so per-round
        // collectives allocate nothing in steady state
        let mut buf = std::mem::take(&mut self.coll_buf);
        let out = if self.rank == 0 {
            let mut acc = v;
            for p in 1..self.nprocs {
                self.recv_into(p, MsgKind::Collective, seq, 0, &mut buf);
                acc = op(acc, decode_u64(&buf));
            }
            for p in 1..self.nprocs {
                self.send_from(p, MsgKind::Collective, seq, 1, &acc.to_le_bytes());
            }
            acc
        } else {
            self.send_from(0, MsgKind::Collective, seq, 0, &v.to_le_bytes());
            self.recv_into(0, MsgKind::Collective, seq, 1, &mut buf);
            decode_u64(&buf)
        };
        self.coll_buf = buf;
        out
    }

    /// Global max. All processes must call every collective in the same
    /// order; matching is sequenced by an internal collective counter.
    pub fn allreduce_max_u64(&mut self, v: u64) -> u64 {
        self.allreduce_u64(v, u64::max)
    }

    /// Global sum.
    pub fn allreduce_sum_u64(&mut self, v: u64) -> u64 {
        self.allreduce_u64(v, u64::wrapping_add)
    }

    /// Element-wise global sum of a vector; every process must pass the
    /// same length.
    pub fn allreduce_sum_vec_u64(&mut self, vals: &mut [u64]) {
        let seq = self.next_coll();
        if self.nprocs == 1 {
            return;
        }
        let mut buf = std::mem::take(&mut self.coll_buf);
        if self.rank == 0 {
            for p in 1..self.nprocs {
                self.recv_into(p, MsgKind::Collective, seq, 0, &mut buf);
                assert_eq!(buf.len(), vals.len() * 8, "allreduce vec length mismatch");
                for (a, b) in vals.iter_mut().zip(decode_u64s_iter(&buf)) {
                    *a = a.wrapping_add(b);
                }
            }
            encode_u64s_into(vals, &mut buf);
            for p in 1..self.nprocs {
                self.send_from(p, MsgKind::Collective, seq, 1, &buf);
            }
        } else {
            encode_u64s_into(vals, &mut buf);
            self.send_from(0, MsgKind::Collective, seq, 0, &buf);
            self.recv_into(0, MsgKind::Collective, seq, 1, &mut buf);
            assert_eq!(buf.len(), vals.len() * 8, "allreduce vec length mismatch");
            for (a, b) in vals.iter_mut().zip(decode_u64s_iter(&buf)) {
                *a = b;
            }
        }
        self.coll_buf = buf;
    }

    /// Synchronize all processes (and, in synchronous mode, their clocks).
    pub fn barrier(&mut self) {
        self.allreduce_max_u64(0);
    }

    // --- split collectives (BSP step engine) -----------------------------
    //
    // The blocking allreduces above interleave sends and receives across
    // ranks, which only works when every rank runs on its own thread. The
    // step engine instead splits each collective into three engine steps
    // that never block:
    //
    //   1. `coll_send_*`   — every rank draws the sequence number; ranks
    //                        != 0 send their contribution to rank 0.
    //   2. `coll_reduce_*` — rank 0 folds the contributions (in rank
    //                        order, exactly as the blocking reduction) and
    //                        broadcasts the result; other ranks idle.
    //   3. `coll_finish_*` — ranks != 0 receive the result; rank 0 (and
    //                        the single-process case) returns its value.
    //
    // Per rank this performs the *same* sends and receives, in the same
    // order, with the same payloads as the blocking counterpart, so every
    // modeled quantity — messages, bytes, virtual clocks — is bit-for-bit
    // identical (`split_collectives_match_blocking` pins this).

    /// Phase 1 of a split allreduce over one `u64`; returns the sequence
    /// number to pass to the later phases.
    pub fn coll_send_u64(&mut self, v: u64) -> u32 {
        let seq = self.next_coll();
        if self.nprocs > 1 && self.rank != 0 {
            self.send_from(0, MsgKind::Collective, seq, 0, &v.to_le_bytes());
        }
        seq
    }

    /// Phase 2: rank 0 folds every contribution into `v` with `op` and
    /// broadcasts; must only be called on rank 0 (no-op when single-proc).
    pub fn coll_reduce_u64(&mut self, seq: u32, v: u64, op: fn(u64, u64) -> u64) -> u64 {
        if self.nprocs == 1 {
            return v;
        }
        debug_assert_eq!(self.rank, 0, "coll_reduce is rank 0's phase");
        let mut buf = std::mem::take(&mut self.coll_buf);
        let mut acc = v;
        for p in 1..self.nprocs {
            self.try_recv_into(p, MsgKind::Collective, seq, 0, &mut buf);
            acc = op(acc, decode_u64(&buf));
        }
        for p in 1..self.nprocs {
            self.send_from(p, MsgKind::Collective, seq, 1, &acc.to_le_bytes());
        }
        self.coll_buf = buf;
        acc
    }

    /// Phase 3: the reduced value. Rank 0 passes what `coll_reduce_u64`
    /// returned; other ranks' `acc` argument is ignored (they receive).
    pub fn coll_finish_u64(&mut self, seq: u32, acc: u64) -> u64 {
        if self.nprocs == 1 || self.rank == 0 {
            return acc;
        }
        let mut buf = std::mem::take(&mut self.coll_buf);
        self.try_recv_into(0, MsgKind::Collective, seq, 1, &mut buf);
        let out = decode_u64(&buf);
        self.coll_buf = buf;
        out
    }

    /// Phase 1 of a split element-wise vector sum (every process passes
    /// the same length, as in [`allreduce_sum_vec_u64`]).
    ///
    /// [`allreduce_sum_vec_u64`]: Endpoint::allreduce_sum_vec_u64
    pub fn coll_send_vec_u64(&mut self, vals: &[u64]) -> u32 {
        let seq = self.next_coll();
        if self.nprocs > 1 && self.rank != 0 {
            let mut buf = std::mem::take(&mut self.coll_buf);
            encode_u64s_into(vals, &mut buf);
            self.send_from(0, MsgKind::Collective, seq, 0, &buf);
            self.coll_buf = buf;
        }
        seq
    }

    /// Phase 2 (rank 0 only): fold contributions into `vals` and broadcast.
    pub fn coll_reduce_vec_u64(&mut self, seq: u32, vals: &mut [u64]) {
        if self.nprocs == 1 {
            return;
        }
        debug_assert_eq!(self.rank, 0, "coll_reduce is rank 0's phase");
        let mut buf = std::mem::take(&mut self.coll_buf);
        for p in 1..self.nprocs {
            self.try_recv_into(p, MsgKind::Collective, seq, 0, &mut buf);
            assert_eq!(buf.len(), vals.len() * 8, "allreduce vec length mismatch");
            for (a, b) in vals.iter_mut().zip(decode_u64s_iter(&buf)) {
                *a = a.wrapping_add(b);
            }
        }
        encode_u64s_into(vals, &mut buf);
        for p in 1..self.nprocs {
            self.send_from(p, MsgKind::Collective, seq, 1, &buf);
        }
        self.coll_buf = buf;
    }

    /// Phase 3: ranks != 0 overwrite `vals` with the broadcast result;
    /// rank 0 (whose `vals` were reduced in place) is a no-op.
    pub fn coll_finish_vec_u64(&mut self, seq: u32, vals: &mut [u64]) {
        if self.nprocs == 1 || self.rank == 0 {
            return;
        }
        let mut buf = std::mem::take(&mut self.coll_buf);
        self.try_recv_into(0, MsgKind::Collective, seq, 1, &mut buf);
        assert_eq!(buf.len(), vals.len() * 8, "allreduce vec length mismatch");
        for (a, b) in vals.iter_mut().zip(decode_u64s_iter(&buf)) {
            *a = b;
        }
        self.coll_buf = buf;
    }

    // --- reliable-delivery layer -----------------------------------------

    /// Drive the reliable layer for one engine step `tick`, called by the
    /// supervised engine at the top of every step (a no-op when the layer
    /// is inert). In order:
    ///
    /// 1. **standalone acks** — for every peer still owed one from the
    ///    *previous* step: anything owed here survived a full step of
    ///    piggyback opportunities, which is the modeled ack timeout;
    /// 2. **intake** — drain the channel, harvesting piggybacked acks and
    ///    discarding duplicates (releasing retransmit entries *before* the
    ///    timeout scan below, so a just-acked message is never re-sent);
    /// 3. **retransmission** — re-send every unacked entry whose backoff
    ///    expired, charging full send-side accounting each time.
    ///
    /// Returns `Err(peer)` when an entry exhausted [`MAX_SEND_ATTEMPTS`] —
    /// the supervised engine surfaces that as `StopCause::Unreachable`.
    pub fn reliable_sweep(&mut self, tick: u64) -> Result<(), usize> {
        if !self.reliable {
            return Ok(());
        }
        self.tick = tick;
        for p in 0..self.nprocs {
            if p != self.rank && self.ack_owed[p] {
                self.send_standalone_ack(p);
            }
        }
        while let Ok(m) = self.rx.try_recv() {
            self.intake(m);
        }
        for p in 0..self.nprocs {
            if p == self.rank {
                continue;
            }
            let mut q = std::mem::take(&mut self.unacked[p]);
            for u in q.iter_mut() {
                if u.next_retry > tick {
                    continue;
                }
                if u.attempt >= MAX_SEND_ATTEMPTS {
                    self.unacked[p] = q;
                    return Err(p);
                }
                u.attempt += 1;
                u.next_retry = tick + retry_backoff(u.attempt);
                let bytes = u.payload.len() + MSG_HEADER_BYTES;
                self.sent_msgs += 1;
                self.sent_bytes += bytes as u64;
                self.clock += self.model.transfer_secs(bytes);
                self.retransmits += 1;
                let mut payload = self.pool.take();
                payload.extend_from_slice(&u.payload);
                let msg = Message {
                    from: self.rank,
                    kind: u.kind,
                    round: u.round,
                    seq: u.seq,
                    payload,
                    arrival: self.clock,
                    link_seq: u.link_seq,
                    ack: 0,
                };
                let attempt = u.attempt;
                self.transmit(p, msg, attempt);
            }
            self.unacked[p] = q;
        }
        Ok(())
    }

    /// Send a standalone cumulative ack to `to`, charged like any
    /// (payload-free) message. Standalone acks face the loss coin too: a
    /// lost one leaves `ack_owed` set (loss is decided before the
    /// bookkeeping in [`transmit`](Endpoint::transmit)), so the next sweep
    /// retries and the protocol converges.
    fn send_standalone_ack(&mut self, to: usize) {
        self.sent_msgs += 1;
        self.sent_bytes += MSG_HEADER_BYTES as u64;
        self.clock += self.model.transfer_secs(MSG_HEADER_BYTES);
        self.acks_sent += 1;
        let aseq = self.ack_seq[to];
        self.ack_seq[to] += 1;
        let msg = Message {
            from: self.rank,
            kind: MsgKind::Ack,
            round: 0,
            seq: aseq,
            payload: self.pool.take(),
            arrival: self.clock,
            link_seq: 0,
            ack: 0,
        };
        self.transmit(to, msg, 1);
    }

    /// Whether any reliable send still awaits its peer's ack — pending
    /// retransmissions count as future progress for deadlock detection.
    pub fn has_unacked(&self) -> bool {
        self.unacked.iter().any(|q| !q.is_empty())
    }

    /// Turn on the consumed-message replay log (the supervised engine sets
    /// this on every endpoint when interval checkpointing can revive a
    /// rank by replay).
    pub fn enable_replay_log(&mut self) {
        self.log_consumed = true;
    }

    /// Capture the transport state a revived rank resumes from. Clears the
    /// replay log: everything consumed before this point is baked into the
    /// machine snapshot taken alongside.
    pub fn checkpoint(&mut self) -> EndpointSnapshot {
        self.consumed_log.clear();
        EndpointSnapshot {
            clock: self.clock,
            sent_msgs: self.sent_msgs,
            sent_bytes: self.sent_bytes,
            recv_msgs: self.recv_msgs,
            dropped_msgs: self.dropped_msgs,
            non_teardown_drops: self.non_teardown_drops,
            injected_delays: self.injected_delays,
            injected_reorders: self.injected_reorders,
            injected_losses: self.injected_losses,
            retransmits: self.retransmits,
            acks_sent: self.acks_sent,
            dup_discards: self.dup_discards,
            coll_seq: self.coll_seq,
            next_link_seq: self.next_link_seq.clone(),
            unacked: self.unacked.clone(),
        }
    }

    /// Roll the endpoint back to `snap` (crash revival under interval
    /// checkpointing): the rank's own modeled work and the reliable
    /// layer's **sender** state rewind — replayed sends reuse their
    /// original link seqs, so every peer dedup-discards them — while
    /// receiver-side dedup state stays current (see [`EndpointSnapshot`]).
    /// Messages consumed since the checkpoint return to `pending` for
    /// replay; messages still held at the sender die with the crash (their
    /// retransmit entries re-cover them).
    pub fn restore(&mut self, snap: &EndpointSnapshot) {
        self.clock = snap.clock;
        self.sent_msgs = snap.sent_msgs;
        self.sent_bytes = snap.sent_bytes;
        self.recv_msgs = snap.recv_msgs;
        self.dropped_msgs = snap.dropped_msgs;
        self.non_teardown_drops = snap.non_teardown_drops;
        self.injected_delays = snap.injected_delays;
        self.injected_reorders = snap.injected_reorders;
        self.injected_losses = snap.injected_losses;
        self.retransmits = snap.retransmits;
        self.acks_sent = snap.acks_sent;
        self.dup_discards = snap.dup_discards;
        self.coll_seq = snap.coll_seq;
        self.next_link_seq = snap.next_link_seq.clone();
        self.unacked = snap.unacked.clone();
        for m in self.consumed_log.drain(..).rev() {
            self.pending.push_front(m);
        }
        self.held.clear();
    }
}

// --- wire encoding -------------------------------------------------------
//
// Every format has an `_into` encoder (clears and fills a reusable buffer)
// and an `_iter` decoder (streams straight off the payload slice) so hot
// paths never allocate; the `Vec`-returning forms remain for tests and
// cold paths.

pub fn encode_u64(v: u64) -> Vec<u8> {
    v.to_le_bytes().to_vec()
}

pub fn decode_u64(b: &[u8]) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[..8]);
    u64::from_le_bytes(a)
}

pub fn encode_u64s_into(vs: &[u64], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(vs.len() * 8);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

pub fn encode_u64s(vs: &[u64]) -> Vec<u8> {
    let mut out = Vec::new();
    encode_u64s_into(vs, &mut out);
    out
}

pub fn decode_u64s_iter(b: &[u8]) -> impl Iterator<Item = u64> + '_ {
    b.chunks_exact(8).map(|c| {
        let mut a = [0u8; 8];
        a.copy_from_slice(c);
        u64::from_le_bytes(a)
    })
}

pub fn decode_u64s(b: &[u8]) -> Vec<u64> {
    decode_u64s_iter(b).collect()
}

pub fn encode_u32s_into(vs: &[u32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(vs.len() * 4);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

pub fn encode_u32s(vs: &[u32]) -> Vec<u8> {
    let mut out = Vec::new();
    encode_u32s_into(vs, &mut out);
    out
}

pub fn decode_u32s_iter(b: &[u8]) -> impl Iterator<Item = u32> + '_ {
    b.chunks_exact(4).map(|c| {
        let mut a = [0u8; 4];
        a.copy_from_slice(c);
        u32::from_le_bytes(a)
    })
}

pub fn decode_u32s(b: &[u8]) -> Vec<u32> {
    decode_u32s_iter(b).collect()
}

/// Append one `(id, color)` pair to a wire buffer — for callers that build
/// a payload directly in a pooled buffer without staging a pair list.
#[inline]
pub fn push_pair(out: &mut Vec<u8>, a: u32, b: u32) {
    out.extend_from_slice(&a.to_le_bytes());
    out.extend_from_slice(&b.to_le_bytes());
}

/// Encode `(id, color)` pairs — the boundary-update wire format.
pub fn encode_pairs_into(ps: &[(u32, u32)], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(ps.len() * 8);
    for &(a, b) in ps {
        push_pair(out, a, b);
    }
}

pub fn encode_pairs(ps: &[(u32, u32)]) -> Vec<u8> {
    let mut out = Vec::new();
    encode_pairs_into(ps, &mut out);
    out
}

pub fn decode_pairs_iter(b: &[u8]) -> impl Iterator<Item = (u32, u32)> + '_ {
    b.chunks_exact(8).map(|c| {
        let mut x = [0u8; 4];
        let mut y = [0u8; 4];
        x.copy_from_slice(&c[..4]);
        y.copy_from_slice(&c[4..]);
        (u32::from_le_bytes(x), u32::from_le_bytes(y))
    })
}

pub fn decode_pairs(b: &[u8]) -> Vec<(u32, u32)> {
    decode_pairs_iter(b).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_encodings() {
        assert_eq!(decode_u64(&encode_u64(0xDEAD_BEEF_0BAD_F00D)), 0xDEAD_BEEF_0BAD_F00D);
        let vs = vec![0u64, 1, u64::MAX];
        assert_eq!(decode_u64s(&encode_u64s(&vs)), vs);
        let us = vec![7u32, 0, u32::MAX];
        assert_eq!(decode_u32s(&encode_u32s(&us)), us);
        let ps = vec![(1u32, 2u32), (u32::MAX, 0)];
        assert_eq!(decode_pairs(&encode_pairs(&ps)), ps);
    }

    #[test]
    fn exact_message_and_byte_accounting() {
        let mut eps = network(2, NetworkModel::ideal());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, MsgKind::Colors, 0, 0, vec![0u8; 24]);
        a.send(1, MsgKind::Colors, 0, 1, Vec::new());
        assert_eq!(a.sent_msgs, 2);
        assert_eq!(
            a.sent_bytes,
            (24 + MSG_HEADER_BYTES + MSG_HEADER_BYTES) as u64
        );
        let p0 = b.recv_from(0, MsgKind::Colors, 0, 0);
        let p1 = b.recv_from(0, MsgKind::Colors, 0, 1);
        assert_eq!(p0.len(), 24);
        assert!(p1.is_empty());
        assert_eq!(b.recv_msgs, 2);
        assert_eq!(b.sent_msgs, 0);
    }

    #[test]
    fn pooled_send_recv_accounting_matches_alloc_path() {
        // send_from/recv_into must be observationally identical to
        // send/recv_from: same bytes, same counters, same clocks
        let model = NetworkModel::new(1e-3, 1e-6);
        let mut eps = network(2, model);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let payload = [7u8; 40];
        a.send(1, MsgKind::Colors, 0, 0, payload.to_vec());
        a.send_from(1, MsgKind::Colors, 0, 1, &payload);
        assert_eq!(a.sent_msgs, 2);
        assert_eq!(a.sent_bytes, 2 * (40 + MSG_HEADER_BYTES) as u64);
        let t_alloc = {
            let eps2 = network(2, model);
            let mut e = eps2.into_iter().next().unwrap();
            e.send(0, MsgKind::Colors, 0, 0, payload.to_vec()); // self-send
            e.clock
        };
        let t_pool = {
            let eps2 = network(2, model);
            let mut e = eps2.into_iter().next().unwrap();
            e.send_from(0, MsgKind::Colors, 0, 0, &payload);
            e.clock
        };
        assert_eq!(t_alloc.to_bits(), t_pool.to_bits(), "clock charge diverged");
        let v = b.recv_from(0, MsgKind::Colors, 0, 0);
        let mut w = Vec::new();
        b.recv_into(0, MsgKind::Colors, 0, 1, &mut w);
        assert_eq!(v, payload.to_vec());
        assert_eq!(w, payload.to_vec());
        assert_eq!(b.recv_msgs, 2);
    }

    #[test]
    fn buffers_recycle_through_the_pool() {
        let mut eps = network(1, NetworkModel::ideal());
        let mut e = eps.pop().unwrap();
        let mut out = Vec::new();
        // self-send loop: after the first iteration the pool feeds each
        // send; recv_into keeps handing the buffer back
        for i in 0..100u32 {
            let mut buf = e.take_buf();
            assert!(buf.is_empty());
            buf.extend_from_slice(&i.to_le_bytes());
            e.send(0, MsgKind::Colors, 0, i, buf);
            e.recv_into(0, MsgKind::Colors, 0, i, &mut out);
            assert_eq!(out, i.to_le_bytes().to_vec());
        }
        assert_eq!(e.sent_msgs, 100);
        assert_eq!(e.recv_msgs, 100);
        assert_eq!(e.dropped_msgs, 0);
    }

    #[test]
    fn teardown_drops_are_counted() {
        let mut eps = network(2, NetworkModel::ideal());
        let mut a = eps.remove(0);
        drop(eps); // receiver endpoint gone
        a.teardown = true;
        a.send(1, MsgKind::Colors, 0, 0, vec![1, 2, 3]);
        assert_eq!(a.dropped_msgs, 1);
        // the wire cost was still paid (accounting is send-side)
        assert_eq!(a.sent_msgs, 1);
        assert_eq!(a.sent_bytes, (3 + MSG_HEADER_BYTES) as u64);
    }

    #[test]
    fn non_teardown_drops_are_tracked() {
        let mut eps = network(2, NetworkModel::ideal());
        let mut a = eps.remove(0);
        drop(eps); // receiver endpoint gone, teardown NOT acknowledged
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            a.send(1, MsgKind::Colors, 0, 0, vec![1]);
        }));
        // debug builds keep the loud oracle; release builds record and go on
        assert_eq!(r.is_err(), cfg!(debug_assertions));
        assert_eq!(a.dropped_msgs, 1);
        assert_eq!(a.non_teardown_drops, 1);
    }

    #[test]
    fn inert_plan_is_bit_for_bit_the_clean_transport() {
        let model = NetworkModel::new(1e-3, 1e-6);
        let mut clean = network(2, model);
        let mut inert = network_faulted(2, model, FaultPlan::none());
        for i in 0..10u32 {
            clean[0].send(1, MsgKind::Colors, 0, i, vec![0u8; (i * 7) as usize]);
            inert[0].send(1, MsgKind::Colors, 0, i, vec![0u8; (i * 7) as usize]);
        }
        for i in 0..10u32 {
            clean[1].recv_from(0, MsgKind::Colors, 0, i);
            inert[1].recv_from(0, MsgKind::Colors, 0, i);
        }
        for r in 0..2 {
            // sweeping an inert endpoint is a guaranteed no-op
            inert[r].reliable_sweep(99).unwrap();
            assert_eq!(clean[r].clock.to_bits(), inert[r].clock.to_bits());
            assert_eq!(clean[r].sent_msgs, inert[r].sent_msgs);
            assert_eq!(clean[r].sent_bytes, inert[r].sent_bytes);
            assert_eq!(clean[r].recv_msgs, inert[r].recv_msgs);
            assert_eq!(inert[r].injected_delays + inert[r].injected_reorders, 0);
            assert_eq!(
                inert[r].injected_losses
                    + inert[r].retransmits
                    + inert[r].acks_sent
                    + inert[r].dup_discards,
                0
            );
        }
    }

    #[test]
    fn injected_delay_defers_arrival_not_send_cost() {
        let model = NetworkModel::new(1e-3, 1e-6);
        let plan = FaultPlan {
            seed: 1,
            delay_prob: 1.0,
            delay_secs: 0.5,
            ..FaultPlan::none()
        };
        let mut faulted = network_faulted(2, model, plan);
        let mut clean = network(2, model);
        clean[0].send(1, MsgKind::Colors, 0, 0, vec![0u8; 100]);
        faulted[0].send(1, MsgKind::Colors, 0, 0, vec![0u8; 100]);
        assert_eq!(clean[0].clock.to_bits(), faulted[0].clock.to_bits());
        assert_eq!(faulted[0].injected_delays, 1);
        clean[1].recv_from(0, MsgKind::Colors, 0, 0);
        faulted[1].recv_from(0, MsgKind::Colors, 0, 0);
        assert!(
            (faulted[1].clock - (clean[1].clock + 0.5)).abs() < 1e-12,
            "delayed arrival must move the waiting receiver's clock by delay_secs"
        );
    }

    #[test]
    fn reordered_messages_are_held_until_flushed() {
        let plan = FaultPlan {
            seed: 1,
            reorder_prob: 1.0,
            ..FaultPlan::none()
        };
        let mut eps = network_faulted(2, NetworkModel::ideal(), plan);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, MsgKind::Colors, 0, 0, vec![7]);
        assert_eq!(a.injected_reorders, 1);
        assert_eq!(a.sent_msgs, 1, "held messages are still counted as sent");
        assert!(!b.have_msg(0, MsgKind::Colors, 0, 0));
        assert_eq!(a.flush_held(), 1);
        assert!(b.have_msg(0, MsgKind::Colors, 0, 0));
        assert_eq!(b.try_recv_from(0, MsgKind::Colors, 0, 0), vec![7]);
        assert_eq!(a.flush_held(), 0);
    }

    #[test]
    fn iter_decoders_match_vec_decoders() {
        let vs = vec![0u64, 1, u64::MAX, 42];
        let b = encode_u64s(&vs);
        assert_eq!(decode_u64s_iter(&b).collect::<Vec<_>>(), vs);
        let us = vec![7u32, 0, u32::MAX];
        let b = encode_u32s(&us);
        assert_eq!(decode_u32s_iter(&b).collect::<Vec<_>>(), us);
        let ps = vec![(1u32, 2u32), (u32::MAX, 0), (9, 9)];
        let mut buf = vec![0xAAu8; 3]; // _into must clear stale content
        encode_pairs_into(&ps, &mut buf);
        assert_eq!(decode_pairs_iter(&buf).collect::<Vec<_>>(), ps);
        let mut manual = Vec::new();
        for &(x, y) in &ps {
            push_pair(&mut manual, x, y);
        }
        assert_eq!(manual, buf);
    }

    #[test]
    fn out_of_order_matching_buffers() {
        let mut eps = network(2, NetworkModel::ideal());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, MsgKind::Colors, 1, 0, vec![1]);
        a.send(1, MsgKind::Plan, 1, 0, vec![2]);
        a.send(1, MsgKind::Colors, 2, 0, vec![3]);
        // receive in a different order than sent
        assert_eq!(b.recv_from(0, MsgKind::Colors, 2, 0), vec![3]);
        assert_eq!(b.recv_from(0, MsgKind::Colors, 1, 0), vec![1]);
        assert_eq!(b.recv_from(0, MsgKind::Plan, 1, 0), vec![2]);
    }

    #[test]
    fn clock_advances_by_alpha_beta_and_recv_waits() {
        let model = NetworkModel::new(1e-3, 1e-6);
        let mut eps = network(2, model);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.clock = 5.0;
        let payload = vec![0u8; 1000 - MSG_HEADER_BYTES];
        a.send(1, MsgKind::Colors, 0, 0, payload);
        let expect = 5.0 + 1e-3 + 1e-6 * 1000.0;
        assert!((a.clock - expect).abs() < 1e-12);
        // sync receiver waits until arrival
        b.clock = 0.0;
        b.recv_from(0, MsgKind::Colors, 0, 0);
        assert!((b.clock - expect).abs() < 1e-12);
        // a later local clock is not rolled back
        a.send(1, MsgKind::Colors, 0, 1, Vec::new());
        b.clock = 100.0;
        b.recv_from(0, MsgKind::Colors, 0, 1);
        assert!((b.clock - 100.0).abs() < 1e-12);
    }

    #[test]
    fn async_recv_does_not_wait() {
        let mut eps = network(2, NetworkModel::new(1.0, 0.0));
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, MsgKind::Colors, 0, 0, vec![9]);
        b.wait_on_recv = false;
        assert_eq!(b.recv_from(0, MsgKind::Colors, 0, 0), vec![9]);
        assert_eq!(b.clock, 0.0, "async receive must not advance the clock");
    }

    #[test]
    fn ideal_network_sends_cost_zero_time() {
        let mut eps = network(2, NetworkModel::ideal());
        let mut a = eps.remove(0);
        for i in 0..100 {
            a.send(1, MsgKind::Colors, 0, i, vec![0u8; 64]);
        }
        assert_eq!(a.clock, 0.0);
        assert_eq!(a.sent_msgs, 100);
    }

    #[test]
    fn try_recv_matches_blocking_recv_and_panics_on_miss() {
        let model = NetworkModel::new(1e-3, 1e-6);
        let mut eps = network(2, model);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let payload = [9u8; 32];
        a.send_from(1, MsgKind::Colors, 0, 0, &payload);
        a.send_from(1, MsgKind::Colors, 0, 1, &payload);
        // blocking and try paths consume identically (counters + clock)
        let v = b.recv_from(0, MsgKind::Colors, 0, 0);
        let clock_after_blocking = b.clock;
        b.clock = 0.0;
        let w = b.try_recv_from(0, MsgKind::Colors, 0, 1);
        assert_eq!(v, w);
        assert_eq!(b.clock.to_bits(), clock_after_blocking.to_bits());
        assert_eq!(b.recv_msgs, 2);
        // a receive for a message that was never sent is a loud bug
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            b.try_recv_from(0, MsgKind::Colors, 9, 9)
        }));
        assert!(r.is_err(), "missing message must panic, not block");
    }

    /// The split (engine) collectives must be bit-for-bit identical to the
    /// blocking ones: same results, same per-rank message/byte counters,
    /// same virtual clocks.
    #[test]
    fn split_collectives_match_blocking() {
        for procs in [1usize, 2, 5] {
            let model = NetworkModel::default();
            // blocking reference, one thread per rank
            let eps = network(procs, model);
            let reference: Vec<(u64, u64, Vec<u64>, u64, u64, u64)> = std::thread::scope(|s| {
                let hs: Vec<_> = eps
                    .into_iter()
                    .enumerate()
                    .map(|(r, ep)| {
                        s.spawn(move || {
                            let mut ep = ep;
                            let mx = ep.allreduce_max_u64(10 + r as u64);
                            let sm = ep.allreduce_sum_u64(r as u64 + 1);
                            let mut v = vec![r as u64, 1];
                            ep.allreduce_sum_vec_u64(&mut v);
                            (mx, sm, v, ep.clock.to_bits(), ep.sent_msgs, ep.sent_bytes)
                        })
                    })
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });

            // split version, phase-stepped on a single thread
            let mut eps = network(procs, model);
            let seqs: Vec<u32> = eps
                .iter_mut()
                .enumerate()
                .map(|(r, ep)| ep.coll_send_u64(10 + r as u64))
                .collect();
            let acc = eps[0].coll_reduce_u64(seqs[0], 10, u64::max);
            let maxs: Vec<u64> = eps
                .iter_mut()
                .enumerate()
                .map(|(r, ep)| ep.coll_finish_u64(seqs[r], acc))
                .collect();
            let seqs: Vec<u32> = eps
                .iter_mut()
                .enumerate()
                .map(|(r, ep)| ep.coll_send_u64(r as u64 + 1))
                .collect();
            let acc = eps[0].coll_reduce_u64(seqs[0], 1, u64::wrapping_add);
            let sums: Vec<u64> = eps
                .iter_mut()
                .enumerate()
                .map(|(r, ep)| ep.coll_finish_u64(seqs[r], acc))
                .collect();
            let mut vecs: Vec<Vec<u64>> = (0..procs).map(|r| vec![r as u64, 1]).collect();
            let seqs: Vec<u32> = eps
                .iter_mut()
                .zip(vecs.iter())
                .map(|(ep, v)| ep.coll_send_vec_u64(v))
                .collect();
            eps[0].coll_reduce_vec_u64(seqs[0], &mut vecs[0]);
            for (r, (ep, v)) in eps.iter_mut().zip(vecs.iter_mut()).enumerate() {
                ep.coll_finish_vec_u64(seqs[r], v);
            }

            for (r, (mx, sm, v, clock_bits, msgs, bytes)) in reference.into_iter().enumerate() {
                assert_eq!(maxs[r], mx, "p{r} max (procs={procs})");
                assert_eq!(sums[r], sm, "p{r} sum (procs={procs})");
                assert_eq!(vecs[r], v, "p{r} vec (procs={procs})");
                assert_eq!(
                    eps[r].clock.to_bits(),
                    clock_bits,
                    "p{r} clock diverged (procs={procs})"
                );
                assert_eq!(eps[r].sent_msgs, msgs, "p{r} msgs (procs={procs})");
                assert_eq!(eps[r].sent_bytes, bytes, "p{r} bytes (procs={procs})");
            }
        }
    }

    /// A loss-free plan that still activates the reliable layer (interval
    /// checkpointing with a crash on the books).
    fn reliable_no_loss_plan() -> FaultPlan {
        use crate::dist::fault::Crash;
        FaultPlan {
            seed: 1,
            crashes: vec![Crash {
                rank: 0,
                step: 1_000_000, // never reached in these unit tests
                down_steps: 1,
            }],
            checkpoint_interval: 4,
            ..FaultPlan::none()
        }
    }

    #[test]
    fn lossy_link_delivers_every_message_exactly_once() {
        let run = || {
            let plan = FaultPlan {
                seed: 11,
                loss_prob: 0.3,
                ..FaultPlan::none()
            };
            let mut eps = network_faulted(2, NetworkModel::ideal(), plan);
            let mut b = eps.pop().unwrap();
            let mut a = eps.pop().unwrap();
            for i in 0..50u32 {
                a.send(1, MsgKind::Colors, 0, i, vec![i as u8; 3]);
            }
            let mut remaining: Vec<u32> = (0..50).collect();
            for tick in 0..10_000u64 {
                a.reliable_sweep(tick)
                    .expect("loss=0.3 must never exhaust the retry budget");
                b.reliable_sweep(tick).unwrap();
                remaining.retain(|&i| {
                    if b.have_msg(0, MsgKind::Colors, 0, i) {
                        assert_eq!(b.recv_from(0, MsgKind::Colors, 0, i), vec![i as u8; 3]);
                        false
                    } else {
                        true
                    }
                });
                if remaining.is_empty() && !a.has_unacked() {
                    break;
                }
            }
            assert!(remaining.is_empty(), "undelivered: {remaining:?}");
            assert!(!a.has_unacked(), "every send must end acknowledged");
            assert_eq!(b.recv_msgs, 50, "exactly-once delivery");
            assert!(a.injected_losses > 0, "loss=0.3 over 50 messages must lose some");
            assert!(a.retransmits > 0, "losses must be re-covered");
            assert!(b.acks_sent > 0, "receiver must ack");
            (
                a.sent_msgs,
                a.clock.to_bits(),
                a.injected_losses,
                a.retransmits,
                b.acks_sent,
                b.dup_discards,
            )
        };
        assert_eq!(run(), run(), "same seed, same retransmit/ack/dup trace");
    }

    #[test]
    fn retry_cap_trips_unreachable_with_exact_loss_accounting() {
        // loss=1.0 is unreachable by construction (the CLI rejects it; the
        // struct admits it precisely for this worst case)
        let plan = FaultPlan {
            seed: 3,
            loss_prob: 1.0,
            ..FaultPlan::none()
        };
        let mut eps = network_faulted(2, NetworkModel::ideal(), plan);
        let mut a = eps.remove(0);
        a.send(1, MsgKind::Colors, 0, 0, vec![7]);
        let mut tripped = None;
        for tick in 0..1000u64 {
            if let Err(p) = a.reliable_sweep(tick) {
                tripped = Some(p);
                break;
            }
        }
        assert_eq!(tripped, Some(1), "peer 1 must be declared unreachable");
        assert_eq!(
            a.injected_losses,
            MAX_SEND_ATTEMPTS as u64,
            "every attempt was lost"
        );
        assert_eq!(a.retransmits, (MAX_SEND_ATTEMPTS - 1) as u64);
        assert!(a.has_unacked(), "the doomed entry stays on the books");
    }

    #[test]
    fn duplicate_is_discarded_and_reacked() {
        let mut eps = network_faulted(2, NetworkModel::ideal(), reliable_no_loss_plan());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, MsgKind::Colors, 0, 0, vec![5]);
        // the receiver never acks in time: the sender's backoff expires and
        // it retransmits, so two copies are on the wire
        a.reliable_sweep(2).unwrap();
        assert_eq!(a.retransmits, 1);
        assert!(b.have_msg(0, MsgKind::Colors, 0, 0));
        assert_eq!(b.dup_discards, 1, "second copy discarded at intake");
        assert_eq!(b.recv_from(0, MsgKind::Colors, 0, 0), vec![5]);
        assert_eq!(b.recv_msgs, 1, "dedup means exactly-once");
        // the discard re-owes an ack; the next sweep sends it standalone
        b.reliable_sweep(3).unwrap();
        assert_eq!(b.acks_sent, 1);
        a.reliable_sweep(4).unwrap();
        assert!(!a.has_unacked(), "standalone ack must release the entry");
        assert!(
            a.pending.is_empty(),
            "standalone acks are swallowed at intake, never matched"
        );
    }

    #[test]
    fn snapshot_restore_rewinds_sender_state_and_replays_consumed() {
        let mut eps = network_faulted(2, NetworkModel::ideal(), reliable_no_loss_plan());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.enable_replay_log();
        b.send(0, MsgKind::Colors, 0, 0, vec![1]);
        b.send(0, MsgKind::Colors, 0, 1, vec![2]);
        b.send(0, MsgKind::Colors, 0, 2, vec![3]);
        assert_eq!(a.recv_from(1, MsgKind::Colors, 0, 0), vec![1]);
        let snap = a.checkpoint();
        // post-checkpoint work: two consumes and one send, all to be redone
        assert_eq!(a.recv_from(1, MsgKind::Colors, 0, 1), vec![2]);
        assert_eq!(a.recv_from(1, MsgKind::Colors, 0, 2), vec![3]);
        a.send(1, MsgKind::Colors, 5, 0, vec![9]);
        assert_eq!(b.recv_from(0, MsgKind::Colors, 5, 0), vec![9]);
        let (msgs_at_crash, recv_at_crash) = (a.sent_msgs, a.recv_msgs);
        a.restore(&snap);
        assert_eq!(a.recv_msgs, 1, "receive accounting rewound");
        assert_eq!(a.recv_from(1, MsgKind::Colors, 0, 1), vec![2]);
        assert_eq!(a.recv_from(1, MsgKind::Colors, 0, 2), vec![3]);
        assert_eq!(a.recv_msgs, recv_at_crash, "replay re-applies the consumes");
        // the replayed send reuses link seq 1 and is absorbed by b's dedup
        a.send(1, MsgKind::Colors, 5, 0, vec![9]);
        assert_eq!(a.sent_msgs, msgs_at_crash, "send accounting replays identically");
        assert!(
            !b.have_msg(0, MsgKind::Colors, 5, 0),
            "replayed send must be dedup-discarded, not redelivered"
        );
        assert_eq!(b.dup_discards, 1);
        assert_eq!(b.recv_msgs, 1, "b never double-consumes");
    }

    #[test]
    fn collectives_across_threads() {
        for procs in [1usize, 2, 5] {
            let eps = network(procs, NetworkModel::default());
            let outs: Vec<(u64, u64, Vec<u64>)> = std::thread::scope(|s| {
                let handles: Vec<_> = eps
                    .into_iter()
                    .enumerate()
                    .map(|(r, ep)| {
                        s.spawn(move || {
                            let mut ep = ep;
                            let mx = ep.allreduce_max_u64(10 + r as u64);
                            let sm = ep.allreduce_sum_u64(r as u64 + 1);
                            let mut v = vec![r as u64, 1];
                            ep.allreduce_sum_vec_u64(&mut v);
                            ep.barrier();
                            (mx, sm, v)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let p = procs as u64;
            for (mx, sm, v) in outs {
                assert_eq!(mx, 10 + p - 1);
                assert_eq!(sm, p * (p + 1) / 2);
                assert_eq!(v, vec![p * (p - 1) / 2, p]);
            }
        }
    }
}
