//! In-process message transport with exact per-endpoint accounting.
//!
//! `network(P, model)` builds `P` fully-connected [`Endpoint`]s over
//! unbounded channels; one OS thread drives each endpoint (see
//! [`runner`](crate::dist::runner)). Every send is counted (messages and
//! bytes, including a fixed per-message header) and charged to the sender's
//! virtual clock through the α-β [`NetworkModel`]; a synchronous receive
//! advances the receiver's clock to the message's arrival time, which is
//! how supersteps, collectives and the recoloring deadline protocol cost
//! virtual time. Matching is exact on `(from, kind, round, seq)` with an
//! out-of-order buffer, so processes may run arbitrarily far apart in real
//! time while the virtual schedule stays deterministic.

use crate::dist::cost::NetworkModel;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Fixed accounting overhead per message (envelope: kind/round/seq/len).
pub const MSG_HEADER_BYTES: usize = 16;

/// Message classes; part of the match key so phases can never steal each
/// other's traffic even when processes drift apart in real time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgKind {
    /// Boundary color updates of the superstep framework.
    Colors,
    /// Color-class updates of distributed recoloring.
    Recolor,
    /// Piggyback plan (per-pair nonempty-step schedule / deadlines).
    Plan,
    /// Internal collectives (allreduce / barrier).
    Collective,
}

struct Message {
    from: usize,
    kind: MsgKind,
    round: u32,
    seq: u32,
    payload: Vec<u8>,
    /// Sender's virtual clock when the message finished injecting — the
    /// earliest virtual time the receiver can observe it.
    arrival: f64,
}

/// One simulated process's communication endpoint.
pub struct Endpoint {
    pub rank: usize,
    pub nprocs: usize,
    pub model: NetworkModel,
    /// Virtual clock in seconds.
    pub clock: f64,
    pub sent_msgs: u64,
    pub sent_bytes: u64,
    pub recv_msgs: u64,
    /// `true` (synchronous): a receive waits — the clock advances to the
    /// arrival time. `false` (asynchronous): data is consumed without
    /// advancing the clock, modeling fully overlapped communication.
    pub wait_on_recv: bool,
    txs: Vec<Sender<Message>>,
    rx: Receiver<Message>,
    pending: VecDeque<Message>,
    coll_seq: u32,
}

/// Build a fully-connected network of `procs` endpoints.
pub fn network(procs: usize, model: NetworkModel) -> Vec<Endpoint> {
    let mut txs = Vec::with_capacity(procs);
    let mut rxs = Vec::with_capacity(procs);
    for _ in 0..procs {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| Endpoint {
            rank,
            nprocs: procs,
            model,
            clock: 0.0,
            sent_msgs: 0,
            sent_bytes: 0,
            recv_msgs: 0,
            wait_on_recv: true,
            txs: txs.clone(),
            rx,
            pending: VecDeque::new(),
            coll_seq: 0,
        })
        .collect()
}

impl Endpoint {
    /// Send `payload` to `to`. Counted exactly; the sender's clock pays the
    /// α-β injection cost, which is also the receiver-visible arrival time.
    pub fn send(&mut self, to: usize, kind: MsgKind, round: u32, seq: u32, payload: Vec<u8>) {
        let bytes = payload.len() + MSG_HEADER_BYTES;
        self.sent_msgs += 1;
        self.sent_bytes += bytes as u64;
        self.clock += self.model.transfer_secs(bytes);
        let msg = Message {
            from: self.rank,
            kind,
            round,
            seq,
            payload,
            arrival: self.clock,
        };
        if to == self.rank {
            self.pending.push_back(msg);
        } else {
            // receiver may already have shut down (harmless at teardown)
            let _ = self.txs[to].send(msg);
        }
    }

    /// Blocking receive of the message matching `(from, kind, round, seq)`
    /// exactly; non-matching messages are buffered for later receives.
    pub fn recv_from(&mut self, from: usize, kind: MsgKind, round: u32, seq: u32) -> Vec<u8> {
        if let Some(i) = self
            .pending
            .iter()
            .position(|m| m.from == from && m.kind == kind && m.round == round && m.seq == seq)
        {
            let m = self.pending.remove(i).unwrap();
            return self.consume(m);
        }
        loop {
            let m = self
                .rx
                .recv()
                .expect("transport channel closed with a receive outstanding");
            if m.from == from && m.kind == kind && m.round == round && m.seq == seq {
                return self.consume(m);
            }
            self.pending.push_back(m);
        }
    }

    fn consume(&mut self, m: Message) -> Vec<u8> {
        self.recv_msgs += 1;
        if self.wait_on_recv && m.arrival > self.clock {
            self.clock = m.arrival;
        }
        m.payload
    }

    fn next_coll(&mut self) -> u32 {
        let s = self.coll_seq;
        self.coll_seq += 1;
        s
    }

    fn allreduce_u64(&mut self, v: u64, op: fn(u64, u64) -> u64) -> u64 {
        let seq = self.next_coll();
        if self.nprocs == 1 {
            return v;
        }
        if self.rank == 0 {
            let mut acc = v;
            for p in 1..self.nprocs {
                let data = self.recv_from(p, MsgKind::Collective, seq, 0);
                acc = op(acc, decode_u64(&data));
            }
            for p in 1..self.nprocs {
                self.send(p, MsgKind::Collective, seq, 1, encode_u64(acc));
            }
            acc
        } else {
            self.send(0, MsgKind::Collective, seq, 0, encode_u64(v));
            decode_u64(&self.recv_from(0, MsgKind::Collective, seq, 1))
        }
    }

    /// Global max. All processes must call every collective in the same
    /// order; matching is sequenced by an internal collective counter.
    pub fn allreduce_max_u64(&mut self, v: u64) -> u64 {
        self.allreduce_u64(v, u64::max)
    }

    /// Global sum.
    pub fn allreduce_sum_u64(&mut self, v: u64) -> u64 {
        self.allreduce_u64(v, u64::wrapping_add)
    }

    /// Element-wise global sum of a vector; every process must pass the
    /// same length.
    pub fn allreduce_sum_vec_u64(&mut self, vals: &mut [u64]) {
        let seq = self.next_coll();
        if self.nprocs == 1 {
            return;
        }
        if self.rank == 0 {
            for p in 1..self.nprocs {
                let data = self.recv_from(p, MsgKind::Collective, seq, 0);
                let theirs = decode_u64s(&data);
                assert_eq!(theirs.len(), vals.len(), "allreduce vec length mismatch");
                for (a, b) in vals.iter_mut().zip(theirs) {
                    *a = a.wrapping_add(b);
                }
            }
            let payload = encode_u64s(vals);
            for p in 1..self.nprocs {
                self.send(p, MsgKind::Collective, seq, 1, payload.clone());
            }
        } else {
            self.send(0, MsgKind::Collective, seq, 0, encode_u64s(vals));
            let data = self.recv_from(0, MsgKind::Collective, seq, 1);
            let theirs = decode_u64s(&data);
            vals.copy_from_slice(&theirs);
        }
    }

    /// Synchronize all processes (and, in synchronous mode, their clocks).
    pub fn barrier(&mut self) {
        self.allreduce_max_u64(0);
    }
}

// --- wire encoding -------------------------------------------------------

pub fn encode_u64(v: u64) -> Vec<u8> {
    v.to_le_bytes().to_vec()
}

pub fn decode_u64(b: &[u8]) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[..8]);
    u64::from_le_bytes(a)
}

pub fn encode_u64s(vs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vs.len() * 8);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn decode_u64s(b: &[u8]) -> Vec<u64> {
    b.chunks_exact(8)
        .map(|c| {
            let mut a = [0u8; 8];
            a.copy_from_slice(c);
            u64::from_le_bytes(a)
        })
        .collect()
}

pub fn encode_u32s(vs: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vs.len() * 4);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn decode_u32s(b: &[u8]) -> Vec<u32> {
    b.chunks_exact(4)
        .map(|c| {
            let mut a = [0u8; 4];
            a.copy_from_slice(c);
            u32::from_le_bytes(a)
        })
        .collect()
}

/// Encode `(id, color)` pairs — the boundary-update wire format.
pub fn encode_pairs(ps: &[(u32, u32)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(ps.len() * 8);
    for &(a, b) in ps {
        out.extend_from_slice(&a.to_le_bytes());
        out.extend_from_slice(&b.to_le_bytes());
    }
    out
}

pub fn decode_pairs(b: &[u8]) -> Vec<(u32, u32)> {
    b.chunks_exact(8)
        .map(|c| {
            let mut x = [0u8; 4];
            let mut y = [0u8; 4];
            x.copy_from_slice(&c[..4]);
            y.copy_from_slice(&c[4..]);
            (u32::from_le_bytes(x), u32::from_le_bytes(y))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_encodings() {
        assert_eq!(decode_u64(&encode_u64(0xDEAD_BEEF_0BAD_F00D)), 0xDEAD_BEEF_0BAD_F00D);
        let vs = vec![0u64, 1, u64::MAX];
        assert_eq!(decode_u64s(&encode_u64s(&vs)), vs);
        let us = vec![7u32, 0, u32::MAX];
        assert_eq!(decode_u32s(&encode_u32s(&us)), us);
        let ps = vec![(1u32, 2u32), (u32::MAX, 0)];
        assert_eq!(decode_pairs(&encode_pairs(&ps)), ps);
    }

    #[test]
    fn exact_message_and_byte_accounting() {
        let mut eps = network(2, NetworkModel::ideal());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, MsgKind::Colors, 0, 0, vec![0u8; 24]);
        a.send(1, MsgKind::Colors, 0, 1, Vec::new());
        assert_eq!(a.sent_msgs, 2);
        assert_eq!(
            a.sent_bytes,
            (24 + MSG_HEADER_BYTES + MSG_HEADER_BYTES) as u64
        );
        let p0 = b.recv_from(0, MsgKind::Colors, 0, 0);
        let p1 = b.recv_from(0, MsgKind::Colors, 0, 1);
        assert_eq!(p0.len(), 24);
        assert!(p1.is_empty());
        assert_eq!(b.recv_msgs, 2);
        assert_eq!(b.sent_msgs, 0);
    }

    #[test]
    fn out_of_order_matching_buffers() {
        let mut eps = network(2, NetworkModel::ideal());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, MsgKind::Colors, 1, 0, vec![1]);
        a.send(1, MsgKind::Plan, 1, 0, vec![2]);
        a.send(1, MsgKind::Colors, 2, 0, vec![3]);
        // receive in a different order than sent
        assert_eq!(b.recv_from(0, MsgKind::Colors, 2, 0), vec![3]);
        assert_eq!(b.recv_from(0, MsgKind::Colors, 1, 0), vec![1]);
        assert_eq!(b.recv_from(0, MsgKind::Plan, 1, 0), vec![2]);
    }

    #[test]
    fn clock_advances_by_alpha_beta_and_recv_waits() {
        let model = NetworkModel::new(1e-3, 1e-6);
        let mut eps = network(2, model);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.clock = 5.0;
        let payload = vec![0u8; 1000 - MSG_HEADER_BYTES];
        a.send(1, MsgKind::Colors, 0, 0, payload);
        let expect = 5.0 + 1e-3 + 1e-6 * 1000.0;
        assert!((a.clock - expect).abs() < 1e-12);
        // sync receiver waits until arrival
        b.clock = 0.0;
        b.recv_from(0, MsgKind::Colors, 0, 0);
        assert!((b.clock - expect).abs() < 1e-12);
        // a later local clock is not rolled back
        a.send(1, MsgKind::Colors, 0, 1, Vec::new());
        b.clock = 100.0;
        b.recv_from(0, MsgKind::Colors, 0, 1);
        assert!((b.clock - 100.0).abs() < 1e-12);
    }

    #[test]
    fn async_recv_does_not_wait() {
        let mut eps = network(2, NetworkModel::new(1.0, 0.0));
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, MsgKind::Colors, 0, 0, vec![9]);
        b.wait_on_recv = false;
        assert_eq!(b.recv_from(0, MsgKind::Colors, 0, 0), vec![9]);
        assert_eq!(b.clock, 0.0, "async receive must not advance the clock");
    }

    #[test]
    fn ideal_network_sends_cost_zero_time() {
        let mut eps = network(2, NetworkModel::ideal());
        let mut a = eps.remove(0);
        for i in 0..100 {
            a.send(1, MsgKind::Colors, 0, i, vec![0u8; 64]);
        }
        assert_eq!(a.clock, 0.0);
        assert_eq!(a.sent_msgs, 100);
    }

    #[test]
    fn collectives_across_threads() {
        for procs in [1usize, 2, 5] {
            let eps = network(procs, NetworkModel::default());
            let outs: Vec<(u64, u64, Vec<u64>)> = std::thread::scope(|s| {
                let handles: Vec<_> = eps
                    .into_iter()
                    .enumerate()
                    .map(|(r, ep)| {
                        s.spawn(move || {
                            let mut ep = ep;
                            let mx = ep.allreduce_max_u64(10 + r as u64);
                            let sm = ep.allreduce_sum_u64(r as u64 + 1);
                            let mut v = vec![r as u64, 1];
                            ep.allreduce_sum_vec_u64(&mut v);
                            ep.barrier();
                            (mx, sm, v)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let p = procs as u64;
            for (mx, sm, v) in outs {
                assert_eq!(mx, 10 + p - 1);
                assert_eq!(sm, p * (p + 1) / 2);
                assert_eq!(v, vec![p * (p - 1) / 2, p]);
            }
        }
    }
}
