//! Cost models driving the per-process virtual clocks.
//!
//! The distributed runtime simulates P processes on one host; wallclock
//! would measure the simulator, not the simulated machine. Instead every
//! process advances a *virtual clock*: local work is charged through a
//! [`CostModel`] (per-vertex selection overhead, per-neighbor scan, per-byte
//! pack/unpack) and communication through an α-β [`NetworkModel`]
//! (latency + inverse bandwidth, LogP-style with the sender paying the
//! injection overhead). Fixed rates make experiments machine-independent
//! and byte-for-byte reproducible; calibrated rates anchor the virtual
//! times to the host.

use std::time::Instant;

/// Per-operation compute costs in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Per-vertex overhead of one selection (epoch bump + pick).
    pub vertex_secs: f64,
    /// Per-neighbor scan cost (read color, mark forbidden).
    pub edge_secs: f64,
    /// Per-byte message pack/unpack cost.
    pub byte_secs: f64,
}

impl CostModel {
    /// Fixed rates for deterministic tests and benches: roughly a 2010s-era
    /// cluster node (the paper's testbed class), so virtual times land in a
    /// realistic range.
    pub fn fixed() -> Self {
        CostModel {
            vertex_secs: 60e-9,
            edge_secs: 18e-9,
            byte_secs: 0.25e-9,
        }
    }

    /// Calibrate the per-edge rate on this host with a short timed greedy
    /// pass, scaling the fixed profile; falls back to [`CostModel::fixed`]
    /// when the measurement is degenerate.
    pub fn calibrated() -> Self {
        use crate::color::{greedy_color, Ordering, Selection};
        use crate::graph::synth;
        let g = synth::erdos_renyi(4000, 24_000, 7);
        let scans = 2.0 * 2.0 * g.num_edges() as f64; // two timed passes
        let t0 = Instant::now();
        std::hint::black_box(greedy_color(&g, Ordering::Natural, Selection::FirstFit, 1));
        std::hint::black_box(greedy_color(&g, Ordering::Natural, Selection::FirstFit, 2));
        let secs = t0.elapsed().as_secs_f64();
        let fixed = CostModel::fixed();
        let measured_edge = secs / scans;
        // clamp to a sane band around the fixed profile
        let scale = (measured_edge / fixed.edge_secs).clamp(0.05, 50.0);
        if !scale.is_finite() {
            return fixed;
        }
        CostModel {
            vertex_secs: fixed.vertex_secs * scale,
            edge_secs: fixed.edge_secs * scale,
            byte_secs: fixed.byte_secs * scale,
        }
    }

    /// Virtual seconds for coloring `vertices` vertices scanning
    /// `edge_scans` neighbor entries.
    #[inline]
    pub fn color_cost(&self, vertices: u64, edge_scans: u64) -> f64 {
        vertices as f64 * self.vertex_secs + edge_scans as f64 * self.edge_secs
    }

    /// Virtual seconds for packing/unpacking `bytes` of message payload.
    #[inline]
    pub fn pack_cost(&self, bytes: u64) -> f64 {
        bytes as f64 * self.byte_secs
    }
}

/// α-β point-to-point network model: a message of `b` bytes occupies the
/// sender for `α + β·b` virtual seconds and becomes visible to the receiver
/// at the sender's clock after that charge. A synchronous receive waits for
/// the arrival; an asynchronous receive consumes the data without waiting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Per-message latency/injection overhead in seconds.
    pub alpha: f64,
    /// Per-byte inverse bandwidth in seconds.
    pub beta: f64,
}

impl NetworkModel {
    pub fn new(alpha: f64, beta: f64) -> Self {
        NetworkModel { alpha, beta }
    }

    /// Zero-cost network: communication is free, only synchronization
    /// (waiting for data that does not exist yet) costs virtual time.
    pub fn ideal() -> Self {
        NetworkModel {
            alpha: 0.0,
            beta: 0.0,
        }
    }

    /// Virtual seconds to move `bytes` across one link.
    #[inline]
    pub fn transfer_secs(&self, bytes: usize) -> f64 {
        self.alpha + self.beta * bytes as f64
    }
}

impl Default for NetworkModel {
    /// A commodity-cluster interconnect: 1.5 µs latency, 1 GB/s bandwidth.
    fn default() -> Self {
        NetworkModel {
            alpha: 1.5e-6,
            beta: 1.0e-9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_costs_positive_and_ordered() {
        let c = CostModel::fixed();
        assert!(c.vertex_secs > 0.0 && c.edge_secs > 0.0 && c.byte_secs > 0.0);
        // a selection costs more than a single neighbor scan
        assert!(c.vertex_secs > c.edge_secs);
        assert_eq!(c.color_cost(0, 0), 0.0);
        let one = c.color_cost(1, 10);
        assert!((one - (c.vertex_secs + 10.0 * c.edge_secs)).abs() < 1e-18);
        assert!((c.pack_cost(100) - 100.0 * c.byte_secs).abs() < 1e-18);
    }

    #[test]
    fn ideal_network_is_free() {
        let n = NetworkModel::ideal();
        assert_eq!(n.transfer_secs(0), 0.0);
        assert_eq!(n.transfer_secs(1 << 20), 0.0);
    }

    #[test]
    fn alpha_beta_math() {
        let n = NetworkModel::new(1e-3, 1e-9);
        assert!((n.transfer_secs(0) - 1e-3).abs() < 1e-15);
        assert!((n.transfer_secs(1000) - (1e-3 + 1e-6)).abs() < 1e-15);
        // latency-dominated for small messages, bandwidth-dominated at 1GB
        assert!(n.transfer_secs(8) < 2.0 * n.alpha);
        assert!(n.transfer_secs(1_000_000_000) > 0.5);
    }

    #[test]
    fn default_network_reasonable() {
        let n = NetworkModel::default();
        assert!(n.alpha > 0.0 && n.beta > 0.0);
        assert!(n.alpha < 1e-4, "default latency should be microseconds");
    }

    #[test]
    fn calibrated_is_sane() {
        let c = CostModel::calibrated();
        assert!(c.edge_secs > 0.0 && c.edge_secs.is_finite());
        assert!(c.vertex_secs > c.edge_secs);
    }
}
