//! The Bozdağ superstep framework (paper §2.2, §3): speculative distributed
//! greedy coloring with boundary conflict detection and re-resolution
//! rounds.
//!
//! Each round, every process splits its to-color list into supersteps of
//! `superstep_size` vertices. A superstep colors its batch against the
//! current local view (owned + ghost colors), then exchanges the batch's
//! boundary colors with every neighbor process. Because updates from
//! superstep *s* are visible before superstep *s+1* anywhere, conflicts can
//! only arise between vertices colored in the *same* superstep on opposite
//! sides of a cut edge; the end-of-round sweep detects them and the
//! [`loses`] tie-break (a static random priority, mirrored bit-for-bit by
//! the Pallas `conflict_detect` kernel) picks the unique loser, which is
//! recolored next round. Losers shrink strictly every round — the
//! max-priority loser always wins its next conflicts — so the loop
//! terminates; a serialized cleanup round bounds the worst case at
//! `max_rounds`.
//!
//! Sync vs async (paper §2.2.1): the color decisions are identical — the
//! modes differ in what the virtual clock charges. Synchronous receives
//! wait for the sender's virtual arrival (lockstep supersteps); in
//! asynchronous mode communication is fully overlapped: receives consume
//! data without waiting, so makespan reflects only local work and sends —
//! faster, as in the paper.

use crate::color::order::{self, Ordering};
use crate::color::select::{SelectState, Selection};
use crate::color::UNCOLORED;
use crate::coordinator::event::{emit_rank0, Event, Observer};
use crate::dist::comm::{self, Endpoint, MsgKind};
use crate::dist::cost::CostModel;
use crate::dist::proc::{ColorState, LocalGraph};
use crate::dist::ProcMetrics;
use crate::util::rng::{mix64, Rng};

/// Knobs of the superstep framework.
#[derive(Debug, Clone, Copy)]
pub struct FrameworkConfig {
    pub ordering: Ordering,
    pub selection: Selection,
    /// Vertices colored between boundary exchanges.
    pub superstep_size: usize,
    /// Synchronous superstep communication (see module docs).
    pub sync: bool,
    pub seed: u64,
    /// Conflict-resolution round cap; past it one serialized cleanup round
    /// guarantees a valid result.
    pub max_rounds: u32,
}

impl Default for FrameworkConfig {
    fn default() -> Self {
        FrameworkConfig {
            ordering: Ordering::InternalFirst,
            selection: Selection::FirstFit,
            superstep_size: 1000,
            sync: true,
            seed: 42,
            max_rounds: 200,
        }
    }
}

/// The framework's conflict tie-break: `u` loses to `v` under a static
/// per-seed random priority, ties on the smaller global id. Antisymmetric
/// and total for `u != v`; mirrored by the Pallas `conflict_detect` kernel.
#[inline]
pub fn loses(u: u32, v: u32, seed: u64) -> bool {
    let pu = mix64(seed, u as u64) as u32;
    let pv = mix64(seed, v as u64) as u32;
    pu < pv || (pu == pv && u < v)
}

/// Run a communication closure and book its virtual time under the "comm"
/// phase of `metrics`.
pub fn comm_timed<T, F: FnOnce(&mut Endpoint) -> T>(
    ep: &mut Endpoint,
    metrics: &mut ProcMetrics,
    f: F,
) -> T {
    let t0 = ep.clock;
    let out = f(ep);
    metrics.phases.add("comm", ep.clock - t0);
    out
}

#[inline]
fn epoch(round: u32, step: u64) -> u64 {
    ((round as u64) << 32) | step
}

/// Per-process staging reused across supersteps and rounds, so the
/// steady-state superstep performs zero heap allocations (DESIGN.md
/// "Memory discipline on hot paths"): boundary updates are staged in
/// per-neighbor buffers, encoded into pooled transport buffers, and
/// decoded from a single receive scratch.
#[derive(Clone)]
struct ExchangeScratch {
    /// Per-neighbor `(global id, color)` staging, aligned with
    /// `neighbor_procs`.
    upd: Vec<Vec<(u32, u32)>>,
    /// Receive/decode staging.
    dec: Vec<u8>,
    /// Per-process superstep counts of the current round.
    steps_of: Vec<u64>,
    /// Owner-dedup scratch for one boundary vertex.
    parts: Vec<usize>,
}

impl ExchangeScratch {
    fn for_graph(lg: &LocalGraph) -> Self {
        ExchangeScratch {
            upd: vec![Vec::new(); lg.neighbor_procs.len()],
            dec: Vec::new(),
            steps_of: vec![0; lg.nprocs],
            parts: Vec::new(),
        }
    }
}

/// One process's share of a speculative distributed coloring.
///
/// Colors `to_color` (owned local ids) into `state`, exchanging boundary
/// colors with neighbor processes every superstep and resolving cut-edge
/// conflicts in rounds. `order_override` (used by asynchronous recoloring)
/// bypasses `fw.ordering` with an explicit visit order. Rank 0 streams
/// [`Event::SuperstepDone`] / [`Event::ConflictRound`] to `obs`; emission
/// never touches the virtual clocks, so observed runs are bit-for-bit
/// identical to unobserved ones.
#[allow(clippy::too_many_arguments)]
pub fn color_process(
    ep: &mut Endpoint,
    lg: &LocalGraph,
    fw: &FrameworkConfig,
    cost: &CostModel,
    state: &mut ColorState,
    to_color: Vec<u32>,
    order_override: Option<Vec<u32>>,
    obs: Option<&dyn Observer>,
) -> ProcMetrics {
    color_process_cancellable(ep, lg, fw, cost, state, to_color, order_override, None, obs).0
}

/// [`color_process`] with the thread runner's cancellation hook: when a
/// [`CancelToken`] is attached, every process votes at the top of each
/// conflict-resolution round (`check` against its own virtual clock) and an
/// `allreduce_max` of the votes makes the stop decision **uniform** — no
/// rank ever stops sending while a peer still waits on its messages. The
/// coloring is then left as the last completed round's state (partial on
/// round 1, conflicted afterwards; the pipeline repairs it under the
/// `Degrade` policy) and the latched cause is returned.
///
/// The consensus collective advances the virtual clock, so a token-carrying
/// run models slightly more communication than a bare one — only the
/// `cancel: None` path (what [`color_process`] takes) is bit-for-bit
/// pinned against the BSP engine.
#[allow(clippy::too_many_arguments)]
pub fn color_process_cancellable(
    ep: &mut Endpoint,
    lg: &LocalGraph,
    fw: &FrameworkConfig,
    cost: &CostModel,
    state: &mut ColorState,
    to_color: Vec<u32>,
    order_override: Option<Vec<u32>>,
    cancel: Option<&crate::util::cancel::CancelToken>,
    obs: Option<&dyn Observer>,
) -> (ProcMetrics, Option<crate::util::cancel::StopCause>) {
    let mut stopped = None;
    let mut metrics = ProcMetrics {
        rank: ep.rank,
        ..Default::default()
    };
    let t_start = ep.clock;
    ep.wait_on_recv = fw.sync;
    let n_owned = lg.n_owned();

    // Local-degree estimate seeds StaggeredFirstFit's window.
    let estimate = (0..n_owned)
        .map(|v| lg.csr.degree(v as u32))
        .max()
        .unwrap_or(0) as u32
        + 1;
    let mut st = SelectState::new(
        fw.selection,
        estimate,
        mix64(fw.seed ^ 0xC0_10B, lg.rank as u64),
    );

    let mut pending: Vec<u32> = match order_override {
        Some(o) => o,
        None => {
            let mut rng = Rng::new(mix64(fw.seed ^ 0x0BDE_B, lg.rank as u64));
            // one pass over the owned adjacency to build the order
            ep.clock += cost.color_cost(to_color.len() as u64, lg.csr.xadj[n_owned]) * 0.25;
            order::compute_order(
                &lg.csr,
                &to_color,
                fw.ordering,
                |v| lg.is_boundary[v as usize],
                &mut rng,
            )
        }
    };

    let ss = fw.superstep_size.max(1);
    // Epoch (round, superstep) at which each local vertex was last colored.
    let mut colored_at: Vec<u64> = vec![u64::MAX; lg.n_local()];
    let mut round: u32 = 0;
    let mut scratch = ExchangeScratch::for_graph(lg);
    let mut losers: Vec<u32> = Vec::new();

    loop {
        if let Some(tok) = cancel {
            // per-round consensus: everyone votes, the max decides, so the
            // break below happens on every rank at the same round boundary
            let vote = tok.check(ep.clock).is_some() as u64;
            let agreed = comm_timed(ep, &mut metrics, |ep| ep.allreduce_max_u64(vote));
            if agreed != 0 {
                // the voter latched the token before contributing, and the
                // collective's channel sync publishes the latch to peers
                stopped = tok.stopped();
                break;
            }
        }
        round += 1;
        let my_steps = pending.len().div_ceil(ss) as u64;
        // every process learns every step count, so pairs can skip the
        // exchange for supersteps where the sender has nothing to color —
        // conflict-resolution rounds stay cheap
        scratch.steps_of.fill(0);
        scratch.steps_of[ep.rank] = my_steps;
        ep.allreduce_sum_vec_u64(&mut scratch.steps_of);
        let max_steps = scratch.steps_of.iter().copied().max().unwrap_or(0);

        for step in 0..max_steps {
            let lo = (step as usize) * ss;
            let batch: &[u32] = if lo < pending.len() {
                &pending[lo..(lo + ss).min(pending.len())]
            } else {
                &[]
            };

            // -- compute: color the batch against the current local view
            let mut scans: u64 = 0;
            for &v in batch {
                st.begin_vertex();
                let s = lg.csr.xadj[v as usize] as usize;
                let e = lg.csr.xadj[v as usize + 1] as usize;
                scans += (e - s) as u64;
                for &u in &lg.csr.adjncy[s..e] {
                    let cu = state.colors[u as usize];
                    if cu != UNCOLORED {
                        st.forbid(cu);
                    }
                }
                state.colors[v as usize] = st.pick();
                colored_at[v as usize] = epoch(round, step);
            }
            ep.clock += cost.color_cost(batch.len() as u64, scans);

            // -- exchange: this batch's boundary colors, one message per
            //    neighbor per non-empty superstep (the step-count vector
            //    tells receivers which supersteps each sender skips)
            for u in scratch.upd.iter_mut() {
                u.clear();
            }
            for &v in batch {
                if !lg.is_boundary[v as usize] {
                    continue;
                }
                scratch.parts.clear();
                let s = lg.csr.xadj[v as usize] as usize;
                let e = lg.csr.xadj[v as usize + 1] as usize;
                for &u in &lg.csr.adjncy[s..e] {
                    if (u as usize) >= n_owned {
                        scratch.parts.push(lg.owner[u as usize] as usize);
                    }
                }
                scratch.parts.sort_unstable();
                scratch.parts.dedup();
                for &q in scratch.parts.iter() {
                    let qi = lg.neighbor_procs.binary_search(&q).unwrap();
                    scratch.upd[qi].push((lg.global_ids[v as usize], state.colors[v as usize]));
                }
            }
            if step < my_steps {
                for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                    let mut payload = ep.take_buf();
                    comm::encode_pairs_into(&scratch.upd[qi], &mut payload);
                    ep.clock += cost.pack_cost(payload.len() as u64);
                    ep.send(q, MsgKind::Colors, round, step as u32, payload);
                }
            }
            for &q in &lg.neighbor_procs {
                if step >= scratch.steps_of[q] {
                    continue; // that sender had no batch this superstep
                }
                ep.recv_into(q, MsgKind::Colors, round, step as u32, &mut scratch.dec);
                ep.clock += cost.pack_cost(scratch.dec.len() as u64);
                for (gid, c) in comm::decode_pairs_iter(&scratch.dec) {
                    let li = lg.local_of(gid) as usize;
                    state.colors[li] = c;
                    colored_at[li] = epoch(round, step);
                }
            }
            emit_rank0(
                obs,
                ep.rank,
                Event::SuperstepDone {
                    round,
                    step: step as u32,
                },
            );
        }

        // -- end-of-round sweep: same-superstep collisions on cut edges.
        // Updates from earlier supersteps were visible, so only equal
        // epochs can collide; the loser recolors next round.
        losers.clear();
        let mut sweep_scans: u64 = 0;
        for &v in &pending {
            if !lg.is_boundary[v as usize] {
                continue;
            }
            let cv = state.colors[v as usize];
            let ev = colored_at[v as usize];
            let s = lg.csr.xadj[v as usize] as usize;
            let e = lg.csr.xadj[v as usize + 1] as usize;
            sweep_scans += (e - s) as u64;
            let mut lost = false;
            for &u in &lg.csr.adjncy[s..e] {
                let ui = u as usize;
                if ui < n_owned
                    || state.colors[ui] != cv
                    || colored_at[ui] != ev
                {
                    continue;
                }
                if loses(lg.global_ids[v as usize], lg.global_ids[ui], fw.seed) {
                    lost = true;
                    metrics.conflicts += 1;
                }
            }
            if lost {
                losers.push(v);
            }
        }
        ep.clock += cost.color_cost(0, sweep_scans);

        let global_losers = ep.allreduce_sum_u64(losers.len() as u64);
        emit_rank0(
            obs,
            ep.rank,
            Event::ConflictRound {
                round,
                conflicts: global_losers,
            },
        );
        if global_losers == 0 {
            break;
        }
        if round >= fw.max_rounds {
            serial_cleanup(ep, lg, cost, &mut st, state, &losers, round + 1, &mut scratch);
            round += 1;
            break;
        }
        std::mem::swap(&mut pending, &mut losers);
    }

    metrics.rounds += round;
    metrics.phases.add("color", ep.clock - t_start);
    (metrics, stopped)
}

/// Worst-case safety valve: processes take turns (rank order) recoloring
/// their remaining losers, so no two conflicting vertices ever choose
/// concurrently and the result is conflict-free by construction.
#[allow(clippy::too_many_arguments)]
fn serial_cleanup(
    ep: &mut Endpoint,
    lg: &LocalGraph,
    cost: &CostModel,
    st: &mut SelectState,
    state: &mut ColorState,
    losers: &[u32],
    tag: u32,
    scratch: &mut ExchangeScratch,
) {
    let n_owned = lg.n_owned();
    for r in 0..lg.nprocs {
        if lg.rank as usize == r {
            let mut scans: u64 = 0;
            for u in scratch.upd.iter_mut() {
                u.clear();
            }
            for &v in losers {
                st.begin_vertex();
                let s = lg.csr.xadj[v as usize] as usize;
                let e = lg.csr.xadj[v as usize + 1] as usize;
                scans += (e - s) as u64;
                for &u in &lg.csr.adjncy[s..e] {
                    let cu = state.colors[u as usize];
                    if cu != UNCOLORED {
                        st.forbid(cu);
                    }
                }
                state.colors[v as usize] = st.pick();
                scratch.parts.clear();
                for &u in &lg.csr.adjncy[s..e] {
                    if (u as usize) >= n_owned {
                        scratch.parts.push(lg.owner[u as usize] as usize);
                    }
                }
                scratch.parts.sort_unstable();
                scratch.parts.dedup();
                for &q in scratch.parts.iter() {
                    let qi = lg.neighbor_procs.binary_search(&q).unwrap();
                    scratch.upd[qi].push((lg.global_ids[v as usize], state.colors[v as usize]));
                }
            }
            ep.clock += cost.color_cost(losers.len() as u64, scans);
            for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                let mut payload = ep.take_buf();
                comm::encode_pairs_into(&scratch.upd[qi], &mut payload);
                ep.send(q, MsgKind::Colors, tag, r as u32, payload);
            }
        } else if lg.neighbor_procs.binary_search(&r).is_ok() {
            ep.recv_into(r, MsgKind::Colors, tag, r as u32, &mut scratch.dec);
            for (gid, c) in comm::decode_pairs_iter(&scratch.dec) {
                state.colors[lg.local_of(gid) as usize] = c;
            }
        }
    }
}

/// [`color_process`] as an explicit step state machine for the BSP step
/// engine ([`dist::engine`](crate::dist::engine)): each
/// [`step_once`](FrameworkStep::step_once) call runs one non-blocking
/// slice — a superstep's compute+send, its receive half, a split-collective
/// phase, or one turn of the serialized cleanup. The machine performs the
/// *same* endpoint operations in the same per-process order as
/// `color_process`, so every modeled quantity (colors, messages, bytes,
/// conflicts, virtual clocks) is bit-for-bit identical; keep the two in
/// lockstep when either changes.
///
/// `Clone` snapshots the whole machine (colors, scratch, collective
/// cursors) — the supervising engine's checkpoint for crash recovery.
#[derive(Clone)]
pub struct FrameworkStep<'a> {
    lg: &'a LocalGraph,
    fw: FrameworkConfig,
    cost: CostModel,
    obs: Option<&'a dyn Observer>,
    to_color: Vec<u32>,
    order_override: Option<Vec<u32>>,
    colors: ColorState,
    metrics: ProcMetrics,
    st: SelectState,
    scratch: ExchangeScratch,
    pending: Vec<u32>,
    losers: Vec<u32>,
    colored_at: Vec<u64>,
    t_start: f64,
    round: u32,
    my_steps: u64,
    max_steps: u64,
    coll_seq: u32,
    coll_acc: u64,
    state: FwState,
}

/// Which slice of `color_process` the next `step_once` call executes.
#[derive(Clone, Copy)]
enum FwState {
    /// Visit order + its cost charge (the code before the round loop).
    Init,
    /// Round entry: superstep counts staged and contributed (collective
    /// phase 1).
    RoundBegin,
    /// Step-count collective phase 2 (rank 0 reduces + broadcasts).
    RoundReduce,
    /// Step-count collective phase 3; decides the round's superstep count.
    RoundFinish,
    /// Superstep `s`: color the batch, stage and send boundary updates.
    ColorStep(u64),
    /// Superstep `s`: receive + apply the peers' updates (sent one engine
    /// step earlier).
    ExchangeStep(u64),
    /// End-of-round conflict sweep + loser-count collective phase 1.
    Sweep,
    /// Loser-count collective phase 2.
    SweepReduce,
    /// Loser-count collective phase 3; break / cleanup / next round.
    SweepFinish,
    /// Serialized cleanup, rank `r`'s turn to recolor and send.
    CleanupSend(usize),
    /// Serialized cleanup, `r`'s neighbors receive (one step later).
    CleanupRecv(usize),
    Finished,
}

impl<'a> FrameworkStep<'a> {
    /// Mirror of the [`color_process`] signature; `colors` is the entry
    /// color state (`ColorState::uncolored` for an initial coloring).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        lg: &'a LocalGraph,
        fw: &FrameworkConfig,
        cost: &CostModel,
        colors: ColorState,
        to_color: Vec<u32>,
        order_override: Option<Vec<u32>>,
        obs: Option<&'a dyn Observer>,
    ) -> Self {
        let n_owned = lg.n_owned();
        let estimate = (0..n_owned)
            .map(|v| lg.csr.degree(v as u32))
            .max()
            .unwrap_or(0) as u32
            + 1;
        let st = SelectState::new(
            fw.selection,
            estimate,
            mix64(fw.seed ^ 0xC0_10B, lg.rank as u64),
        );
        FrameworkStep {
            lg,
            fw: *fw,
            cost: *cost,
            obs,
            to_color,
            order_override,
            colors,
            metrics: ProcMetrics {
                rank: lg.rank as usize,
                ..Default::default()
            },
            st,
            scratch: ExchangeScratch::for_graph(lg),
            pending: Vec::new(),
            losers: Vec::new(),
            colored_at: vec![u64::MAX; lg.n_local()],
            t_start: 0.0,
            round: 0,
            my_steps: 0,
            max_steps: 0,
            coll_seq: 0,
            coll_acc: 0,
            state: FwState::Init,
        }
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, FwState::Finished)
    }

    /// The finished machine's color state and metrics (the
    /// `color_process` return value plus the colors it filled in place).
    pub fn into_parts(self) -> (ColorState, ProcMetrics) {
        assert!(self.is_finished(), "framework step machine still running");
        (self.colors, self.metrics)
    }

    /// Best-so-far harvest for a cancelled run: the color state exactly as
    /// the machine last left it — complete if finished, otherwise partially
    /// colored and possibly conflicted on cut edges (the pipeline's repair
    /// pass finishes the job). No finished assertion, by design.
    pub fn abort_colors(self) -> ColorState {
        self.colors
    }

    fn finish(&mut self, ep: &mut Endpoint) {
        self.metrics.rounds += self.round;
        self.metrics.phases.add("color", ep.clock - self.t_start);
        self.state = FwState::Finished;
    }

    /// Whether the next [`step_once`](Self::step_once) slice can run
    /// without a blocking-receive miss: every message it consumes has
    /// already arrived. The supervising engine polls this to park
    /// machines while a crashed peer's messages are outstanding; states
    /// that receive nothing are always ready.
    pub fn ready(&mut self, ep: &mut Endpoint) -> bool {
        let lg = self.lg;
        match self.state {
            FwState::RoundReduce | FwState::SweepReduce => {
                ep.rank != 0
                    || (1..lg.nprocs)
                        .all(|p| ep.have_msg(p, MsgKind::Collective, self.coll_seq, 0))
            }
            FwState::RoundFinish | FwState::SweepFinish => {
                ep.rank == 0 || ep.have_msg(0, MsgKind::Collective, self.coll_seq, 1)
            }
            FwState::ExchangeStep(step) => lg.neighbor_procs.iter().all(|&q| {
                step >= self.scratch.steps_of[q]
                    || ep.have_msg(q, MsgKind::Colors, self.round, step as u32)
            }),
            FwState::CleanupRecv(r) => {
                ep.rank == r
                    || lg.neighbor_procs.binary_search(&r).is_err()
                    || ep.have_msg(r, MsgKind::Colors, self.round + 1, r as u32)
            }
            _ => true,
        }
    }

    /// Run one engine step; `true` once the machine reached `Finished`.
    pub fn step_once(&mut self, ep: &mut Endpoint) -> bool {
        let lg = self.lg;
        let n_owned = lg.n_owned();
        match self.state {
            FwState::Init => {
                self.t_start = ep.clock;
                ep.wait_on_recv = self.fw.sync;
                self.pending = match self.order_override.take() {
                    Some(o) => o,
                    None => {
                        let mut rng = Rng::new(mix64(self.fw.seed ^ 0x0BDE_B, lg.rank as u64));
                        ep.clock += self
                            .cost
                            .color_cost(self.to_color.len() as u64, lg.csr.xadj[n_owned])
                            * 0.25;
                        order::compute_order(
                            &lg.csr,
                            &self.to_color,
                            self.fw.ordering,
                            |v| lg.is_boundary[v as usize],
                            &mut rng,
                        )
                    }
                };
                self.state = FwState::RoundBegin;
            }
            FwState::RoundBegin => {
                self.round += 1;
                let ss = self.fw.superstep_size.max(1);
                self.my_steps = self.pending.len().div_ceil(ss) as u64;
                self.scratch.steps_of.fill(0);
                self.scratch.steps_of[ep.rank] = self.my_steps;
                self.coll_seq = ep.coll_send_vec_u64(&self.scratch.steps_of);
                self.state = FwState::RoundReduce;
            }
            FwState::RoundReduce => {
                if ep.rank == 0 {
                    ep.coll_reduce_vec_u64(self.coll_seq, &mut self.scratch.steps_of);
                }
                self.state = FwState::RoundFinish;
            }
            FwState::RoundFinish => {
                ep.coll_finish_vec_u64(self.coll_seq, &mut self.scratch.steps_of);
                self.max_steps = self.scratch.steps_of.iter().copied().max().unwrap_or(0);
                self.state = if self.max_steps == 0 {
                    FwState::Sweep
                } else {
                    FwState::ColorStep(0)
                };
            }
            FwState::ColorStep(step) => {
                let ss = self.fw.superstep_size.max(1);
                let lo = (step as usize) * ss;
                let hi = (lo + ss).min(self.pending.len());
                let (lo, hi) = if lo < self.pending.len() {
                    (lo, hi)
                } else {
                    (0, 0)
                };

                // -- compute: color the batch against the current local view
                let mut scans: u64 = 0;
                for &v in &self.pending[lo..hi] {
                    self.st.begin_vertex();
                    let s = lg.csr.xadj[v as usize] as usize;
                    let e = lg.csr.xadj[v as usize + 1] as usize;
                    scans += (e - s) as u64;
                    for &u in &lg.csr.adjncy[s..e] {
                        let cu = self.colors.colors[u as usize];
                        if cu != UNCOLORED {
                            self.st.forbid(cu);
                        }
                    }
                    self.colors.colors[v as usize] = self.st.pick();
                    self.colored_at[v as usize] = epoch(self.round, step);
                }
                ep.clock += self.cost.color_cost((hi - lo) as u64, scans);

                // -- stage + send this batch's boundary colors
                for u in self.scratch.upd.iter_mut() {
                    u.clear();
                }
                for &v in &self.pending[lo..hi] {
                    if !lg.is_boundary[v as usize] {
                        continue;
                    }
                    self.scratch.parts.clear();
                    let s = lg.csr.xadj[v as usize] as usize;
                    let e = lg.csr.xadj[v as usize + 1] as usize;
                    for &u in &lg.csr.adjncy[s..e] {
                        if (u as usize) >= n_owned {
                            self.scratch.parts.push(lg.owner[u as usize] as usize);
                        }
                    }
                    self.scratch.parts.sort_unstable();
                    self.scratch.parts.dedup();
                    for &q in self.scratch.parts.iter() {
                        let qi = lg.neighbor_procs.binary_search(&q).unwrap();
                        self.scratch.upd[qi]
                            .push((lg.global_ids[v as usize], self.colors.colors[v as usize]));
                    }
                }
                if step < self.my_steps {
                    for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                        let mut payload = ep.take_buf();
                        comm::encode_pairs_into(&self.scratch.upd[qi], &mut payload);
                        ep.clock += self.cost.pack_cost(payload.len() as u64);
                        ep.send(q, MsgKind::Colors, self.round, step as u32, payload);
                    }
                }
                self.state = FwState::ExchangeStep(step);
            }
            FwState::ExchangeStep(step) => {
                for &q in &lg.neighbor_procs {
                    if step >= self.scratch.steps_of[q] {
                        continue; // that sender had no batch this superstep
                    }
                    ep.try_recv_into(
                        q,
                        MsgKind::Colors,
                        self.round,
                        step as u32,
                        &mut self.scratch.dec,
                    );
                    ep.clock += self.cost.pack_cost(self.scratch.dec.len() as u64);
                    for (gid, c) in comm::decode_pairs_iter(&self.scratch.dec) {
                        let li = lg.local_of(gid) as usize;
                        self.colors.colors[li] = c;
                        self.colored_at[li] = epoch(self.round, step);
                    }
                }
                emit_rank0(
                    self.obs,
                    ep.rank,
                    Event::SuperstepDone {
                        round: self.round,
                        step: step as u32,
                    },
                );
                let next = step + 1;
                self.state = if next < self.max_steps {
                    FwState::ColorStep(next)
                } else {
                    FwState::Sweep
                };
            }
            FwState::Sweep => {
                self.losers.clear();
                let mut sweep_scans: u64 = 0;
                for &v in &self.pending {
                    if !lg.is_boundary[v as usize] {
                        continue;
                    }
                    let cv = self.colors.colors[v as usize];
                    let ev = self.colored_at[v as usize];
                    let s = lg.csr.xadj[v as usize] as usize;
                    let e = lg.csr.xadj[v as usize + 1] as usize;
                    sweep_scans += (e - s) as u64;
                    let mut lost = false;
                    for &u in &lg.csr.adjncy[s..e] {
                        let ui = u as usize;
                        if ui < n_owned
                            || self.colors.colors[ui] != cv
                            || self.colored_at[ui] != ev
                        {
                            continue;
                        }
                        if loses(lg.global_ids[v as usize], lg.global_ids[ui], self.fw.seed) {
                            lost = true;
                            self.metrics.conflicts += 1;
                        }
                    }
                    if lost {
                        self.losers.push(v);
                    }
                }
                ep.clock += self.cost.color_cost(0, sweep_scans);
                self.coll_acc = self.losers.len() as u64;
                self.coll_seq = ep.coll_send_u64(self.coll_acc);
                self.state = FwState::SweepReduce;
            }
            FwState::SweepReduce => {
                if ep.rank == 0 {
                    self.coll_acc =
                        ep.coll_reduce_u64(self.coll_seq, self.coll_acc, u64::wrapping_add);
                }
                self.state = FwState::SweepFinish;
            }
            FwState::SweepFinish => {
                let global_losers = ep.coll_finish_u64(self.coll_seq, self.coll_acc);
                emit_rank0(
                    self.obs,
                    ep.rank,
                    Event::ConflictRound {
                        round: self.round,
                        conflicts: global_losers,
                    },
                );
                if global_losers == 0 {
                    self.finish(ep);
                } else if self.round >= self.fw.max_rounds {
                    self.state = FwState::CleanupSend(0);
                } else {
                    std::mem::swap(&mut self.pending, &mut self.losers);
                    self.state = FwState::RoundBegin;
                }
            }
            FwState::CleanupSend(r) => {
                let tag = self.round + 1;
                if ep.rank == r {
                    let mut scans: u64 = 0;
                    for u in self.scratch.upd.iter_mut() {
                        u.clear();
                    }
                    for &v in &self.losers {
                        self.st.begin_vertex();
                        let s = lg.csr.xadj[v as usize] as usize;
                        let e = lg.csr.xadj[v as usize + 1] as usize;
                        scans += (e - s) as u64;
                        for &u in &lg.csr.adjncy[s..e] {
                            let cu = self.colors.colors[u as usize];
                            if cu != UNCOLORED {
                                self.st.forbid(cu);
                            }
                        }
                        self.colors.colors[v as usize] = self.st.pick();
                        self.scratch.parts.clear();
                        for &u in &lg.csr.adjncy[s..e] {
                            if (u as usize) >= n_owned {
                                self.scratch.parts.push(lg.owner[u as usize] as usize);
                            }
                        }
                        self.scratch.parts.sort_unstable();
                        self.scratch.parts.dedup();
                        for &q in self.scratch.parts.iter() {
                            let qi = lg.neighbor_procs.binary_search(&q).unwrap();
                            self.scratch.upd[qi]
                                .push((lg.global_ids[v as usize], self.colors.colors[v as usize]));
                        }
                    }
                    ep.clock += self.cost.color_cost(self.losers.len() as u64, scans);
                    for (qi, &q) in lg.neighbor_procs.iter().enumerate() {
                        let mut payload = ep.take_buf();
                        comm::encode_pairs_into(&self.scratch.upd[qi], &mut payload);
                        ep.send(q, MsgKind::Colors, tag, r as u32, payload);
                    }
                }
                self.state = FwState::CleanupRecv(r);
            }
            FwState::CleanupRecv(r) => {
                let tag = self.round + 1;
                if ep.rank != r && lg.neighbor_procs.binary_search(&r).is_ok() {
                    ep.try_recv_into(r, MsgKind::Colors, tag, r as u32, &mut self.scratch.dec);
                    for (gid, c) in comm::decode_pairs_iter(&self.scratch.dec) {
                        self.colors.colors[lg.local_of(gid) as usize] = c;
                    }
                }
                if r + 1 < lg.nprocs {
                    self.state = FwState::CleanupSend(r + 1);
                } else {
                    self.round += 1;
                    self.finish(ep);
                }
            }
            FwState::Finished => {}
        }
        self.is_finished()
    }
}

impl crate::dist::engine::StepProcess for FrameworkStep<'_> {
    fn poll_ready(&mut self, ep: &mut Endpoint) -> bool {
        self.ready(ep)
    }

    /// Standalone use of the framework on the engine: once finished, the
    /// result carries the endpoint's cumulative accounting, exactly as a
    /// thread-runner closure wrapping [`color_process`] would report.
    fn step(&mut self, ep: &mut Endpoint) -> crate::dist::engine::StepOutcome {
        use crate::dist::engine::StepOutcome;
        if !self.step_once(ep) {
            return StepOutcome::Running;
        }
        let colors = std::mem::replace(&mut self.colors, ColorState { colors: Vec::new() });
        let mut metrics = std::mem::take(&mut self.metrics);
        metrics.vtime = ep.clock;
        metrics.sent_msgs = ep.sent_msgs;
        metrics.sent_bytes = ep.sent_bytes;
        metrics.recv_msgs = ep.recv_msgs;
        metrics.dropped_msgs = ep.dropped_msgs;
        metrics.non_teardown_drops = ep.non_teardown_drops;
        StepOutcome::Done(crate::dist::ProcResult {
            colors: colors.owned_pairs(self.lg),
            metrics,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::cost::NetworkModel;
    use crate::dist::proc::build_local_graphs;
    use crate::graph::synth;
    use crate::partition::{self, Partitioner};

    #[test]
    fn loses_is_antisymmetric_and_seed_dependent() {
        for seed in [0u64, 7, 0xDEAD] {
            for (u, v) in [(0u32, 1u32), (5, 9), (1000, 17)] {
                assert_ne!(loses(u, v, seed), loses(v, u, seed));
            }
        }
        // some pair flips across seeds (priorities are seed-derived)
        let flips = (0..64u32)
            .filter(|&i| loses(2 * i, 2 * i + 1, 1) != loses(2 * i, 2 * i + 1, 2))
            .count();
        assert!(flips > 0);
    }

    /// End-to-end over raw endpoints: a 2-proc framework run colors a path
    /// validly and deterministically.
    fn run_two_procs(sync: bool) -> (Vec<(u32, u32)>, Vec<ProcMetrics>, f64) {
        let g = synth::grid2d(10, 10);
        let part = partition::partition(&g, Partitioner::Block, 2, 1);
        let (_, locals) = build_local_graphs(&g, &part);
        let eps = comm::network(2, NetworkModel::default());
        let fw = FrameworkConfig {
            superstep_size: 16,
            sync,
            ..Default::default()
        };
        let cost = CostModel::fixed();
        let mut outs: Vec<Option<(Vec<(u32, u32)>, ProcMetrics, f64)>> = vec![None, None];
        std::thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .zip(locals.iter())
                .map(|(ep, lg)| {
                    let fw = &fw;
                    let cost = &cost;
                    s.spawn(move || {
                        let mut ep = ep;
                        let mut state = ColorState::uncolored(lg);
                        let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
                        let m = color_process(&mut ep, lg, fw, cost, &mut state, to, None, None);
                        (state.owned_pairs(lg), m, ep.clock)
                    })
                })
                .collect();
            for (i, h) in hs.into_iter().enumerate() {
                outs[i] = Some(h.join().unwrap());
            }
        });
        let mut pairs = Vec::new();
        let mut ms = Vec::new();
        let mut makespan: f64 = 0.0;
        for (p, m, c) in outs.into_iter().map(|o| o.unwrap()) {
            pairs.extend(p);
            ms.push(m);
            makespan = makespan.max(c);
        }
        pairs.sort_unstable();
        (pairs, ms, makespan)
    }

    #[test]
    fn framework_two_procs_valid_and_deterministic() {
        let (a, ms, _) = run_two_procs(true);
        let (b, _, _) = run_two_procs(true);
        assert_eq!(a, b, "sync framework must be deterministic");
        let g = synth::grid2d(10, 10);
        let mut coloring = crate::color::Coloring::uncolored(100);
        for (gid, c) in &a {
            coloring.set(*gid, *c);
        }
        coloring.validate(&g).unwrap();
        assert!(ms.iter().all(|m| m.rounds >= 1));
    }

    #[test]
    fn async_same_colors_lower_virtual_time() {
        let (a, _, t_sync) = run_two_procs(true);
        let (b, _, t_async) = run_two_procs(false);
        assert_eq!(a, b, "modes differ only in clock accounting");
        assert!(
            t_async <= t_sync,
            "async {t_async} should not exceed sync {t_sync}"
        );
    }

    /// The step-machine port must be bit-for-bit equal to `color_process`
    /// on the thread runner: colors, per-proc messages/bytes, conflicts,
    /// and virtual clocks.
    #[test]
    fn framework_step_machine_matches_thread_runner_bit_for_bit() {
        use crate::dist::{engine, runner};
        let g = synth::fem_like(700, 9.0, 24, 0.01, 3, "fw-step");
        for (procs, sync, ss) in [(1usize, true, 64), (3, true, 16), (5, false, 7), (4, true, 1)] {
            let part = partition::partition(&g, Partitioner::Block, procs, 1);
            let (_, locals) = build_local_graphs(&g, &part);
            let fw = FrameworkConfig {
                superstep_size: ss,
                sync,
                selection: crate::color::Selection::RandomX(6),
                ..Default::default()
            };
            let cost = CostModel::fixed();
            let net = NetworkModel::default();
            let by_threads = runner::run_distributed_with(&g, &locals, net, |ep, lg| {
                let mut state = ColorState::uncolored(lg);
                let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
                let mut m = color_process(ep, lg, &fw, &cost, &mut state, to, None, None);
                m.vtime = ep.clock;
                m.sent_msgs = ep.sent_msgs;
                m.sent_bytes = ep.sent_bytes;
                m.recv_msgs = ep.recv_msgs;
                m.dropped_msgs = ep.dropped_msgs;
                crate::dist::ProcResult {
                    colors: state.owned_pairs(lg),
                    metrics: m,
                }
            });
            let by_engine = engine::run_steps(g.num_vertices(), &locals, net, |lg| {
                let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
                FrameworkStep::new(lg, &fw, &cost, ColorState::uncolored(lg), to, None, None)
            });
            assert_eq!(
                by_threads.coloring.colors, by_engine.coloring.colors,
                "colors diverged (procs={procs} sync={sync} ss={ss})"
            );
            for (a, b) in by_threads.per_proc.iter().zip(by_engine.per_proc.iter()) {
                assert_eq!(a.rank, b.rank);
                assert_eq!(a.sent_msgs, b.sent_msgs, "p{} msgs", a.rank);
                assert_eq!(a.sent_bytes, b.sent_bytes, "p{} bytes", a.rank);
                assert_eq!(a.recv_msgs, b.recv_msgs, "p{} recvs", a.rank);
                assert_eq!(a.conflicts, b.conflicts, "p{} conflicts", a.rank);
                assert_eq!(a.rounds, b.rounds, "p{} rounds", a.rank);
                assert_eq!(
                    a.vtime.to_bits(),
                    b.vtime.to_bits(),
                    "p{} virtual clock diverged",
                    a.rank
                );
                assert_eq!(a.dropped_msgs, 0);
                assert_eq!(b.dropped_msgs, 0);
            }
        }
    }

    /// The serialized cleanup path (max_rounds exceeded) must also agree
    /// across execution paths.
    #[test]
    fn framework_step_machine_matches_on_cleanup_path() {
        use crate::dist::{engine, runner};
        let g = synth::erdos_renyi(400, 2400, 17);
        let part = partition::partition(&g, Partitioner::Block, 4, 1);
        let (_, locals) = build_local_graphs(&g, &part);
        // max_rounds 1 forces the serialized cleanup almost surely
        let fw = FrameworkConfig {
            superstep_size: 8,
            max_rounds: 1,
            ..Default::default()
        };
        let cost = CostModel::fixed();
        let net = NetworkModel::default();
        let by_threads = runner::run_distributed_with(&g, &locals, net, |ep, lg| {
            let mut state = ColorState::uncolored(lg);
            let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
            let mut m = color_process(ep, lg, &fw, &cost, &mut state, to, None, None);
            m.vtime = ep.clock;
            m.sent_msgs = ep.sent_msgs;
            m.sent_bytes = ep.sent_bytes;
            crate::dist::ProcResult {
                colors: state.owned_pairs(lg),
                metrics: m,
            }
        });
        let by_engine = engine::run_steps(g.num_vertices(), &locals, net, |lg| {
            let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
            FrameworkStep::new(lg, &fw, &cost, ColorState::uncolored(lg), to, None, None)
        });
        by_threads.coloring.validate(&g).unwrap();
        assert_eq!(by_threads.coloring.colors, by_engine.coloring.colors);
        for (a, b) in by_threads.per_proc.iter().zip(by_engine.per_proc.iter()) {
            assert_eq!(a.sent_msgs, b.sent_msgs);
            assert_eq!(a.sent_bytes, b.sent_bytes);
            assert_eq!(a.vtime.to_bits(), b.vtime.to_bits());
            assert_eq!(a.rounds, b.rounds);
        }
    }
}
