//! `dgcolor` — distributed graph coloring with iterative recoloring.
//!
//! Subcommands:
//!   info       --graph <spec>                     graph summary
//!   generate   --graph <spec> --out <file.mtx>    write a generated graph
//!   partition  --graph <spec> --procs P           partition quality
//!   seq        --graph <spec> [--ordering O] [--selection S] [--recolor N]
//!   color      --graph <spec> --procs P [framework/recoloring options]
//!   kernel     --graph <spec>                     kernel-backend coloring
//!
//! Graph specs: `path/to/file.mtx`, `grid:ROWSxCOLS`, `er:N:M`,
//! `rmat-er:SCALE[:EF]`, `rmat-good:SCALE[:EF]`, `rmat-bad:SCALE[:EF]`,
//! `fem:N:AVGDEG:MAXDEG`, or a Table-1 name (`auto`, `bmw3_2`, `hood`,
//! `ldoor`, `msdoor`, `pwtk`) at `--scale` fraction of paper size.

use dgcolor::bail;
use dgcolor::color::recolor::{self, RecolorSchedule};
use dgcolor::util::error::{Context, Error, Result};
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::{ColoringConfig, Job, JsonLines, Session};
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::graph::{mtx, stats, synth, CsrGraph};
use dgcolor::partition::{self, Partitioner};
use dgcolor::util::args::Args;
use dgcolor::util::table::{fmt_secs, Table};
use dgcolor::util::rng::Rng;
use dgcolor::util::timer::Timer;
use std::path::Path;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let (sub, args) = Args::from_env()?.subcommand();
    // `dgcolor <sub> --help` / `-h` prints the subcommand's usage instead
    // of failing on a missing --graph. Scan raw argv: the parser would
    // otherwise swallow `-h` as the value of a preceding boolean flag
    // (`dgcolor color --json -h`).
    let want_help = std::env::args()
        .skip(1)
        .any(|a| a == "--help" || a == "-h");
    match sub.as_deref() {
        Some("-h") | Some("help") => {
            print_help();
            Ok(())
        }
        Some(cmd) if want_help => match usage_for(cmd) {
            Some(usage) => {
                println!("{usage}");
                Ok(())
            }
            None => bail!("unknown subcommand {cmd:?} (try --help)"),
        },
        Some("info") => cmd_info(&args),
        Some("generate") => cmd_generate(&args),
        Some("partition") => cmd_partition(&args),
        Some("seq") => cmd_seq(&args),
        Some("color") => cmd_color(&args),
        Some("kernel") => cmd_kernel(&args),
        Some(other) => bail!("unknown subcommand {other:?} (try --help)"),
        None => {
            print_help();
            Ok(())
        }
    }
}

/// Per-subcommand usage text (`dgcolor <sub> --help`).
fn usage_for(cmd: &str) -> Option<&'static str> {
    match cmd {
        "info" => Some(
            "usage: dgcolor info --graph <spec>\n\
             \n\
             Print a summary (|V|, |E|, Δ, average degree) of the graph.",
        ),
        "generate" => Some(
            "usage: dgcolor generate --graph <spec> --out <file.mtx>\n\
             \n\
             Materialize a generated graph as a Matrix-Market file.",
        ),
        "partition" => Some(
            "usage: dgcolor partition --graph <spec> [--procs P] [--partitioner block|bfs]\n\
             \u{20}                        [--seed S]\n\
             \n\
             Partition the graph and report edge cut, boundary size and imbalance.",
        ),
        "seq" => Some(
            "usage: dgcolor seq --graph <spec> [--ordering nat|lf|sl|if|bf] [--selection ff|sff|lu|r<X>]\n\
             \u{20}                  [--recolor N] [--schedule nd|ni|rv|rand|ND-RAND%x] [--distance 1|2]\n\
             \u{20}                  [--seed S]\n\
             \n\
             Sequential greedy coloring with optional Culberson iterated-greedy recoloring.",
        ),
        "color" => Some(
            "usage: dgcolor color --graph <spec> [--procs P] [--ordering O] [--selection S]\n\
             \u{20}                    [--superstep N] [--async] [--recolor N] [--arc]\n\
             \u{20}                    [--schedule nd|ni|rv|rand|ND-RAND%x] [--scheme base|piggyback]\n\
             \u{20}                    [--stop-eps F] [--partitioner block|bfs] [--seed S]\n\
             \u{20}                    [--ideal-net] [--engine auto|threads|bsp|datapar] [--json]\n\
             \u{20}                    [--faults seed=S[,delay=P][,reorder=P][,loss=P][,crash=R@S[+D]]...]\n\
             \u{20}                    [--ckpt-interval N]\n\
             \u{20}                    [--deadline SECS] [--vbudget VSECS] [--degrade]\n\
             \u{20}                    [--priority interactive|sweep]\n\
             \n\
             Distributed coloring with optional iterative recoloring.\n\
             --stop-eps F  stop recoloring once an iteration improves the color\n\
             \u{20}             count by less than the relative fraction F\n\
             --engine E    execution path: bsp step engine (default via auto) or\n\
             \u{20}             one OS thread per simulated process; every job shape\n\
             \u{20}             (no recoloring, RC and aRC) runs on either engine\n\
             \u{20}             with bit-for-bit identical results, only wallclock\n\
             \u{20}             differs; the effective engine is reported in --json.\n\
             \u{20}             datapar instead runs a shared-memory speculative\n\
             \u{20}             coloring loop (no simulated transport): colorings\n\
             \u{20}             differ from the transport engines' but stay\n\
             \u{20}             deterministic per seed regardless of worker count;\n\
             \u{20}             it rejects --recolor/--arc and --faults, and auto\n\
             \u{20}             never selects it\n\
             --faults SPEC inject seeded transport faults (message delay,\n\
             \u{20}             reorder and per-transmission loss probabilities,\n\
             \u{20}             plus any number of crash=R@S[+D] crash-stops of\n\
             \u{20}             rank R at step S for D steps) on the supervised\n\
             \u{20}             bsp engine; loss activates reliable delivery\n\
             \u{20}             (acks + retransmission with a finite retry cap);\n\
             \u{20}             works with every recoloring mode (aRC included) but\n\
             \u{20}             not with --engine threads or datapar; conflicts left\n\
             \u{20}             by faults are repaired after Done\n\
             --ckpt-interval N  supervised checkpoint cadence in engine steps\n\
             \u{20}             (default 1 = every step); N>1 makes revived ranks\n\
             \u{20}             replay the steps since their last checkpoint, with\n\
             \u{20}             receiver-side dedup absorbing the replayed sends\n\
             --json        stream one JSON event per phase/superstep/iteration\n\
             \u{20}             (plus a final result record) instead of the table\n\
             \n\
             Service knobs (the scheduler uses the same four):\n\
             --deadline S  wall-clock deadline in seconds; the run stops at its\n\
             \u{20}             next engine checkpoint once it passes (any engine)\n\
             --vbudget V   virtual-clock budget in modeled seconds — the\n\
             \u{20}             deterministic stop knob: the same job stops at the\n\
             \u{20}             same checkpoint every run; transport engines only\n\
             \u{20}             (datapar has no virtual clock and rejects it)\n\
             --degrade     on a stop, return the best-so-far coloring repaired\n\
             \u{20}             to validity and flagged degraded, instead of the\n\
             \u{20}             typed cancelled/deadline-exceeded error\n\
             --priority C  scheduling class (interactive|sweep) under the\n\
             \u{20}             library Scheduler; a direct CLI run ignores it",
        ),
        "kernel" => Some(
            "usage: dgcolor kernel --graph <spec> [--selection ff|r<X>] [--seed S]\n\
             \n\
             Color through the AOT-compiled Pallas kernels over PJRT\n\
             (requires `make artifacts` and a build with --features xla).",
        ),
        _ => None,
    }
}

fn print_help() {
    println!(
        "dgcolor — distributed graph coloring with iterative recoloring\n\
         \n\
         usage: dgcolor <info|generate|partition|seq|color|kernel> --graph <spec> [options]\n\
         \u{20}      dgcolor <subcommand> --help for per-subcommand options\n\
         \n\
         graph specs: file.mtx | grid:RxC | er:N:M | rmat-(er|good|bad):SCALE[:EF]\n\
         \u{20}             | fem:N:AVG:MAX | auto|bmw3_2|hood|ldoor|msdoor|pwtk [--scale F]\n\
         \n\
         color options: --procs P --ordering nat|lf|sl|if|bf --selection ff|sff|lu|r<X>\n\
         \u{20}              --superstep N --async --recolor N --schedule nd|ni|rv|rand|ND-RAND%x\n\
         \u{20}              --scheme base|piggyback --arc --partitioner block|bfs --seed S\n\
         \u{20}              --stop-eps F (early-stop recoloring) --engine auto|threads|bsp|datapar\n\
         \u{20}              --faults SPEC (seeded fault injection) --json (stream events)\n\
         \u{20}              --deadline S --vbudget V --degrade --priority interactive|sweep"
    );
}

/// Resolve a graph spec (see module docs).
pub fn load_graph(args: &Args) -> Result<CsrGraph> {
    let spec = args.get_str("graph").context("missing --graph <spec>")?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    if spec.ends_with(".mtx") {
        return mtx::read_mtx(Path::new(spec));
    }
    let parts: Vec<&str> = spec.split(':').collect();
    let g = match parts[0] {
        "grid" => {
            let dims: Vec<usize> = parts[1]
                .split('x')
                .map(|s| s.parse().context("grid dims"))
                .collect::<Result<_>>()?;
            synth::grid2d(dims[0], dims[1])
        }
        "er" => synth::erdos_renyi(parts[1].parse()?, parts[2].parse()?, seed),
        "fem" => synth::fem_like(
            parts[1].parse()?,
            parts[2].parse()?,
            parts[3].parse()?,
            0.005,
            seed,
            spec,
        ),
        "rmat-er" | "rmat-good" | "rmat-bad" => {
            let scale: u32 = parts[1].parse()?;
            let ef: usize = if parts.len() > 2 { parts[2].parse()? } else { 8 };
            let p = match parts[0] {
                "rmat-er" => RmatParams::er(scale, ef),
                "rmat-good" => RmatParams::good(scale, ef),
                _ => RmatParams::bad(scale, ef),
            };
            rmat::generate(&p, seed, parts[0])
        }
        name => {
            let spec = synth::TABLE1_SPECS
                .iter()
                .find(|s| s.name == name)
                .with_context(|| format!("unknown graph spec {name:?}"))?;
            let scale: f64 = args.get_or("scale", 0.1f64)?;
            synth::paper_graph(spec, scale, seed)
        }
    };
    Ok(g)
}

fn cmd_info(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let s = stats::summarize(&g);
    let mut t = Table::new(&format!("graph {}", s.name), &["metric", "value"]);
    t.row(&["|V|", &s.num_vertices.to_string()]);
    t.row(&["|E|", &s.num_edges.to_string()]);
    t.row(&["Δ", &s.max_degree.to_string()]);
    t.row(&["avg degree", &format!("{:.2}", s.avg_degree)]);
    t.row(&["isolated", &s.isolated.to_string()]);
    t.print();
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let out = args.get_str("out").context("missing --out <file.mtx>")?;
    mtx::write_mtx(&g, Path::new(out))?;
    println!("wrote {} (|V|={} |E|={})", out, g.num_vertices(), g.num_edges());
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let procs: usize = args.get_or("procs", 4usize)?;
    let method: Partitioner = args
        .str_or("partitioner", "bfs")
        .parse()
        .map_err(Error::msg)?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let t = Timer::start();
    let p = partition::partition(&g, method, procs, seed);
    let m = partition::metrics(&g, &p);
    let mut tab = Table::new(
        &format!("{method:?} partition of {} into {procs}", g.name),
        &["metric", "value"],
    );
    tab.row(&["edge cut", &m.edge_cut.to_string()]);
    tab.row(&["boundary vertices", &m.boundary_vertices.to_string()]);
    tab.row(&["imbalance", &format!("{:.3}", m.imbalance)]);
    tab.row(&["partition time", &fmt_secs(t.secs())]);
    tab.print();
    Ok(())
}

fn cmd_seq(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let ordering: Ordering = args
        .str_or("ordering", "nat")
        .parse()
        .map_err(Error::msg)?;
    let selection: Selection = args
        .str_or("selection", "ff")
        .parse()
        .map_err(Error::msg)?;
    let iters: u32 = args.get_or("recolor", 0u32)?;
    let schedule: RecolorSchedule = args
        .str_or("schedule", "nd")
        .parse()
        .map_err(Error::msg)?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let distance: u32 = args.get_or("distance", 1u32)?;

    let t = Timer::start();
    let c0 = match distance {
        1 => greedy_color(&g, ordering, selection, seed),
        2 => dgcolor::color::distance2::greedy_color_d2(&g, ordering, selection, seed),
        d => bail!("unsupported --distance {d} (1|2)"),
    };
    let t_color = t.secs();
    if distance == 2 {
        dgcolor::color::distance2::validate_d2(&g, &c0)
            .map_err(|(u, v)| dgcolor::err!("distance-2 conflict ({u},{v})"))?;
    } else {
        c0.validate(&g).map_err(|e| dgcolor::err!("{e}"))?;
    }

    let mut tab = Table::new(
        &format!("sequential coloring of {}", g.name),
        &["metric", "value"],
    );
    tab.row(&["ordering", ordering.short_name()]);
    tab.row(&["selection", &selection.short_name()]);
    tab.row(&["colors", &c0.num_colors().to_string()]);
    tab.row(&["time", &fmt_secs(t_color)]);
    if iters > 0 {
        let mut rng = Rng::new(seed);
        let t = Timer::start();
        let (cr, trace) = if distance == 2 {
            let mut c = c0.clone();
            let mut trace = vec![c.num_colors()];
            for i in 1..=iters {
                c = dgcolor::color::distance2::recolor_once_d2(
                    &g,
                    &c,
                    schedule.permutation_at(i),
                    &mut rng,
                );
                trace.push(c.num_colors());
            }
            dgcolor::color::distance2::validate_d2(&g, &c)
                .map_err(|(u, v)| dgcolor::err!("distance-2 conflict ({u},{v})"))?;
            (c, trace)
        } else {
            recolor::recolor_iterate(&g, &c0, schedule, iters, &mut rng)
        };
        if distance == 1 {
            cr.validate(&g).map_err(|e| dgcolor::err!("{e}"))?;
        }
        tab.row(&["recolor schedule", &schedule.label()]);
        tab.row(&["recolor iterations", &iters.to_string()]);
        tab.row(&["colors after recoloring", &cr.num_colors().to_string()]);
        tab.row(&["recolor time", &fmt_secs(t.secs())]);
        tab.row(&["trace", &format!("{trace:?}")]);
    }
    tab.print();
    Ok(())
}

fn cmd_color(args: &Args) -> Result<()> {
    let session = Session::new(load_graph(args)?);
    let cfg = ColoringConfig::from_args(args)?;
    let job = Job::from_config(cfg.clone())?;
    if args.has_flag("json") {
        let r = session.run_observed(&job, &JsonLines)?;
        println!("{}", r.summary_json());
        return Ok(());
    }
    let r = session.run(&job)?;
    let mut tab = Table::new(
        &format!(
            "distributed coloring of {} [{}]",
            session.graph().name,
            r.config_label
        ),
        &["metric", "value"],
    );
    tab.row(&["processes", &cfg.num_procs.to_string()]);
    tab.row(&["engine", r.engine.name()]);
    if r.degraded {
        tab.row(&["degraded", "yes (stopped early, best-so-far repaired)"]);
    }
    tab.row(&["colors", &r.num_colors.to_string()]);
    tab.row(&["initial colors", &r.initial_colors.to_string()]);
    tab.row(&["recolor trace", &format!("{:?}", r.recolor_trace)]);
    tab.row(&["virtual makespan", &fmt_secs(r.metrics.makespan)]);
    tab.row(&["messages", &r.metrics.total_msgs.to_string()]);
    tab.row(&["bytes", &r.metrics.total_bytes.to_string()]);
    tab.row(&["conflicts", &r.metrics.total_conflicts.to_string()]);
    tab.row(&["rounds", &r.metrics.rounds.to_string()]);
    tab.row(&["edge cut", &r.partition_metrics.edge_cut.to_string()]);
    tab.row(&["sim wallclock", &fmt_secs(r.metrics.wall_secs)]);
    if let Some(dp) = &r.datapar {
        tab.row(&["datapar speculated", &dp.speculated.to_string()]);
        tab.row(&["datapar conflicted", &dp.conflicted.to_string()]);
        tab.row(&["datapar chunks", &dp.chunks.to_string()]);
        tab.row(&["datapar workers", &dp.workers.to_string()]);
    }
    tab.print();
    Ok(())
}

fn cmd_kernel(args: &Args) -> Result<()> {
    use dgcolor::color::Coloring;
    use dgcolor::runtime::{BatchColorer, KernelRuntime};
    if !KernelRuntime::artifacts_present() {
        bail!("kernel runtime unavailable — run `make artifacts` and build with `--features xla`");
    }
    let g = load_graph(args)?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let x: Option<u32> = match args.get_str("selection") {
        Some(s) => match s.parse::<Selection>().map_err(Error::msg)? {
            Selection::FirstFit => None,
            Selection::RandomX(x) => Some(x),
            other => bail!("kernel backend supports ff|r<X>, not {other:?}"),
        },
        None => None,
    };
    let rt = KernelRuntime::load(&KernelRuntime::artifacts_dir())?;
    let mut bc = BatchColorer::new(rt, seed);
    let order: Vec<u32> = (0..g.num_vertices() as u32).collect();
    let mut c = Coloring::uncolored(g.num_vertices());
    let t = Timer::start();
    bc.color_sequence(&g, &order, x, &mut c)?;
    let secs = t.secs();
    c.validate(&g).map_err(|e| dgcolor::err!("{e}"))?;
    let mut tab = Table::new(
        &format!("kernel-backend coloring of {}", g.name),
        &["metric", "value"],
    );
    tab.row(&["colors", &c.num_colors().to_string()]);
    tab.row(&["kernel calls", &bc.kernel_calls.to_string()]);
    tab.row(&["native fallbacks", &bc.fallbacks.to_string()]);
    tab.row(&["time", &fmt_secs(secs)]);
    tab.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_subcommand_has_usage() {
        for cmd in ["info", "generate", "partition", "seq", "color", "kernel"] {
            let u = usage_for(cmd).unwrap();
            assert!(
                u.starts_with(&format!("usage: dgcolor {cmd}")),
                "usage for {cmd} malformed"
            );
            assert!(u.contains("--graph"), "{cmd} usage must mention --graph");
        }
        assert!(usage_for("nope").is_none());
    }

    #[test]
    fn color_usage_documents_new_flags() {
        let u = usage_for("color").unwrap();
        assert!(u.contains("--stop-eps"));
        assert!(u.contains("--json"));
        assert!(u.contains("--faults"));
        assert!(u.contains("crash=R@S"));
        assert!(u.contains("loss=P"));
        assert!(u.contains("--ckpt-interval N"));
        assert!(u.contains("retry cap"));
        assert!(u.contains("replay the steps since their last checkpoint"));
        // the validation matrix: aRC runs on both transport engines,
        // faults exclude threads and datapar, datapar rejects recoloring
        assert!(u.contains("aRC included"));
        assert!(u.contains("not with --engine threads or datapar"));
        assert!(u.contains("--engine auto|threads|bsp|datapar"));
        assert!(u.contains("rejects --recolor/--arc and --faults"));
    }

    #[test]
    fn color_usage_documents_service_knobs() {
        let u = usage_for("color").unwrap();
        // the help matrix for the service layer: all four knobs, the
        // engine restriction on the virtual budget, and both stop
        // behaviors (typed error vs degraded result)
        assert!(u.contains("--deadline SECS"));
        assert!(u.contains("--vbudget VSECS"));
        assert!(u.contains("--degrade"));
        assert!(u.contains("--priority interactive|sweep"));
        assert!(u.contains("datapar has no virtual clock"));
        assert!(u.contains("deadline-exceeded"));
        assert!(u.contains("flagged degraded"));
    }
}
