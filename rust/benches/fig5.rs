//! Fig 5: recoloring on the real-world graphs — FSS (First-Fit + SL + sync)
//! vs FSS+RC (synchronous, piggybacked) vs FSS+aRC, normalized colors and
//! normalized virtual runtime vs processor count. Sequential LF/SL lines
//! printed as quality references. One session per graph: every
//! (mode, procs) job reuses the session's cached partitions.

#[path = "common.rs"]
mod common;

use dgcolor::color::recolor::Permutation;
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::RecolorMode;
use dgcolor::dist::recolor::RecolorConfig;
use dgcolor::util::table::Table;

fn main() {
    common::print_header("Fig 5 — FSS vs FSS+RC vs FSS+aRC on real-world graphs");
    let sessions = common::real_world_sessions();
    // baselines: NAT colors + NAT virtual time at P=1
    let mut base_colors = Vec::new();
    let mut base_time = Vec::new();
    for (_, s) in &sessions {
        let mut cfg = common::base_cfg(1);
        cfg.ordering = Ordering::Natural;
        let r = common::run(s, cfg);
        base_colors.push(r.num_colors as f64);
        base_time.push(r.metrics.makespan.max(1e-12));
    }
    let seq_lf: Vec<f64> = sessions
        .iter()
        .map(|(_, s)| {
            greedy_color(s.graph(), Ordering::LargestFirst, Selection::FirstFit, 1).num_colors()
                as f64
        })
        .collect();
    let seq_sl: Vec<f64> = sessions
        .iter()
        .map(|(_, s)| {
            greedy_color(s.graph(), Ordering::SmallestLast, Selection::FirstFit, 1).num_colors()
                as f64
        })
        .collect();
    println!(
        "sequential references: LF = {:.3}, SL = {:.3} (normalized colors)",
        common::norm_geo(&seq_lf, &base_colors),
        common::norm_geo(&seq_sl, &base_colors)
    );

    let modes: [(&str, fn(u64) -> RecolorMode); 3] = [
        ("FSS", |_| RecolorMode::None),
        ("FSS+RC", |seed| {
            RecolorMode::Sync(RecolorConfig {
                seed,
                ..Default::default()
            })
        }),
        ("FSS+aRC", |_| RecolorMode::Async {
            perm: Permutation::NonDecreasing,
            iterations: 1,
        }),
    ];

    let mut tc = Table::new(
        "normalized number of colors (geomean)",
        &["procs", "FSS", "FSS+RC", "FSS+aRC"],
    );
    let mut tt = Table::new(
        "normalized virtual runtime (geomean)",
        &["procs", "FSS", "FSS+RC", "FSS+aRC"],
    );
    for &p in &common::procs_list() {
        let mut color_cells = vec![p.to_string()];
        let mut time_cells = vec![p.to_string()];
        for (_, mk) in &modes {
            let mut colors = Vec::new();
            let mut times = Vec::new();
            for (_, s) in &sessions {
                let mut cfg = common::base_cfg(p);
                cfg.ordering = Ordering::SmallestLast;
                cfg.recolor = mk(42);
                let r = common::run(s, cfg);
                colors.push(r.num_colors as f64);
                times.push(r.metrics.makespan.max(1e-12));
            }
            color_cells.push(format!("{:.3}", common::norm_geo(&colors, &base_colors)));
            time_cells.push(format!("{:.3}", common::norm_geo(&times, &base_time)));
        }
        tc.row(&color_cells);
        tt.row(&time_cells);
        // the next proc count is a fresh partition key: bound retention
        for (_, s) in &sessions {
            s.clear_cached_partitions();
        }
    }
    tc.print();
    tt.print();
    tc.save_csv("fig5_colors").unwrap();
    tt.save_csv("fig5_runtime").unwrap();
    println!(
        "shape check (paper): RC stays below sequential-LF colors at high P\n\
         (≈18% better than FSS); aRC between; RC ≈ aRC in runtime thanks to\n\
         piggybacking"
    );
}
