//! Fig 7: impact of the *number* of recoloring iterations on the
//! real-world graphs in distributed memory — normalized colors vs P for
//! 0/1/2/5/10 ND iterations, with sequential LF/SL reference lines. One
//! session per graph: all 5×|procs| jobs share the cached partitions.

#[path = "common.rs"]
mod common;

use dgcolor::color::recolor::{Permutation, RecolorSchedule};
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::RecolorMode;
use dgcolor::dist::recolor::{CommScheme, RecolorConfig};
use dgcolor::util::table::Table;

fn main() {
    common::print_header("Fig 7 — number of recoloring iterations (real-world, distributed)");
    let sessions = common::real_world_sessions();
    let mut base_colors = Vec::new();
    for (_, s) in &sessions {
        base_colors.push(
            greedy_color(s.graph(), Ordering::Natural, Selection::FirstFit, 1).num_colors() as f64,
        );
    }
    let seq_lf: Vec<f64> = sessions
        .iter()
        .map(|(_, s)| {
            greedy_color(s.graph(), Ordering::LargestFirst, Selection::FirstFit, 1).num_colors()
                as f64
        })
        .collect();
    let seq_sl: Vec<f64> = sessions
        .iter()
        .map(|(_, s)| {
            greedy_color(s.graph(), Ordering::SmallestLast, Selection::FirstFit, 1).num_colors()
                as f64
        })
        .collect();
    println!(
        "sequential references: LF = {:.3}, SL = {:.3}",
        common::norm_geo(&seq_lf, &base_colors),
        common::norm_geo(&seq_sl, &base_colors)
    );

    let iter_counts = [0u32, 1, 2, 5, 10];
    let mut t = Table::new(
        "normalized colors (geomean) by recoloring iterations",
        &["procs", "RC0", "RC1", "RC2", "RC5", "RC10"],
    );
    for &p in &common::procs_list() {
        let mut cells = vec![p.to_string()];
        for &iters in &iter_counts {
            let mut colors = Vec::new();
            for (_, s) in &sessions {
                let mut cfg = common::base_cfg(p);
                cfg.ordering = Ordering::SmallestLast;
                cfg.recolor = if iters == 0 {
                    RecolorMode::None
                } else {
                    RecolorMode::Sync(RecolorConfig {
                        schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
                        iterations: iters,
                        scheme: CommScheme::Piggyback,
                        seed: 42,
                        ..Default::default()
                    })
                };
                let r = common::run(s, cfg);
                colors.push(r.num_colors as f64);
            }
            cells.push(format!("{:.3}", common::norm_geo(&colors, &base_colors)));
        }
        t.row(&cells);
        // all iteration counts shared this proc count's partition key
        for (_, s) in &sessions {
            s.clear_cached_partitions();
        }
    }
    t.print();
    t.save_csv("fig7").unwrap();
    println!(
        "shape check (paper): one iteration already beats sequential LF at\n\
         P=512; ten iterations approach sequential SL"
    );
}
