//! Fig 4: base vs piggybacked synchronous recoloring — per real-world
//! graph: one-iteration recoloring time split into preparation (plan) and
//! coloring, plus message counts. The paper reports ~80% fewer messages,
//! 20-70% faster recoloring, and prep ≤ 12% of improved total.

#[path = "common.rs"]
mod common;

use dgcolor::color::recolor::{Permutation, RecolorSchedule};
use dgcolor::color::{greedy_color, Coloring, Ordering, Selection};
use dgcolor::dist::comm::network;
use dgcolor::dist::cost::CostModel;
use dgcolor::dist::proc::{build_local_graphs, ColorState};
use dgcolor::dist::recolor::{recolor_process_sync, CommScheme, RecolorConfig};
use dgcolor::dist::{DistMetrics, NetworkModel, ProcMetrics};
use dgcolor::graph::CsrGraph;
use dgcolor::partition::{self, Partitioner};
use dgcolor::util::bench::full_scale;
use dgcolor::util::table::{fmt_secs, Table};

fn run_scheme(g: &CsrGraph, init: &Coloring, procs: usize, scheme: CommScheme) -> DistMetrics {
    let part = partition::partition(g, Partitioner::BfsGrow, procs, 1);
    let (_, locals) = build_local_graphs(g, &part);
    let cost = CostModel::fixed();
    let eps = network(procs, NetworkModel::default());
    let cfg = RecolorConfig {
        schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
        iterations: 1,
        scheme,
        seed: 11,
        ..Default::default()
    };
    let mut per: Vec<Option<ProcMetrics>> = (0..procs).map(|_| None).collect();
    std::thread::scope(|s| {
        let hs: Vec<_> = eps
            .into_iter()
            .zip(locals.iter())
            .map(|(ep, lg)| {
                s.spawn(move || {
                    let mut ep = ep;
                    let mut state = ColorState::from_global(lg, init);
                    let mut trace = Vec::new();
                    recolor_process_sync(&mut ep, lg, &cost, &cfg, &mut state, &mut trace, None)
                })
            })
            .collect();
        for (i, h) in hs.into_iter().enumerate() {
            per[i] = Some(h.join().unwrap());
        }
    });
    let per: Vec<ProcMetrics> = per.into_iter().map(|m| m.unwrap()).collect();
    DistMetrics::aggregate(&per, 0.0)
}

fn main() {
    common::print_header("Fig 4 — piggybacking: one recoloring iteration, base vs improved");
    let procs = if full_scale() { 512 } else { 64 };
    let mut t = Table::new(
        &format!("base vs piggyback at {procs} procs"),
        &[
            "graph",
            "base msgs",
            "pb msgs",
            "msg reduction",
            "base time",
            "pb time",
            "time gain",
            "prep share",
        ],
    );
    let mut total_red = Vec::new();
    for (spec, g) in common::real_world_graphs() {
        let init = greedy_color(&g, Ordering::SmallestLast, Selection::FirstFit, 5);
        let mb = run_scheme(&g, &init, procs, CommScheme::Base);
        let mp = run_scheme(&g, &init, procs, CommScheme::Piggyback);
        let red = 1.0 - mp.total_msgs as f64 / mb.total_msgs as f64;
        let gain = 1.0 - mp.makespan / mb.makespan;
        let prep = mp.phase_max.get("plan") / mp.makespan;
        total_red.push(red);
        t.row(&[
            spec.name.to_string(),
            mb.total_msgs.to_string(),
            mp.total_msgs.to_string(),
            format!("{:.0}%", red * 100.0),
            fmt_secs(mb.makespan),
            fmt_secs(mp.makespan),
            format!("{:.0}%", gain * 100.0),
            format!("{:.0}%", prep * 100.0),
        ]);
    }
    t.print();
    t.save_csv("fig4").unwrap();
    let avg = total_red.iter().sum::<f64>() / total_red.len() as f64;
    println!(
        "avg message reduction: {:.0}% (paper: ~80% at its scale/colors);\n\
         shape check: piggyback wins time on every graph; prep bounded",
        avg * 100.0
    );
}
