//! Table 2: the three RMAT graphs — V, E, Δ and sequential NAT/LF/SL
//! colors. Paper runs scale 24; bench default scale 16 (REPRO_FULL=1 for
//! paper size). The class structure (ER vs skewed) is scale-invariant.

#[path = "common.rs"]
mod common;

use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::util::table::{fmt_secs, Table};
use dgcolor::util::timer::Timer;

/// Paper's Table 2 reference rows (scale 24).
const PAPER: [(&str, usize, usize, usize, usize, usize, usize); 3] = [
    ("RMAT-ER", 16_777_216, 134_217_624, 42, 12, 10, 10),
    ("RMAT-Good", 16_777_216, 134_181_065, 1_278, 28, 15, 14),
    ("RMAT-Bad", 16_777_216, 133_658_199, 38_143, 146, 89, 88),
];

fn main() {
    common::print_header("Table 2 — synthetic (RMAT) graph properties & sequential coloring");
    let mut t = Table::new(
        "ours vs paper-at-scale-24 (parentheses)",
        &["graph", "|V|", "|E|", "Δ", "NAT", "LF", "SL", "NAT time"],
    );
    for (g, p) in common::rmat_graphs().iter().zip(PAPER.iter()) {
        let timer = Timer::start();
        let nat = greedy_color(g, Ordering::Natural, Selection::FirstFit, 1);
        let t_nat = timer.secs();
        let lf = greedy_color(g, Ordering::LargestFirst, Selection::FirstFit, 1);
        let sl = greedy_color(g, Ordering::SmallestLast, Selection::FirstFit, 1);
        t.row(&[
            g.name.clone(),
            format!("{} ({})", g.num_vertices(), p.1),
            format!("{} ({})", g.num_edges(), p.2),
            format!("{} ({})", g.max_degree(), p.3),
            format!("{} ({})", nat.num_colors(), p.4),
            format!("{} ({})", lf.num_colors(), p.5),
            format!("{} ({})", sl.num_colors(), p.6),
            fmt_secs(t_nat),
        ]);
    }
    t.print();
    t.save_csv("table2").unwrap();
    println!("shape check: ER ≪ Good ≪ Bad in Δ and colors; SL ≈ LF < NAT");
}
