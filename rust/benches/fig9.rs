//! Fig 9: the Fig-8 sweep with one (a) and two (b) Non-Decreasing
//! synchronous recoloring iterations at P=32.

#[path = "common.rs"]
mod common;

use dgcolor::coordinator::sweep::{paper_grid, run_sweep};
use dgcolor::coordinator::ColoringConfig;
use dgcolor::util::table::Table;

fn main() {
    common::print_header("Fig 9 — parameter sweep with ND recoloring (P=32)");
    // one session per graph across both sweeps: ND1 and ND2 share the
    // same partition key, so each graph partitions exactly once
    let sessions = common::sessions(
        common::real_world_graphs()
            .into_iter()
            .map(|(_, g)| g)
            .collect(),
    );
    let baseline = ColoringConfig::default();
    for iters in [1u32, 2] {
        let configs = paper_grid(iters, 42);
        let points = run_sweep(&sessions, configs, &baseline, 32).unwrap();
        let mut t = Table::new(
            &format!("ND{iters} sweep points"),
            &["config", "norm colors", "norm time"],
        );
        let mut best_random: Option<(String, f64, f64)> = None;
        let mut best_ff: Option<(String, f64, f64)> = None;
        for p in &points {
            t.row(&[
                p.label.clone(),
                format!("{:.3}", p.norm_colors),
                format!("{:.3}", p.norm_time),
            ]);
            let entry = (p.label.clone(), p.norm_colors, p.norm_time);
            if p.label.starts_with('R') {
                if best_random.as_ref().is_none_or(|b| p.norm_colors < b.1) {
                    best_random = Some(entry);
                }
            } else if p.label.starts_with('F') {
                if best_ff.as_ref().is_none_or(|b| p.norm_colors < b.1) {
                    best_ff = Some(entry);
                }
            }
        }
        t.save_csv(&format!("fig9_nd{iters}")).unwrap();
        let br = best_random.unwrap();
        let bf = best_ff.unwrap();
        println!(
            "ND{iters}: best Random-X point {} colors={:.3} time={:.3} | best FF point {} colors={:.3} time={:.3}",
            br.0, br.1, br.2, bf.0, bf.1, bf.2
        );
    }
    println!(
        "shape check (paper): with ≥1 recoloring iteration every Random-X\n\
         strategy beats First-Fit on colors; recoloring time correlates with\n\
         the initial color count, so Random-X pays a runtime premium"
    );
}
