//! Fig 2: sequential recoloring — {NAT, LF, SL} vertex orderings × {RV, NI,
//! ND} color-class permutations, normalized colors vs iteration (0..20),
//! geometric mean over the six real-world graphs (normalized to NAT on one
//! processor, exactly like the paper).

#[path = "common.rs"]
mod common;

use dgcolor::color::recolor::{recolor_iterate, Permutation, RecolorSchedule};
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::util::table::Table;
use dgcolor::util::Rng;

const ITERS: u32 = 20;

fn main() {
    common::print_header("Fig 2 — sequential recoloring: orderings × permutations");
    let graphs = common::real_world_graphs();
    let baselines: Vec<f64> = graphs
        .iter()
        .map(|(_, g)| {
            greedy_color(g, Ordering::Natural, Selection::FirstFit, 1).num_colors() as f64
        })
        .collect();

    let mut t = Table::new(
        "normalized colors (geomean over graphs) after k recoloring iterations",
        &["series", "k=0", "k=1", "k=2", "k=5", "k=10", "k=20"],
    );
    let checkpoints = [0usize, 1, 2, 5, 10, 20];
    for ord in [Ordering::Natural, Ordering::LargestFirst, Ordering::SmallestLast] {
        for perm in [Permutation::Reverse, Permutation::NonIncreasing, Permutation::NonDecreasing] {
            // traces per graph
            let mut per_k: Vec<Vec<f64>> = vec![Vec::new(); checkpoints.len()];
            for (_, g) in &graphs {
                let c0 = greedy_color(g, ord, Selection::FirstFit, 1);
                let mut rng = Rng::new(7);
                let (_, trace) =
                    recolor_iterate(g, &c0, RecolorSchedule::Fixed(perm), ITERS, &mut rng);
                for (i, &k) in checkpoints.iter().enumerate() {
                    per_k[i].push(trace[k] as f64);
                }
            }
            let mut row = vec![format!("{}+RC-{}", ord.short_name(), perm.short_name())];
            for vals in per_k.iter() {
                row.push(format!("{:.3}", common::norm_geo(vals, &baselines)));
            }
            t.row(&row);
        }
    }
    t.print();
    t.save_csv("fig2").unwrap();
    println!(
        "shape check: ND lowest at k=20; NI weakest; SL+RC-ND best overall\n\
         (paper: SL≈0.78 at k=0, ND reaches ≈0.8×NAT after 20 iterations)"
    );
}
