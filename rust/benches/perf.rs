//! §Perf microbenchmarks: real-wallclock throughput of every hot path —
//! sequential greedy (edges/s), recoloring iteration, orderings, the
//! message transport (allocating vs pooled), ghost lookups, the
//! partitioners, and (when artifacts exist) the PJRT kernel batch latency.
//! Results feed EXPERIMENTS.md §Perf, and `--json <path>` writes the
//! machine-readable `BENCH_perf.json` trajectory (format in DESIGN.md
//! "Memory discipline on hot paths"):
//!
//! ```text
//! cargo bench --bench perf -- --json ../BENCH_perf.json
//! ```

#[path = "common.rs"]
mod common;

use dgcolor::color::recolor::{recolor_once, Permutation};
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::{Job, Priority, Scheduler, SchedulerConfig, Session};
use dgcolor::dist::comm::{network, MsgKind};
use dgcolor::dist::cost::CostModel;
use dgcolor::dist::proc::{build_local_graphs, build_local_graphs_parallel};
use dgcolor::dist::{Engine, NetworkModel};
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::graph::synth;
use dgcolor::partition::{self, Partitioner};
use dgcolor::util::args::Args;
use dgcolor::util::bench::{bench, BenchConfig, BenchResult, JsonReport};
use dgcolor::util::Rng;

/// `bench`, recorded into the JSON trajectory.
fn b<T>(
    rep: &mut JsonReport,
    cfg: &BenchConfig,
    name: &str,
    f: impl FnMut(usize) -> T,
) -> BenchResult {
    let r = bench(name, cfg, f);
    rep.record(&r);
    r
}

const TRANSPORT_MSGS: u32 = 10_000;

fn main() {
    let args = Args::from_env().expect("args");
    common::print_header("§Perf — hot-path microbenchmarks (real wallclock)");
    let cfg = BenchConfig::default();
    let mut rep = JsonReport::new();

    // L3.1: sequential greedy throughput on a large ER-ish graph
    let g = rmat::generate(&RmatParams::er(18, 8), 3, "er18");
    let edges = 2.0 * g.num_edges() as f64;
    let r = b(&mut rep, &cfg, "greedy FF natural (er18, 2M edges)", |i| {
        greedy_color(&g, Ordering::Natural, Selection::FirstFit, i as u64)
    });
    println!("    → {:.1}M edge-scans/s", edges / r.min() / 1e6);

    // L3.2: greedy on mesh (branchier degree distribution)
    let mesh = synth::fem_like(100_000, 25.0, 76, 0.004, 5, "mesh100k");
    let mesh_edges = 2.0 * mesh.num_edges() as f64;
    let r = b(&mut rep, &cfg, "greedy FF natural (mesh 1.25M edges)", |i| {
        greedy_color(&mesh, Ordering::Natural, Selection::FirstFit, i as u64)
    });
    println!("    → {:.1}M edge-scans/s", mesh_edges / r.min() / 1e6);

    // L3.3: selection strategies overhead vs FF
    for sel in [Selection::StaggeredFirstFit, Selection::LeastUsed, Selection::RandomX(10)] {
        b(&mut rep, &cfg, &format!("greedy {} (mesh)", sel.short_name()), |i| {
            greedy_color(&mesh, Ordering::Natural, sel, i as u64)
        });
    }

    // L3.4: orderings
    for ord in [Ordering::LargestFirst, Ordering::SmallestLast] {
        b(&mut rep, &cfg, &format!("greedy FF {} (mesh)", ord.short_name()), |i| {
            greedy_color(&mesh, ord, Selection::FirstFit, i as u64)
        });
    }

    // L3.5: one recoloring iteration (target ≤ 1.3× greedy)
    let c0 = greedy_color(&mesh, Ordering::Natural, Selection::FirstFit, 1);
    let mut rng = Rng::new(9);
    let rr = b(&mut rep, &cfg, "recolor_once ND (mesh)", |_| {
        recolor_once(&mesh, &c0, Permutation::NonDecreasing, &mut rng)
    });
    println!("    → {:.1}M edge-scans/s", mesh_edges / rr.min() / 1e6);

    // L3.6: partitioners
    b(&mut rep, &cfg, "block partition (mesh, 64 parts)", |_| {
        partition::partition(&mesh, Partitioner::Block, 64, 1)
    });
    b(&mut rep, &cfg, "bfs-grow partition (mesh, 64 parts)", |_| {
        partition::partition(&mesh, Partitioner::BfsGrow, 64, 1)
    });

    // L3.7: transport bookkeeping, loopback (no thread channel in the way).
    // "alloc" is the pre-pool shape — one fresh Vec per message, the
    // received Vec dropped; "pooled" is the steady-state zero-allocation
    // path. The ratio is the tentpole claim of the pooled transport.
    let r_alloc = b(&mut rep, &cfg, "transport loopback 10k msgs (alloc per msg)", |_| {
        let mut eps = network(1, NetworkModel::ideal());
        let mut e = eps.pop().unwrap();
        for i in 0..TRANSPORT_MSGS {
            e.send(0, MsgKind::Colors, 0, i, vec![0u8; 64]);
            let _ = e.recv_from(0, MsgKind::Colors, 0, i);
        }
        e
    });
    let r_pool = b(&mut rep, &cfg, "transport loopback 10k msgs (pooled)", |_| {
        let mut eps = network(1, NetworkModel::ideal());
        let mut e = eps.pop().unwrap();
        let payload = [0u8; 64];
        let mut out = Vec::new();
        for i in 0..TRANSPORT_MSGS {
            e.send_from(0, MsgKind::Colors, 0, i, &payload);
            e.recv_into(0, MsgKind::Colors, 0, i, &mut out);
        }
        e
    });
    println!(
        "    → {:.2}µs vs {:.2}µs per message — pooled speedup {:.2}×",
        r_alloc.min() / TRANSPORT_MSGS as f64 * 1e6,
        r_pool.min() / TRANSPORT_MSGS as f64 * 1e6,
        r_alloc.min() / r_pool.min()
    );

    // L3.8: cross-thread exchange with both endpoints sending and
    // receiving (the superstep traffic shape; pools self-sustain)
    b(&mut rep, &cfg, "transport 2-proc exchange 10k msgs (pooled)", |_| {
        let mut eps = network(2, NetworkModel::ideal());
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            let payload = [0u8; 64];
            let mut out = Vec::new();
            for i in 0..TRANSPORT_MSGS {
                e1.send_from(0, MsgKind::Colors, 0, i, &payload);
                e1.recv_into(0, MsgKind::Colors, 0, i, &mut out);
            }
            e1
        });
        let payload = [0u8; 64];
        let mut out = Vec::new();
        for i in 0..TRANSPORT_MSGS {
            e0.send_from(1, MsgKind::Colors, 0, i, &payload);
            e0.recv_into(1, MsgKind::Colors, 0, i, &mut out);
        }
        t.join().unwrap()
    });

    // L3.9: dense ghost indexing — every ghost on every process once
    let part = partition::partition(&mesh, Partitioner::BfsGrow, 16, 1);
    let (_, locals) = build_local_graphs(&mesh, &part);
    let queries: Vec<(usize, u32)> = locals
        .iter()
        .enumerate()
        .flat_map(|(p, l)| l.global_ids[l.n_owned()..].iter().map(move |&g| (p, g)))
        .collect();
    let r = b(&mut rep, &cfg, "ghost local_of (mesh, 16 parts)", |_| {
        let mut acc = 0u64;
        for &(p, gid) in &queries {
            acc += locals[p].local_of(gid) as u64;
        }
        acc
    });
    println!(
        "    → {:.1}M ghost lookups/s ({} ghosts)",
        queries.len() as f64 / r.min() / 1e6,
        queries.len()
    );

    // L3.10: BSP step engine vs thread-per-proc runner at growing process
    // counts (same modeled results — tests pin bit-for-bit equality — so
    // the ratio is pure simulator wallclock). The thread runner pays one
    // OS thread per simulated process; the engine runs every process on
    // min(cores, p) pooled workers, so the gap widens with p.
    let dist_g = rmat::generate(&RmatParams::er(14, 8), 11, "er14");
    let session = Session::new(dist_g).with_cost_model(CostModel::fixed());
    for procs in [4usize, 16, 64, 256] {
        let job = |engine: Engine| {
            Job::on(&session)
                .procs(procs)
                .engine(engine)
                .build()
                .unwrap()
        };
        // warm the partition + local-graph cache: both paths then measure
        // only the distributed run itself
        session.run(&job(Engine::Bsp)).expect("warmup run");
        let rt = b(
            &mut rep,
            &cfg,
            &format!("dist run p={procs} (thread runner, er14)"),
            |_| session.run(&job(Engine::Threads)).unwrap().num_colors,
        );
        let re = b(
            &mut rep,
            &cfg,
            &format!("dist run p={procs} (step engine, er14)"),
            |_| session.run(&job(Engine::Bsp)).unwrap().num_colors,
        );
        println!(
            "    → step engine {:.2}× vs thread runner at p={procs}",
            rt.min() / re.min()
        );
    }

    // L3.10b: the same engine-vs-threads series for aRC — the job shape
    // the engine split used to route to threads unconditionally. The aRC
    // machine embeds a full framework rerun per iteration, so this also
    // exercises the engine's deepest nested-machine path.
    for procs in [4usize, 16, 64, 256] {
        let job = |engine: Engine| {
            Job::on(&session)
                .procs(procs)
                .async_recolor(Permutation::NonDecreasing, 2)
                .engine(engine)
                .build()
                .unwrap()
        };
        session.run(&job(Engine::Bsp)).expect("warmup run");
        let rt = b(
            &mut rep,
            &cfg,
            &format!("dist aRC-ND2 p={procs} (thread runner, er14)"),
            |_| session.run(&job(Engine::Threads)).unwrap().num_colors,
        );
        let re = b(
            &mut rep,
            &cfg,
            &format!("dist aRC-ND2 p={procs} (step engine, er14)"),
            |_| session.run(&job(Engine::Bsp)).unwrap().num_colors,
        );
        println!(
            "    → step engine {:.2}× vs thread runner at p={procs} (aRC)",
            rt.min() / re.min()
        );
    }

    // L3.11: local-graph artifacts — fresh serial build vs the pooled
    // parallel build vs a session cache hit (Arc clone, effectively free)
    let part64 = partition::partition(session.graph(), Partitioner::BfsGrow, 64, 1);
    b(&mut rep, &cfg, "local graphs p=64 build (serial, er14)", |_| {
        build_local_graphs(session.graph(), &part64)
    });
    b(&mut rep, &cfg, "local graphs p=64 build (pooled, er14)", |_| {
        build_local_graphs_parallel(session.graph(), &part64)
    });
    let handle = session.partition(Partitioner::BfsGrow, 64, 1);
    handle.locals(session.graph()); // populate the cache
    let rc = b(&mut rep, &cfg, "local graphs p=64 (session cached)", |_| {
        handle.locals(session.graph()).locals.len()
    });
    println!(
        "    → cached local-graph lookup {:.3}µs (vs a full rebuild per run)",
        rc.min() * 1e6
    );

    // L3.12: the DataPar shared-memory engine vs both transport engines
    // at growing scale — the raw-speed claim. These are *different
    // algorithms* (datapar colorings legitimately differ), so the
    // comparison is wallclock, not modeled quantities. Warmups populate
    // the partition + local-graph caches first, so the transport engines
    // measure only the distributed run itself.
    for scale in [17u32, 20] {
        let name = format!("er{scale}");
        let dp_g = rmat::generate(&RmatParams::er(scale, 8), 21, &name);
        println!(
            "    datapar vs transport on {name}: |V|={} |E|={}",
            dp_g.num_vertices(),
            dp_g.num_edges()
        );
        let s = Session::new(dp_g).with_cost_model(CostModel::fixed());
        let dp_job = || {
            Job::on(&s)
                .engine(Engine::DataPar)
                .seed(21)
                .build()
                .unwrap()
        };
        let tr_job = |engine: Engine| {
            Job::on(&s)
                .procs(8)
                .engine(engine)
                .seed(21)
                .build()
                .unwrap()
        };
        s.run(&dp_job()).expect("warmup run");
        s.run(&tr_job(Engine::Bsp)).expect("warmup run");
        let rd = b(&mut rep, &cfg, &format!("datapar run ({name})"), |_| {
            s.run(&dp_job()).unwrap().num_colors
        });
        let re = b(&mut rep, &cfg, &format!("bsp p=8 run ({name})"), |_| {
            s.run(&tr_job(Engine::Bsp)).unwrap().num_colors
        });
        let rt = b(&mut rep, &cfg, &format!("threads p=8 run ({name})"), |_| {
            s.run(&tr_job(Engine::Threads)).unwrap().num_colors
        });
        println!(
            "    → datapar {:.2}× vs bsp, {:.2}× vs threads ({name})",
            re.min() / rd.min(),
            rt.min() / rd.min()
        );
    }

    // L3.13: scheduler overhead — the same job run directly on a session
    // vs submitted through the Scheduler (admission + token creation +
    // queue + dispatcher handoff + handle delivery). The delta is the
    // per-job service-layer tax; it must stay microseconds against
    // millisecond jobs. Then a mixed interactive/sweep batch through the
    // dispatcher — the fairness rule's steady-state throughput shape.
    let sched_g = rmat::generate(&RmatParams::er(13, 8), 31, "er13");
    let direct = Session::new(sched_g.clone()).with_cost_model(CostModel::fixed());
    let sj = Job::builder().procs(4).seed(31).build().unwrap();
    direct.run(&sj).expect("warmup run");
    let rd = b(&mut rep, &cfg, "job direct p=4 (er13)", |_| {
        direct.run(&sj).unwrap().num_colors
    });
    let sched = Scheduler::new(SchedulerConfig::default());
    let tenant = sched.add_tenant(Session::new(sched_g).with_cost_model(CostModel::fixed()));
    sched.submit(tenant, sj).unwrap().wait().expect("warmup run");
    let rs = b(&mut rep, &cfg, "job via scheduler p=4 (er13)", |_| {
        sched.submit(tenant, sj).unwrap().wait().unwrap().num_colors
    });
    println!(
        "    → scheduler overhead {:.1}µs per job ({:.3}× direct)",
        (rs.min() - rd.min()) * 1e6,
        rs.min() / rd.min()
    );
    let inter = Job::builder().procs(2).seed(31).build().unwrap();
    let sweep = Job::builder()
        .procs(4)
        .seed(31)
        .selection(Selection::RandomX(5))
        .priority(Priority::Sweep)
        .build()
        .unwrap();
    let rm = b(&mut rep, &cfg, "scheduler mixed batch 6i+3s (er13)", |_| {
        let handles: Vec<_> = (0..9)
            .map(|i| {
                let job = if i % 3 == 2 { sweep } else { inter };
                sched.submit(tenant, job).unwrap()
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.wait().unwrap().num_colors)
            .sum::<usize>()
    });
    println!("    → {:.2}ms per 9-job mixed batch", rm.min() * 1e3);

    // L1/L2: PJRT kernel batch latency (when artifacts are built)
    if dgcolor::runtime::KernelRuntime::artifacts_present() {
        let rt =
            dgcolor::runtime::KernelRuntime::load(&dgcolor::runtime::KernelRuntime::artifacts_dir())
                .expect("artifacts load");
        let matrix = vec![-1i32; 256 * 64];
        let r = b(&mut rep, &cfg, "PJRT first_fit batch (256×64)", |_| {
            rt.first_fit_batch(&matrix).unwrap()
        });
        println!(
            "    → {:.1}µs per batch, {:.2}µs per vertex",
            r.min() * 1e6,
            r.min() * 1e6 / 256.0
        );
        let u = vec![0.5f32; 256];
        b(&mut rep, &cfg, "PJRT random_x batch (256×64)", |_| {
            rt.random_x_batch(&matrix, &u, 5).unwrap()
        });
        let e = vec![0i32; 4096];
        b(&mut rep, &cfg, "PJRT conflict batch (4096 edges)", |_| {
            rt.conflict_batch(&e, &e, &e, &e, &e, &e).unwrap()
        });
    } else {
        println!("(PJRT kernel benches skipped: run `make artifacts`)");
    }

    if let Some(path) = args.get_str("json") {
        rep.write(path).expect("write BENCH_perf.json");
        println!("\nwrote {path}");
    }
}
