//! §Perf microbenchmarks: real-wallclock throughput of every hot path —
//! sequential greedy (edges/s), recoloring iteration, orderings, the
//! message transport, the partitioners, and (when artifacts exist) the
//! PJRT kernel batch latency. Results feed EXPERIMENTS.md §Perf.

#[path = "common.rs"]
mod common;

use dgcolor::color::recolor::{recolor_once, Permutation};
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::dist::comm::{network, MsgKind};
use dgcolor::dist::NetworkModel;
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::graph::synth;
use dgcolor::partition::{self, Partitioner};
use dgcolor::util::bench::{bench, BenchConfig};
use dgcolor::util::Rng;

fn main() {
    common::print_header("§Perf — hot-path microbenchmarks (real wallclock)");
    let cfg = BenchConfig::default();

    // L3.1: sequential greedy throughput on a large ER-ish graph
    let g = rmat::generate(&RmatParams::er(18, 8), 3, "er18");
    let edges = 2.0 * g.num_edges() as f64;
    let r = bench("greedy FF natural (er18, 2M edges)", &cfg, |i| {
        greedy_color(&g, Ordering::Natural, Selection::FirstFit, i as u64)
    });
    println!(
        "    → {:.1}M edge-scans/s",
        edges / r.min() / 1e6
    );

    // L3.2: greedy on mesh (branchier degree distribution)
    let mesh = synth::fem_like(100_000, 25.0, 76, 0.004, 5, "mesh100k");
    let mesh_edges = 2.0 * mesh.num_edges() as f64;
    let r = bench("greedy FF natural (mesh 1.25M edges)", &cfg, |i| {
        greedy_color(&mesh, Ordering::Natural, Selection::FirstFit, i as u64)
    });
    println!("    → {:.1}M edge-scans/s", mesh_edges / r.min() / 1e6);

    // L3.3: selection strategies overhead vs FF
    for sel in [Selection::StaggeredFirstFit, Selection::LeastUsed, Selection::RandomX(10)] {
        bench(&format!("greedy {} (mesh)", sel.short_name()), &cfg, |i| {
            greedy_color(&mesh, Ordering::Natural, sel, i as u64)
        });
    }

    // L3.4: orderings
    for ord in [Ordering::LargestFirst, Ordering::SmallestLast] {
        bench(&format!("greedy FF {} (mesh)", ord.short_name()), &cfg, |i| {
            greedy_color(&mesh, ord, Selection::FirstFit, i as u64)
        });
    }

    // L3.5: one recoloring iteration (target ≤ 1.3× greedy)
    let c0 = greedy_color(&mesh, Ordering::Natural, Selection::FirstFit, 1);
    let mut rng = Rng::new(9);
    let rr = bench("recolor_once ND (mesh)", &cfg, |_| {
        recolor_once(&mesh, &c0, Permutation::NonDecreasing, &mut rng)
    });
    println!("    → {:.1}M edge-scans/s", mesh_edges / rr.min() / 1e6);

    // L3.6: partitioners
    bench("block partition (mesh, 64 parts)", &cfg, |_| {
        partition::partition(&mesh, Partitioner::Block, 64, 1)
    });
    bench("bfs-grow partition (mesh, 64 parts)", &cfg, |_| {
        partition::partition(&mesh, Partitioner::BfsGrow, 64, 1)
    });

    // L3.7: transport round-trip cost (real thread channel overhead)
    let r = bench("transport 10k msgs ping-pong", &cfg, |_| {
        let mut eps = network(2, NetworkModel::ideal());
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            for i in 0..10_000u32 {
                e1.send(0, MsgKind::Colors, 0, i, vec![0u8; 8]);
            }
            e1
        });
        for i in 0..10_000u32 {
            let _ = e0.recv_from(1, MsgKind::Colors, 0, i);
        }
        t.join().unwrap()
    });
    println!("    → {:.2}µs per message (real)", r.min() / 10_000.0 * 1e6);

    // L1/L2: PJRT kernel batch latency (when artifacts are built)
    if dgcolor::runtime::KernelRuntime::artifacts_present() {
        let rt =
            dgcolor::runtime::KernelRuntime::load(&dgcolor::runtime::KernelRuntime::artifacts_dir())
                .expect("artifacts load");
        let matrix = vec![-1i32; 256 * 64];
        let r = bench("PJRT first_fit batch (256×64)", &cfg, |_| {
            rt.first_fit_batch(&matrix).unwrap()
        });
        println!(
            "    → {:.1}µs per batch, {:.2}µs per vertex",
            r.min() * 1e6,
            r.min() * 1e6 / 256.0
        );
        let u = vec![0.5f32; 256];
        bench("PJRT random_x batch (256×64)", &cfg, |_| {
            rt.random_x_batch(&matrix, &u, 5).unwrap()
        });
        let e = vec![0i32; 4096];
        bench("PJRT conflict batch (4096 edges)", &cfg, |_| {
            rt.conflict_batch(&e, &e, &e, &e, &e, &e).unwrap()
        });
    } else {
        println!("(PJRT kernel benches skipped: run `make artifacts`)");
    }
}
