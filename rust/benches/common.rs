//! Shared workloads + helpers for the paper-reproduction benches.
//!
//! Default scales are sized for a 1-core CI box; `REPRO_FULL=1` raises
//! every workload to the paper's sizes (2^24 RMAT, full |V| stand-ins).

#![allow(dead_code)]

use dgcolor::coordinator::{ColoringConfig, Job, RunResult, Session};
use dgcolor::dist::cost::CostModel;
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::graph::synth::{self, PaperGraphSpec, TABLE1_SPECS};
use dgcolor::graph::CsrGraph;
use dgcolor::util::bench::full_scale;
use dgcolor::util::stats;

/// The six Table-1 stand-ins at bench scale. `DGCOLOR_SCALE` overrides the
/// fraction of paper |V| (default 0.02; REPRO_FULL=1 → 1.0).
pub fn real_world_graphs() -> Vec<(&'static PaperGraphSpec, CsrGraph)> {
    let scale = std::env::var("DGCOLOR_SCALE")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(if full_scale() { 1.0 } else { 0.02 });
    TABLE1_SPECS
        .iter()
        .enumerate()
        .map(|(i, spec)| (spec, synth::paper_graph(spec, scale, 1000 + i as u64)))
        .collect()
}

/// RMAT scale: paper = 24; bench default = 16 (64k vertices, ~500k edges).
pub fn rmat_scale() -> u32 {
    if full_scale() {
        24
    } else {
        16
    }
}

pub fn rmat_graphs() -> Vec<CsrGraph> {
    let s = rmat_scale();
    vec![
        rmat::generate(&RmatParams::er(s, 8), 11, "RMAT-ER"),
        rmat::generate(&RmatParams::good(s, 8), 12, "RMAT-Good"),
        rmat::generate(&RmatParams::bad(s, 8), 13, "RMAT-Bad"),
    ]
}

/// Wrap graphs in coordinator sessions with the fixed cost model pinned —
/// every bench job shares partitions per `(partitioner, procs, seed)` key.
pub fn sessions(graphs: Vec<CsrGraph>) -> Vec<Session> {
    graphs
        .into_iter()
        .map(|g| Session::new(g).with_cost_model(CostModel::fixed()))
        .collect()
}

/// [`real_world_graphs`] as sessions, keeping the spec for labels.
pub fn real_world_sessions() -> Vec<(&'static PaperGraphSpec, Session)> {
    real_world_graphs()
        .into_iter()
        .map(|(spec, g)| (spec, Session::new(g).with_cost_model(CostModel::fixed())))
        .collect()
}

/// Run one config on a session; bench configs are static, so validation
/// or run failures are bugs worth a panic.
pub fn run(s: &Session, cfg: ColoringConfig) -> RunResult {
    s.run(&Job::from_config(cfg).expect("valid bench config"))
        .expect("bench run failed")
}

/// Processor counts swept by the distributed benches (paper: 1..512).
pub fn procs_list() -> Vec<usize> {
    if full_scale() {
        vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512]
    } else {
        vec![1, 2, 4, 8, 16, 32, 64]
    }
}

/// Fixed-cost config so bench results are deterministic run to run; the
/// perf bench measures real wallclock separately.
pub fn base_cfg(procs: usize) -> ColoringConfig {
    ColoringConfig {
        num_procs: procs,
        fixed_cost: Some(CostModel::fixed()),
        ..Default::default()
    }
}

/// Normalize per-graph values to per-graph baselines, geometric mean — the
/// paper's aggregation.
pub fn norm_geo(values: &[f64], baselines: &[f64]) -> f64 {
    stats::normalized_geomean(values, baselines)
}

pub fn print_header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "scale: {} (REPRO_FULL=1 for paper scale)",
        if full_scale() { "FULL (paper)" } else { "bench" }
    );
}
