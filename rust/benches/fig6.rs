//! Fig 6: impact of recoloring on the RMAT graphs — per-graph colors for
//! FSS / FSS+aRC / FSS+RC vs processor count (a,b,c) and aggregated
//! normalized runtime (d). Block partitioning, as in the paper. One
//! session per graph shares the block partitions across all three modes.

#[path = "common.rs"]
mod common;

use dgcolor::color::recolor::Permutation;
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::{ColoringConfig, RecolorMode};
use dgcolor::dist::recolor::RecolorConfig;
use dgcolor::partition::Partitioner;
use dgcolor::util::table::Table;

fn main() {
    common::print_header("Fig 6 — recoloring on RMAT graphs");
    let sessions = common::sessions(common::rmat_graphs());
    let procs: Vec<usize> = common::procs_list().into_iter().filter(|&p| p >= 4).collect();

    let mk_cfg = |p: usize, mode: RecolorMode| ColoringConfig {
        ordering: Ordering::SmallestLast,
        partitioner: Partitioner::Block,
        recolor: mode,
        ..common::base_cfg(p)
    };

    // (a)-(c): colors per graph
    let mut time_rows: Vec<(usize, Vec<f64>, Vec<f64>, Vec<f64>)> = procs
        .iter()
        .map(|&p| (p, Vec::new(), Vec::new(), Vec::new()))
        .collect();
    let mut base_time: Vec<f64> = Vec::new();
    for s in &sessions {
        let g = s.graph();
        let seq_lf = greedy_color(g, Ordering::LargestFirst, Selection::FirstFit, 1).num_colors();
        let seq_sl = greedy_color(g, Ordering::SmallestLast, Selection::FirstFit, 1).num_colors();
        let mut t = Table::new(
            &format!("{} — number of colors (seq LF={seq_lf}, SL={seq_sl})", g.name),
            &["procs", "FSS", "FSS+aRC", "FSS+RC"],
        );
        // runtime baseline: natural ordering at 4 procs (paper's RMAT norm)
        let mut cfg4 = common::base_cfg(4);
        cfg4.partitioner = Partitioner::Block;
        cfg4.ordering = Ordering::Natural;
        let rb = common::run(s, cfg4);
        base_time.push(rb.metrics.makespan.max(1e-12));

        for (pi, &p) in procs.iter().enumerate() {
            let fss = common::run(s, mk_cfg(p, RecolorMode::None));
            let arc = common::run(
                s,
                mk_cfg(
                    p,
                    RecolorMode::Async {
                        perm: Permutation::NonDecreasing,
                        iterations: 1,
                    },
                ),
            );
            let rc = common::run(s, mk_cfg(p, RecolorMode::Sync(RecolorConfig::default())));
            t.row(&[
                p.to_string(),
                fss.num_colors.to_string(),
                arc.num_colors.to_string(),
                rc.num_colors.to_string(),
            ]);
            time_rows[pi].1.push(fss.metrics.makespan.max(1e-12));
            time_rows[pi].2.push(arc.metrics.makespan.max(1e-12));
            time_rows[pi].3.push(rc.metrics.makespan.max(1e-12));
            // the three modes shared this proc count's partition; the
            // next proc count is a fresh key, so bound retention
            s.clear_cached_partitions();
        }
        t.print();
        t.save_csv(&format!("fig6_colors_{}", g.name)).unwrap();
    }

    // (d): aggregated normalized runtime
    let mut t = Table::new(
        "aggregated normalized runtime (geomean, vs NAT @ 4 procs)",
        &["procs", "FSS", "FSS+aRC", "FSS+RC"],
    );
    for (p, fss, arc, rc) in &time_rows {
        t.row(&[
            p.to_string(),
            format!("{:.3}", common::norm_geo(fss, &base_time)),
            format!("{:.3}", common::norm_geo(arc, &base_time)),
            format!("{:.3}", common::norm_geo(rc, &base_time)),
        ]);
    }
    t.print();
    t.save_csv("fig6_runtime").unwrap();
    println!(
        "shape check (paper): RC conflict-free → colors near sequential LF/SL\n\
         (up to 50% better than FSS on Good/Bad); aRC <10% better than FSS;\n\
         RC runtime overhead shrinks as P grows"
    );
}
