//! Table 1: the six real-world graphs — V, E, Δ, sequential colors under
//! NAT/LF/SL, and sequential Natural coloring time. Paper values printed
//! alongside ours (stand-in graphs; see DESIGN.md §1 substitutions).

#[path = "common.rs"]
mod common;

use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::util::table::{fmt_secs, Table};
use dgcolor::util::timer::Timer;

fn main() {
    common::print_header("Table 1 — real-world graph properties & sequential coloring");
    let mut t = Table::new(
        "ours vs paper (paper numbers in parentheses)",
        &["graph", "|V|", "|E|", "Δ", "NAT", "LF", "SL", "seq time"],
    );
    for (spec, g) in common::real_world_graphs() {
        let timer = Timer::start();
        let nat = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 1);
        let t_nat = timer.secs();
        nat.validate(&g).expect("valid");
        let lf = greedy_color(&g, Ordering::LargestFirst, Selection::FirstFit, 1);
        let sl = greedy_color(&g, Ordering::SmallestLast, Selection::FirstFit, 1);
        t.row(&[
            spec.name.to_string(),
            format!("{} ({})", g.num_vertices(), spec.v),
            format!("{} ({})", g.num_edges(), spec.e),
            format!("{} ({})", g.max_degree(), spec.max_deg),
            format!("{} ({})", nat.num_colors(), spec.seq_colors_nat),
            format!("{} ({})", lf.num_colors(), spec.seq_colors_lf),
            format!("{} ({})", sl.num_colors(), spec.seq_colors_sl),
            fmt_secs(t_nat),
        ]);
    }
    t.print();
    t.save_csv("table1").unwrap();
    println!("shape check: SL ≤ LF ≤ NAT per row, Δ matched to paper targets");
}
