//! Fig 3: randomness in the color-class permutation — ND vs RAND vs
//! ND-RAND%5 / %10 / %2^i over 60 iterations, averaged over repeated runs
//! (paper: 10 runs; bench default 3, REPRO_FULL=1 → 10), per vertex-visit
//! ordering, geomean-normalized over the real-world set.

#[path = "common.rs"]
mod common;

use dgcolor::color::recolor::{recolor_iterate, Permutation, RecolorSchedule};
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::util::bench::full_scale;
use dgcolor::util::stats;
use dgcolor::util::table::Table;
use dgcolor::util::Rng;

const ITERS: u32 = 60;

fn main() {
    common::print_header("Fig 3 — ND vs randomized permutation schedules (60 iterations)");
    let runs = if full_scale() { 10 } else { 3 };
    let graphs = common::real_world_graphs();
    let baselines: Vec<f64> = graphs
        .iter()
        .map(|(_, g)| {
            greedy_color(g, Ordering::Natural, Selection::FirstFit, 1).num_colors() as f64
        })
        .collect();
    let schedules: [(&str, RecolorSchedule); 5] = [
        ("ND", RecolorSchedule::Fixed(Permutation::NonDecreasing)),
        ("RAND", RecolorSchedule::Fixed(Permutation::Random)),
        ("ND-RAND%5", RecolorSchedule::NdRandEvery(5)),
        ("ND-RAND%10", RecolorSchedule::NdRandEvery(10)),
        ("ND-RAND%2^i", RecolorSchedule::NdRandPow2),
    ];
    let checkpoints = [1usize, 5, 10, 20, 40, 60];

    for ord in [Ordering::Natural, Ordering::LargestFirst, Ordering::SmallestLast] {
        let mut t = Table::new(
            &format!("{} ordering — normalized colors (avg of {runs} runs)", ord.short_name()),
            &["schedule", "k=1", "k=5", "k=10", "k=20", "k=40", "k=60"],
        );
        for (label, sched) in &schedules {
            // full traces once per (graph, run); checkpoints read from them
            let mut per_graph_at_k: Vec<Vec<f64>> = vec![Vec::new(); checkpoints.len()];
            for (_, g) in &graphs {
                let c0 = greedy_color(g, ord, Selection::FirstFit, 1);
                let mut traces: Vec<Vec<usize>> = Vec::new();
                for run in 0..runs {
                    let mut rng = Rng::new(1000 + run as u64);
                    let (_, trace) = recolor_iterate(g, &c0, *sched, ITERS, &mut rng);
                    traces.push(trace);
                }
                for (i, &k) in checkpoints.iter().enumerate() {
                    let at_k: Vec<f64> = traces.iter().map(|tr| tr[k] as f64).collect();
                    per_graph_at_k[i].push(stats::mean(&at_k));
                }
            }
            let mut cells = vec![label.to_string()];
            for vals in &per_graph_at_k {
                cells.push(format!("{:.3}", common::norm_geo(vals, &baselines)));
            }
            t.row(&cells);
        }
        t.print();
        t.save_csv(&format!("fig3_{}", ord.short_name())).unwrap();
    }
    println!(
        "shape check (paper): for NAT, rarefied randomness (ND-RAND%2^i) wins;\n\
         for LF/SL at high iteration counts plain ND catches up or wins"
    );
}
