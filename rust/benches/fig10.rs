//! Fig 10: the combined time-quality trade-off — union of the Fig-8/9
//! sweeps (0, 1, 2 ND recoloring iterations) with the Pareto frontier and
//! the paper's two recommended presets highlighted.

#[path = "common.rs"]
mod common;

use dgcolor::coordinator::sweep::{paper_grid, pareto, run_sweep, SweepPoint};
use dgcolor::coordinator::ColoringConfig;
use dgcolor::util::table::Table;

fn main() {
    common::print_header("Fig 10 — combined time-quality trade-off (P=32)");
    // the 3×64 grid shares one partition key: each graph partitions once
    // for the union of all three sweeps
    let sessions = common::sessions(
        common::real_world_graphs()
            .into_iter()
            .map(|(_, g)| g)
            .collect(),
    );
    let baseline = ColoringConfig::default();
    let mut all: Vec<SweepPoint> = Vec::new();
    for iters in [0u32, 1, 2] {
        let configs = paper_grid(iters, 42);
        all.extend(run_sweep(&sessions, configs, &baseline, 32).unwrap());
    }
    let mut t = Table::new(
        "all points (0/1/2 ND iterations)",
        &["config", "norm colors", "norm time", "RC iters"],
    );
    for p in &all {
        t.row(&[
            p.label.clone(),
            format!("{:.3}", p.norm_colors),
            format!("{:.3}", p.norm_time),
            p.recolor_iters.to_string(),
        ]);
    }
    t.save_csv("fig10_all").unwrap();

    let front = pareto(&all);
    let mut t = Table::new(
        "Pareto frontier",
        &["config", "norm colors", "norm time", "RC iters"],
    );
    for p in &front {
        t.row(&[
            p.label.clone(),
            format!("{:.3}", p.norm_colors),
            format!("{:.3}", p.norm_time),
            p.recolor_iters.to_string(),
        ]);
    }
    t.print();
    t.save_csv("fig10_pareto").unwrap();

    // the paper's comparison: R(5|10)IxxND1 dominates FIxxND2 and FSxxND2
    let best = |pred: &dyn Fn(&SweepPoint) -> bool| -> Option<&SweepPoint> {
        all.iter()
            .filter(|p| pred(p))
            .min_by(|a, b| a.norm_colors.partial_cmp(&b.norm_colors).unwrap())
    };
    let r_nd1 = best(&|p| {
        (p.label.starts_with("R5I") || p.label.starts_with("R10I")) && p.recolor_iters == 1
    });
    let f_nd2 = best(&|p| p.label.starts_with("FI") && p.recolor_iters == 2);
    let fs_nd2 = best(&|p| p.label.starts_with("FS") && p.recolor_iters == 2);
    if let (Some(r), Some(f), Some(fs)) = (r_nd1, f_nd2, fs_nd2) {
        println!(
            "\npaper check — R(5|10)IxxND1 vs FIxxND2 vs FSxxND2:\n\
             {:<18} colors {:.3} time {:.3}\n\
             {:<18} colors {:.3} time {:.3}\n\
             {:<18} colors {:.3} time {:.3}",
            r.label, r.norm_colors, r.norm_time, f.label, f.norm_colors, f.norm_time, fs.label,
            fs.norm_colors, fs.norm_time
        );
        println!(
            "dominates: {}",
            r.norm_colors <= f.norm_colors.min(fs.norm_colors)
        );
    }
    println!("recommendations — speed: FIxxND0; quality: R(5-10)IxxND1");
}
