//! Fig 8: the no-recoloring parameter sweep at P=32 — superstep size
//! {500,1k,5k,10k} × ordering {Internal-First, SL} × {sync, async} ×
//! selection {FF, R5, R10, R50}; normalized colors vs normalized runtime
//! scatter, clustered by (selection, ordering) as in the paper.

#[path = "common.rs"]
mod common;

use dgcolor::coordinator::sweep::{paper_grid, run_sweep};
use dgcolor::coordinator::ColoringConfig;
use dgcolor::util::stats;
use dgcolor::util::table::Table;
use std::collections::BTreeMap;

fn main() {
    common::print_header("Fig 8 — parameter sweep without recoloring (P=32)");
    // sessions pin the fixed cost model and share one partitioning of each
    // graph across the whole 64-config grid
    let sessions = common::sessions(
        common::real_world_graphs()
            .into_iter()
            .map(|(_, g)| g)
            .collect(),
    );
    let configs = paper_grid(0, 42);
    let baseline = ColoringConfig::default();
    let points = run_sweep(&sessions, configs, &baseline, 32).unwrap();

    // full scatter to CSV
    let mut t = Table::new("sweep points", &["config", "norm colors", "norm time"]);
    for p in &points {
        t.row(&[
            p.label.clone(),
            format!("{:.3}", p.norm_colors),
            format!("{:.3}", p.norm_time),
        ]);
    }
    t.save_csv("fig8").unwrap();

    // clustered view (paper tags clusters R5Ixx, FSxx, ...)
    let mut clusters: BTreeMap<String, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for p in &points {
        // label looks like "R5I1000s-0" → cluster key "R5Ixx"
        let key = cluster_key(&p.label);
        let e = clusters.entry(key).or_default();
        e.0.push(p.norm_colors);
        e.1.push(p.norm_time);
    }
    let mut t = Table::new(
        "clusters (superstep × comm pattern folded)",
        &["cluster", "norm colors (mean)", "norm time (mean)"],
    );
    for (k, (c, tt)) in &clusters {
        t.row(&[
            k.clone(),
            format!("{:.3}", stats::mean(c)),
            format!("{:.3}", stats::mean(tt)),
        ]);
    }
    t.print();
    t.save_csv("fig8_clusters").unwrap();
    println!(
        "shape check (paper): Internal-First faster than SL, SL fewer colors;\n\
         colors degrade as X grows in Random-X; superstep/comm ≈ no effect"
    );
}

/// Fold superstep size and comm pattern out of a config label, mirroring
/// the paper's cluster tags: "R5I1000s-0" → "R5Ixx". Labels are
/// "<SEL><ORD><SS><s|a>-<RC>" with SEL ∈ {F, SF, LU, R5, R10, R50}.
fn cluster_key(label: &str) -> String {
    for sel in ["R50", "R10", "R5", "SF", "LU", "F"] {
        if let Some(rest) = label.strip_prefix(sel) {
            let ord = rest.chars().next().unwrap_or('?');
            return format!("{sel}{ord}xx");
        }
    }
    label.to_string()
}
