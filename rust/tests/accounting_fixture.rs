//! Accounting-invariance fixture: the pooled transport, dense ghost
//! indexing, scratch hoisting — and now the BSP step engine — must not
//! change any *modeled* quantity. For four fixed transport jobs (framework
//! coloring + 2 RC iterations with Base and Piggyback, and framework
//! coloring + 2 aRC iterations with the ND and NI permutations) this pins
//! — bit-for-bit — the final coloring, every process's `sent_msgs` /
//! `sent_bytes` / `recv_msgs`, and every virtual clock (as
//! `f64::to_bits`), against a committed fixture file. Every fixture case runs on **both execution
//! paths** — the thread-per-process runner and the BSP step engine — and
//! the two serializations must agree exactly before either is compared to
//! the pin. A fifth `[datapar]` job pins the shared-memory speculative
//! engine the same way: its coloring hash, rounds and speculated/conflicted
//! counts must agree bit-for-bit across pool sizes {1, 2, 8} before the
//! common serialization is compared to the pin. A sixth `[faults-loss]`
//! job pins the reliable-delivery layer: a fixed lossy multi-crash
//! supervised run must reproduce the fault-free coloring exactly, and its
//! loss / retransmission / ack / dedup accounting is pinned like every
//! other modeled quantity.
//!
//! Bless protocol: if `tests/fixtures/accounting_v1.txt` is absent (first
//! run in a fresh environment) or `DGCOLOR_BLESS=1` is set, the observed
//! values are written and the test passes; any later run that disagrees
//! with the committed file fails. Until the fixture is generated and
//! committed by an environment with a toolchain, a fresh checkout
//! self-blesses — set `DGCOLOR_REQUIRE_FIXTURE=1` to turn a missing
//! fixture into a failure instead (for environments that must enforce the
//! pin). Once the file is committed, every checkout enforces it
//! automatically. Independently of the fixture, every run checks that two
//! executions agree bit-for-bit and that nothing was dropped by the
//! transport.

use dgcolor::color::recolor::{Permutation, RecolorSchedule};
use dgcolor::color::{Coloring, Ordering, Selection};
use dgcolor::dist::comm;
use dgcolor::dist::cost::{CostModel, NetworkModel};
use dgcolor::dist::engine::{self, StepOutcome, StepProcess};
use dgcolor::dist::framework::{self, FrameworkConfig, FrameworkStep};
use dgcolor::dist::proc::{build_local_graphs, ColorState, LocalGraph};
use dgcolor::dist::recolor::{
    recolor_process_async, recolor_process_sync, AsyncRcStep, CommScheme, RecolorConfig,
    SyncRcStep,
};
use dgcolor::dist::{Endpoint, ProcMetrics, ProcResult};
use dgcolor::graph::{synth, CsrGraph};
use dgcolor::partition::{self, Partitioner};
use dgcolor::shm;
use dgcolor::util::pool::WorkerPool;
use std::path::Path;

const FIXTURE: &str = "tests/fixtures/accounting_v1.txt";
const PROCS: usize = 4;

fn fnv1a(bytes: impl Iterator<Item = u8>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn fixture_graph() -> CsrGraph {
    synth::fem_like(600, 10.0, 26, 0.01, 5, "fixture")
}

fn fixture_fw() -> FrameworkConfig {
    FrameworkConfig {
        ordering: Ordering::InternalFirst,
        selection: Selection::RandomX(8),
        superstep_size: 64,
        sync: true,
        seed: 42,
        max_rounds: 200,
    }
}

fn fixture_rc(scheme: CommScheme) -> RecolorConfig {
    RecolorConfig {
        schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
        iterations: 2,
        scheme,
        seed: 7,
        early_stop: None,
    }
}

/// Serialize one process's modeled quantities, one line.
fn proc_line(m: &ProcMetrics) -> String {
    format!(
        "proc {} msgs={} bytes={} recv={} dropped={} clock={:016x} trace={:?}",
        m.rank,
        m.sent_msgs,
        m.sent_bytes,
        m.recv_msgs,
        m.dropped_msgs,
        m.vtime.to_bits(),
        m.recolor_trace,
    )
}

fn merge_and_hash(g: &CsrGraph, pairs: Vec<Vec<(u32, u32)>>, lines: &mut Vec<String>) {
    let mut coloring = Coloring::uncolored(g.num_vertices());
    for ps in pairs {
        for (gid, c) in ps {
            coloring.set(gid, c);
        }
    }
    coloring.validate(g).unwrap();
    let hash = fnv1a(coloring.colors.iter().flat_map(|c| c.to_le_bytes()));
    lines.push(format!(
        "coloring colors={} hash={hash:016x}",
        coloring.num_colors()
    ));
}

/// The fixed job on the thread-per-process runner (the reference oracle).
fn run_fixture_threads(scheme: CommScheme) -> Vec<String> {
    let g = fixture_graph();
    let part = partition::partition(&g, Partitioner::Block, PROCS, 1);
    let (_, locals) = build_local_graphs(&g, &part);
    let eps = comm::network(PROCS, NetworkModel::default());
    let cost = CostModel::fixed();
    let fw = fixture_fw();
    let rc = fixture_rc(scheme);

    let mut outs: Vec<Option<(Vec<(u32, u32)>, String)>> = (0..PROCS).map(|_| None).collect();
    std::thread::scope(|s| {
        let hs: Vec<_> = eps
            .into_iter()
            .zip(locals.iter())
            .map(|(ep, lg)| {
                let fw = &fw;
                let rc = &rc;
                let cost = &cost;
                s.spawn(move || {
                    let mut ep = ep;
                    let mut state = ColorState::uncolored(lg);
                    let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
                    framework::color_process(&mut ep, lg, fw, cost, &mut state, to, None, None);
                    let mut trace = Vec::new();
                    recolor_process_sync(&mut ep, lg, cost, rc, &mut state, &mut trace, None);
                    assert_eq!(ep.dropped_msgs, 0, "transport dropped messages");
                    let m = ProcMetrics {
                        rank: ep.rank,
                        vtime: ep.clock,
                        sent_msgs: ep.sent_msgs,
                        sent_bytes: ep.sent_bytes,
                        recv_msgs: ep.recv_msgs,
                        dropped_msgs: ep.dropped_msgs,
                        recolor_trace: trace,
                        ..Default::default()
                    };
                    (state.owned_pairs(lg), proc_line(&m))
                })
            })
            .collect();
        for (i, h) in hs.into_iter().enumerate() {
            outs[i] = Some(h.join().unwrap());
        }
    });

    let mut pairs = Vec::new();
    let mut lines = Vec::new();
    for (ps, line) in outs.into_iter().map(|o| o.unwrap()) {
        pairs.push(ps);
        lines.push(line);
    }
    merge_and_hash(&g, pairs, &mut lines);
    lines
}

/// The same fixed job as a step machine: framework port chained into the
/// sync-RC port, with the fixture's accounting read off the endpoint.
struct FixtureMachine<'a> {
    lg: &'a LocalGraph,
    cost: CostModel,
    rc_cfg: RecolorConfig,
    fw: Option<FrameworkStep<'a>>,
    rc: Option<SyncRcStep<'a>>,
}

impl StepProcess for FixtureMachine<'_> {
    fn step(&mut self, ep: &mut Endpoint) -> StepOutcome {
        if let Some(fw) = self.fw.as_mut() {
            if fw.step_once(ep) {
                let (colors, _m) = self.fw.take().unwrap().into_parts();
                self.rc = Some(SyncRcStep::new(self.lg, &self.cost, self.rc_cfg, colors, None));
            }
            return StepOutcome::Running;
        }
        if self.rc.as_mut().expect("rc machine").step_once(ep) {
            let (colors, trace, _m) = self.rc.take().unwrap().into_parts();
            assert_eq!(ep.dropped_msgs, 0, "transport dropped messages");
            let metrics = ProcMetrics {
                rank: ep.rank,
                vtime: ep.clock,
                sent_msgs: ep.sent_msgs,
                sent_bytes: ep.sent_bytes,
                recv_msgs: ep.recv_msgs,
                dropped_msgs: ep.dropped_msgs,
                recolor_trace: trace,
                ..Default::default()
            };
            return StepOutcome::Done(ProcResult {
                colors: colors.owned_pairs(self.lg),
                metrics,
            });
        }
        StepOutcome::Running
    }
}

/// The fixed job on the BSP step engine.
fn run_fixture_engine(scheme: CommScheme) -> Vec<String> {
    let g = fixture_graph();
    let part = partition::partition(&g, Partitioner::Block, PROCS, 1);
    let (_, locals) = build_local_graphs(&g, &part);
    let cost = CostModel::fixed();
    let fw = fixture_fw();
    let rc_cfg = fixture_rc(scheme);

    let out = engine::run_steps(g.num_vertices(), &locals, NetworkModel::default(), |lg| {
        let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
        FixtureMachine {
            lg,
            cost,
            rc_cfg,
            fw: Some(FrameworkStep::new(
                lg,
                &fw,
                &cost,
                ColorState::uncolored(lg),
                to,
                None,
                None,
            )),
            rc: None,
        }
    });

    let mut lines: Vec<String> = out.per_proc.iter().map(proc_line).collect();
    let hash = fnv1a(out.coloring.colors.iter().flat_map(|c| c.to_le_bytes()));
    out.coloring.validate(&g).unwrap();
    lines.push(format!(
        "coloring colors={} hash={hash:016x}",
        out.coloring.num_colors()
    ));
    lines
}

/// aRC iterations for the fixed aRC jobs. Early-stop stays off so the
/// trace length is pinned.
const ARC_ITERS: u32 = 2;

/// The fixed aRC job on the thread-per-process runner: framework coloring
/// followed by the pipeline's per-iteration aRC loop (speculative rerun +
/// post-iteration `k` allreduce).
fn run_arc_threads(perm: Permutation) -> Vec<String> {
    let g = fixture_graph();
    let part = partition::partition(&g, Partitioner::Block, PROCS, 1);
    let (_, locals) = build_local_graphs(&g, &part);
    let eps = comm::network(PROCS, NetworkModel::default());
    let cost = CostModel::fixed();
    let fw = fixture_fw();

    let mut outs: Vec<Option<(Vec<(u32, u32)>, String)>> = (0..PROCS).map(|_| None).collect();
    std::thread::scope(|s| {
        let hs: Vec<_> = eps
            .into_iter()
            .zip(locals.iter())
            .map(|(ep, lg)| {
                let fw = &fw;
                let cost = &cost;
                s.spawn(move || {
                    let mut ep = ep;
                    let mut state = ColorState::uncolored(lg);
                    let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
                    framework::color_process(&mut ep, lg, fw, cost, &mut state, to, None, None);
                    let mut m = ProcMetrics::default();
                    let mut trace = Vec::new();
                    for iter in 1..=ARC_ITERS {
                        let im = recolor_process_async(
                            &mut ep, lg, cost, fw, perm, iter, fw.seed, &mut state, None,
                        );
                        m.phases.merge(&im.phases);
                        let local_kmax = (0..lg.n_owned())
                            .map(|v| state.colors[v] as u64 + 1)
                            .max()
                            .unwrap_or(0);
                        let k = framework::comm_timed(&mut ep, &mut m, |ep| {
                            ep.allreduce_max_u64(local_kmax)
                        });
                        trace.push(k as usize);
                    }
                    assert_eq!(ep.dropped_msgs, 0, "transport dropped messages");
                    let m = ProcMetrics {
                        rank: ep.rank,
                        vtime: ep.clock,
                        sent_msgs: ep.sent_msgs,
                        sent_bytes: ep.sent_bytes,
                        recv_msgs: ep.recv_msgs,
                        dropped_msgs: ep.dropped_msgs,
                        recolor_trace: trace,
                        ..Default::default()
                    };
                    (state.owned_pairs(lg), proc_line(&m))
                })
            })
            .collect();
        for (i, h) in hs.into_iter().enumerate() {
            outs[i] = Some(h.join().unwrap());
        }
    });

    let mut pairs = Vec::new();
    let mut lines = Vec::new();
    for (ps, line) in outs.into_iter().map(|o| o.unwrap()) {
        pairs.push(ps);
        lines.push(line);
    }
    merge_and_hash(&g, pairs, &mut lines);
    lines
}

/// The same fixed aRC job as a step machine: framework port chained into
/// the aRC port, the shape [`JobMachine`] runs on the BSP engine.
struct ArcFixtureMachine<'a> {
    lg: &'a LocalGraph,
    cost: CostModel,
    fw_cfg: FrameworkConfig,
    perm: Permutation,
    fw: Option<FrameworkStep<'a>>,
    arc: Option<AsyncRcStep<'a>>,
}

impl StepProcess for ArcFixtureMachine<'_> {
    fn step(&mut self, ep: &mut Endpoint) -> StepOutcome {
        if let Some(fw) = self.fw.as_mut() {
            if fw.step_once(ep) {
                let (colors, _m) = self.fw.take().unwrap().into_parts();
                // early-stop is off, so the `prev_k` baseline is inert
                self.arc = Some(AsyncRcStep::new(
                    self.lg,
                    &self.cost,
                    &self.fw_cfg,
                    self.perm,
                    ARC_ITERS,
                    self.fw_cfg.seed,
                    None,
                    0,
                    colors,
                    None,
                ));
            }
            return StepOutcome::Running;
        }
        if self.arc.as_mut().expect("arc machine").step_once(ep) {
            let (colors, trace, _m) = self.arc.take().unwrap().into_parts();
            assert_eq!(ep.dropped_msgs, 0, "transport dropped messages");
            let metrics = ProcMetrics {
                rank: ep.rank,
                vtime: ep.clock,
                sent_msgs: ep.sent_msgs,
                sent_bytes: ep.sent_bytes,
                recv_msgs: ep.recv_msgs,
                dropped_msgs: ep.dropped_msgs,
                recolor_trace: trace,
                ..Default::default()
            };
            return StepOutcome::Done(ProcResult {
                colors: colors.owned_pairs(self.lg),
                metrics,
            });
        }
        StepOutcome::Running
    }
}

/// The fixed aRC job on the BSP step engine.
fn run_arc_engine(perm: Permutation) -> Vec<String> {
    let g = fixture_graph();
    let part = partition::partition(&g, Partitioner::Block, PROCS, 1);
    let (_, locals) = build_local_graphs(&g, &part);
    let cost = CostModel::fixed();
    let fw = fixture_fw();

    let out = engine::run_steps(g.num_vertices(), &locals, NetworkModel::default(), |lg| {
        let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
        ArcFixtureMachine {
            lg,
            cost,
            fw_cfg: fw,
            perm,
            fw: Some(FrameworkStep::new(
                lg,
                &fw,
                &cost,
                ColorState::uncolored(lg),
                to,
                None,
                None,
            )),
            arc: None,
        }
    });

    let mut lines: Vec<String> = out.per_proc.iter().map(proc_line).collect();
    let hash = fnv1a(out.coloring.colors.iter().flat_map(|c| c.to_le_bytes()));
    out.coloring.validate(&g).unwrap();
    lines.push(format!(
        "coloring colors={} hash={hash:016x}",
        out.coloring.num_colors()
    ));
    lines
}

/// The fixed DataPar job: the shared-memory speculative engine on the
/// fixture graph at one pool size. No transport, so the modeled quantities
/// are the coloring itself plus the round/speculation accounting.
fn run_datapar(workers: usize) -> Vec<String> {
    let g = fixture_graph();
    let cfg = shm::DataParConfig {
        ordering: Ordering::Natural,
        selection: Selection::RandomX(8),
        seed: 42,
        // small chunks force plenty of cross-chunk speculation on 600
        // vertices — the part that could plausibly go racy
        chunk_size: 64,
        max_rounds: 200,
    };
    let (c, m) = shm::color_graph_on(&WorkerPool::new(workers), &g, &cfg).unwrap();
    c.validate(&g).unwrap();
    let hash = fnv1a(c.colors.iter().flat_map(|c| c.to_le_bytes()));
    vec![format!(
        "datapar colors={} hash={hash:016x} rounds={} speculated={} conflicted={}",
        c.num_colors(),
        m.rounds,
        m.speculated,
        m.conflicted,
    )]
}

/// The fixed lossy supervised job: reliable delivery under 10% link loss
/// plus two crash-stops recovered from interval checkpoints. The reliable
/// layer must be invisible in the answer — the coloring is asserted equal
/// to the fault-free run of the same job — while its loss / retransmit /
/// ack / dedup accounting is pinned like every other modeled quantity.
fn run_faults_loss() -> Vec<String> {
    use dgcolor::coordinator::job::nd;
    use dgcolor::coordinator::{Job, Session};
    use dgcolor::dist::{Crash, FaultPlan};
    let s = Session::new(fixture_graph()).with_cost_model(CostModel::fixed());
    let mk = |plan: FaultPlan| {
        Job::on(&s)
            .procs(PROCS)
            .selection(Selection::RandomX(8))
            .sync_recolor(nd(1))
            .seed(42)
            .faults(plan)
            .build()
            .unwrap()
    };
    let plain = s.run(&mk(FaultPlan::none())).unwrap();
    let plan = FaultPlan {
        seed: 17,
        loss_prob: 0.1,
        crashes: vec![
            Crash { rank: 1, step: 2, down_steps: 2 },
            Crash { rank: 2, step: 4, down_steps: 2 },
        ],
        checkpoint_interval: 2,
        ..FaultPlan::none()
    };
    let r = s.run(&mk(plan)).unwrap();
    assert_eq!(
        plain.coloring.colors, r.coloring.colors,
        "[faults-loss] reliable recovery changed the answer"
    );
    assert_eq!(
        r.metrics.total_non_teardown_drops, 0,
        "[faults-loss] losses must not surface as drops"
    );
    assert!(
        r.metrics.total_injected_losses > 0,
        "[faults-loss] the plan injected no losses"
    );
    assert_eq!(r.metrics.total_restarts, 2, "[faults-loss] both crashes must fire");
    let hash = fnv1a(r.coloring.colors.iter().flat_map(|c| c.to_le_bytes()));
    vec![
        format!(
            "reliable msgs={} losses={} retx={} acks={} dups={} restarts={} makespan={:016x}",
            r.metrics.total_msgs,
            r.metrics.total_injected_losses,
            r.metrics.total_retransmits,
            r.metrics.total_acks_sent,
            r.metrics.total_dup_discards,
            r.metrics.total_restarts,
            r.metrics.makespan.to_bits(),
        ),
        format!("coloring colors={} hash={hash:016x}", r.coloring.num_colors()),
    ]
}

fn observed() -> String {
    let mut all = vec![format!("# accounting fixture v1, {PROCS} procs")];
    for (label, scheme) in [("base", CommScheme::Base), ("piggyback", CommScheme::Piggyback)] {
        let threads = run_fixture_threads(scheme);
        let engine = run_fixture_engine(scheme);
        assert_eq!(
            threads, engine,
            "[{label}] BSP step engine diverged from the thread runner"
        );
        all.push(format!("[{label}]"));
        all.extend(threads);
    }
    for (label, perm) in [
        ("arc-nd", Permutation::NonDecreasing),
        ("arc-ni", Permutation::NonIncreasing),
    ] {
        let threads = run_arc_threads(perm);
        let engine = run_arc_engine(perm);
        assert_eq!(
            threads, engine,
            "[{label}] BSP step engine diverged from the thread runner"
        );
        all.push(format!("[{label}]"));
        all.extend(threads);
    }
    {
        let one = run_datapar(1);
        for workers in [2, 8] {
            assert_eq!(
                one,
                run_datapar(workers),
                "[datapar] {workers}-worker run diverged from the 1-worker run"
            );
        }
        all.push("[datapar]".to_string());
        all.extend(one);
    }
    {
        all.push("[faults-loss]".to_string());
        all.extend(run_faults_loss());
    }
    let mut s = all.join("\n");
    s.push('\n');
    s
}

#[test]
fn accounting_is_bit_for_bit_stable() {
    let now = observed();
    // determinism within this build — two runs, identical serialization
    // (and `observed` itself asserts thread-runner == step-engine)
    assert_eq!(now, observed(), "accounting not deterministic across runs");

    let path = Path::new(FIXTURE);
    let env1 = |k| std::env::var(k).map(|v| v == "1").unwrap_or(false);
    let bless = env1("DGCOLOR_BLESS");
    if !path.exists() && !bless {
        assert!(
            !env1("DGCOLOR_REQUIRE_FIXTURE"),
            "{FIXTURE} is missing; generate it once with DGCOLOR_BLESS=1 and commit it"
        );
    }
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, &now).unwrap();
        eprintln!("accounting fixture (re)blessed at {FIXTURE}; commit it to pin these values");
        return;
    }
    let pinned = std::fs::read_to_string(path).unwrap();
    assert_eq!(
        now, pinned,
        "modeled quantities diverged from the committed fixture \
         ({FIXTURE}); if the change is intentional, rebless with DGCOLOR_BLESS=1"
    );
}
