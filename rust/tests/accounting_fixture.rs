//! Accounting-invariance fixture: the pooled transport, dense ghost
//! indexing, and scratch hoisting must not change any *modeled* quantity.
//! For two fixed jobs (framework coloring + 2 RC iterations, Base and
//! Piggyback) this pins — bit-for-bit — the final coloring, every
//! process's `sent_msgs` / `sent_bytes` / `recv_msgs`, and every virtual
//! clock (as `f64::to_bits`), against a committed fixture file.
//!
//! Bless protocol: if `tests/fixtures/accounting_v1.txt` is absent (first
//! run in a fresh environment) or `DGCOLOR_BLESS=1` is set, the observed
//! values are written and the test passes; any later run that disagrees
//! with the committed file fails. Until the fixture is generated and
//! committed by an environment with a toolchain, a fresh checkout
//! self-blesses — set `DGCOLOR_REQUIRE_FIXTURE=1` to turn a missing
//! fixture into a failure instead (for environments that must enforce the
//! pin). Once the file is committed, every checkout enforces it
//! automatically. Independently of the fixture, every run checks that two
//! executions agree bit-for-bit and that nothing was dropped by the
//! transport.

use dgcolor::color::recolor::{Permutation, RecolorSchedule};
use dgcolor::color::{Coloring, Ordering, Selection};
use dgcolor::dist::comm;
use dgcolor::dist::cost::{CostModel, NetworkModel};
use dgcolor::dist::framework::{self, FrameworkConfig};
use dgcolor::dist::proc::{build_local_graphs, ColorState};
use dgcolor::dist::recolor::{recolor_process_sync, CommScheme, RecolorConfig};
use dgcolor::graph::synth;
use dgcolor::partition::{self, Partitioner};
use std::path::Path;

const FIXTURE: &str = "tests/fixtures/accounting_v1.txt";
const PROCS: usize = 4;

fn fnv1a(bytes: impl Iterator<Item = u8>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Run the fixed job and serialize every modeled quantity, one line each.
fn run_fixture(scheme: CommScheme) -> Vec<String> {
    let g = synth::fem_like(600, 10.0, 26, 0.01, 5, "fixture");
    let part = partition::partition(&g, Partitioner::Block, PROCS, 1);
    let (_, locals) = build_local_graphs(&g, &part);
    let eps = comm::network(PROCS, NetworkModel::default());
    let cost = CostModel::fixed();
    let fw = FrameworkConfig {
        ordering: Ordering::InternalFirst,
        selection: Selection::RandomX(8),
        superstep_size: 64,
        sync: true,
        seed: 42,
        max_rounds: 200,
    };
    let rc = RecolorConfig {
        schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
        iterations: 2,
        scheme,
        seed: 7,
        early_stop: None,
    };

    let mut outs: Vec<Option<(Vec<(u32, u32)>, Vec<String>)>> = (0..PROCS).map(|_| None).collect();
    std::thread::scope(|s| {
        let hs: Vec<_> = eps
            .into_iter()
            .zip(locals.iter())
            .map(|(ep, lg)| {
                let fw = &fw;
                let rc = &rc;
                let cost = &cost;
                s.spawn(move || {
                    let mut ep = ep;
                    let mut state = ColorState::uncolored(lg);
                    let to: Vec<u32> = (0..lg.n_owned() as u32).collect();
                    framework::color_process(&mut ep, lg, fw, cost, &mut state, to, None, None);
                    let mut trace = Vec::new();
                    recolor_process_sync(&mut ep, lg, cost, rc, &mut state, &mut trace, None);
                    let line = format!(
                        "proc {} msgs={} bytes={} recv={} dropped={} clock={:016x} trace={:?}",
                        ep.rank,
                        ep.sent_msgs,
                        ep.sent_bytes,
                        ep.recv_msgs,
                        ep.dropped_msgs,
                        ep.clock.to_bits(),
                        trace,
                    );
                    assert_eq!(ep.dropped_msgs, 0, "transport dropped messages");
                    (state.owned_pairs(lg), vec![line])
                })
            })
            .collect();
        for (i, h) in hs.into_iter().enumerate() {
            outs[i] = Some(h.join().unwrap());
        }
    });

    let mut coloring = Coloring::uncolored(g.num_vertices());
    let mut lines = Vec::new();
    for (pairs, ls) in outs.into_iter().map(|o| o.unwrap()) {
        for (gid, c) in pairs {
            coloring.set(gid, c);
        }
        lines.extend(ls);
    }
    coloring.validate(&g).unwrap();
    let hash = fnv1a(coloring.colors.iter().flat_map(|c| c.to_le_bytes()));
    lines.push(format!(
        "coloring colors={} hash={hash:016x}",
        coloring.num_colors()
    ));
    lines
}

fn observed() -> String {
    let mut all = vec![format!("# accounting fixture v1, {PROCS} procs")];
    for (label, scheme) in [("base", CommScheme::Base), ("piggyback", CommScheme::Piggyback)] {
        all.push(format!("[{label}]"));
        all.extend(run_fixture(scheme));
    }
    let mut s = all.join("\n");
    s.push('\n');
    s
}

#[test]
fn accounting_is_bit_for_bit_stable() {
    let now = observed();
    // determinism within this build — two runs, identical serialization
    assert_eq!(now, observed(), "accounting not deterministic across runs");

    let path = Path::new(FIXTURE);
    let env1 = |k| std::env::var(k).map(|v| v == "1").unwrap_or(false);
    let bless = env1("DGCOLOR_BLESS");
    if !path.exists() && !bless {
        assert!(
            !env1("DGCOLOR_REQUIRE_FIXTURE"),
            "{FIXTURE} is missing; generate it once with DGCOLOR_BLESS=1 and commit it"
        );
    }
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, &now).unwrap();
        eprintln!("accounting fixture (re)blessed at {FIXTURE}; commit it to pin these values");
        return;
    }
    let pinned = std::fs::read_to_string(path).unwrap();
    assert_eq!(
        now, pinned,
        "modeled quantities diverged from the committed fixture \
         ({FIXTURE}); if the change is intentional, rebless with DGCOLOR_BLESS=1"
    );
}
