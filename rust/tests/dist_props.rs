//! Property tests for the distributed runtime (`util::prop` harness):
//! session runs must return a valid coloring across random graphs, seeds,
//! process counts, superstep sizes, both communication modes, and every
//! recoloring mode — plus determinism and trace-shape invariants.

use dgcolor::color::recolor::{Permutation, RecolorSchedule};
use dgcolor::color::{Ordering, Selection};
use dgcolor::coordinator::{ColoringConfig, Job, RecolorMode, RunResult, Session};
use dgcolor::dist::cost::CostModel;
use dgcolor::dist::proc::build_local_graphs;
use dgcolor::dist::recolor::{CommScheme, RecolorConfig};
use dgcolor::dist::{Engine, NetworkModel};
use dgcolor::graph::{CsrGraph, GraphBuilder};
use dgcolor::partition::{self, Partitioner};
use dgcolor::util::prop::{check, PropConfig};
use dgcolor::util::Rng;

fn random_graph(rng: &mut Rng) -> CsrGraph {
    let n = rng.range(2, 500);
    let m = rng.range(1, 5 * n);
    let mut b = GraphBuilder::new(n);
    for _ in 0..m {
        b.add_edge(rng.range(0, n) as u32, rng.range(0, n) as u32);
    }
    b.build(format!("dp-{n}-{m}"))
}

fn random_config(rng: &mut Rng) -> ColoringConfig {
    let ordering = *rng.choose(&[
        Ordering::Natural,
        Ordering::InternalFirst,
        Ordering::BoundaryFirst,
        Ordering::LargestFirst,
        Ordering::SmallestLast,
    ]);
    let selection = *rng.choose(&[
        Selection::FirstFit,
        Selection::StaggeredFirstFit,
        Selection::LeastUsed,
        Selection::RandomX(rng.range(1, 30) as u32),
    ]);
    let recolor = match rng.below(4) {
        0 => RecolorMode::None,
        1 => RecolorMode::Sync(RecolorConfig {
            schedule: RecolorSchedule::Fixed(*rng.choose(&[
                Permutation::NonDecreasing,
                Permutation::NonIncreasing,
                Permutation::Reverse,
                Permutation::Random,
            ])),
            iterations: rng.range(1, 4) as u32,
            scheme: if rng.chance(0.5) {
                CommScheme::Base
            } else {
                CommScheme::Piggyback
            },
            seed: rng.next_u64(),
            ..Default::default()
        }),
        2 => RecolorMode::Async {
            perm: *rng.choose(&[
                Permutation::NonDecreasing,
                Permutation::NonIncreasing,
                Permutation::Reverse,
                Permutation::Random,
            ]),
            iterations: rng.range(1, 4) as u32,
        },
        _ => RecolorMode::Sync(RecolorConfig::default()),
    };
    ColoringConfig {
        num_procs: rng.range(1, 10),
        superstep_size: rng.range(1, 400),
        sync: rng.chance(0.5),
        ordering,
        selection,
        recolor,
        seed: rng.next_u64(),
        network: if rng.chance(0.3) {
            NetworkModel::ideal()
        } else {
            NetworkModel::default()
        },
        fixed_cost: Some(CostModel::fixed()),
        ..Default::default()
    }
}

fn run(s: &Session, cfg: &ColoringConfig) -> Result<RunResult, String> {
    let job = Job::from_config(cfg.clone()).map_err(|e| e.to_string())?;
    s.run(&job).map_err(|e| format!("{}: {e}", cfg.label()))
}

#[test]
fn prop_session_runs_always_valid() {
    check(
        "session runs valid across graphs/configs/modes",
        PropConfig { cases: 40, seed: 0xD157 },
        |rng, _| {
            let s = Session::new(random_graph(rng));
            let cfg = random_config(rng);
            // the pipeline validates internally and errors on any conflict
            let r = run(&s, &cfg)?;
            r.coloring
                .validate(s.graph())
                .map_err(|e| format!("{}: {e}", cfg.label()))?;
            if r.num_colors != r.coloring.num_colors() {
                return Err("num_colors disagrees with coloring".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sync_runs_are_deterministic() {
    check(
        "sync determinism",
        PropConfig { cases: 12, seed: 0xD158 },
        |rng, _| {
            let s = Session::new(random_graph(rng));
            let mut cfg = random_config(rng);
            cfg.sync = true;
            // the second run reuses the cached partition: determinism here
            // also pins cache-hit equivalence
            let a = run(&s, &cfg)?;
            let b = run(&s, &cfg)?;
            if a.coloring.colors != b.coloring.colors {
                return Err(format!("colors diverged for {}", cfg.label()));
            }
            if a.metrics.total_msgs != b.metrics.total_msgs
                || a.metrics.total_bytes != b.metrics.total_bytes
                || a.metrics.total_conflicts != b.metrics.total_conflicts
            {
                return Err(format!("accounting diverged for {}", cfg.label()));
            }
            if (a.metrics.makespan - b.metrics.makespan).abs() > 1e-15 {
                return Err(format!("makespan diverged for {}", cfg.label()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sync_recolor_trace_is_monotone() {
    check(
        "RC trace monotone (Culberson)",
        PropConfig { cases: 20, seed: 0xD159 },
        |rng, _| {
            let g = random_graph(rng);
            let iters = rng.range(1, 5) as u32;
            let cfg = ColoringConfig {
                num_procs: rng.range(1, 7),
                selection: Selection::RandomX(rng.range(2, 20) as u32),
                recolor: RecolorMode::Sync(RecolorConfig {
                    schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
                    iterations: iters,
                    scheme: CommScheme::Piggyback,
                    seed: rng.next_u64(),
                    ..Default::default()
                }),
                seed: rng.next_u64(),
                fixed_cost: Some(CostModel::fixed()),
                ..Default::default()
            };
            let r = run(&Session::new(g), &cfg)?;
            if r.recolor_trace.len() != iters as usize + 1 {
                return Err(format!(
                    "trace length {} != {}",
                    r.recolor_trace.len(),
                    iters + 1
                ));
            }
            if !r.recolor_trace.windows(2).all(|w| w[1] <= w[0]) {
                return Err(format!("trace not monotone: {:?}", r.recolor_trace));
            }
            if *r.recolor_trace.last().unwrap() != r.num_colors {
                return Err("trace tail != final colors".into());
            }
            Ok(())
        },
    );
}

/// The BSP step engine and the thread-per-process runner must be
/// bit-for-bit interchangeable across random graphs, partitions and
/// configs (every sync recolor mode and aRC permutation, both comm
/// schemes, both superstep communication modes, random superstep sizes
/// and process counts).
#[test]
fn prop_step_engine_matches_thread_runner() {
    check(
        "BSP step engine == thread runner",
        PropConfig { cases: 25, seed: 0xD15C },
        |rng, _| {
            let s = Session::new(random_graph(rng));
            let mut cfg = random_config(rng);
            cfg.engine = Engine::Threads;
            let t = run(&s, &cfg)?;
            cfg.engine = Engine::Bsp;
            let e = run(&s, &cfg)?;
            if t.coloring.colors != e.coloring.colors {
                return Err(format!("colors diverged for {}", cfg.label()));
            }
            if t.recolor_trace != e.recolor_trace {
                return Err(format!("traces diverged for {}", cfg.label()));
            }
            if t.metrics.total_msgs != e.metrics.total_msgs
                || t.metrics.total_bytes != e.metrics.total_bytes
                || t.metrics.total_conflicts != e.metrics.total_conflicts
                || t.metrics.rounds != e.metrics.rounds
            {
                return Err(format!("accounting diverged for {}", cfg.label()));
            }
            if t.metrics.makespan.to_bits() != e.metrics.makespan.to_bits() {
                return Err(format!("makespan bits diverged for {}", cfg.label()));
            }
            if t.metrics.total_dropped != 0 || e.metrics.total_dropped != 0 {
                return Err(format!("dropped messages for {}", cfg.label()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dense_lookup_agrees_with_hashmap_reference() {
    // `LocalGraph::local_of` (O(1) GlobalMap read for owned vertices +
    // binary search over the sorted ghost tail) must agree with the
    // HashMap the old implementation kept, on every vertex of every
    // process, across random graphs, partitioners, and process counts.
    check(
        "dense ghost indexing == HashMap reference",
        PropConfig { cases: 40, seed: 0xD15B },
        |rng, _| {
            let g = random_graph(rng);
            let procs = rng.range(1, 9);
            let partitioner = if rng.chance(0.5) {
                Partitioner::Block
            } else {
                Partitioner::BfsGrow
            };
            let part = partition::partition(&g, partitioner, procs, rng.next_u64());
            let (gmap, locals) = build_local_graphs(&g, &part);
            for lg in &locals {
                let mut reference = std::collections::HashMap::new();
                for (i, &gid) in lg.global_ids.iter().enumerate() {
                    reference.insert(gid, i as u32);
                }
                for (&gid, &li) in reference.iter() {
                    if lg.local_of(gid) != li {
                        return Err(format!(
                            "p{}: local_of({gid}) = {} != {li}",
                            lg.rank,
                            lg.local_of(gid)
                        ));
                    }
                }
                // owned lookups are direct GlobalMap reads — pin the
                // directory itself so the O(1) path can't silently rot
                for i in 0..lg.n_owned() {
                    let gid = lg.global_ids[i] as usize;
                    if gmap.owner[gid] != lg.rank || gmap.local[gid] != i as u32 {
                        return Err(format!(
                            "p{}: GlobalMap disagrees at gid {gid}: owner {} local {}",
                            lg.rank, gmap.owner[gid], gmap.local[gid]
                        ));
                    }
                }
                // ghost tail must be sorted or the binary search is unsound
                let ghosts = &lg.global_ids[lg.n_owned()..];
                if !ghosts.windows(2).all(|w| w[0] < w[1]) {
                    return Err(format!("p{}: ghost tail not strictly sorted", lg.rank));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_comm_schemes_agree() {
    check(
        "Base == Piggyback results",
        PropConfig { cases: 15, seed: 0xD15A },
        |rng, _| {
            let s = Session::new(random_graph(rng));
            let seed = rng.next_u64();
            let procs = rng.range(1, 8);
            let mk = |scheme| ColoringConfig {
                num_procs: procs,
                recolor: RecolorMode::Sync(RecolorConfig {
                    schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
                    iterations: 2,
                    scheme,
                    seed: 7,
                    ..Default::default()
                }),
                seed,
                fixed_cost: Some(CostModel::fixed()),
                ..Default::default()
            };
            let a = run(&s, &mk(CommScheme::Base))?;
            let b = run(&s, &mk(CommScheme::Piggyback))?;
            if a.coloring.colors != b.coloring.colors {
                return Err("schemes disagree".into());
            }
            Ok(())
        },
    );
}
