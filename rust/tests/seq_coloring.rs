//! Integration tests: sequential coloring core across graph families.

use dgcolor::color::recolor::{self, Permutation, RecolorSchedule};
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::graph::synth;
use dgcolor::util::Rng;

#[test]
fn all_orderings_all_selections_valid_on_all_families() {
    let graphs = vec![
        synth::grid2d(15, 15),
        synth::erdos_renyi(800, 4800, 3),
        synth::fem_like(1000, 10.0, 25, 0.005, 4, "fem"),
        rmat::generate(&RmatParams::bad(9, 6), 5, "rmat-bad"),
        synth::star(64),
        synth::complete(12),
    ];
    for g in &graphs {
        for ord in [
            Ordering::Natural,
            Ordering::LargestFirst,
            Ordering::SmallestLast,
            Ordering::IncidenceDegree,
            Ordering::Random,
        ] {
            for sel in [
                Selection::FirstFit,
                Selection::StaggeredFirstFit,
                Selection::LeastUsed,
                Selection::RandomX(5),
            ] {
                let c = greedy_color(g, ord, sel, 7);
                c.validate(g)
                    .unwrap_or_else(|e| panic!("{} {ord:?} {sel:?}: {e}", g.name));
                assert!(
                    c.num_colors() <= g.max_degree() + 5 + 1,
                    "{} {ord:?} {sel:?}: {} colors vs Δ+X+1",
                    g.name,
                    c.num_colors()
                );
            }
        }
    }
}

#[test]
fn paper_ordering_hierarchy_on_fem_meshes() {
    // Table 1 trend: SL ≤ LF ≤ NAT (allow slack of 2 — heuristics).
    let mut sl_wins = 0;
    let mut cases = 0;
    for seed in 0..4 {
        let g = synth::fem_like(4000, 14.0, 40, 0.005, seed, "fem");
        let nat = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 1).num_colors();
        let lf = greedy_color(&g, Ordering::LargestFirst, Selection::FirstFit, 1).num_colors();
        let sl = greedy_color(&g, Ordering::SmallestLast, Selection::FirstFit, 1).num_colors();
        assert!(lf <= nat + 2, "LF {lf} vs NAT {nat}");
        assert!(sl <= lf + 2, "SL {sl} vs LF {lf}");
        if sl < nat {
            sl_wins += 1;
        }
        cases += 1;
    }
    assert!(
        sl_wins * 2 >= cases,
        "SL should usually beat NAT ({sl_wins}/{cases})"
    );
}

#[test]
fn iterated_greedy_converges_and_never_worsens() {
    let g = rmat::generate(&RmatParams::good(10, 8), 11, "rmat-good");
    let c0 = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 2);
    let mut rng = Rng::new(5);
    let (best, trace) =
        recolor::recolor_iterate(&g, &c0, RecolorSchedule::NdRandPow2, 20, &mut rng);
    best.validate(&g).unwrap();
    assert!(trace.windows(2).all(|w| w[1] <= w[0]), "trace {trace:?}");
    assert!(best.num_colors() < c0.num_colors(), "no improvement: {trace:?}");
}

#[test]
fn nd_beats_ni_usually() {
    // Fig 2: ND the best fixed permutation, NI the weakest.
    let mut nd_total = 0usize;
    let mut ni_total = 0usize;
    for seed in 0..3 {
        let g = synth::fem_like(3000, 13.0, 35, 0.005, seed + 100, "fem");
        let c0 = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 3);
        let mut rng = Rng::new(seed);
        let (nd, _) = recolor::recolor_iterate(
            &g,
            &c0,
            RecolorSchedule::Fixed(Permutation::NonDecreasing),
            10,
            &mut rng,
        );
        let (ni, _) = recolor::recolor_iterate(
            &g,
            &c0,
            RecolorSchedule::Fixed(Permutation::NonIncreasing),
            10,
            &mut rng,
        );
        nd_total += nd.num_colors();
        ni_total += ni.num_colors();
    }
    assert!(nd_total <= ni_total, "ND {nd_total} vs NI {ni_total}");
}

#[test]
fn random_x_balance_property() {
    // §3.2: Random-X balances class sizes better than first fit (FF
    // front-loads low colors on mesh-like graphs; Random-X spreads).
    let g = synth::fem_like(8000, 13.0, 32, 0.004, 9, "fem");
    let ff = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 1);
    let r10 = greedy_color(&g, Ordering::Natural, Selection::RandomX(10), 1);
    assert!(
        r10.balance() < ff.balance(),
        "R10 balance {} vs FF balance {}",
        r10.balance(),
        ff.balance()
    );
}
