//! Tests for the session-based job API: partition-cache-hit equivalence
//! with the legacy `run_job` shim, builder validation, the early-stop
//! policy, and the observer event-stream invariants.

use dgcolor::color::Selection;
use dgcolor::coordinator::job::nd;
use dgcolor::coordinator::{
    ColoringConfig, Event, EventLog, Job, Phase, RunResult, Session,
};
use dgcolor::dist::cost::CostModel;
use dgcolor::graph::synth;

fn bitwise_eq(a: &RunResult, b: &RunResult) {
    assert_eq!(a.coloring.colors, b.coloring.colors, "colors differ");
    assert_eq!(a.recolor_trace, b.recolor_trace, "traces differ");
    assert_eq!(a.num_colors, b.num_colors);
    assert_eq!(a.initial_colors, b.initial_colors);
    assert_eq!(a.metrics.total_msgs, b.metrics.total_msgs);
    assert_eq!(a.metrics.total_bytes, b.metrics.total_bytes);
    assert_eq!(a.metrics.total_conflicts, b.metrics.total_conflicts);
    assert_eq!(
        a.metrics.makespan.to_bits(),
        b.metrics.makespan.to_bits(),
        "makespan differs"
    );
    assert_eq!(a.partition_metrics, b.partition_metrics);
    assert_eq!(a.config_label, b.config_label);
}

/// A session run from the partition cache equals a fresh `run_job` call
/// bit for bit — caching and observation are pure speedups.
#[test]
fn cached_run_equals_fresh_run_job_bit_for_bit() {
    let g = synth::fem_like(1500, 11.0, 28, 0.004, 3, "fem");
    let cfg = ColoringConfig {
        num_procs: 6,
        selection: Selection::RandomX(5),
        recolor: dgcolor::coordinator::RecolorMode::Sync(nd(2)),
        fixed_cost: Some(CostModel::fixed()),
        ..Default::default()
    };
    #[allow(deprecated)]
    let fresh = dgcolor::coordinator::run_job(&g, &cfg).unwrap();

    let s = Session::new(g);
    let job = Job::from_config(cfg).unwrap();
    let first = s.run(&job).unwrap(); // cache miss
    let log = EventLog::new();
    let second = s.run_observed(&job, &log).unwrap(); // cache hit, observed
    assert_eq!(s.partition_calls(), 1, "second run must hit the cache");
    assert!(!log.events().is_empty());

    bitwise_eq(&fresh, &first);
    bitwise_eq(&fresh, &second);
}

#[test]
fn builder_validation_errors_surface() {
    let s = Session::new(synth::grid2d(6, 6));
    assert!(Job::on(&s).procs(0).run().is_err());
    assert!(Job::on(&s).superstep(0).run().is_err());
    assert!(Job::on(&s).selection(Selection::RandomX(0)).run().is_err());
    assert!(Job::on(&s).sync_recolor(nd(0)).run().is_err());
    // early stop without recoloring is rejected before anything runs
    assert!(Job::on(&s).stop_when_improvement_below(0.05).run().is_err());
    assert!(Job::on(&s)
        .sync_recolor(nd(3))
        .stop_when_improvement_below(1.5)
        .run()
        .is_err());
    // nothing valid ran: no partitions were computed
    assert_eq!(s.partition_calls(), 0);
}

/// Early stop produces an exact prefix of the unstopped trace: iterations
/// are pure functions of (seed, iteration index), so stopping early never
/// changes the iterations that do run.
#[test]
fn early_stop_trace_is_prefix_of_full_trace() {
    let s = Session::new(synth::fem_like(2500, 12.0, 30, 0.004, 9, "fem"))
        .with_cost_model(CostModel::fixed());
    let full = Job::on(&s)
        .procs(6)
        .selection(Selection::RandomX(10))
        .sync_recolor(nd(8))
        .run()
        .unwrap();
    let stopped = Job::on(&s)
        .procs(6)
        .selection(Selection::RandomX(10))
        .sync_recolor(nd(8))
        .stop_when_improvement_below(0.03)
        .run()
        .unwrap();
    assert!(
        stopped.recolor_trace.len() <= full.recolor_trace.len(),
        "stopped {:?} vs full {:?}",
        stopped.recolor_trace,
        full.recolor_trace
    );
    assert_eq!(
        stopped.recolor_trace[..],
        full.recolor_trace[..stopped.recolor_trace.len()],
        "early-stopped trace must be a prefix"
    );
    // the run stopped for the right reason: the last executed iteration
    // improved by less than eps (unless all 8 iterations ran)
    if stopped.recolor_trace.len() < full.recolor_trace.len() {
        let n = stopped.recolor_trace.len();
        let prev = stopped.recolor_trace[n - 2] as f64;
        let last = stopped.recolor_trace[n - 1] as f64;
        assert!((prev - last) / prev.max(1.0) < 0.03);
        // and every earlier iteration improved by at least eps
        for w in stopped.recolor_trace[..n - 1].windows(2) {
            assert!(
                (w[0] as f64 - w[1] as f64) / (w[0] as f64).max(1.0) >= 0.03,
                "iteration before the stop improved too little: {:?}",
                stopped.recolor_trace
            );
        }
    }
}

/// The event stream is well ordered: phases in pipeline order, recoloring
/// iterations consecutive from 1 with `k`s exactly matching the trace,
/// `Done` last with the final color count.
#[test]
fn observer_event_stream_is_well_ordered() {
    let s = Session::new(synth::fem_like(2000, 11.0, 26, 0.004, 4, "fem"))
        .with_cost_model(CostModel::fixed());
    let log = EventLog::new();
    let r = Job::on(&s)
        .procs(4)
        .selection(Selection::RandomX(5))
        .sync_recolor(nd(3))
        .run_observed(&log)
        .unwrap();
    let events = log.take();

    // phases appear exactly once, in pipeline order
    let phase_indices: Vec<usize> = events
        .iter()
        .enumerate()
        .filter_map(|(i, e)| matches!(e, Event::PhaseStarted { .. }).then_some(i))
        .collect();
    let phases: Vec<Phase> = events
        .iter()
        .filter_map(|e| match e {
            Event::PhaseStarted { phase } => Some(*phase),
            _ => None,
        })
        .collect();
    assert_eq!(
        phases,
        vec![
            Phase::Partition,
            Phase::InitialColoring,
            Phase::Recoloring,
            Phase::Validation,
        ]
    );
    assert_eq!(phase_indices[0], 0, "stream opens with PhaseStarted(Partition)");
    assert!(matches!(events.last(), Some(Event::Done { .. })));
    match events.last() {
        Some(Event::Done { result }) => assert_eq!(*result, Ok(r.num_colors)),
        _ => unreachable!(),
    }

    // superstep/conflict events land between InitialColoring and Recoloring
    for (i, e) in events.iter().enumerate() {
        if matches!(e, Event::SuperstepDone { .. } | Event::ConflictRound { .. }) {
            assert!(i > phase_indices[1], "{e:?} before initial coloring");
            assert!(i < phase_indices[3], "{e:?} after validation started");
        }
    }
    // conflict rounds are strictly increasing and terminate with 0 losers
    let rounds: Vec<(u32, u64)> = events
        .iter()
        .filter_map(|e| match e {
            Event::ConflictRound { round, conflicts } => Some((*round, *conflicts)),
            _ => None,
        })
        .collect();
    assert!(!rounds.is_empty());
    assert!(rounds.windows(2).all(|w| w[0].0 < w[1].0));
    assert_eq!(rounds.last().unwrap().1, 0, "last round resolves everything");

    // recoloring iterations: consecutive from 1, ks == recolor_trace[1..]
    let iters: Vec<(u32, usize)> = events
        .iter()
        .filter_map(|e| match e {
            Event::RecolorIteration { iter, k } => Some((*iter, *k)),
            _ => None,
        })
        .collect();
    assert_eq!(
        iters.iter().map(|&(i, _)| i).collect::<Vec<_>>(),
        (1..=3).collect::<Vec<_>>()
    );
    assert_eq!(
        iters.iter().map(|&(_, k)| k).collect::<Vec<_>>(),
        r.recolor_trace[1..].to_vec(),
        "event ks must match the recolor trace"
    );
    for (i, _) in events.iter().enumerate().filter(|(_, e)| {
        matches!(e, Event::RecolorIteration { .. })
    }) {
        assert!(i > phase_indices[2] && i < phase_indices[3]);
    }
}

/// aRC runs also stream `RecolorIteration` events matching the trace, and
/// a run without recoloring has no Recoloring phase at all.
#[test]
fn observer_covers_arc_and_no_recolor_runs() {
    let s = Session::new(synth::grid2d(20, 20)).with_cost_model(CostModel::fixed());

    let log = EventLog::new();
    let r = Job::on(&s)
        .procs(4)
        .async_recolor(dgcolor::color::recolor::Permutation::NonDecreasing, 2)
        .run_observed(&log)
        .unwrap();
    let ks: Vec<usize> = log
        .take()
        .iter()
        .filter_map(|e| match e {
            Event::RecolorIteration { k, .. } => Some(*k),
            _ => None,
        })
        .collect();
    assert_eq!(ks, r.recolor_trace[1..].to_vec());

    let log = EventLog::new();
    Job::on(&s).procs(4).speed().run_observed(&log).unwrap();
    let events = log.take();
    assert!(events
        .iter()
        .all(|e| !matches!(e, Event::PhaseStarted { phase: Phase::Recoloring }
            | Event::RecolorIteration { .. })));
}

/// Observed and unobserved runs are identical — emission never touches
/// the virtual clocks.
#[test]
fn observation_does_not_perturb_results() {
    let s = Session::new(synth::erdos_renyi(900, 5400, 11)).with_cost_model(CostModel::fixed());
    let job = Job::on(&s).procs(5).quality().build().unwrap();
    let plain = s.run(&job).unwrap();
    let log = EventLog::new();
    let observed = s.run_observed(&job, &log).unwrap();
    bitwise_eq(&plain, &observed);
}
