//! PJRT runtime integration: the AOT-compiled kernels must load, execute,
//! and agree with the native implementations. Requires `make artifacts`
//! (tests skip with a notice when artifacts are absent).

use dgcolor::color::{greedy_color, Coloring, Ordering, Selection, UNCOLORED};
use dgcolor::graph::synth;
use dgcolor::runtime::{BatchColorer, KernelRuntime};

fn runtime() -> Option<KernelRuntime> {
    if !KernelRuntime::artifacts_present() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(KernelRuntime::load(&KernelRuntime::artifacts_dir()).expect("loading artifacts"))
}

#[test]
fn first_fit_kernel_semantics() {
    let Some(rt) = runtime() else { return };
    let mut matrix = vec![-1i32; 256 * 64];
    // row 0: forbid {0,1,3} → expect 2
    matrix[0] = 0;
    matrix[1] = 1;
    matrix[2] = 3;
    // row 1: forbid {} → 0 ; row 2: forbid 0..64 → 64
    for d in 0..64 {
        matrix[2 * 64 + d] = d as i32;
    }
    let out = rt.first_fit_batch(&matrix).unwrap();
    assert_eq!(out[0], 2);
    assert_eq!(out[1], 0);
    assert_eq!(out[2], 64);
    assert!(out[3..].iter().all(|&c| c == 0));
}

#[test]
fn random_x_kernel_in_window() {
    let Some(rt) = runtime() else { return };
    let mut matrix = vec![-1i32; 256 * 64];
    for row in 0..256 {
        matrix[row * 64] = 0; // forbid color 0 everywhere
        matrix[row * 64 + 1] = 2; // and color 2
    }
    let u: Vec<f32> = (0..256).map(|i| i as f32 / 256.0).collect();
    let out = rt.random_x_batch(&matrix, &u, 5).unwrap();
    // first 5 permissible: 1,3,4,5,6
    for &c in &out {
        assert!([1, 3, 4, 5, 6].contains(&c), "picked {c}");
    }
    assert_eq!(out[0], 1, "u=0 must take the first permissible");
}

#[test]
fn forbid_mask_kernel_bits() {
    let Some(rt) = runtime() else { return };
    let mut matrix = vec![-1i32; 256 * 64];
    matrix[0] = 0;
    matrix[1] = 33;
    matrix[2] = 255;
    let out = rt.forbid_mask_batch(&matrix).unwrap();
    assert_eq!(out[0] as u32, 1);
    assert_eq!(out[1] as u32, 1 << 1);
    assert_eq!(out[7] as u32, 1 << 31);
    assert!(out[8..16].iter().all(|&w| w == 0), "row 1 must be empty");
}

#[test]
fn conflict_kernel_agrees_with_flags() {
    let Some(rt) = runtime() else { return };
    let e = 4096;
    let mut cu = vec![-1i32; e];
    let mut cv = vec![-1i32; e];
    let mut pu = vec![0i32; e];
    let mut pv = vec![0i32; e];
    let gu: Vec<i32> = (0..e as i32).collect();
    let gv: Vec<i32> = (0..e as i32).map(|x| x + e as i32).collect();
    // edge 0: conflict, pu<pv → u loses; edge 1: conflict, pv<pu → v loses;
    // edge 2: no conflict; edge 3: tie → smaller gid (u) loses
    cu[0] = 5;
    cv[0] = 5;
    pu[0] = 1;
    pv[0] = 2;
    cu[1] = 7;
    cv[1] = 7;
    pu[1] = 9;
    pv[1] = 3;
    cu[2] = 1;
    cv[2] = 2;
    cu[3] = 4;
    cv[3] = 4;
    pu[3] = 6;
    pv[3] = 6;
    let (lu, lv) = rt.conflict_batch(&cu, &cv, &pu, &pv, &gu, &gv).unwrap();
    assert_eq!((lu[0], lv[0]), (1, 0));
    assert_eq!((lu[1], lv[1]), (0, 1));
    assert_eq!((lu[2], lv[2]), (0, 0));
    assert_eq!((lu[3], lv[3]), (1, 0));
}

#[test]
fn batch_colorer_valid_on_graphs() {
    let Some(rt) = runtime() else { return };
    let mut bc = BatchColorer::new(rt, 42);
    for g in [
        synth::grid2d(20, 20),
        synth::fem_like(1500, 11.0, 28, 0.004, 3, "fem"),
        synth::erdos_renyi(800, 4800, 4),
    ] {
        let order: Vec<u32> = (0..g.num_vertices() as u32).collect();
        let mut c = Coloring::uncolored(g.num_vertices());
        bc.color_sequence(&g, &order, None, &mut c).unwrap();
        c.validate(&g).unwrap_or_else(|e| panic!("{}: {e}", g.name));
        assert!(c.num_colors() <= g.max_degree() + 1);
    }
    assert!(bc.kernel_calls > 0, "kernel path must actually run");
}

#[test]
fn batch_colorer_random_x_valid() {
    let Some(rt) = runtime() else { return };
    let mut bc = BatchColorer::new(rt, 7);
    let g = synth::fem_like(1200, 10.0, 24, 0.004, 9, "fem");
    let order: Vec<u32> = (0..g.num_vertices() as u32).collect();
    let mut c = Coloring::uncolored(g.num_vertices());
    bc.color_sequence(&g, &order, Some(5), &mut c).unwrap();
    c.validate(&g).unwrap();
    assert!(c.num_colors() <= g.max_degree() + 5 + 1);
}

#[test]
fn batch_colorer_fallback_on_oversize_degree() {
    let Some(rt) = runtime() else { return };
    let mut bc = BatchColorer::new(rt, 1);
    let g = synth::star(200); // hub degree 199 > DMAX
    let order: Vec<u32> = (0..200).collect();
    let mut c = Coloring::uncolored(200);
    bc.color_sequence(&g, &order, None, &mut c).unwrap();
    c.validate(&g).unwrap();
    assert_eq!(c.num_colors(), 2);
    assert!(bc.fallbacks >= 1, "hub must fall back natively");
}

#[test]
fn kernel_first_fit_matches_native_exactly() {
    // kernel-batched speculative FF and native sequential FF both honor
    // "smallest permissible against finalized neighbors"; on a natural
    // order the end results must agree in color count and validity — and
    // on bipartite structured graphs, exactly.
    let Some(rt) = runtime() else { return };
    let mut bc = BatchColorer::new(rt, 3);
    let g = synth::grid2d(16, 16);
    let order: Vec<u32> = (0..g.num_vertices() as u32).collect();
    let mut kc = Coloring::uncolored(g.num_vertices());
    bc.color_sequence(&g, &order, None, &mut kc).unwrap();
    let nc = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 0);
    kc.validate(&g).unwrap();
    assert_eq!(kc.num_colors(), nc.num_colors());
}

#[test]
fn batch_colorer_respects_preset_colors() {
    let Some(rt) = runtime() else { return };
    let mut bc = BatchColorer::new(rt, 5);
    let g = synth::path(10);
    let mut c = Coloring::uncolored(10);
    c.set(5, 0);
    let order: Vec<u32> = (0..10).filter(|&v| v != 5).collect();
    bc.color_sequence(&g, &order, None, &mut c).unwrap();
    assert_eq!(c.get(5), 0);
    assert!(!c.colors.contains(&UNCOLORED));
    c.validate(&g).unwrap();
}
