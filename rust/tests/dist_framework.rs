//! Integration tests for the distributed superstep framework.

use dgcolor::color::{Ordering, Selection};
use dgcolor::coordinator::{run_job, ColoringConfig};
use dgcolor::dist::cost::CostModel;
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::graph::synth;
use dgcolor::partition::Partitioner;

fn cfg(procs: usize) -> ColoringConfig {
    ColoringConfig {
        num_procs: procs,
        fixed_cost: Some(CostModel::fixed()),
        ..Default::default()
    }
}

#[test]
fn valid_across_proc_counts_and_graphs() {
    let graphs = vec![
        synth::grid2d(24, 24),
        synth::erdos_renyi(1200, 7200, 5),
        rmat::generate(&RmatParams::good(10, 6), 6, "rmat-good"),
    ];
    for g in &graphs {
        for procs in [1, 2, 4, 8, 16] {
            let r = run_job(g, &cfg(procs)).unwrap();
            assert!(
                r.num_colors <= g.max_degree() + 1,
                "{} p={procs}: {} colors",
                g.name,
                r.num_colors
            );
        }
    }
}

#[test]
fn sync_mode_is_deterministic() {
    let g = synth::erdos_renyi(1000, 8000, 17);
    let a = run_job(&g, &cfg(8)).unwrap();
    let b = run_job(&g, &cfg(8)).unwrap();
    assert_eq!(a.coloring.colors, b.coloring.colors);
    assert_eq!(a.metrics.total_msgs, b.metrics.total_msgs);
    assert_eq!(a.metrics.makespan, b.metrics.makespan);
}

#[test]
fn conflicts_grow_with_procs_on_er() {
    // the framework's conflicts come from boundary edges colored in the
    // same superstep; more processors → more boundary → more conflicts
    let g = rmat::generate(&RmatParams::er(12, 8), 9, "rmat-er");
    let few = run_job(&g, &cfg(2)).unwrap();
    let many = run_job(&g, &cfg(32)).unwrap();
    assert!(
        many.metrics.total_conflicts >= few.metrics.total_conflicts,
        "p=2 {} vs p=32 {}",
        few.metrics.total_conflicts,
        many.metrics.total_conflicts
    );
}

#[test]
fn smaller_supersteps_fewer_conflicts_more_messages() {
    let g = rmat::generate(&RmatParams::er(11, 8), 10, "rmat-er");
    let mut small = cfg(8);
    small.superstep_size = 100;
    let mut large = cfg(8);
    large.superstep_size = 5000;
    let rs = run_job(&g, &small).unwrap();
    let rl = run_job(&g, &large).unwrap();
    assert!(
        rs.metrics.total_msgs > rl.metrics.total_msgs,
        "small {} vs large {}",
        rs.metrics.total_msgs,
        rl.metrics.total_msgs
    );
    assert!(
        rs.metrics.total_conflicts <= rl.metrics.total_conflicts,
        "small {} vs large {}",
        rs.metrics.total_conflicts,
        rl.metrics.total_conflicts
    );
}

#[test]
fn async_valid_and_converges() {
    let g = rmat::generate(&RmatParams::good(10, 8), 12, "rmat-good");
    let mut c = cfg(8);
    c.sync = false;
    c.superstep_size = 200;
    let r = run_job(&g, &c).unwrap();
    assert!(r.num_colors <= g.max_degree() + 1);
    assert!(r.metrics.rounds < 50, "rounds {}", r.metrics.rounds);
}

#[test]
fn orderings_work_distributed() {
    let g = synth::fem_like(2000, 12.0, 30, 0.0, 8, "fem");
    for ord in [
        Ordering::Natural,
        Ordering::InternalFirst,
        Ordering::BoundaryFirst,
        Ordering::LargestFirst,
        Ordering::SmallestLast,
    ] {
        let mut c = cfg(6);
        c.ordering = ord;
        let r = run_job(&g, &c).unwrap();
        assert!(r.num_colors <= g.max_degree() + 1, "{ord:?}");
    }
}

#[test]
fn selections_work_distributed() {
    let g = synth::erdos_renyi(1500, 9000, 21);
    for sel in [
        Selection::FirstFit,
        Selection::StaggeredFirstFit,
        Selection::LeastUsed,
        Selection::RandomX(5),
        Selection::RandomX(50),
    ] {
        let mut c = cfg(6);
        c.selection = sel;
        let r = run_job(&g, &c).unwrap();
        assert!(
            r.num_colors <= g.max_degree() + 50 + 1,
            "{sel:?}: {}",
            r.num_colors
        );
    }
}

#[test]
fn random_x_reduces_conflicts() {
    // §3.2: random selection decorrelates concurrent choices
    let g = rmat::generate(&RmatParams::er(12, 8), 30, "rmat-er");
    let mut ff = cfg(16);
    ff.superstep_size = 5000;
    let mut r5 = ff;
    r5.selection = Selection::RandomX(5);
    let cf = run_job(&g, &ff).unwrap();
    let cr = run_job(&g, &r5).unwrap();
    assert!(
        cr.metrics.total_conflicts < cf.metrics.total_conflicts,
        "R5 {} vs FF {}",
        cr.metrics.total_conflicts,
        cf.metrics.total_conflicts
    );
}

#[test]
fn block_vs_bfs_partition_boundary() {
    let g = synth::fem_like(4000, 12.0, 30, 0.0, 9, "fem");
    let mut blk = cfg(8);
    blk.partitioner = Partitioner::Block;
    let mut bfs = cfg(8);
    bfs.partitioner = Partitioner::BfsGrow;
    let rb = run_job(&g, &blk).unwrap();
    let rg = run_job(&g, &bfs).unwrap();
    // both valid; bfs-grow should not have wildly more cut than block
    assert!(rb.num_colors <= g.max_degree() + 1);
    assert!(rg.num_colors <= g.max_degree() + 1);
}

#[test]
fn virtual_time_grows_with_messages_not_wallclock() {
    let g = synth::erdos_renyi(800, 4000, 2);
    let mut a = cfg(2);
    a.network = dgcolor::dist::NetworkModel::ideal();
    let mut b = cfg(2);
    b.network = dgcolor::dist::NetworkModel::new(1e-3, 1e-9);
    let ra = run_job(&g, &a).unwrap();
    let rb = run_job(&g, &b).unwrap();
    assert!(rb.metrics.makespan > ra.metrics.makespan + 1e-4);
    assert_eq!(ra.coloring.colors, rb.coloring.colors, "net model must not change results");
}
