//! Integration tests for the distributed superstep framework, driven
//! through the session API.

use dgcolor::color::{Ordering, Selection};
use dgcolor::coordinator::{ColoringConfig, Job, RunResult, Session};
use dgcolor::dist::cost::CostModel;
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::graph::synth;
use dgcolor::graph::CsrGraph;
use dgcolor::partition::Partitioner;

fn session(g: CsrGraph) -> Session {
    Session::new(g).with_cost_model(CostModel::fixed())
}

fn run(s: &Session, cfg: ColoringConfig) -> RunResult {
    s.run(&Job::from_config(cfg).unwrap()).unwrap()
}

fn cfg(procs: usize) -> ColoringConfig {
    ColoringConfig {
        num_procs: procs,
        ..Default::default()
    }
}

#[test]
fn valid_across_proc_counts_and_graphs() {
    let graphs = vec![
        synth::grid2d(24, 24),
        synth::erdos_renyi(1200, 7200, 5),
        rmat::generate(&RmatParams::good(10, 6), 6, "rmat-good"),
    ];
    for g in graphs {
        let s = session(g);
        for procs in [1, 2, 4, 8, 16] {
            let r = run(&s, cfg(procs));
            assert!(
                r.num_colors <= s.graph().max_degree() + 1,
                "{} p={procs}: {} colors",
                s.graph().name,
                r.num_colors
            );
        }
    }
}

#[test]
fn sync_mode_is_deterministic() {
    let s = session(synth::erdos_renyi(1000, 8000, 17));
    let a = run(&s, cfg(8));
    let b = run(&s, cfg(8)); // second run hits the partition cache
    assert_eq!(a.coloring.colors, b.coloring.colors);
    assert_eq!(a.metrics.total_msgs, b.metrics.total_msgs);
    assert_eq!(a.metrics.makespan, b.metrics.makespan);
    assert_eq!(s.partition_calls(), 1);
}

#[test]
fn conflicts_grow_with_procs_on_er() {
    // the framework's conflicts come from boundary edges colored in the
    // same superstep; more processors → more boundary → more conflicts
    let s = session(rmat::generate(&RmatParams::er(12, 8), 9, "rmat-er"));
    let few = run(&s, cfg(2));
    let many = run(&s, cfg(32));
    assert!(
        many.metrics.total_conflicts >= few.metrics.total_conflicts,
        "p=2 {} vs p=32 {}",
        few.metrics.total_conflicts,
        many.metrics.total_conflicts
    );
}

#[test]
fn smaller_supersteps_fewer_conflicts_more_messages() {
    let s = session(rmat::generate(&RmatParams::er(11, 8), 10, "rmat-er"));
    let rs = s
        .run(&Job::on(&s).procs(8).superstep(100).build().unwrap())
        .unwrap();
    let rl = s
        .run(&Job::on(&s).procs(8).superstep(5000).build().unwrap())
        .unwrap();
    assert!(
        rs.metrics.total_msgs > rl.metrics.total_msgs,
        "small {} vs large {}",
        rs.metrics.total_msgs,
        rl.metrics.total_msgs
    );
    assert!(
        rs.metrics.total_conflicts <= rl.metrics.total_conflicts,
        "small {} vs large {}",
        rs.metrics.total_conflicts,
        rl.metrics.total_conflicts
    );
}

#[test]
fn async_valid_and_converges() {
    let s = session(rmat::generate(&RmatParams::good(10, 8), 12, "rmat-good"));
    let r = Job::on(&s)
        .procs(8)
        .async_comm()
        .superstep(200)
        .run()
        .unwrap();
    assert!(r.num_colors <= s.graph().max_degree() + 1);
    assert!(r.metrics.rounds < 50, "rounds {}", r.metrics.rounds);
}

#[test]
fn orderings_work_distributed() {
    let s = session(synth::fem_like(2000, 12.0, 30, 0.0, 8, "fem"));
    for ord in [
        Ordering::Natural,
        Ordering::InternalFirst,
        Ordering::BoundaryFirst,
        Ordering::LargestFirst,
        Ordering::SmallestLast,
    ] {
        let r = Job::on(&s).procs(6).ordering(ord).run().unwrap();
        assert!(r.num_colors <= s.graph().max_degree() + 1, "{ord:?}");
    }
    // five orderings, one partition key
    assert_eq!(s.partition_calls(), 1);
}

#[test]
fn selections_work_distributed() {
    let s = session(synth::erdos_renyi(1500, 9000, 21));
    for sel in [
        Selection::FirstFit,
        Selection::StaggeredFirstFit,
        Selection::LeastUsed,
        Selection::RandomX(5),
        Selection::RandomX(50),
    ] {
        let r = Job::on(&s).procs(6).selection(sel).run().unwrap();
        assert!(
            r.num_colors <= s.graph().max_degree() + 50 + 1,
            "{sel:?}: {}",
            r.num_colors
        );
    }
}

#[test]
fn random_x_reduces_conflicts() {
    // §3.2: random selection decorrelates concurrent choices
    let s = session(rmat::generate(&RmatParams::er(12, 8), 30, "rmat-er"));
    let cf = Job::on(&s).procs(16).superstep(5000).run().unwrap();
    let cr = Job::on(&s)
        .procs(16)
        .superstep(5000)
        .selection(Selection::RandomX(5))
        .run()
        .unwrap();
    assert!(
        cr.metrics.total_conflicts < cf.metrics.total_conflicts,
        "R5 {} vs FF {}",
        cr.metrics.total_conflicts,
        cf.metrics.total_conflicts
    );
}

#[test]
fn block_vs_bfs_partition_boundary() {
    let s = session(synth::fem_like(4000, 12.0, 30, 0.0, 9, "fem"));
    let rb = Job::on(&s)
        .procs(8)
        .partitioner(Partitioner::Block)
        .run()
        .unwrap();
    let rg = Job::on(&s)
        .procs(8)
        .partitioner(Partitioner::BfsGrow)
        .run()
        .unwrap();
    // both valid; bfs-grow should not have wildly more cut than block
    assert!(rb.num_colors <= s.graph().max_degree() + 1);
    assert!(rg.num_colors <= s.graph().max_degree() + 1);
    // two partitioners → two cache keys
    assert_eq!(s.partition_calls(), 2);
}

#[test]
fn virtual_time_grows_with_messages_not_wallclock() {
    let s = session(synth::erdos_renyi(800, 4000, 2));
    let ra = Job::on(&s)
        .procs(2)
        .network(dgcolor::dist::NetworkModel::ideal())
        .run()
        .unwrap();
    let rb = Job::on(&s)
        .procs(2)
        .network(dgcolor::dist::NetworkModel::new(1e-3, 1e-9))
        .run()
        .unwrap();
    assert!(rb.metrics.makespan > ra.metrics.makespan + 1e-4);
    assert_eq!(ra.coloring.colors, rb.coloring.colors, "net model must not change results");
}
