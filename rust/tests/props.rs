//! Property-based tests over randomized graphs, partitions and configs,
//! driven by the in-house prop harness (`util::prop`).

use dgcolor::color::recolor::{recolor_once, Permutation};
use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::{ColoringConfig, Job, RecolorMode, Session};
use dgcolor::dist::cost::CostModel;
use dgcolor::dist::framework::loses;
use dgcolor::dist::proc::build_local_graphs;
use dgcolor::graph::{synth, CsrGraph, GraphBuilder};
use dgcolor::partition::{self, Partition, Partitioner};
use dgcolor::util::prop::{check, PropConfig};
use dgcolor::util::Rng;

fn random_graph(rng: &mut Rng) -> CsrGraph {
    let n = rng.range(2, 400);
    let m = rng.range(1, 4 * n);
    let mut b = GraphBuilder::new(n);
    for _ in 0..m {
        let u = rng.range(0, n) as u32;
        let v = rng.range(0, n) as u32;
        b.add_edge(u, v);
    }
    b.build(format!("prop-{n}-{m}"))
}

#[test]
fn prop_greedy_always_valid_and_bounded() {
    check(
        "greedy valid",
        PropConfig { cases: 60, seed: 101 },
        |rng, _| {
            let g = random_graph(rng);
            let ord = *rng.choose(&[
                Ordering::Natural,
                Ordering::LargestFirst,
                Ordering::SmallestLast,
                Ordering::IncidenceDegree,
                Ordering::Random,
            ]);
            let x = rng.range(1, 20) as u32;
            let sel = *rng.choose(&[
                Selection::FirstFit,
                Selection::StaggeredFirstFit,
                Selection::LeastUsed,
                Selection::RandomX(x),
            ]);
            let c = greedy_color(&g, ord, sel, rng.next_u64());
            if let Err(e) = c.validate(&g) {
                return Err(format!("{ord:?} {sel:?} invalid: {e}"));
            }
            let bound = g.max_degree() + x as usize + 1;
            if c.num_colors() > bound {
                return Err(format!("{} colors > bound {bound}", c.num_colors()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_recolor_never_increases_colors() {
    check(
        "recolor monotone",
        PropConfig { cases: 40, seed: 202 },
        |rng, _| {
            let g = random_graph(rng);
            let mut c = greedy_color(&g, Ordering::Natural, Selection::RandomX(8), rng.next_u64());
            for _ in 0..3 {
                let perm = *rng.choose(&[
                    Permutation::Reverse,
                    Permutation::NonIncreasing,
                    Permutation::NonDecreasing,
                    Permutation::Random,
                ]);
                let next = recolor_once(&g, &c, perm, rng);
                next.validate(&g).map_err(|e| e.to_string())?;
                if next.num_colors() > c.num_colors() {
                    return Err(format!(
                        "{perm:?} increased {} -> {}",
                        c.num_colors(),
                        next.num_colors()
                    ));
                }
                c = next;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_distributed_always_valid() {
    check(
        "distributed valid",
        PropConfig { cases: 25, seed: 303 },
        |rng, _| {
            let g = random_graph(rng);
            let procs = rng.range(1, 9);
            let cfg = ColoringConfig {
                num_procs: procs,
                superstep_size: rng.range(1, 300),
                sync: rng.chance(0.5),
                partitioner: if rng.chance(0.5) {
                    Partitioner::Block
                } else {
                    Partitioner::BfsGrow
                },
                recolor: if rng.chance(0.5) {
                    RecolorMode::Sync(Default::default())
                } else {
                    RecolorMode::None
                },
                seed: rng.next_u64(),
                fixed_cost: Some(CostModel::fixed()),
                ..Default::default()
            };
            let job = Job::from_config(cfg).map_err(|e| e.to_string())?;
            Session::new(g).run(&job).map_err(|e| e.to_string())?;
            Ok(())
        },
    );
}

#[test]
fn prop_conflict_tiebreak_antisymmetric_and_total() {
    check(
        "loses() total order",
        PropConfig { cases: 200, seed: 404 },
        |rng, _| {
            let a = rng.next_u32();
            let b = rng.next_u32();
            let seed = rng.next_u64();
            if a == b {
                return Ok(());
            }
            let ab = loses(a, b, seed);
            let ba = loses(b, a, seed);
            if ab == ba {
                return Err(format!("not antisymmetric for ({a},{b})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_local_views_partition_edges() {
    check(
        "local views conserve edges",
        PropConfig { cases: 30, seed: 505 },
        |rng, _| {
            let g = random_graph(rng);
            let procs = rng.range(1, 7);
            let part = partition::partition(
                &g,
                if rng.chance(0.5) {
                    Partitioner::Block
                } else {
                    Partitioner::BfsGrow
                },
                procs,
                rng.next_u64(),
            );
            let (_, locals) = build_local_graphs(&g, &part);
            let owned_total: usize = locals.iter().map(|l| l.n_owned()).sum();
            if owned_total != g.num_vertices() {
                return Err(format!("owned {owned_total} != |V| {}", g.num_vertices()));
            }
            let deg_total: u64 = locals.iter().map(|l| l.csr.xadj[l.n_owned()]).sum();
            if deg_total != 2 * g.num_edges() as u64 {
                return Err(format!("degree sum {deg_total} != 2|E|"));
            }
            // boundary flags must match the partition
            for l in &locals {
                for (i, &gid) in l.global_ids.iter().enumerate() {
                    let really = g
                        .neighbors(gid)
                        .iter()
                        .any(|&u| part.part_of(u) != l.rank);
                    if really != l.is_boundary[i] {
                        return Err(format!("boundary flag wrong at {gid}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partitions_cover_and_balance() {
    check(
        "partitions well formed",
        PropConfig { cases: 40, seed: 606 },
        |rng, _| {
            let g = random_graph(rng);
            let k = rng.range(1, 12);
            let p: Partition =
                partition::partition(&g, Partitioner::BfsGrow, k, rng.next_u64());
            if p.parts.len() != g.num_vertices() {
                return Err("wrong length".into());
            }
            if p.parts.iter().any(|&x| x as usize >= k) {
                return Err("part out of range".into());
            }
            let sizes = p.sizes();
            let max = *sizes.iter().max().unwrap();
            // cap from bfs_grow is avg*1.03 (+1 rounding, +reseeding slack)
            let avg = g.num_vertices() as f64 / k as f64;
            if (max as f64) > avg * 1.35 + 2.0 {
                return Err(format!("imbalanced: max {max} avg {avg}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mtx_roundtrip() {
    check(
        "mtx roundtrip",
        PropConfig { cases: 15, seed: 707 },
        |rng, case| {
            let g = random_graph(rng);
            let dir = std::env::temp_dir().join("dgcolor_prop_mtx");
            std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
            let p = dir.join(format!("g{case}.mtx"));
            dgcolor::graph::mtx::write_mtx(&g, &p).map_err(|e| e.to_string())?;
            let g2 = dgcolor::graph::mtx::read_mtx(&p).map_err(|e| e.to_string())?;
            if g.xadj != g2.xadj || g.adjncy != g2.adjncy {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fem_generator_respects_structure() {
    check(
        "fem generator",
        PropConfig { cases: 10, seed: 808 },
        |rng, _| {
            let n = rng.range(100, 2000);
            let avg = 4.0 + rng.f64() * 12.0;
            let g = synth::fem_like(n, avg, 40, 0.01, rng.next_u64(), "f");
            g.validate().map_err(|e| e)?;
            let got = 2.0 * g.num_edges() as f64 / g.num_vertices() as f64;
            if (got - avg).abs() / avg > 0.4 {
                return Err(format!("avg degree {got} vs target {avg}"));
            }
            Ok(())
        },
    );
}
