//! Integration tests for distributed recoloring (RC and aRC) — including
//! the paper's central equivalence: distributed synchronous recoloring
//! produces exactly the sequential iterated-greedy result.

use dgcolor::color::recolor::{recolor_once, Permutation, RecolorSchedule};
use dgcolor::color::{greedy_color, Coloring, Ordering, Selection};
use dgcolor::coordinator::{ColoringConfig, Job, RecolorMode, Session};
use dgcolor::dist::comm::network;
use dgcolor::dist::cost::CostModel;
use dgcolor::dist::proc::{build_local_graphs, ColorState};
use dgcolor::dist::recolor::{recolor_process_sync, CommScheme, RecolorConfig};
use dgcolor::dist::NetworkModel;
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::graph::synth;
use dgcolor::graph::CsrGraph;
use dgcolor::partition::{self, Partitioner};
use dgcolor::util::Rng;

/// Run distributed sync recoloring directly over a given initial coloring
/// and return the merged global result.
fn dist_recolor(
    g: &CsrGraph,
    initial: &Coloring,
    procs: usize,
    perm: Permutation,
    scheme: CommScheme,
    seed: u64,
) -> (Coloring, Vec<usize>, dgcolor::dist::DistMetrics) {
    let part = partition::partition(g, Partitioner::Block, procs, 1);
    let (_, locals) = build_local_graphs(g, &part);
    let cost = CostModel::fixed();
    let eps = network(procs, NetworkModel::default());
    let cfg = RecolorConfig {
        schedule: RecolorSchedule::Fixed(perm),
        iterations: 1,
        scheme,
        seed,
        ..Default::default()
    };
    let mut outs: Vec<Option<(Vec<(u32, u32)>, Vec<usize>, dgcolor::dist::ProcMetrics)>> =
        (0..procs).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (ep, lg) in eps.into_iter().zip(locals.iter()) {
            let cfgr = cfg;
            handles.push(s.spawn(move || {
                let mut ep = ep;
                let mut state = ColorState::from_global(lg, initial);
                let mut trace = Vec::new();
                let m = recolor_process_sync(
                    &mut ep, lg, &cost, &cfgr, &mut state, &mut trace, None,
                );
                (state.owned_pairs(lg), trace, m)
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            outs[i] = Some(h.join().unwrap());
        }
    });
    let mut coloring = Coloring::uncolored(g.num_vertices());
    let mut per_proc = Vec::new();
    let mut trace = Vec::new();
    for (pairs, t, m) in outs.into_iter().map(|o| o.unwrap()) {
        for (gid, c) in pairs {
            coloring.set(gid, c);
        }
        // every process derives its trace from allreduced counts — the
        // invariant the pipeline's take-instead-of-clone relies on
        if !trace.is_empty() {
            assert_eq!(trace, t, "per-process recolor traces diverged");
        }
        trace = t;
        per_proc.push(m);
    }
    let metrics = dgcolor::dist::DistMetrics::aggregate(&per_proc, 0.0);
    (coloring, trace, metrics)
}

/// THE equivalence theorem (paper §3): distributed sync recoloring with a
/// given class permutation equals sequential iterated greedy with the same
/// permutation — for any number of processors and both comm schemes.
#[test]
fn distributed_rc_equals_sequential_ig() {
    let graphs = vec![
        synth::grid2d(16, 16),
        synth::fem_like(1500, 11.0, 28, 0.004, 2, "fem"),
        rmat::generate(&RmatParams::good(9, 6), 3, "rmat-good"),
    ];
    for g in &graphs {
        let initial = greedy_color(g, Ordering::Natural, Selection::FirstFit, 9);
        for perm in [Permutation::NonDecreasing, Permutation::NonIncreasing, Permutation::Reverse]
        {
            // sequential reference
            let mut rng = Rng::new(0); // unused by deterministic perms
            let seq = recolor_once(g, &initial, perm, &mut rng);
            for procs in [1, 3, 8] {
                for scheme in [CommScheme::Base, CommScheme::Piggyback] {
                    let (dist, trace, _) =
                        dist_recolor(g, &initial, procs, perm, scheme, 77);
                    dist.validate(g).unwrap();
                    assert_eq!(
                        dist.colors, seq.colors,
                        "{} {perm:?} p={procs} {scheme:?} differs from sequential",
                        g.name
                    );
                    assert_eq!(trace, vec![seq.num_colors()]);
                }
            }
        }
    }
}

#[test]
fn rc_random_perm_identical_across_procs_given_seed() {
    // RAND permutations must be generated identically on every process
    let g = synth::fem_like(1200, 10.0, 24, 0.0, 5, "fem");
    let initial = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 4);
    let (a, _, _) = dist_recolor(&g, &initial, 4, Permutation::Random, CommScheme::Piggyback, 5);
    let (b, _, _) = dist_recolor(&g, &initial, 7, Permutation::Random, CommScheme::Piggyback, 5);
    a.validate(&g).unwrap();
    // same seed → same permutation → same result regardless of proc count
    assert_eq!(a.colors, b.colors);
}

#[test]
fn rc_is_conflict_free() {
    let g = rmat::generate(&RmatParams::bad(10, 6), 8, "rmat-bad");
    let initial = greedy_color(&g, Ordering::Natural, Selection::RandomX(10), 2);
    let (out, _, m) = dist_recolor(
        &g,
        &initial,
        8,
        Permutation::NonDecreasing,
        CommScheme::Piggyback,
        3,
    );
    out.validate(&g).unwrap();
    assert_eq!(m.total_conflicts, 0, "sync RC can never conflict");
}

#[test]
fn multiple_iterations_monotone_and_improving() {
    let g = synth::fem_like(3000, 13.0, 32, 0.004, 6, "fem");
    let cfg = ColoringConfig {
        num_procs: 8,
        selection: Selection::RandomX(10),
        fixed_cost: Some(CostModel::fixed()),
        recolor: RecolorMode::Sync(RecolorConfig {
            schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
            iterations: 10,
            scheme: CommScheme::Piggyback,
            seed: 42,
            ..Default::default()
        }),
        ..Default::default()
    };
    let r = Session::new(g).run(&Job::from_config(cfg).unwrap()).unwrap();
    assert_eq!(r.recolor_trace.len(), 11);
    assert!(
        r.recolor_trace.windows(2).all(|w| w[1] <= w[0]),
        "{:?}",
        r.recolor_trace
    );
    assert!(r.num_colors < r.initial_colors);
}

#[test]
fn arc_valid_and_usually_helps() {
    let s = Session::new(rmat::generate(&RmatParams::good(10, 8), 14, "rmat-good"))
        .with_cost_model(CostModel::fixed());
    let no_rc = Job::on(&s)
        .procs(8)
        .ordering(Ordering::SmallestLast)
        .run()
        .unwrap();
    let arc = Job::on(&s)
        .procs(8)
        .ordering(Ordering::SmallestLast)
        .async_recolor(Permutation::NonDecreasing, 1)
        .run()
        .unwrap();
    // paper §4.2.3: aRC's improvement over FSS is modest (<10% on RMAT) and
    // can dip slightly below FSS on small instances — require "ballpark"
    assert!(
        (arc.num_colors as f64) <= 1.2 * no_rc.num_colors as f64 + 1.0,
        "aRC {} vs FSS {}",
        arc.num_colors,
        no_rc.num_colors
    );
}

#[test]
fn rc_beats_arc_on_quality() {
    // paper §4.2.3: sync RC yields fewer (or equal) colors than aRC
    let s = Session::new(rmat::generate(&RmatParams::bad(10, 6), 15, "rmat-bad"))
        .with_cost_model(CostModel::fixed());
    let mk = |mode: RecolorMode| {
        let cfg = ColoringConfig {
            num_procs: 8,
            recolor: mode,
            ..Default::default()
        };
        s.run(&Job::from_config(cfg).unwrap()).unwrap().num_colors
    };
    let rc = mk(RecolorMode::Sync(RecolorConfig::default()));
    let arc = mk(RecolorMode::Async {
        perm: Permutation::NonDecreasing,
        iterations: 1,
    });
    assert!(rc <= arc + 1, "RC {rc} vs aRC {arc}");
}
