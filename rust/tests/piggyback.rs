//! Tests for the piggybacked communication scheme (paper §3.1 / Fig 4):
//! identical results, far fewer messages, no empty messages, bounded
//! preparation overhead, and lower virtual runtime.

use dgcolor::color::recolor::{Permutation, RecolorSchedule};
use dgcolor::color::{greedy_color, Coloring, Ordering, Selection};
use dgcolor::dist::comm::network;
use dgcolor::dist::cost::CostModel;
use dgcolor::dist::proc::{build_local_graphs, ColorState};
use dgcolor::dist::recolor::{recolor_process_sync, CommScheme, RecolorConfig};
use dgcolor::dist::{DistMetrics, NetworkModel, ProcMetrics};
use dgcolor::graph::synth;
use dgcolor::graph::CsrGraph;
use dgcolor::partition::{self, Partitioner};

fn run_scheme(
    g: &CsrGraph,
    initial: &Coloring,
    procs: usize,
    scheme: CommScheme,
    iterations: u32,
) -> (Coloring, DistMetrics) {
    // ParMETIS-analogue partitioning, as the paper uses for real graphs
    let part = partition::partition(g, Partitioner::BfsGrow, procs, 1);
    let (_, locals) = build_local_graphs(g, &part);
    let cost = CostModel::fixed();
    let eps = network(procs, NetworkModel::default());
    let cfg = RecolorConfig {
        schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
        iterations,
        scheme,
        seed: 11,
        ..Default::default()
    };
    let mut outs: Vec<Option<(Vec<(u32, u32)>, ProcMetrics)>> = (0..procs).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (ep, lg) in eps.into_iter().zip(locals.iter()) {
            handles.push(s.spawn(move || {
                let mut ep = ep;
                let mut state = ColorState::from_global(lg, initial);
                let mut trace = Vec::new();
                let m = recolor_process_sync(
                    &mut ep, lg, &cost, &cfg, &mut state, &mut trace, None,
                );
                (state.owned_pairs(lg), m)
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            outs[i] = Some(h.join().unwrap());
        }
    });
    let mut coloring = Coloring::uncolored(g.num_vertices());
    let mut per_proc = Vec::new();
    for (pairs, m) in outs.into_iter().map(|o| o.unwrap()) {
        for (gid, c) in pairs {
            coloring.set(gid, c);
        }
        per_proc.push(m);
    }
    (coloring, DistMetrics::aggregate(&per_proc, 0.0))
}

/// The Fig-4 setting: enough processes that per-pair boundaries are small —
/// the regime in which the paper runs recoloring (64 procs, 8/node).
fn workload() -> (CsrGraph, Coloring) {
    let g = synth::fem_like(20_000, 25.0, 76, 0.004, 21, "fem");
    // Fig 4 seeds recoloring from an FSS-style coloring (first fit + SL):
    // steeply decaying class sizes → many near-empty color steps, the
    // regime piggybacking exploits.
    let init = greedy_color(&g, Ordering::SmallestLast, Selection::FirstFit, 5);
    (g, init)
}
/// Fig 4's regime: enough processes that per-pair boundaries are small
/// relative to the number of color classes. (The paper runs 8 procs/node on
/// 64 nodes; the win grows with P — the fig4 bench sweeps this.)
const PROCS: usize = 64;

#[test]
fn piggyback_same_result_far_fewer_messages() {
    let (g, init) = workload();
    let (cb, mb) = run_scheme(&g, &init, PROCS, CommScheme::Base, 1);
    let (cp, mp) = run_scheme(&g, &init, PROCS, CommScheme::Piggyback, 1);
    assert_eq!(cb.colors, cp.colors, "schemes must agree exactly");
    // paper: ~80% fewer messages at 512 procs; at this test's scale (64
    // procs, k≈15 vs the paper's k≈40) require at least 25% — the fig4
    // bench sweeps P and reports the full reduction curve
    assert!(
        (mp.total_msgs as f64) < 0.75 * mb.total_msgs as f64,
        "piggyback {} vs base {} messages",
        mp.total_msgs,
        mb.total_msgs
    );
}

#[test]
fn piggyback_faster_in_virtual_time() {
    let (g, init) = workload();
    let (_, mb) = run_scheme(&g, &init, PROCS, CommScheme::Base, 1);
    let (_, mp) = run_scheme(&g, &init, PROCS, CommScheme::Piggyback, 1);
    assert!(
        mp.makespan < mb.makespan,
        "piggyback {} vs base {} seconds",
        mp.makespan,
        mb.makespan
    );
}

#[test]
fn preparation_overhead_is_bounded() {
    // paper Fig 4: preparation ≤ ~12% of the improved total time
    let (g, init) = workload();
    let (_, mp) = run_scheme(&g, &init, PROCS, CommScheme::Piggyback, 1);
    let plan = mp.phase_max.get("plan");
    let total = mp.makespan;
    assert!(plan > 0.0, "plan phase must be accounted");
    assert!(
        plan / total < 0.25,
        "plan {plan} vs total {total} (ratio {})",
        plan / total
    );
}

#[test]
fn base_sends_empty_messages_piggyback_does_not() {
    // base message count per pair per direction = k (number of classes);
    // piggyback sends only deadline + flush + plan messages
    let (g, init) = workload();
    let k = init.num_colors() as u64;
    let procs = 4;
    let (_, mb) = run_scheme(&g, &init, procs, CommScheme::Base, 1);
    // count ordered neighbor pairs from the partition
    let part = partition::partition(&g, Partitioner::Block, procs, 1);
    let (_, locals) = build_local_graphs(&g, &part);
    let pairs: u64 = locals.iter().map(|l| l.neighbor_procs.len() as u64).sum();
    // base recoloring traffic = k msgs per ordered pair (+ a few collectives)
    assert!(
        mb.total_msgs >= pairs * k,
        "expected ≥ {} base msgs, got {}",
        pairs * k,
        mb.total_msgs
    );
}

#[test]
fn schemes_agree_over_multiple_iterations() {
    let (g, init) = workload();
    let (cb, _) = run_scheme(&g, &init, 6, CommScheme::Base, 3);
    let (cp, _) = run_scheme(&g, &init, 6, CommScheme::Piggyback, 3);
    cb.validate(&g).unwrap();
    assert_eq!(cb.colors, cp.colors);
}

#[test]
fn piggyback_message_reduction_grows_with_colors() {
    // more color classes → more empty messages in base → bigger win
    let g = synth::fem_like(3000, 16.0, 60, 0.01, 31, "fem");
    let few_colors = greedy_color(&g, Ordering::SmallestLast, Selection::FirstFit, 1);
    let many_colors = greedy_color(&g, Ordering::Natural, Selection::RandomX(50), 1);
    let ratio = |init: &Coloring| {
        let (_, mb) = run_scheme(&g, init, 6, CommScheme::Base, 1);
        let (_, mp) = run_scheme(&g, init, 6, CommScheme::Piggyback, 1);
        mp.total_msgs as f64 / mb.total_msgs as f64
    };
    let r_few = ratio(&few_colors);
    let r_many = ratio(&many_colors);
    assert!(
        r_many < r_few,
        "reduction should grow with classes: few {r_few:.3} many {r_many:.3}"
    );
}
