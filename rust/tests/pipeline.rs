//! End-to-end pipeline tests: the paper's "speed" and "quality" presets,
//! scaling behaviour, and metric sanity — all through the session API.

use dgcolor::coordinator::{Job, Session};
use dgcolor::dist::cost::CostModel;
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::graph::synth;
use dgcolor::graph::CsrGraph;

fn session(g: CsrGraph) -> Session {
    Session::new(g).with_cost_model(CostModel::fixed())
}

#[test]
fn speed_and_quality_presets_run() {
    // bmw3_2-like density: enough color headroom for recoloring to matter
    let s = session(synth::fem_like(6000, 30.0, 90, 0.01, 77, "fem"));
    let speed = Job::on(&s).procs(8).speed().run().unwrap();
    let quality = Job::on(&s).procs(8).quality().run().unwrap();
    // the quality preset must produce fewer colors …
    assert!(
        quality.num_colors < speed.num_colors,
        "quality {} vs speed {}",
        quality.num_colors,
        speed.num_colors
    );
    // … and its recoloring iteration must have improved its own initial
    assert!(quality.num_colors < quality.initial_colors);
    // … at a higher (but sane) runtime
    assert!(quality.metrics.makespan > speed.metrics.makespan);
    assert!(quality.metrics.makespan < 100.0 * speed.metrics.makespan);
    // both presets share (partitioner, procs, seed): one partition call
    assert_eq!(s.partition_calls(), 1);
}

#[test]
fn recoloring_quality_stable_as_procs_grow() {
    // paper's headline: RC keeps colors near-sequential as P grows, while
    // the plain framework drifts upward on conflict-heavy graphs
    let s = session(rmat::generate(&RmatParams::good(11, 8), 3, "rmat-good"));
    let colors_at = |p: usize| Job::on(&s).procs(p).quality().run().unwrap().num_colors;
    let c4 = colors_at(4);
    let c32 = colors_at(32);
    assert!(
        c32 as f64 <= c4 as f64 * 1.3 + 2.0,
        "quality drifted: p=4 → {c4}, p=32 → {c32}"
    );
}

#[test]
fn makespan_improves_with_procs_on_large_graph() {
    // virtual time must show parallel speedup from 1 to 8 procs on a
    // compute-heavy workload
    let s = session(rmat::generate(&RmatParams::er(14, 8), 4, "rmat-er"));
    let t1 = Job::on(&s).procs(1).speed().run().unwrap().metrics.makespan;
    let t8 = Job::on(&s).procs(8).speed().run().unwrap().metrics.makespan;
    assert!(
        t8 < t1,
        "no virtual speedup: t1={t1} t8={t8}"
    );
}

#[test]
fn metrics_are_consistent() {
    let s = session(synth::grid2d(30, 30));
    let r = Job::on(&s).procs(6).quality().run().unwrap();
    let m = &r.metrics;
    assert_eq!(m.num_procs, 6);
    assert!(m.total_bytes > 0);
    assert!(m.total_msgs > 0);
    assert!(m.makespan > 0.0);
    assert!(m.wall_secs > 0.0);
    assert!(m.phase_sums.get("color") > 0.0);
    assert!(m.phase_sums.get("recolor") > 0.0);
    assert!(m.phase_sums.get("plan") > 0.0, "piggyback plan phase missing");
    // partition metrics present
    assert!(r.partition_metrics.imbalance >= 1.0);
}

#[test]
fn trace_records_initial_plus_iterations() {
    let s = session(synth::grid2d(20, 20));
    let r = Job::on(&s).procs(4).quality().run().unwrap();
    assert_eq!(r.recolor_trace.len(), 2); // initial + 1 ND iteration
    assert_eq!(r.initial_colors, r.recolor_trace[0]);
    assert_eq!(r.num_colors, *r.recolor_trace.last().unwrap());
}
