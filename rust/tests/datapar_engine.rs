//! Property tests for the DataPar engine (`util::prop` harness): the
//! shared-memory speculative coloring must stay valid across random
//! graphs and configurations, bit-for-bit identical across pool sizes
//! {1, 2, 8}, and within the greedy Δ+1 bound of the sequential
//! first-fit baseline — plus the Session/Job end-to-end shapes for
//! `--engine datapar`.

use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::{Event, EventLog, Job, Phase, Session};
use dgcolor::dist::Engine;
use dgcolor::graph::{CsrGraph, GraphBuilder};
use dgcolor::shm::{self, DataParConfig};
use dgcolor::util::pool::WorkerPool;
use dgcolor::util::prop::{check, PropConfig};
use dgcolor::util::Rng;

fn random_graph(rng: &mut Rng) -> CsrGraph {
    let n = rng.range(2, 600);
    let m = rng.range(1, 5 * n);
    let mut b = GraphBuilder::new(n);
    for _ in 0..m {
        b.add_edge(rng.range(0, n) as u32, rng.range(0, n) as u32);
    }
    b.build(format!("dp-prop-{n}-{m}"))
}

fn random_config(rng: &mut Rng) -> DataParConfig {
    DataParConfig {
        ordering: *rng.choose(&[
            Ordering::Natural,
            Ordering::LargestFirst,
            Ordering::SmallestLast,
            Ordering::Random,
        ]),
        selection: *rng.choose(&[
            Selection::FirstFit,
            Selection::StaggeredFirstFit,
            Selection::LeastUsed,
            Selection::RandomX(rng.range(1, 20) as u32),
        ]),
        seed: rng.next_u64(),
        // down to chunk_size 1, where *every* edge crosses chunks — the
        // maximally speculative grid
        chunk_size: rng.range(1, 256),
        max_rounds: 0,
    }
}

#[test]
fn prop_datapar_valid_and_worker_count_invariant() {
    check(
        "datapar valid + identical across pools {1,2,8}",
        PropConfig { cases: 40, seed: 0xDA7A },
        |rng, _| {
            let g = random_graph(rng);
            let cfg = random_config(rng);
            let (c1, m1) =
                shm::color_graph_on(&WorkerPool::new(1), &g, &cfg).map_err(|e| e.to_string())?;
            c1.validate(&g).map_err(|e| format!("{}: {e}", g.name))?;
            for workers in [2usize, 8] {
                let (cw, mw) = shm::color_graph_on(&WorkerPool::new(workers), &g, &cfg)
                    .map_err(|e| e.to_string())?;
                if c1.colors != cw.colors {
                    return Err(format!("{}: colors diverged at {workers} workers", g.name));
                }
                if m1.rounds != mw.rounds || m1.speculated != mw.speculated {
                    return Err(format!(
                        "{}: round trace diverged at {workers} workers",
                        g.name
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_first_fit_stays_within_greedy_bound() {
    check(
        "datapar first-fit within Δ+1 of the sequential baseline",
        PropConfig { cases: 30, seed: 0xDA7B },
        |rng, _| {
            let g = random_graph(rng);
            let cfg = DataParConfig {
                ordering: Ordering::Natural,
                selection: Selection::FirstFit,
                chunk_size: rng.range(1, 128),
                seed: rng.next_u64(),
                max_rounds: 0,
            };
            let (c, _) = shm::color_graph(&g, &cfg).map_err(|e| e.to_string())?;
            c.validate(&g).map_err(|e| e.to_string())?;
            let bound = g.max_degree() + 1;
            if c.num_colors() > bound {
                return Err(format!(
                    "{}: {} colors exceeds Δ+1 = {bound}",
                    g.name,
                    c.num_colors()
                ));
            }
            // the sequential first-fit baseline obeys the same fixed bound,
            // so the two can never be more than Δ apart
            let seq = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 1);
            if c.num_colors() > seq.num_colors() + g.max_degree() {
                return Err(format!(
                    "{}: datapar {} vs sequential {} breaks the Δ gap bound",
                    g.name,
                    c.num_colors(),
                    seq.num_colors()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_session_datapar_jobs_always_valid() {
    check(
        "session --engine datapar runs valid and deterministic",
        PropConfig { cases: 15, seed: 0xDA7C },
        |rng, _| {
            let s = Session::new(random_graph(rng));
            let seed = rng.next_u64();
            let selection = *rng.choose(&[Selection::FirstFit, Selection::RandomX(5)]);
            let run = || {
                Job::on(&s)
                    .engine(Engine::DataPar)
                    .selection(selection)
                    .seed(seed)
                    .run()
                    .map_err(|e| e.to_string())
            };
            let a = run()?;
            a.coloring.validate(s.graph()).map_err(|e| e.to_string())?;
            if a.engine != Engine::DataPar {
                return Err(format!("ran on {:?} instead of DataPar", a.engine));
            }
            let dp = a.datapar.as_ref().ok_or("RunResult.datapar missing")?;
            if dp.per_round.len() as u32 != dp.rounds {
                return Err("per_round length disagrees with rounds".into());
            }
            let b = run()?;
            if a.coloring.colors != b.coloring.colors {
                return Err("datapar session runs not deterministic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn datapar_event_stream_has_the_engine_shape() {
    // no Partition phase (the engine skips partitioning entirely), one
    // ConflictRound per resolve round, and a Done carrying the color count
    let g = dgcolor::graph::synth::fem_like(1200, 9.0, 24, 0.02, 6, "dp-e2e");
    let s = Session::new(g);
    let log = EventLog::default();
    let r = Job::on(&s)
        .engine(Engine::DataPar)
        .selection(Selection::RandomX(4))
        .run_observed(&log)
        .unwrap();
    let events = log.events();
    assert!(
        !events
            .iter()
            .any(|e| matches!(e, Event::PhaseStarted { phase: Phase::Partition })),
        "datapar must not partition"
    );
    let dp = r.datapar.as_ref().unwrap();
    let rounds = events
        .iter()
        .filter(|e| matches!(e, Event::ConflictRound { .. }))
        .count();
    assert_eq!(rounds as u32, dp.rounds);
    assert!(events.iter().any(
        |e| matches!(e, Event::Done { result: Ok(k) } if *k == r.num_colors)
    ));
    // transport-shaped jobs stay rejected at the session boundary too
    assert!(Job::on(&s)
        .engine(Engine::DataPar)
        .quality() // quality() implies a sync RC iteration
        .run()
        .is_err());
}
