//! Chaos tests for the seeded fault-injection harness and the supervised
//! recovery engine (PR 6), extended with message loss, reliable delivery
//! and multi-crash periodic checkpointing (PR 10). CI's `chaos` job
//! reruns the property tests in release mode over a seed matrix via
//! `DGCOLOR_PROP_SEED`, and sweeps link-loss rates via
//! `DGCOLOR_CHAOS_LOSS`.

use dgcolor::color::recolor::Permutation;
use dgcolor::color::Selection;
use dgcolor::coordinator::job::nd;
use dgcolor::coordinator::{pipeline, Event, EventLog, Job, Session};
use dgcolor::dist::cost::CostModel;
use dgcolor::dist::{Crash, FaultPlan};
use dgcolor::graph::synth;
use dgcolor::prop_assert;
use dgcolor::util::error::ErrorKind;
use dgcolor::util::prop;
use dgcolor::util::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

fn session(g: dgcolor::graph::CsrGraph) -> Session {
    Session::new(g).with_cost_model(CostModel::fixed())
}

/// Link-loss rate for the chaos properties: `DGCOLOR_CHAOS_LOSS` pins it
/// (CI's chaos job sweeps the knob), otherwise roughly half the cases run
/// lossless and the rest draw a rate below 0.25.
fn chaos_loss(rng: &mut Rng) -> f64 {
    match std::env::var("DGCOLOR_CHAOS_LOSS") {
        Ok(v) => v
            .parse()
            .unwrap_or_else(|_| panic!("DGCOLOR_CHAOS_LOSS must be a probability, got {v:?}")),
        Err(_) => {
            if rng.chance(0.5) {
                0.25 * rng.f64()
            } else {
                0.0
            }
        }
    }
}

/// Zero, one or two random crash-stops — multi-crash plans (possibly on
/// the same rank, possibly overlapping) are part of the chaos space.
fn random_crashes(rng: &mut Rng, procs: usize, max_step: u64) -> Vec<Crash> {
    (0..rng.below(3))
        .map(|_| Crash {
            rank: rng.below(procs as u64) as u32,
            step: rng.below(max_step),
            down_steps: 1 + rng.below(3),
        })
        .collect()
}

/// `FaultPlan::none()` is the default of every job: attaching it
/// explicitly changes nothing — not the label, not a single modeled bit.
/// (The accounting fixture pins the fault-free numbers themselves; this
/// pins that the fault plumbing stays inert without a plan.)
#[test]
fn inert_plan_job_is_bitwise_identical_to_default() {
    let s = session(synth::fem_like(900, 9.0, 24, 0.004, 3, "fem"));
    let base = Job::on(&s).procs(5).quality().build().unwrap();
    let inert = Job::on(&s)
        .procs(5)
        .quality()
        .faults(FaultPlan::none())
        .build()
        .unwrap();
    assert_eq!(base.label(), inert.label(), "none() must not touch the label");
    let a = s.run(&base).unwrap();
    let b = s.run(&inert).unwrap();
    assert_eq!(a.coloring.colors, b.coloring.colors);
    assert_eq!(a.recolor_trace, b.recolor_trace);
    assert_eq!(a.metrics.total_msgs, b.metrics.total_msgs);
    assert_eq!(a.metrics.total_bytes, b.metrics.total_bytes);
    assert_eq!(a.metrics.makespan.to_bits(), b.metrics.makespan.to_bits());
    assert_eq!(a.metrics.total_injected_delays, 0);
    assert_eq!(a.metrics.total_restarts, 0);
}

/// A plan that delays *every* message by zero virtual seconds exercises
/// the whole supervised path — the single-threaded engine, the fault
/// branches in the transport, the retry-based receives — without changing
/// any modeled quantity, so the result must match the fault-free run bit
/// for bit while the injection counters prove the machinery ran.
#[test]
fn zero_secs_delay_plan_keeps_modeled_quantities_bitwise() {
    let s = session(synth::fem_like(1000, 10.0, 24, 0.004, 5, "fem"));
    let plain = s.run(&Job::on(&s).procs(5).quality().build().unwrap()).unwrap();
    let plan = FaultPlan {
        seed: 11,
        delay_prob: 1.0,
        delay_secs: 0.0,
        ..FaultPlan::none()
    };
    let faulted = s
        .run(&Job::on(&s).procs(5).quality().faults(plan).build().unwrap())
        .unwrap();
    assert_eq!(plain.coloring.colors, faulted.coloring.colors);
    assert_eq!(plain.recolor_trace, faulted.recolor_trace);
    assert_eq!(plain.metrics.total_msgs, faulted.metrics.total_msgs);
    assert_eq!(plain.metrics.total_bytes, faulted.metrics.total_bytes);
    assert_eq!(
        plain.metrics.makespan.to_bits(),
        faulted.metrics.makespan.to_bits(),
        "zero-second delays must not move the virtual clocks"
    );
    assert_eq!(plain.metrics.total_injected_delays, 0);
    assert!(
        faulted.metrics.total_injected_delays > 0,
        "the supervised path must actually have injected delays"
    );
}

/// Same plan, same job ⇒ the same recovery trace, twice: identical event
/// streams (including `FaultInjected`/`ProcRestarted`), identical
/// colorings, and the restart accounted on the crash rank.
#[test]
fn same_seed_crash_recovery_trace_is_reproducible() {
    let s = session(synth::fem_like(800, 9.0, 22, 0.004, 7, "fem"));
    let plan = FaultPlan {
        seed: 7,
        delay_prob: 0.05,
        delay_secs: 1e-4,
        reorder_prob: 0.05,
        crashes: vec![Crash {
            rank: 1,
            step: 2,
            down_steps: 2,
        }],
        ..FaultPlan::none()
    };
    let job = Job::on(&s)
        .procs(4)
        .selection(Selection::RandomX(5))
        .sync_recolor(nd(1))
        .faults(plan)
        .build()
        .unwrap();
    let run = || {
        let log = EventLog::new();
        let r = s.run_observed(&job, &log).unwrap();
        (log.take(), r)
    };
    let (ev1, r1) = run();
    let (ev2, r2) = run();
    assert_eq!(ev1, ev2, "recovery traces diverged across identical runs");
    assert_eq!(r1.coloring.colors, r2.coloring.colors);
    assert_eq!(r1.metrics.makespan.to_bits(), r2.metrics.makespan.to_bits());
    assert!(ev1
        .iter()
        .any(|e| *e == Event::FaultInjected { rank: 1, step: 2 }));
    assert!(ev1
        .iter()
        .any(|e| matches!(e, Event::ProcRestarted { rank: 1, .. })));
    assert_eq!(r1.metrics.total_restarts, 1);
    r1.coloring.validate(s.graph()).unwrap();
}

/// aRC is supervisable too (the engine-split rejection is gone): a crash
/// landing *inside* a recoloring iteration must either recover to a valid
/// coloring or end in a typed error — and the whole recovery trace must
/// replay bit-for-bit under the same seed.
#[test]
fn faulted_arc_crash_during_recoloring_is_reproducible() {
    let s = session(synth::fem_like(800, 9.0, 22, 0.004, 7, "fem"));
    // the framework phase on this job finishes in well under 25 engine
    // steps, so a step-25 crash lands inside the aRC iterations
    let plan = FaultPlan {
        seed: 13,
        delay_prob: 0.05,
        delay_secs: 1e-4,
        reorder_prob: 0.05,
        crashes: vec![Crash {
            rank: 1,
            step: 25,
            down_steps: 2,
        }],
        ..FaultPlan::none()
    };
    let job = Job::on(&s)
        .procs(4)
        .selection(Selection::RandomX(5))
        .async_recolor(Permutation::NonDecreasing, 2)
        .faults(plan)
        .build()
        .expect("aRC + faults must validate now that the rejection is gone");
    let run = || {
        let log = EventLog::new();
        let r = s.run_observed(&job, &log);
        (log.take(), r)
    };
    let (ev1, r1) = run();
    let (ev2, r2) = run();
    assert_eq!(ev1, ev2, "recovery traces diverged across identical runs");
    assert!(
        ev1.iter()
            .any(|e| *e == Event::FaultInjected { rank: 1, step: 25 }),
        "crash was not injected"
    );
    assert!(
        ev1.iter()
            .any(|e| matches!(e, Event::RecolorIteration { .. })),
        "job never reached a recoloring iteration"
    );
    match (&r1, &r2) {
        (Ok(a), Ok(b)) => {
            assert_eq!(a.coloring.colors, b.coloring.colors);
            assert_eq!(a.recolor_trace, b.recolor_trace);
            assert_eq!(a.metrics.makespan.to_bits(), b.metrics.makespan.to_bits());
            assert!(a.metrics.total_restarts >= 1, "no restart was accounted");
            a.coloring.validate(s.graph()).unwrap();
        }
        (Err(a), Err(b)) => {
            // a typed error is an acceptable ending, but it too must be
            // reproducible
            assert_eq!(a.to_string(), b.to_string());
        }
        _ => panic!("identical faulted runs disagreed on success"),
    }
}

/// A job the supervisor cannot finish (the crash rank stays down past the
/// livelock guard) fails as a typed error AND terminates its event stream
/// with `Done { result: Err(..) }` — observers never hang on a failed job.
#[test]
fn failed_job_surfaces_done_err_event() {
    let s = session(synth::grid2d(3, 3));
    let plan = FaultPlan {
        seed: 1,
        crashes: vec![Crash {
            rank: 0,
            step: 0,
            down_steps: u64::MAX / 2,
        }],
        ..FaultPlan::none()
    };
    let log = EventLog::new();
    let res = Job::on(&s).procs(1).faults(plan).run_observed(&log);
    let err = res.unwrap_err().to_string();
    assert!(err.contains("livelock"), "unexpected error: {err}");
    match log.take().last() {
        Some(Event::Done { result: Err(e) }) => {
            assert!(e.msg.contains("livelock"), "unexpected Done error: {e}");
            assert_eq!(e.kind, ErrorKind::Generic, "livelock is an uncategorized failure");
        }
        other => panic!("expected a Done(Err) event, got {other:?}"),
    }
}

/// The localized repair pass fixes a deliberately corrupted coloring,
/// reports each pass as `RepairPass`, and converges in one pass (a
/// sequential first-fit repair against the current coloring cannot
/// introduce new conflicts).
#[test]
fn repair_pass_fixes_corrupted_coloring() {
    use dgcolor::color::{greedy_color, Ordering};
    let g = synth::grid2d(12, 12);
    let mut c = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 1);
    c.validate(&g).unwrap();
    // corrupt: copy a neighbor's color onto a handful of vertices
    for v in [5u32, 40, 77, 100] {
        let u = g.neighbors(v)[0];
        c.colors[v as usize] = c.colors[u as usize];
    }
    assert!(c.validate(&g).is_err(), "corruption must create conflicts");
    let log = EventLog::new();
    let passes = pipeline::repair_coloring(&g, &mut c, 1, Some(&log)).unwrap();
    assert_eq!(passes, 1, "sequential repair must converge in one pass");
    c.validate(&g).unwrap();
    let events = log.take();
    match &events[..] {
        [Event::RepairPass { pass: 1, conflicts }] => assert!(*conflicts > 0),
        other => panic!("expected exactly one RepairPass event, got {other:?}"),
    }
}

/// The cancellation-chaos property: a virtual-clock budget — the
/// deterministic stop knob — racing random fault plans, half the time
/// under the `Degrade` policy. Every run must end in exactly one of a
/// typed error or a valid coloring (complete or `degraded`), never a
/// panic; the same seed must reproduce the identical ending bit for bit
/// (budget stops compare modeled time, so they replay); and no worker is
/// left wedged — a fault-free job on the same session still succeeds
/// afterwards.
#[test]
fn prop_budget_stops_under_faults_end_typed_or_valid() {
    prop::quickcheck("budget_stops_under_faults", |rng, _case| {
        let n = 120 + rng.below(240) as usize;
        let g = synth::fem_like(n, 7.0, 18, 0.004, rng.next_u64(), "fem");
        let procs = 2 + rng.below(4) as usize;
        let plan = FaultPlan {
            seed: rng.next_u64(),
            delay_prob: 0.05 + 0.25 * rng.f64(),
            delay_secs: 1e-4,
            reorder_prob: 0.25 * rng.f64(),
            loss_prob: chaos_loss(rng),
            crashes: random_crashes(rng, procs, 12),
            checkpoint_interval: 1 + rng.below(3),
        };
        // budgets straddling the fixed-cost makespan: some runs stop
        // mid-flight, some finish inside the budget — both endings are
        // exercised
        let budget = 1e-6 * (1.0 + rng.below(1000) as f64);
        let s = session(g);
        let mut b = Job::on(&s)
            .procs(procs)
            .seed(rng.next_u64())
            .faults(plan)
            .vclock_budget(budget);
        if rng.chance(0.5) {
            b = b.degrade();
        }
        if rng.chance(0.5) {
            b = b.selection(Selection::RandomX(5)).sync_recolor(nd(1));
        }
        let job = b.build().map_err(|e| format!("build failed: {e}"))?;
        let label = job.label();
        let mut endings: Vec<String> = Vec::new();
        for attempt in 0..2 {
            match catch_unwind(AssertUnwindSafe(|| s.run(&job))) {
                Err(p) => {
                    let msg = p
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "non-string panic".into());
                    return Err(format!("{label}: stopped run panicked: {msg}"));
                }
                Ok(Err(e)) => endings.push(format!("err[{:?}]: {e}", e.kind())),
                Ok(Ok(r)) => {
                    prop_assert!(
                        r.coloring.validate(s.graph()).is_ok(),
                        "{label}: attempt {attempt} returned a conflicted coloring \
                         (degraded={})",
                        r.degraded
                    );
                    endings.push(format!(
                        "ok: k={} degraded={} makespan={}",
                        r.num_colors,
                        r.degraded,
                        r.metrics.makespan.to_bits()
                    ));
                }
            }
        }
        prop_assert!(
            endings[0] == endings[1],
            "{label}: same-seed endings diverged: {} vs {}",
            endings[0],
            endings[1]
        );
        // no wedged workers: the shared engine machinery still runs a
        // plain job to completion after the stop
        let plain = Job::on(&s).procs(2).build().map_err(|e| e.to_string())?;
        match catch_unwind(AssertUnwindSafe(|| s.run(&plain))) {
            Ok(Ok(_)) => Ok(()),
            Ok(Err(e)) => Err(format!("{label}: session wedged after a stop: {e}")),
            Err(_) => Err(format!("{label}: panic on the follow-up plain job")),
        }
    });
}

/// The chaos property: random graphs under random fault plans (delays,
/// reorders, one crash) always end in a valid coloring or a typed error —
/// never a panic, never a silently-conflicted result. CI's `chaos` job
/// sweeps `DGCOLOR_PROP_SEED` 1..8 over this in release mode.
#[test]
fn prop_faulted_runs_end_valid() {
    prop::quickcheck("faulted_runs_end_valid", |rng, _case| {
        let n = 120 + rng.below(280) as usize;
        let g = synth::fem_like(n, 7.0, 18, 0.004, rng.next_u64(), "fem");
        let procs = 2 + rng.below(4) as usize;
        let plan = FaultPlan {
            seed: rng.next_u64(),
            delay_prob: 0.05 + 0.25 * rng.f64(),
            delay_secs: 1e-4,
            reorder_prob: 0.25 * rng.f64(),
            loss_prob: chaos_loss(rng),
            crashes: random_crashes(rng, procs, 15),
            checkpoint_interval: 1 + rng.below(3),
        };
        let s = session(g);
        let mut b = Job::on(&s).procs(procs).seed(rng.next_u64()).faults(plan);
        if rng.chance(0.5) {
            b = b.selection(Selection::RandomX(5));
            b = if rng.chance(0.5) {
                b.sync_recolor(nd(1))
            } else {
                b.async_recolor(Permutation::NonDecreasing, 1 + rng.below(2) as u32)
            };
        }
        let job = b.build().map_err(|e| format!("build failed: {e}"))?;
        let label = job.label();
        match catch_unwind(AssertUnwindSafe(|| s.run(&job))) {
            Err(p) => {
                let msg = p
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "non-string panic".into());
                Err(format!("{label}: faulted run panicked: {msg}"))
            }
            Ok(Err(_typed)) => Ok(()), // typed error is an acceptable ending
            Ok(Ok(r)) => {
                prop_assert!(
                    r.coloring.validate(s.graph()).is_ok(),
                    "{label}: run reported success with a conflicted coloring"
                );
                prop_assert!(r.num_colors >= 1, "{label}: empty coloring");
                // injected losses are counted, retransmitted and
                // eventually delivered — they must never surface as
                // silent message drops
                prop_assert!(
                    r.metrics.total_non_teardown_drops == 0,
                    "{label}: {} non-teardown drop(s) leaked past the reliable layer",
                    r.metrics.total_non_teardown_drops
                );
                Ok(())
            }
        }
    });
}

/// The reliable layer is deterministic end to end: the same lossy
/// multi-crash plan over the same job reproduces the identical coloring,
/// virtual makespan, event trace, and — bit for bit — the retransmission,
/// ack and dedup accounting.
#[test]
fn same_seed_lossy_multi_crash_run_reproduces_counts_and_coloring() {
    let s = session(synth::fem_like(800, 9.0, 22, 0.004, 7, "fem"));
    let plan = FaultPlan {
        seed: 23,
        delay_prob: 0.05,
        delay_secs: 1e-4,
        reorder_prob: 0.05,
        loss_prob: 0.15,
        crashes: vec![
            Crash { rank: 1, step: 2, down_steps: 2 },
            Crash { rank: 3, step: 5, down_steps: 1 },
        ],
        checkpoint_interval: 2,
    };
    let job = Job::on(&s)
        .procs(4)
        .selection(Selection::RandomX(5))
        .sync_recolor(nd(1))
        .faults(plan)
        .build()
        .unwrap();
    let run = || {
        let log = EventLog::new();
        let r = s.run_observed(&job, &log).unwrap();
        (log.take(), r)
    };
    let (ev1, r1) = run();
    let (ev2, r2) = run();
    assert_eq!(ev1, ev2, "lossy recovery traces diverged across identical runs");
    assert_eq!(r1.coloring.colors, r2.coloring.colors);
    assert_eq!(r1.metrics.makespan.to_bits(), r2.metrics.makespan.to_bits());
    for (a, b, what) in [
        (r1.metrics.total_injected_losses, r2.metrics.total_injected_losses, "losses"),
        (r1.metrics.total_retransmits, r2.metrics.total_retransmits, "retransmits"),
        (r1.metrics.total_acks_sent, r2.metrics.total_acks_sent, "acks"),
        (r1.metrics.total_dup_discards, r2.metrics.total_dup_discards, "dups"),
    ] {
        assert_eq!(a, b, "{what} accounting diverged across identical runs");
    }
    assert!(
        r1.metrics.total_injected_losses > 0,
        "a 0.15 loss rate over this run must lose at least one transmission"
    );
    // (lost *acks* can be recovered by later cumulative acks without a
    // retransmission, so losses and retransmits need not be equal — but
    // at this loss rate some data message is lost and must be retried)
    assert!(
        r1.metrics.total_retransmits > 0,
        "a 0.15 loss rate must force at least one retransmission"
    );
    assert_eq!(r1.metrics.total_restarts, 2, "both crashed ranks must restart");
    assert_eq!(r1.metrics.total_non_teardown_drops, 0, "losses are not drops");
    assert!(ev1.iter().any(|e| *e == Event::FaultInjected { rank: 1, step: 2 }));
    assert!(ev1.iter().any(|e| matches!(e, Event::ProcRestarted { rank: 3, .. })));
    r1.coloring.validate(s.graph()).unwrap();
}

/// A two-rank crash plan under interval checkpointing (`ckpt=3`) at the
/// session level: the supervisor replays each revived rank from its last
/// periodic checkpoint and the run still ends in a valid coloring that
/// matches the fault-free coloring of the same job (crash recovery is
/// invisible in the answer, not just "some valid answer").
#[test]
fn interval_checkpointed_two_rank_crash_matches_fault_free_coloring() {
    let s = session(synth::fem_like(700, 8.0, 20, 0.004, 3, "fem"));
    let mk = |plan: FaultPlan| {
        Job::on(&s)
            .procs(4)
            .selection(Selection::RandomX(7))
            .sync_recolor(nd(1))
            .faults(plan)
            .build()
            .unwrap()
    };
    let plain = s.run(&mk(FaultPlan::none())).unwrap();
    let plan = FaultPlan {
        seed: 5,
        crashes: vec![
            Crash { rank: 0, step: 3, down_steps: 2 },
            Crash { rank: 2, step: 4, down_steps: 2 },
        ],
        checkpoint_interval: 3,
        ..FaultPlan::none()
    };
    let crashed = s.run(&mk(plan)).unwrap();
    assert_eq!(
        plain.coloring.colors, crashed.coloring.colors,
        "checkpoint replay must reconverge to the fault-free coloring"
    );
    assert_eq!(plain.recolor_trace, crashed.recolor_trace);
    assert_eq!(crashed.metrics.total_restarts, 2);
    assert_eq!(crashed.metrics.total_non_teardown_drops, 0);
    crashed.coloring.validate(s.graph()).unwrap();
}
