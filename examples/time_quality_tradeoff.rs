//! The paper's Fig-10 story as a runnable example: sweep initial-coloring
//! strategies × recoloring iterations on the Table-1 stand-in graphs and
//! print the time-quality frontier, highlighting the paper's two
//! recommended presets ("speed" = FIxxND0, "quality" = R(5-10)IxxND1).
//! The sweep runs on one [`Session`] per graph, so the 12 configurations
//! share a single partitioning of each graph.
//!
//! Run: `cargo run --release --example time_quality_tradeoff`

use dgcolor::color::recolor::{Permutation, RecolorSchedule};
use dgcolor::color::{Ordering, Selection};
use dgcolor::coordinator::sweep::{pareto, run_sweep, SweepPoint};
use dgcolor::coordinator::{ColoringConfig, RecolorMode, Session};
use dgcolor::dist::recolor::{CommScheme, RecolorConfig};
use dgcolor::graph::synth;
use dgcolor::util::table::Table;

fn main() -> dgcolor::util::error::Result<()> {
    // two representative real-world stand-ins at example scale
    let sessions = vec![
        Session::new(synth::paper_graph(&synth::TABLE1_SPECS[0], 0.03, 1)), // auto
        Session::new(synth::paper_graph(&synth::TABLE1_SPECS[2], 0.05, 2)), // hood
    ];
    let procs = 32; // the paper presents Fig 8-10 at 32 processes

    let mut configs = Vec::new();
    for sel in [
        Selection::FirstFit,
        Selection::RandomX(5),
        Selection::RandomX(10),
        Selection::RandomX(50),
    ] {
        for iters in [0u32, 1, 2] {
            let recolor = if iters == 0 {
                RecolorMode::None
            } else {
                RecolorMode::Sync(RecolorConfig {
                    schedule: RecolorSchedule::Fixed(Permutation::NonDecreasing),
                    iterations: iters,
                    scheme: CommScheme::Piggyback,
                    seed: 42,
                    ..Default::default()
                })
            };
            configs.push(ColoringConfig {
                selection: sel,
                ordering: Ordering::InternalFirst,
                recolor,
                ..Default::default()
            });
        }
    }
    let baseline = ColoringConfig {
        ordering: Ordering::InternalFirst,
        ..Default::default()
    };
    let points = run_sweep(&sessions, configs, &baseline, procs)?;
    for s in &sessions {
        assert_eq!(
            s.partition_calls(),
            1,
            "all configs share one partition key"
        );
    }

    let fmt = |p: &SweepPoint| {
        vec![
            p.label.clone(),
            format!("{:.3}", p.norm_colors),
            format!("{:.3}", p.norm_time),
            p.recolor_iters.to_string(),
        ]
    };
    let mut t = Table::new(
        "time-quality sweep (normalized to FF/IF/no-recolor)",
        &["config", "norm colors", "norm time", "RC iters"],
    );
    for p in &points {
        t.row(&fmt(p));
    }
    t.print();
    t.save_csv("tradeoff_sweep")?;

    let front = pareto(&points);
    let mut t = Table::new(
        "pareto frontier (the paper's Fig-10 view)",
        &["config", "norm colors", "norm time", "RC iters"],
    );
    for p in &front {
        t.row(&fmt(p));
    }
    t.print();

    println!(
        "\npaper's recommendations — speed: FIxxND0 (FF, no recoloring);\n\
         quality: R(5-10)IxxND1 (Random-5/10 + one ND recoloring iteration)"
    );
    Ok(())
}
