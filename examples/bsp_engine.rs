//! BSP step engine end-to-end check (the CI oversubscription guard): run
//! one small sweep — framework coloring + 2× piggybacked RC-ND — on the
//! step engine at growing process counts, re-run p=64 on the
//! thread-per-process reference runner, and **assert** the two paths agree
//! bit-for-bit on every modeled quantity while reporting both simulator
//! wallclocks; then repeat the p=64 cross-check for aRC (2× aRC-ND), the
//! job shape that used to fall back to threads. A regression that
//! re-introduces blocking/oversubscription in the engine shows up as a
//! wallclock blowup or an assert here. A final leg reruns the p=64 job
//! over 5%-lossy links on the supervised engine and asserts the reliable
//! layer reproduces the fault-free coloring exactly.
//!
//! Run: `cargo run --release --example bsp_engine`

use dgcolor::color::recolor::Permutation;
use dgcolor::coordinator::job::nd;
use dgcolor::coordinator::{Job, Session};
use dgcolor::dist::{CostModel, Engine, FaultPlan};
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::util::table::{fmt_secs, Table};

fn main() -> dgcolor::util::error::Result<()> {
    let g = rmat::generate(&RmatParams::er(13, 8), 7, "er13");
    println!(
        "RMAT-ER scale 13: |V|={} |E|={} Δ={}\n",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree(),
    );
    let session = Session::new(g).with_cost_model(CostModel::fixed());

    let mut t = Table::new(
        "FSS + 2×RC-ND(piggyback) on the BSP step engine",
        &["procs", "colors", "msgs", "virtual time", "sim wall"],
    );
    for p in [4usize, 16, 64] {
        let r = Job::on(&session)
            .procs(p)
            .sync_recolor(nd(2))
            .engine(Engine::Bsp)
            .run()?;
        t.row(&[
            p.to_string(),
            r.num_colors.to_string(),
            r.metrics.total_msgs.to_string(),
            fmt_secs(r.metrics.makespan),
            fmt_secs(r.metrics.wall_secs),
        ]);
    }
    t.print();

    // reference check at the largest scale: the thread runner must agree
    // on every modeled quantity, bit for bit
    let job = |engine| {
        Job::on(&session)
            .procs(64)
            .sync_recolor(nd(2))
            .engine(engine)
            .build()
            .unwrap()
    };
    let by_engine = session.run(&job(Engine::Bsp))?;
    let by_threads = session.run(&job(Engine::Threads))?;
    assert_eq!(by_engine.coloring.colors, by_threads.coloring.colors);
    assert_eq!(by_engine.recolor_trace, by_threads.recolor_trace);
    assert_eq!(by_engine.metrics.total_msgs, by_threads.metrics.total_msgs);
    assert_eq!(by_engine.metrics.total_bytes, by_threads.metrics.total_bytes);
    assert_eq!(
        by_engine.metrics.makespan.to_bits(),
        by_threads.metrics.makespan.to_bits()
    );
    assert_eq!(by_engine.metrics.total_dropped, 0);
    println!(
        "\np=64 engine vs thread runner: identical results ✓  \
         (sim wall {} vs {})",
        fmt_secs(by_engine.metrics.wall_secs),
        fmt_secs(by_threads.metrics.wall_secs),
    );

    // same cross-check for aRC — the job shape the engine split used to
    // route to threads unconditionally
    let arc_job = |engine| {
        Job::on(&session)
            .procs(64)
            .async_recolor(Permutation::NonDecreasing, 2)
            .engine(engine)
            .build()
            .unwrap()
    };
    let arc_engine = session.run(&arc_job(Engine::Bsp))?;
    let arc_threads = session.run(&arc_job(Engine::Threads))?;
    assert_eq!(arc_engine.coloring.colors, arc_threads.coloring.colors);
    assert_eq!(arc_engine.recolor_trace, arc_threads.recolor_trace);
    assert_eq!(arc_engine.metrics.total_msgs, arc_threads.metrics.total_msgs);
    assert_eq!(arc_engine.metrics.total_bytes, arc_threads.metrics.total_bytes);
    assert_eq!(
        arc_engine.metrics.makespan.to_bits(),
        arc_threads.metrics.makespan.to_bits()
    );
    assert_eq!(arc_engine.metrics.total_dropped, 0);
    assert_eq!(arc_engine.engine, Engine::Bsp);
    println!(
        "p=64 aRC-ND2 engine vs thread runner: identical results ✓  \
         (sim wall {} vs {})",
        fmt_secs(arc_engine.metrics.wall_secs),
        fmt_secs(arc_threads.metrics.wall_secs),
    );

    // reliable delivery at scale: the same p=64 job over 5%-lossy links
    // must hide the loss entirely — the supervised run's coloring matches
    // the fault-free run bit for bit, every lost transmission is
    // re-covered by retransmission, and nothing surfaces as a drop
    let lossy_job = Job::on(&session)
        .procs(64)
        .sync_recolor(nd(2))
        .faults(FaultPlan {
            seed: 9,
            loss_prob: 0.05,
            ..FaultPlan::none()
        })
        .build()
        .unwrap();
    let lossy = session.run(&lossy_job)?;
    assert_eq!(
        lossy.coloring.colors, by_engine.coloring.colors,
        "lossy p=64 run diverged from the fault-free coloring"
    );
    assert_eq!(lossy.recolor_trace, by_engine.recolor_trace);
    assert!(
        lossy.metrics.total_injected_losses > 0 && lossy.metrics.total_retransmits > 0,
        "a 5% loss rate at p=64 must exercise the reliable layer"
    );
    assert_eq!(lossy.metrics.total_non_teardown_drops, 0, "losses are not drops");
    println!(
        "p=64 over 5%-lossy links: fault-free coloring recovered ✓  \
         ({} losses re-covered by {} retransmits, {} acks, {} dups)",
        lossy.metrics.total_injected_losses,
        lossy.metrics.total_retransmits,
        lossy.metrics.total_acks_sent,
        lossy.metrics.total_dup_discards,
    );
    Ok(())
}
