//! End-to-end driver (the EXPERIMENTS.md §E2E run): a real workload —
//! RMAT-Good at 2^18 vertices / ~2M edges — through the full system:
//! one coordinator [`Session`] running partition → distributed superstep
//! coloring → synchronous recoloring with piggybacking, swept over process
//! counts, reporting quality + virtual runtime + exact message counts at
//! each scale. The last run streams its phase/iteration events to stdout.
//!
//! Run: `cargo run --release --example distributed_pipeline`
//! (REPRO_FULL=1 raises the graph to the paper's 2^24 scale.)

use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::event::{Event, Observer};
use dgcolor::coordinator::job::nd;
use dgcolor::coordinator::{Job, Session};
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::partition::Partitioner;
use dgcolor::util::bench::full_scale;
use dgcolor::util::table::{fmt_secs, Table};
use dgcolor::util::timer::Timer;

/// Print recoloring progress as it streams out of the run.
struct IterationPrinter;

impl Observer for IterationPrinter {
    fn on_event(&self, event: &Event) {
        if let Event::RecolorIteration { iter, k } = event {
            println!("  [event] recolor iteration {iter}: {k} colors");
        }
    }
}

fn main() -> dgcolor::util::error::Result<()> {
    let scale = if full_scale() { 24 } else { 18 };
    let gen_t = Timer::start();
    let g = rmat::generate(&RmatParams::good(scale, 8), 7, "rmat-good");
    println!(
        "RMAT-Good scale {scale}: |V|={} |E|={} Δ={} (generated in {})\n",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree(),
        fmt_secs(gen_t.secs()),
    );

    // sequential references (paper Table 2 columns)
    let seq_nat = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 1).num_colors();
    let seq_sl = greedy_color(&g, Ordering::SmallestLast, Selection::FirstFit, 1).num_colors();
    println!("sequential: NAT={seq_nat} SL={seq_sl}\n");

    // one session: the graph is partitioned once per process count and the
    // cost model is calibrated once for the whole sweep
    let session = Session::new(g);
    let mut t = Table::new(
        "FSS + 2×RC-ND(piggyback) across scales",
        &["procs", "initial", "final", "conflicts", "msgs", "virtual time", "sim wall"],
    );
    let procs_list: &[usize] = if full_scale() {
        &[4, 16, 64, 256, 512]
    } else {
        &[4, 16, 64, 128]
    };
    for (i, &p) in procs_list.iter().enumerate() {
        let job = Job::on(&session)
            .procs(p)
            .ordering(Ordering::SmallestLast)
            .partitioner(Partitioner::Block) // paper: block for RMAT
            .sync_recolor(nd(2));
        let r = if i + 1 == procs_list.len() {
            println!("streaming events for the P={p} run:");
            job.run_observed(&IterationPrinter)?
        } else {
            job.run()?
        };
        t.row(&[
            p.to_string(),
            r.initial_colors.to_string(),
            r.num_colors.to_string(),
            r.metrics.total_conflicts.to_string(),
            r.metrics.total_msgs.to_string(),
            fmt_secs(r.metrics.makespan),
            fmt_secs(r.metrics.wall_secs),
        ]);
        // one job per proc count: the key is never revisited, so drop the
        // cached partition (matters at the 2^24 REPRO_FULL scale)
        session.clear_cached_partitions();
    }
    t.print();
    t.save_csv("e2e_distributed_pipeline")?;
    println!("\nheadline check: final colors stay near sequential SL={seq_sl} as P grows ✓");
    Ok(())
}
