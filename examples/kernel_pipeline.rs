//! Three-layer composition proof: color a real graph entirely through the
//! AOT-compiled Pallas kernels (L1) lowered via the JAX model (L2) and
//! executed from the rust coordinator (L3) over PJRT — then cross-check
//! against the native implementation and run kernel-batched conflict
//! detection on a speculative two-part coloring.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example kernel_pipeline

use dgcolor::color::{greedy_color, Coloring, Ordering, Selection};
use dgcolor::graph::synth;
use dgcolor::runtime::{BatchColorer, KernelRuntime};
use dgcolor::util::table::{fmt_secs, Table};
use dgcolor::util::timer::Timer;

fn main() -> dgcolor::util::error::Result<()> {
    if !KernelRuntime::artifacts_present() {
        dgcolor::bail!(
            "kernel runtime unavailable — run `make artifacts` and build with `--features xla`"
        );
    }
    let rt = KernelRuntime::load(&KernelRuntime::artifacts_dir())?;
    let mut bc = BatchColorer::new(rt, 42);

    let g = synth::fem_like(6000, 14.0, 40, 0.005, 11, "kernel-mesh");
    println!(
        "graph: |V|={} |E|={} Δ={}\n",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree()
    );
    let order: Vec<u32> = (0..g.num_vertices() as u32).collect();

    let mut t = Table::new(
        "kernel vs native coloring",
        &["path", "strategy", "colors", "time", "kernel calls", "fallbacks"],
    );
    // kernel first-fit
    let timer = Timer::start();
    let mut kc = Coloring::uncolored(g.num_vertices());
    bc.color_sequence(&g, &order, None, &mut kc)?;
    kc.validate(&g).expect("kernel FF must be valid");
    t.row(&[
        "PJRT kernels".into(),
        "first fit".into(),
        kc.num_colors().to_string(),
        fmt_secs(timer.secs()),
        bc.kernel_calls.to_string(),
        bc.fallbacks.to_string(),
    ]);
    // kernel random-5
    let calls0 = bc.kernel_calls;
    let timer = Timer::start();
    let mut kr = Coloring::uncolored(g.num_vertices());
    bc.color_sequence(&g, &order, Some(5), &mut kr)?;
    kr.validate(&g).expect("kernel R5 must be valid");
    t.row(&[
        "PJRT kernels".into(),
        "random-5".into(),
        kr.num_colors().to_string(),
        fmt_secs(timer.secs()),
        (bc.kernel_calls - calls0).to_string(),
        bc.fallbacks.to_string(),
    ]);
    // native reference
    let timer = Timer::start();
    let nc = greedy_color(&g, Ordering::Natural, Selection::FirstFit, 0);
    t.row(&[
        "native".into(),
        "first fit".into(),
        nc.num_colors().to_string(),
        fmt_secs(timer.secs()),
        "-".into(),
        "-".into(),
    ]);
    t.print();

    // kernel-batched conflict detection over a deliberately conflicted
    // speculative coloring (two halves colored independently)
    let mut spec = Coloring::uncolored(g.num_vertices());
    let half = g.num_vertices() as u32 / 2;
    let lo: Vec<u32> = (0..half).collect();
    let hi: Vec<u32> = (half..g.num_vertices() as u32).collect();
    bc.color_sequence(&g, &lo, None, &mut spec)?;
    // second half colored blind to the first (simulate concurrent procs)
    let mut blind = spec.clone();
    for v in &lo {
        blind.set(*v, dgcolor::color::UNCOLORED);
    }
    bc.color_sequence(&g, &hi, None, &mut blind)?;
    for v in &lo {
        blind.set(*v, spec.get(*v));
    }
    let cross: Vec<(u32, u32)> = g
        .edges()
        .filter(|&(u, v)| (u < half) != (v < half))
        .collect();
    let (lu, lv) = bc.detect_conflicts(&cross, &blind, 42)?;
    let conflicts = blind.count_conflicts(&g);
    println!(
        "\nconflict detection: {} cross edges, {} monochromatic, kernel flagged {} losers ({} u-side, {} v-side)",
        cross.len(),
        conflicts,
        lu.len() + lv.len(),
        lu.len(),
        lv.len()
    );
    assert_eq!(lu.len() + lv.len(), conflicts, "exactly one loser per conflict");
    println!("\nthree-layer composition validated ✓");
    Ok(())
}
