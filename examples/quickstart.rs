//! Quickstart: generate a small FEM-like mesh, color it sequentially with
//! the three paper orderings, run one distributed job with the paper's
//! "quality" preset, and validate everything.
//!
//! Run: `cargo run --release --example quickstart`

use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::{run_job, ColoringConfig};
use dgcolor::graph::synth;
use dgcolor::util::table::{fmt_secs, Table};
use dgcolor::util::timer::Timer;

fn main() -> dgcolor::util::error::Result<()> {
    // 1. a workload: FEM-style mesh, ~8k vertices
    let g = synth::fem_like(8000, 14.0, 40, 0.005, 42, "quickstart-mesh");
    println!(
        "graph: |V|={} |E|={} Δ={}\n",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree()
    );

    // 2. sequential baselines (paper Table 1 style)
    let mut t = Table::new("sequential greedy", &["ordering", "colors", "time"]);
    for ord in [Ordering::Natural, Ordering::LargestFirst, Ordering::SmallestLast] {
        let timer = Timer::start();
        let c = greedy_color(&g, ord, Selection::FirstFit, 1);
        c.validate(&g).expect("valid coloring");
        t.row(&[
            ord.short_name().to_string(),
            c.num_colors().to_string(),
            fmt_secs(timer.secs()),
        ]);
    }
    t.print();

    // 3. distributed runs: "speed" vs "quality" presets on 8 processes
    let mut t = Table::new(
        "distributed (8 procs)",
        &["preset", "colors", "virtual time", "messages"],
    );
    for (name, cfg) in [
        ("speed  (FIxxND0)", ColoringConfig::speed(8)),
        ("quality(R5IxxND1)", ColoringConfig::quality(8)),
    ] {
        let r = run_job(&g, &cfg)?;
        t.row(&[
            name.to_string(),
            r.num_colors.to_string(),
            fmt_secs(r.metrics.makespan),
            r.metrics.total_msgs.to_string(),
        ]);
    }
    t.print();
    println!("\nall colorings validated ✓");
    Ok(())
}
