//! Quickstart: generate a small FEM-like mesh, color it sequentially with
//! the three paper orderings, then open a coordinator [`Session`] and run
//! the paper's "speed"/"quality" presets plus an early-stopped recoloring
//! job through the fluent [`Job`] builder.
//!
//! Run: `cargo run --release --example quickstart`

use dgcolor::color::{greedy_color, Ordering, Selection};
use dgcolor::coordinator::job::nd;
use dgcolor::coordinator::{Job, Session};
use dgcolor::graph::synth;
use dgcolor::util::table::{fmt_secs, Table};
use dgcolor::util::timer::Timer;

fn main() -> dgcolor::util::error::Result<()> {
    // 1. a workload: FEM-style mesh, ~8k vertices
    let g = synth::fem_like(8000, 14.0, 40, 0.005, 42, "quickstart-mesh");
    println!(
        "graph: |V|={} |E|={} Δ={}\n",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree()
    );

    // 2. sequential baselines (paper Table 1 style)
    let mut t = Table::new("sequential greedy", &["ordering", "colors", "time"]);
    for ord in [Ordering::Natural, Ordering::LargestFirst, Ordering::SmallestLast] {
        let timer = Timer::start();
        let c = greedy_color(&g, ord, Selection::FirstFit, 1);
        c.validate(&g).expect("valid coloring");
        t.row(&[
            ord.short_name().to_string(),
            c.num_colors().to_string(),
            fmt_secs(timer.secs()),
        ]);
    }
    t.print();

    // 3. a session owns the graph and caches partitions + the calibrated
    //    cost model, so the three jobs below partition exactly once
    let session = Session::new(g);
    let mut t = Table::new(
        "distributed (8 procs, one session)",
        &["job", "colors", "trace", "virtual time", "messages"],
    );
    let speed = Job::on(&session).procs(8).speed().run()?;
    let quality = Job::on(&session).procs(8).quality().run()?;
    // the new scenario: keep recoloring until an iteration improves the
    // color count by less than 5%
    let early = Job::on(&session)
        .procs(8)
        .selection(Selection::RandomX(5))
        .sync_recolor(nd(6))
        .stop_when_improvement_below(0.05)
        .run()?;
    for (name, r) in [
        ("speed  (FIxxND0)", &speed),
        ("quality(R5IxxND1)", &quality),
        ("ND6 + stop@5%", &early),
    ] {
        t.row(&[
            name.to_string(),
            r.num_colors.to_string(),
            format!("{:?}", r.recolor_trace),
            fmt_secs(r.metrics.makespan),
            r.metrics.total_msgs.to_string(),
        ]);
    }
    t.print();
    println!(
        "\npartition calls for 3 jobs: {} (cached per (partitioner, procs, seed))",
        session.partition_calls()
    );
    println!("early stop ran {} of 6 iterations", early.recolor_trace.len() - 1);
    println!("\nall colorings validated ✓");
    Ok(())
}
