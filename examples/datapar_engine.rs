//! DataPar engine end-to-end check (the CI shared-memory leg): color an
//! RMAT graph through the Job/Session API with `--engine datapar`,
//! **assert** the coloring is valid and bit-for-bit reproducible, then
//! rerun the raw `shm` core across pool sizes {1, 2, 8} and assert the
//! worker-count-independence guarantee the engine is built on. Finishes
//! with a wallclock comparison against the BSP step engine on the same
//! graph — the raw-speed story this engine exists for.
//!
//! Run: `cargo run --release --example datapar_engine`

use dgcolor::color::Selection;
use dgcolor::coordinator::{Job, Session};
use dgcolor::dist::{CostModel, Engine};
use dgcolor::graph::rmat::{self, RmatParams};
use dgcolor::shm::{self, DataParConfig};
use dgcolor::util::pool::WorkerPool;
use dgcolor::util::table::{fmt_secs, Table};

fn main() -> dgcolor::util::error::Result<()> {
    let g = rmat::generate(&RmatParams::er(13, 8), 7, "er13");
    println!(
        "RMAT-ER scale 13: |V|={} |E|={} Δ={}\n",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree(),
    );
    let session = Session::new(g).with_cost_model(CostModel::fixed());

    // end-to-end through the Job/Session API
    let job = || {
        Job::on(&session)
            .engine(Engine::DataPar)
            .selection(Selection::RandomX(5))
            .seed(7)
            .build()
            .unwrap()
    };
    let r = session.run(&job())?;
    r.coloring.validate(session.graph()).unwrap();
    assert_eq!(r.engine, Engine::DataPar);
    let dp = r.datapar.as_ref().expect("datapar metrics");
    let mut t = Table::new("--engine datapar on the Job/Session API", &["metric", "value"]);
    t.row(&["colors", &r.num_colors.to_string()]);
    t.row(&["rounds", &dp.rounds.to_string()]);
    t.row(&["speculated", &dp.speculated.to_string()]);
    t.row(&["conflicted", &dp.conflicted.to_string()]);
    t.row(&["chunks", &dp.chunks.to_string()]);
    t.row(&["workers", &dp.workers.to_string()]);
    t.row(&["wall", &fmt_secs(dp.wall_secs)]);
    t.print();

    let again = session.run(&job())?;
    assert_eq!(r.coloring.colors, again.coloring.colors);
    println!("\nsame job twice: identical coloring ✓");

    // the engine's core guarantee: the coloring is a function of
    // (graph, config), never of the pool size
    let cfg = DataParConfig {
        selection: Selection::RandomX(5),
        seed: 7,
        ..DataParConfig::default()
    };
    let (c1, m1) = shm::color_graph_on(&WorkerPool::new(1), session.graph(), &cfg)?;
    c1.validate(session.graph()).unwrap();
    for workers in [2usize, 8] {
        let (cw, mw) = shm::color_graph_on(&WorkerPool::new(workers), session.graph(), &cfg)?;
        assert_eq!(c1.colors, cw.colors, "colors diverged at {workers} workers");
        assert_eq!(m1.rounds, mw.rounds, "rounds diverged at {workers} workers");
    }
    println!("pool sizes 1/2/8: bit-for-bit identical colorings ✓");

    // the raw-speed story: same graph, same selection, BSP vs DataPar
    let bsp = Job::on(&session)
        .procs(8)
        .selection(Selection::RandomX(5))
        .seed(7)
        .engine(Engine::Bsp)
        .run()?;
    println!(
        "\nwallclock, RMAT-ER 13: datapar {} ({} colors) vs bsp p=8 {} ({} colors)",
        fmt_secs(dp.wall_secs),
        r.num_colors,
        fmt_secs(bsp.metrics.wall_secs),
        bsp.num_colors,
    );
    Ok(())
}
