//! Scheduler soak (CI's `soak` job): eight seeded rounds of mixed
//! interactive/sweep traffic against a deliberately small-capacity
//! [`Scheduler`], asserting the service-layer invariants end to end:
//!
//! * admission control sheds overload with the typed `Overloaded` error
//!   (and sheds *something* — a burst larger than the queue must reject);
//! * every admitted job completes — a successful result (possibly
//!   `degraded` under a budget stop), a typed stop error, or nothing
//!   else: no panics, no hung `wait()`, no silently dropped handles;
//! * the fairness rule's provable max-wait bound holds: an interactive
//!   job admitted at queue position `p` is passed by at most
//!   `p / quantum + 1` sweep jobs, so the observed maximum never exceeds
//!   `queue_cap / quantum + 1`;
//! * the books balance: every admitted job is accounted completed or
//!   failed once all handles are drained.
//!
//! Run: `cargo run --release --example scheduler_soak`

use dgcolor::color::Selection;
use dgcolor::coordinator::job::nd;
use dgcolor::coordinator::{Job, Priority, Scheduler, SchedulerConfig, Session};
use dgcolor::dist::CostModel;
use dgcolor::graph::synth;
use dgcolor::util::error::ErrorKind;
use dgcolor::util::rng::Rng;
use dgcolor::util::table::Table;

const SEEDS: u64 = 8;
const QUEUE_CAP: usize = 6;
const QUANTUM: u32 = 2;
const SUBMITS: usize = 24;

#[derive(Default)]
struct Totals {
    admitted: u64,
    rejected: u64,
    ok: u64,
    ok_degraded: u64,
    stopped: u64,
    max_overtakes: u64,
}

fn main() {
    let mut totals = Totals::default();
    for seed in 1..=SEEDS {
        soak_round(seed, &mut totals);
    }

    let mut t = Table::new(
        &format!("scheduler soak: {SEEDS} seeds × {SUBMITS} submissions"),
        &["metric", "value"],
    );
    t.row(&["admitted", &totals.admitted.to_string()]);
    t.row(&["overload-rejected", &totals.rejected.to_string()]);
    t.row(&["completed ok", &totals.ok.to_string()]);
    t.row(&["  of which degraded", &totals.ok_degraded.to_string()]);
    t.row(&["typed stops", &totals.stopped.to_string()]);
    t.row(&["max sweeps past an interactive", &totals.max_overtakes.to_string()]);
    t.print();

    // the burst is 4× the queue: admission control must have shed load
    assert!(
        totals.rejected > 0,
        "no submission was ever rejected — admission control untested"
    );
    assert!(totals.ok > 0, "no job ever completed");
    println!("\nsoak passed: every ending typed, fairness bound held ✓");
}

fn soak_round(seed: u64, totals: &mut Totals) {
    let sched = Scheduler::new(SchedulerConfig {
        queue_cap: QUEUE_CAP,
        interactive_quantum: QUANTUM,
        start_paused: false,
    });
    let grid = sched.add_tenant(
        Session::new(synth::grid2d(20, 20)).with_cost_model(CostModel::fixed()),
    );
    let fem = sched.add_tenant(
        Session::new(synth::fem_like(500, 8.0, 20, 0.004, seed, "fem"))
            .with_cost_model(CostModel::fixed()),
    );

    let mut rng = Rng::new(seed);
    let mut handles = Vec::new();
    for _ in 0..SUBMITS {
        let tenant = if rng.chance(0.5) { grid } else { fem };
        let interactive = rng.chance(0.7);
        let mut b = Job::builder().seed(rng.next_u64());
        b = if interactive {
            b.procs(2).priority(Priority::Interactive)
        } else {
            b.procs(4)
                .selection(Selection::RandomX(5))
                .sync_recolor(nd(1))
                .priority(Priority::Sweep)
        };
        if rng.chance(0.3) {
            b = b.vclock_budget(1e-6 * (1.0 + rng.below(100) as f64));
            if rng.chance(0.5) {
                b = b.degrade();
            }
        }
        let job = b.build().expect("soak job must validate");
        match sched.submit(tenant, job) {
            Ok(h) => {
                if rng.chance(0.15) {
                    h.cancel(); // client gives up — queued or mid-run
                }
                handles.push(h);
            }
            Err(e) => {
                assert_eq!(
                    e.kind(),
                    ErrorKind::Overloaded,
                    "seed {seed}: submit failed with a non-overload error: {e}"
                );
                totals.rejected += 1;
            }
        }
    }

    totals.admitted += handles.len() as u64;
    for h in handles {
        // a live scheduler completes every admitted job: wait() must
        // return, and only with a success or a typed stop
        match h.wait() {
            Ok(r) => {
                assert!(r.num_colors >= 1, "seed {seed}: empty coloring");
                totals.ok += 1;
                if r.degraded {
                    totals.ok_degraded += 1;
                }
            }
            Err(e) => {
                assert!(
                    e.is_stop(),
                    "seed {seed}: job failed with a non-stop error: {e}"
                );
                totals.stopped += 1;
            }
        }
    }

    let stats = sched.shutdown();
    let bound = (QUEUE_CAP as u64) / (QUANTUM as u64) + 1;
    assert!(
        stats.max_sweeps_before_interactive <= bound,
        "seed {seed}: fairness bound violated — {} sweeps passed an \
         interactive job (bound {bound})",
        stats.max_sweeps_before_interactive
    );
    assert_eq!(
        stats.submitted,
        stats.completed + stats.failed,
        "seed {seed}: accounting leak — {} admitted vs {} completed + {} failed",
        stats.submitted,
        stats.completed,
        stats.failed
    );
    totals.max_overtakes = totals.max_overtakes.max(stats.max_sweeps_before_interactive);
}
