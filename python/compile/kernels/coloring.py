"""Layer-1 Pallas kernels: the data-parallel hot-spot of the distributed
coloring framework.

The framework's per-superstep work — "for a batch of vertices, gather the
colors of their neighbors, build the forbidden set, pick a color" — maps to
three branch-free kernels over fixed-shape tiles:

* ``forbid_mask``    : neighbor colors [B, D] (i32, -1 padded) →
                       forbidden bitset [B, W] (32-bit words as i32).
* ``first_fit``      : bitset → smallest permissible color [B].
* ``random_x_fit``   : bitset + uniforms [B] + X → uniform pick among the
                       first X permissible colors [B].
* ``conflict_detect``: edge endpoint colors + static random priorities →
                       per-edge loser flags (the framework's tie-break).

Hardware adaptation (DESIGN.md §2): the paper targets a CPU cluster; on a
TPU the natural formulation is a VMEM-resident neighbor-color tile with a
compare-broadcast bitset reduction across a [B, D] → [B, W] grid — VPU
work, no MXU. BlockSpec tiles the batch dimension (`BLOCK_B` rows per
block) so the HBM→VMEM stream of neighbor colors overlaps the reduction.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that the
rust runtime loads (see ``aot.py``). Correctness is pinned to the pure-jnp
oracle in ``ref.py`` by ``python/tests``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed kernel-contract shapes (the rust runtime pads/chunks to these).
BATCH = 256          # vertices per batch
DMAX = 64            # padded neighbor slots per vertex
WORDS = 8            # 32-bit mask words → supports colors 0..255
NCOLORS = WORDS * 32
EDGE_BATCH = 4096    # edges per conflict-detection batch
BLOCK_B = 128        # batch-dimension tile (VMEM sizing: see DESIGN.md §7)


def _forbid_mask_kernel(colors_ref, mask_ref):
    """colors [b, D] i32 (-1 = empty slot) → mask [b, W] i32 (u32 bits)."""
    c = colors_ref[...]                        # [b, D]
    valid = c >= 0
    word = jnp.where(valid, c >> 5, WORDS)     # invalid slots → out of range
    bit = jnp.where(valid, (1 << (c & 31)).astype(jnp.uint32), jnp.uint32(0))
    # compare-broadcast across the W words, OR-reduce over the D axis
    words = []
    for w in range(WORDS):
        contrib = jnp.where(word == w, bit, jnp.uint32(0))   # [b, D]
        acc = jax.lax.reduce(
            contrib, jnp.uint32(0), jax.lax.bitwise_or, dimensions=[1]
        )                                                     # [b]
        words.append(acc)
    mask_ref[...] = jnp.stack(words, axis=1).astype(jnp.int32)


def forbid_mask(neigh_colors):
    """Pallas entry: [B, D] i32 → [B, W] i32 bitset."""
    b = neigh_colors.shape[0]
    grid = (b // BLOCK_B,) if b % BLOCK_B == 0 and b >= BLOCK_B else (1,)
    blk = BLOCK_B if grid[0] > 1 else b
    return pl.pallas_call(
        _forbid_mask_kernel,
        out_shape=jax.ShapeDtypeStruct((b, WORDS), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((blk, neigh_colors.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk, WORDS), lambda i: (i, 0)),
        interpret=True,
    )(neigh_colors)


def _bits_from_mask(mask_u32):
    """[b, W] u32 → [b, NCOLORS] bool (bit c of the forbidden set)."""
    lanes = jnp.arange(32, dtype=jnp.uint32)
    # [b, W, 32] → [b, W*32]
    bits = (mask_u32[:, :, None] >> lanes[None, None, :]) & jnp.uint32(1)
    return bits.reshape(mask_u32.shape[0], NCOLORS).astype(jnp.bool_)


def _first_fit_kernel(mask_ref, color_ref):
    m = mask_ref[...].astype(jnp.uint32)       # [b, W]
    forbidden = _bits_from_mask(m)             # [b, C] bool
    # smallest color whose forbidden bit is clear
    color_ref[...] = jnp.argmax(~forbidden, axis=1).astype(jnp.int32)


def first_fit(mask):
    """Pallas entry: forbidden bitset [B, W] i32 → first-fit colors [B]."""
    b = mask.shape[0]
    return pl.pallas_call(
        _first_fit_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(mask)


def _prefix_sum(x):
    """Hillis-Steele inclusive prefix sum along axis 1 in log2(C) shifted
    adds. §Perf: jnp.cumsum lowers (via XLA on this path) to a quadratic
    reduce-window — O(C²) work per row; the doubling scan is O(C·log C) and
    took the AOT random_x batch from 2.17ms to well under first_fit+2×.
    """
    b, c = x.shape
    shift = 1
    while shift < c:
        pad = jnp.zeros((b, shift), x.dtype)
        x = x + jnp.concatenate([pad, x[:, :-shift]], axis=1)
        shift *= 2
    return x


def _permissible_rank(mask_u32):
    """1-based rank of each color among the permissible set, via a
    two-level scan: word-level popcount prefix (W=8 wide) + in-word masked
    popcounts. §Perf iteration 2: replaces the [b, C]-wide doubling scan
    (8 × 256KB concats) with one popcount pass — random_x batch went
    2.17ms → 937µs → ~0.4ms.
    """
    b = mask_u32.shape[0]
    perm_words = ~mask_u32                                     # [b, W]
    pc = jax.lax.population_count(perm_words).astype(jnp.int32)  # [b, W]
    # exclusive prefix over the 8 words (tiny unrolled scan)
    word_prefix = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32), jnp.cumsum(pc[:, :-1], axis=1)], axis=1
    )                                                          # [b, W]
    lanes = jnp.arange(32, dtype=jnp.uint32)
    lane_mask = jnp.where(
        lanes == 31, jnp.uint32(0xFFFFFFFF), (jnp.uint32(1) << (lanes + 1)) - 1
    )                                                          # [32]
    in_word = jax.lax.population_count(
        perm_words[:, :, None] & lane_mask[None, None, :]
    ).astype(jnp.int32)                                        # [b, W, 32]
    rank = word_prefix[:, :, None] + in_word                   # [b, W, 32]
    return rank.reshape(b, NCOLORS)


def _random_x_kernel(mask_ref, u_ref, x_ref, color_ref):
    m = mask_ref[...].astype(jnp.uint32)
    permissible = ~_bits_from_mask(m)          # [b, C] bool
    rank = _permissible_rank(m)                # 1-based rank
    u = u_ref[...]                             # [b] in [0,1)
    x = x_ref[0].astype(jnp.float32)
    # uniform k in [0, X): the (k+1)-th permissible color
    k = jnp.clip((u * x).astype(jnp.int32), 0, x_ref[0] - 1) + 1  # [b]
    hit = permissible & (rank == k[:, None])
    color_ref[...] = jnp.argmax(hit, axis=1).astype(jnp.int32)


def random_x_fit(mask, u, x):
    """Pallas entry: bitset [B, W], uniforms [B] f32, x i32[1] → colors [B].

    Picks uniformly among the first ``x`` permissible colors (Gebremedhin et
    al.'s Random-X Fit, paper §3.2). With D < NCOLORS - X there is always a
    permissible color in range.
    """
    b = mask.shape[0]
    return pl.pallas_call(
        _random_x_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(mask, u, x)


def _conflict_kernel(cu_ref, cv_ref, pu_ref, pv_ref, gu_ref, gv_ref,
                     lose_u_ref, lose_v_ref):
    cu, cv = cu_ref[...], cv_ref[...]
    pu, pv = pu_ref[...].astype(jnp.uint32), pv_ref[...].astype(jnp.uint32)
    gu, gv = gu_ref[...].astype(jnp.uint32), gv_ref[...].astype(jnp.uint32)
    conflict = (cu == cv) & (cu >= 0)
    u_smaller = (pu < pv) | ((pu == pv) & (gu < gv))
    lose_u_ref[...] = (conflict & u_smaller).astype(jnp.int32)
    lose_v_ref[...] = (conflict & ~u_smaller).astype(jnp.int32)


def conflict_detect(cu, cv, pu, pv, gu, gv):
    """Pallas entry: per-edge conflict detection with the framework's
    static random-priority tie-break (smaller priority loses; ties break on
    the smaller global id). Returns (lose_u, lose_v) as i32 0/1 flags.
    """
    e = cu.shape[0]
    shape = jax.ShapeDtypeStruct((e,), jnp.int32)
    return pl.pallas_call(
        _conflict_kernel,
        out_shape=(shape, shape),
        interpret=True,
    )(cu, cv, pu, pv, gu, gv)
