"""Pure-jnp correctness oracle for the Pallas kernels (no pallas imports).

Deliberately written in the most obvious way possible — these functions
define the semantics the kernels (and transitively the rust runtime) are
tested against.
"""

import jax.numpy as jnp

from . import coloring as K


def forbid_mask(neigh_colors):
    """[B, D] i32 → [B, W] i32 forbidden bitset."""
    b, _ = neigh_colors.shape
    colors = jnp.arange(K.NCOLORS, dtype=jnp.int32)            # [C]
    # forbidden[b, c] = any(neigh == c)
    forbidden = (neigh_colors[:, :, None] == colors[None, None, :]).any(axis=1)
    bits = forbidden.reshape(b, K.WORDS, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    words = (bits.astype(jnp.uint32) * weights[None, None, :]).sum(
        axis=2, dtype=jnp.uint32
    )
    return words.astype(jnp.int32)


def _forbidden_bits(mask):
    m = mask.astype(jnp.uint32)
    lanes = jnp.arange(32, dtype=jnp.uint32)
    bits = (m[:, :, None] >> lanes[None, None, :]) & jnp.uint32(1)
    return bits.reshape(mask.shape[0], K.NCOLORS).astype(bool)


def first_fit(mask):
    """[B, W] i32 → smallest color whose bit is clear, per row."""
    return jnp.argmax(~_forbidden_bits(mask), axis=1).astype(jnp.int32)


def random_x_fit(mask, u, x):
    """Uniform pick among the first x permissible colors (k = floor(u*x))."""
    permissible = ~_forbidden_bits(mask)
    rank = jnp.cumsum(permissible.astype(jnp.int32), axis=1)
    xi = x[0]
    k = jnp.clip((u * xi.astype(jnp.float32)).astype(jnp.int32), 0, xi - 1) + 1
    hit = permissible & (rank == k[:, None])
    return jnp.argmax(hit, axis=1).astype(jnp.int32)


def conflict_detect(cu, cv, pu, pv, gu, gv):
    """Per-edge loser flags; mirrors dist::framework::loses in rust."""
    conflict = (cu == cv) & (cu >= 0)
    pu, pv = pu.astype(jnp.uint32), pv.astype(jnp.uint32)
    gu, gv = gu.astype(jnp.uint32), gv.astype(jnp.uint32)
    u_smaller = (pu < pv) | ((pu == pv) & (gu < gv))
    return (
        (conflict & u_smaller).astype(jnp.int32),
        (conflict & ~u_smaller).astype(jnp.int32),
    )
