"""AOT lowering: jax → HLO *text* → artifacts/ for the rust PJRT runtime.

HLO text (NOT ``lowered.compiler_ir(...).serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out ../artifacts`` (the Makefile target).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, args) -> str:
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ns = ap.parse_args()
    os.makedirs(ns.out, exist_ok=True)
    shapes = model.example_args()
    for name, fn in model.ENTRIES.items():
        text = to_hlo_text(fn, shapes[name])
        path = os.path.join(ns.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
