"""Layer-2 JAX compute graph: the batched tentative-coloring step.

Composes the Layer-1 Pallas kernels into the three entry points the rust
coordinator calls per superstep. Lowered once by ``aot.py``; never imported
at runtime.
"""

import jax
import jax.numpy as jnp

from .kernels import coloring as K


def tentative_first_fit(neigh_colors):
    """One first-fit superstep batch: neighbor colors → chosen colors.

    neigh_colors: i32[B, D], -1 padded. Returns i32[B].
    """
    mask = K.forbid_mask(neigh_colors)
    return K.first_fit(mask)


def tentative_random_x(neigh_colors, u, x):
    """One Random-X-Fit superstep batch.

    neigh_colors: i32[B, D]; u: f32[B] uniforms; x: i32[1]. Returns i32[B].
    """
    mask = K.forbid_mask(neigh_colors)
    return K.random_x_fit(mask, u, x)


def detect_conflicts(cu, cv, pu, pv, gu, gv):
    """Batched boundary-edge conflict detection. All i32[E]; returns two
    i32[E] 0/1 loser flags (u-side, v-side)."""
    return K.conflict_detect(cu, cv, pu, pv, gu, gv)


def forbid_mask_only(neigh_colors):
    """The bare forbidden-bitset kernel (exported for tests/diagnostics)."""
    return K.forbid_mask(neigh_colors)


def example_args():
    """Example shapes used for AOT lowering (the kernel contract)."""
    b, d, e = K.BATCH, K.DMAX, K.EDGE_BATCH
    i32 = jnp.int32
    return {
        "first_fit": (jax.ShapeDtypeStruct((b, d), i32),),
        "random_x": (
            jax.ShapeDtypeStruct((b, d), i32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((1,), i32),
        ),
        "conflict": tuple(jax.ShapeDtypeStruct((e,), i32) for _ in range(6)),
        "forbid_mask": (jax.ShapeDtypeStruct((b, d), i32),),
    }


ENTRIES = {
    "first_fit": tentative_first_fit,
    "random_x": tentative_random_x,
    "conflict": detect_conflicts,
    "forbid_mask": forbid_mask_only,
}
