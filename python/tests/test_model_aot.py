"""L2 model shape contracts + AOT lowering sanity (HLO text parseable by
eye: module header, parameter shapes, root tuple)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import to_hlo_text
from compile.kernels import coloring as K


def test_model_entry_shapes():
    shapes = model.example_args()
    nc = jnp.zeros((K.BATCH, K.DMAX), jnp.int32) - 1
    out = model.tentative_first_fit(nc)
    assert out.shape == (K.BATCH,)
    assert out.dtype == jnp.int32

    u = jnp.zeros((K.BATCH,), jnp.float32)
    x = jnp.asarray([5], jnp.int32)
    out = model.tentative_random_x(nc, u, x)
    assert out.shape == (K.BATCH,)

    e = jnp.zeros((K.EDGE_BATCH,), jnp.int32)
    lu, lv = model.detect_conflicts(e, e, e, e, e, e)
    assert lu.shape == lv.shape == (K.EDGE_BATCH,)
    assert set(shapes) == set(model.ENTRIES)


def test_uncolored_batch_first_fit_zero():
    nc = jnp.full((K.BATCH, K.DMAX), -1, jnp.int32)
    out = np.asarray(model.tentative_first_fit(nc))
    np.testing.assert_array_equal(out, np.zeros(K.BATCH, np.int32))


@pytest.mark.parametrize("name", list(model.ENTRIES))
def test_aot_lowering_produces_hlo_text(name):
    text = to_hlo_text(model.ENTRIES[name], model.example_args()[name])
    assert text.startswith("HloModule"), text[:80]
    assert "ROOT" in text
    # interpret-mode pallas must lower to plain HLO: no Mosaic custom-calls
    assert "mosaic" not in text.lower()


def test_aot_writes_artifacts(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        capture_output=True,
        text=True,
        cwd=str(jax.numpy.__file__ and __import__("pathlib").Path(__file__).parent.parent),
    )
    assert r.returncode == 0, r.stderr
    for name in model.ENTRIES:
        p = out / f"{name}.hlo.txt"
        assert p.exists()
        assert p.read_text().startswith("HloModule")
