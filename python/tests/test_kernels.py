"""Kernel vs oracle: the core L1 correctness signal.

Hypothesis sweeps shapes/values; fixed cases pin the contract edges."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import coloring as K
from compile.kernels import ref


def np_colors(rows):
    """list of neighbor-color lists → padded [B, D] i32 array."""
    out = np.full((len(rows), K.DMAX), -1, dtype=np.int32)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return jnp.asarray(out)


# ---- forbid_mask ------------------------------------------------------------

def test_forbid_mask_simple():
    nc = np_colors([[0, 2, 33], []])
    got = np.asarray(K.forbid_mask(nc)).astype(np.uint32)
    want = np.asarray(ref.forbid_mask(nc)).astype(np.uint32)
    np.testing.assert_array_equal(got, want)
    assert got[0, 0] == (1 | (1 << 2))
    assert got[0, 1] == (1 << 1)  # color 33 = word 1, bit 1
    assert got[1].sum() == 0


def test_forbid_mask_all_slots_used():
    nc = jnp.tile(jnp.arange(K.DMAX, dtype=jnp.int32)[None, :], (K.BATCH, 1))
    got = np.asarray(K.forbid_mask(nc)).astype(np.uint32)
    # colors 0..63 forbidden → words 0,1 full, rest empty
    assert (got[:, 0] == 0xFFFFFFFF).all()
    assert (got[:, 1] == 0xFFFFFFFF).all()
    assert (got[:, 2:] == 0).all()


def test_forbid_mask_max_color():
    nc = np_colors([[K.NCOLORS - 1]])
    got = np.asarray(K.forbid_mask(nc)).astype(np.uint32)
    assert got[0, K.WORDS - 1] == np.uint32(1) << 31


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_forbid_mask_matches_ref(data):
    b = data.draw(st.integers(1, 32))
    d = data.draw(st.integers(1, K.DMAX))
    arr = data.draw(
        st.lists(
            st.lists(st.integers(-1, K.NCOLORS - 1), min_size=d, max_size=d),
            min_size=b,
            max_size=b,
        )
    )
    nc = jnp.asarray(np.array(arr, dtype=np.int32))
    got = np.asarray(K.forbid_mask(nc))
    want = np.asarray(ref.forbid_mask(nc))
    np.testing.assert_array_equal(got, want)


# ---- first_fit --------------------------------------------------------------

def ff(rows):
    return np.asarray(K.first_fit(K.forbid_mask(np_colors(rows))))


def test_first_fit_basics():
    got = ff([[0, 1, 3], [], [1, 2, 3], [5]])
    np.testing.assert_array_equal(got, [2, 0, 0, 0])


def test_first_fit_dense_prefix():
    # all of 0..DMAX-1 forbidden → color DMAX
    rows = [list(range(K.DMAX))]
    np.testing.assert_array_equal(ff(rows), [K.DMAX])


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_first_fit_matches_ref_and_is_permissible(data):
    b = data.draw(st.integers(1, 16))
    rows = data.draw(
        st.lists(
            st.lists(st.integers(0, 100), max_size=K.DMAX),
            min_size=b,
            max_size=b,
        )
    )
    nc = np_colors(rows)
    mask = K.forbid_mask(nc)
    got = np.asarray(K.first_fit(mask))
    want = np.asarray(ref.first_fit(mask))
    np.testing.assert_array_equal(got, want)
    for i, r in enumerate(rows):
        assert got[i] not in r
        assert all(c in r for c in range(got[i]))  # truly smallest


# ---- random_x_fit -----------------------------------------------------------

def test_random_x_within_first_x_permissible():
    rows = [[0, 2]] * 8
    nc = np_colors(rows)
    mask = K.forbid_mask(nc)
    x = jnp.asarray([5], dtype=jnp.int32)
    rngs = np.linspace(0.0, 0.999, 8).astype(np.float32)
    got = np.asarray(K.random_x_fit(mask, jnp.asarray(rngs), x))
    # first 5 permissible colors: 1, 3, 4, 5, 6
    assert set(got).issubset({1, 3, 4, 5, 6})
    # u=0 → first permissible; u→1 → 5th permissible
    assert got[0] == 1
    assert got[-1] == 6


def test_random_x_1_equals_first_fit():
    rows = [[0, 1], [3], []]
    nc = np_colors(rows)
    mask = K.forbid_mask(nc)
    u = jnp.asarray(np.random.default_rng(0).random(3), dtype=jnp.float32)
    x1 = jnp.asarray([1], dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(K.random_x_fit(mask, u, x1)),
        np.asarray(K.first_fit(mask)),
    )


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_random_x_matches_ref(data):
    b = data.draw(st.integers(1, 16))
    x = data.draw(st.integers(1, 50))
    rows = data.draw(
        st.lists(
            st.lists(st.integers(0, 120), max_size=K.DMAX),
            min_size=b,
            max_size=b,
        )
    )
    u = data.draw(
        st.lists(
            st.floats(0, 0.998046875, width=32),  # exactly representable
            min_size=b,
            max_size=b,
        )
    )
    nc = np_colors(rows)
    mask = K.forbid_mask(nc)
    uj = jnp.asarray(np.array(u, dtype=np.float32))
    xj = jnp.asarray([x], dtype=jnp.int32)
    got = np.asarray(K.random_x_fit(mask, uj, xj))
    want = np.asarray(ref.random_x_fit(mask, uj, xj))
    np.testing.assert_array_equal(got, want)
    for i, r in enumerate(rows):
        assert got[i] not in r, "picked a forbidden color"


# ---- conflict_detect --------------------------------------------------------

def test_conflict_basics():
    cu = jnp.asarray([1, 2, 3, -1], dtype=jnp.int32)
    cv = jnp.asarray([1, 5, 3, -1], dtype=jnp.int32)
    pu = jnp.asarray([10, 0, 9, 0], dtype=jnp.int32)
    pv = jnp.asarray([20, 0, 4, 0], dtype=jnp.int32)
    gu = jnp.asarray([0, 1, 2, 3], dtype=jnp.int32)
    gv = jnp.asarray([4, 5, 6, 7], dtype=jnp.int32)
    lu, lv = K.conflict_detect(cu, cv, pu, pv, gu, gv)
    np.testing.assert_array_equal(np.asarray(lu), [1, 0, 0, 0])  # pu<pv
    np.testing.assert_array_equal(np.asarray(lv), [0, 0, 1, 0])  # pv<pu
    # uncolored (-1) never conflicts


def test_conflict_tie_breaks_on_gid():
    cu = jnp.asarray([7], dtype=jnp.int32)
    cv = jnp.asarray([7], dtype=jnp.int32)
    p = jnp.asarray([42], dtype=jnp.int32)
    gu = jnp.asarray([3], dtype=jnp.int32)
    gv = jnp.asarray([9], dtype=jnp.int32)
    lu, lv = K.conflict_detect(cu, cv, p, p, gu, gv)
    assert int(lu[0]) == 1 and int(lv[0]) == 0


def test_conflict_priority_is_unsigned():
    # negative i32 priorities must compare as u32 (matches rust mix64 output)
    cu = jnp.asarray([1], dtype=jnp.int32)
    cv = jnp.asarray([1], dtype=jnp.int32)
    pu = jnp.asarray([-1], dtype=jnp.int32)   # u32::MAX
    pv = jnp.asarray([5], dtype=jnp.int32)
    gu = jnp.asarray([0], dtype=jnp.int32)
    gv = jnp.asarray([1], dtype=jnp.int32)
    lu, lv = K.conflict_detect(cu, cv, pu, pv, gu, gv)
    assert int(lv[0]) == 1, "u32::MAX priority must win"


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_conflict_matches_ref_exactly_one_loser(data):
    e = data.draw(st.integers(1, 64))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    cu = jnp.asarray(rng.integers(-1, 5, e), dtype=jnp.int32)
    cv = jnp.asarray(rng.integers(-1, 5, e), dtype=jnp.int32)
    pu = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, e), dtype=jnp.int32)
    pv = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, e), dtype=jnp.int32)
    gu = jnp.asarray(np.arange(e), dtype=jnp.int32)
    gv = jnp.asarray(np.arange(e) + e, dtype=jnp.int32)
    got = K.conflict_detect(cu, cv, pu, pv, gu, gv)
    want = ref.conflict_detect(cu, cv, pu, pv, gu, gv)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    conflict = np.asarray((cu == cv) & (cu >= 0))
    both = np.asarray(got[0]) + np.asarray(got[1])
    np.testing.assert_array_equal(both, conflict.astype(np.int32))
